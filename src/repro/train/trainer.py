"""Fault-tolerant training loop.

Responsibilities:
* builds the jit'd train_step (donated params/opt-state buffers),
* resumes from the latest valid checkpoint (params + optimizer + step),
* fast-forwards the data stream so restarts are bitwise deterministic,
* periodic async checkpoints; final blocking checkpoint,
* simulated-preemption hook (``fail_at_step``) used by the restart tests,
* straggler/heartbeat hook: per-step wall time is recorded; steps slower
  than ``straggler_factor`` x median are counted and surfaced in metrics
  (on real pods this feeds the reassignment policy; on CPU we record).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig, OptState, apply_updates, init_state

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    fail_at_step: int = -1          # simulate preemption (tests)
    straggler_factor: float = 3.0


class SimulatedPreemption(RuntimeError):
    pass


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    donate: bool = True):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_params, new_state, opt_m = apply_updates(
            opt_cfg, params, grads, opt_state)
        out = {"loss": loss, **metrics, **opt_m}
        return new_params, new_state, out

    return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())


class Trainer:
    def __init__(self, loss_fn: Callable, params: PyTree,
                 opt_cfg: OptimizerConfig, data: Iterator[Dict],
                 cfg: TrainerConfig, to_device: Optional[Callable] = None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data = data
        self.to_device = to_device or (lambda b: jax.tree_util.tree_map(
            jnp.asarray, b))
        self.train_step = make_train_step(loss_fn, opt_cfg)
        self.params = params
        self.opt_state = init_state(opt_cfg, params)
        self.step = 0
        self.history: list = []
        self.manager = (CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
                        if cfg.ckpt_dir else None)

    # -- checkpoint glue -------------------------------------------------------

    def try_resume(self) -> bool:
        if self.manager is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        restored, step = self.manager.restore(state)
        if restored is None:
            return False
        self.params = restored["params"]
        self.opt_state = OptState(*restored["opt"]) if not isinstance(
            restored["opt"], OptState) else restored["opt"]
        self.step = int(step)
        return True

    def save(self, block: bool = False):
        if self.manager is None:
            return
        self.manager.save(self.step,
                          {"params": self.params, "opt": self.opt_state},
                          extra={"history_len": len(self.history)},
                          block=block)

    # -- main loop ---------------------------------------------------------------

    def run(self) -> Dict[str, float]:
        resumed = self.try_resume()
        if hasattr(self.data, "skip") and resumed:
            self.data.skip(self.step)
        it = iter(self.data)
        step_times: list = []
        stragglers = 0
        last = None
        while self.step < self.cfg.total_steps:
            batch = self.to_device(next(it))
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])  # blocks; acts as the step barrier
            dt = time.perf_counter() - t0
            step_times.append(dt)
            med = float(np.median(step_times))
            if len(step_times) > 5 and dt > self.cfg.straggler_factor * med:
                stragglers += 1
            self.step += 1
            last = {k: float(v) for k, v in metrics.items()}
            last.update(step=self.step, step_time=dt, stragglers=stragglers)
            if self.step % self.cfg.log_every == 0 or self.step == 1:
                self.history.append(last)
            if self.manager and self.step % self.cfg.ckpt_every == 0:
                self.save()
            if self.step == self.cfg.fail_at_step:
                # checkpoint state is whatever the last periodic save wrote —
                # exactly the crash semantics the restart test verifies.
                raise SimulatedPreemption(f"simulated failure @ {self.step}")
        self.save(block=True)
        return last or {}
