"""Gradient compression for the cross-pod (DCI) all-reduce.

int8 stochastic-free linear quantisation with **error feedback** (EF-SGD,
Seide et al. / Karimireddy et al.): the quantisation residual is carried
to the next step so compression bias does not accumulate. Intended for
the "pod" mesh axis where links are ~10x slower than ICI — it cuts the
collective-term bytes 4x (fp32) / 2x (bf16) at equal step count.

``compressed_psum`` is a shard_map building block; the analytic effect on
the roofline collective term is reported in EXPERIMENTS.md §Perf (this
CPU container cannot measure DCI wall time).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jnp.ndarray
PyTree = Any


def quantize_int8(x: Array) -> Tuple[Array, Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grad: Array, error: Array) -> Tuple[Array, Array, Array]:
    """Error-feedback compression of one tensor.

    Returns (q int8, scale, new_error). new_error = (g+e) - dequant(q)."""
    target = grad + error
    q, scale = quantize_int8(target)
    new_error = target - dequantize_int8(q, scale)
    return q, scale, new_error


def compressed_psum(grad: Array, error: Array, axis: str):
    """int8 all-reduce over ``axis`` with error feedback.

    Mean of per-shard gradients. Wire format per tensor: int8 payload +
    one fp32 scale; each contribution is dequantised with ITS OWN scale
    at the reduction point (ring all-reduce dequantises on add), which the
    psum below models semantically.
    """
    q, scale, new_error = ef_compress(grad, error)
    total = jax.lax.psum(dequantize_int8(q, scale), axis)
    n = jax.lax.axis_size(axis)
    return total / n, new_error


def make_compressed_allreduce(mesh, axis: str = "pod"):
    """Tree-level wrapper: (grads, errors) -> (mean grads, new errors).

    All leaves replicated over the other mesh axes; ``axis`` carries the
    per-pod partial gradients (this mirrors a multi-pod DP step where the
    in-pod reduction already happened over ICI).
    """

    def fn(grads: PyTree, errors: PyTree):
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        err_leaves = jax.tree_util.tree_leaves(errors)

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(tuple(P(axis) for _ in leaves),
                      tuple(P(axis) for _ in err_leaves)),
            out_specs=(tuple(P() for _ in leaves),
                       tuple(P(axis) for _ in err_leaves)),
            check_vma=False,
        )
        def _go(gs, es):
            outs, new_es = [], []
            for g, e in zip(gs, es):
                # leading axis is the pod-stacked dim added by the caller
                o, ne = compressed_psum(g[0], e[0], axis)
                outs.append(o)
                new_es.append(ne[None])
            return tuple(outs), tuple(new_es)

        outs, new_errs = _go(tuple(leaves), tuple(err_leaves))
        return (jax.tree_util.tree_unflatten(treedef, list(outs)),
                jax.tree_util.tree_unflatten(treedef, list(new_errs)))

    return fn
