"""Optimizers from scratch (no optax in this container): Adam(W), Adagrad,
SGD-momentum — pytree-native, pjit-friendly (states inherit param sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray
PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"            # adamw | adam | adagrad | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


class OptState(NamedTuple):
    step: Array
    mu: PyTree       # first moment / momentum / accumulator
    nu: PyTree       # second moment (Adam) or empty


def init_state(cfg: OptimizerConfig, params: PyTree) -> OptState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    if cfg.kind in ("adam", "adamw"):
        return OptState(jnp.int32(0), zeros,
                        jax.tree_util.tree_map(jnp.zeros_like, params))
    return OptState(jnp.int32(0), zeros, jax.tree_util.tree_map(
        lambda x: jnp.zeros((), x.dtype), params))


def global_norm(tree: PyTree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def apply_updates(cfg: OptimizerConfig, params: PyTree, grads: PyTree,
                  state: OptState) -> Tuple[PyTree, OptState, Dict[str, Array]]:
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    if cfg.grad_clip > 0:
        grads, raw_norm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        raw_norm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)

    if cfg.kind in ("adam", "adamw"):
        b1, b2 = cfg.b1, cfg.b2
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state.mu, grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.kind == "adamw" and p.ndim >= 2:   # decay matrices only
                delta = delta + cfg.weight_decay * p
            return p - lr * delta

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        new_state = OptState(step, mu, nu)
    elif cfg.kind == "adagrad":
        mu = jax.tree_util.tree_map(lambda a, g: a + g * g, state.mu, grads)
        new_params = jax.tree_util.tree_map(
            lambda p, a, g: p - lr * g / (jnp.sqrt(a) + cfg.eps),
            params, mu, grads)
        new_state = OptState(step, mu, state.nu)
    elif cfg.kind == "sgd":
        mu = jax.tree_util.tree_map(
            lambda m, g: cfg.momentum * m + g, state.mu, grads)
        new_params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, mu)
        new_state = OptState(step, mu, state.nu)
    else:
        raise ValueError(cfg.kind)
    return new_params, new_state, {"lr": lr, "grad_norm": raw_norm}
