"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic.

Format: one ``.npz`` per checkpoint holding every leaf under a
"/"-joined tree path, plus a JSON manifest (step, leaf paths, digest,
mesh-shape-at-save for diagnostics). Writes go to a temp dir then an
atomic ``os.replace`` — a process killed mid-save never corrupts the
latest valid checkpoint. Restore re-shards leaves onto whatever mesh the
*restoring* job uses (elastic restart: save is logical, load applies the
new sharding), so a 512-chip job can resume a 256-chip checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "|"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template: PyTree, arrays: Dict[str, np.ndarray]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs "
                             f"template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """keep-last-N atomic checkpoints with optional async save thread."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def list_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(manifest):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot to host memory NOW; write to disk (async by default)."""
        arrays = _flatten(tree)  # device->host copy happens here, synchronously
        self.wait()              # never two writers at once

        def _write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": sorted(arrays.keys()),
                "nbytes": int(sum(a.nbytes for a in arrays.values())),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic publish
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None):
        """Load into the template's structure; apply ``shardings`` if given
        (elastic resume onto a different mesh). Returns (tree, step)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        path = self._step_dir(step)
        with np.load(os.path.join(path, "arrays.npz")) as data:
            arrays = {k: data[k] for k in data.files}
        tree = _unflatten_into(template, arrays)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
        return tree, step
