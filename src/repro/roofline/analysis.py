"""Roofline from the compiled dry-run artifact (no hardware required).

Three terms per (arch x shape x mesh), in seconds:
  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes
are NOT in cost_analysis — we parse the post-SPMD HLO text and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (the payload each participant handles).

Hardware constants (TPU v5e-class, per the brief):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (per chip, one link direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shapes like:  bf16[8,512,128]{2,1,0}  or tuples (f32[...], f32[...])
_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3|f8e5m2|s64|s32|s16|s8|u64|"
                       r"u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op kind from HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # `x = bf16[...] all-gather(...)`: opcode appears right after the
        # result shape; skip fusion-comment mentions.
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z0-9-]+)\(", s)
        if not m:
            continue
        op = m.group(1)
        if op.rstrip("-start").rstrip("-done") in _COLLECTIVES or \
                any(op == c or op == c + "-start" for c in _COLLECTIVES):
            base = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if base is None or op.endswith("-done"):
                continue
            lhs = s.split("=")[0] + "= " + s.split("=", 1)[1].split(base)[0]
            out[base] += _shape_bytes(lhs)
            out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    """All byte/FLOP fields are GLOBAL (across chips); cost_analysis() on a
    post-SPMD module reports per-partition numbers, which ``from_compiled``
    multiplies by n_chips. The three terms then match the brief's formulas:
    t_x = global_quantity / (chips * per_chip_rate)."""

    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.n_chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step would sustain if it ran at
        the bound: (model_flops / t_bound) / (chips * peak)."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.t_bound) / (self.n_chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, hlo_text: str, n_chips: int,
                  model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # cost_analysis + the HLO text describe ONE partition of the SPMD
    # program — scale to global totals.
    flops = float(cost.get("flops", 0.0)) * n_chips
    hbm = float(cost.get("bytes accessed", 0.0)) * n_chips
    coll = parse_collective_bytes(hlo_text)
    coll_bytes = float(sum(v for k, v in coll.items()
                           if k != "count")) * n_chips
    return Roofline(flops=flops, hbm_bytes=hbm, collective_bytes=coll_bytes,
                    n_chips=n_chips, model_flops=model_flops)
