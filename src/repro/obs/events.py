"""Bounded structured event journal.

The registry (``repro.obs.metrics``) answers "how much/how fast"; the
journal answers "WHAT happened, in what order, against which catalogue
state". Producers emit small structured records — compaction
start/success/fail/retry/backoff, fault-seam firings, admission-ladder
degradations, cache invalidations, epoch bumps, engine traces — and
each record carries whatever join keys the producer knows, in
particular the snapshot ``version`` and mutation ``epoch``: a request
span whose ``dispatch`` stage recorded ``(version, epoch)`` joins the
journal on equality to recover exactly which compactions, mutations and
invalidations shaped the catalogue it scanned (DESIGN.md §14).

Emission is safe from ANY context the producers run in: a locked
dict-append under the journal's own lock, never calling back into
producer code — so the segmented catalogue can emit while holding its
own lock (the invalidation-listener constraint, see
``SegmentedCatalogue.add_invalidation_listener``) and a fault seam can
emit from a background build thread. The journal is bounded
(``capacity`` events, oldest evicted) and carries both a wall-clock
timestamp (for humans and exports) and a monotonic one (for ordering
against span times).
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Event", "EventJournal"]


class Event:
    """One journal record: ``kind`` + structured ``fields``."""

    __slots__ = ("ts_unix", "t_mono", "seq", "kind", "fields")

    def __init__(self, seq: int, kind: str, fields: Dict[str, object]):
        self.ts_unix = time.time()
        self.t_mono = time.perf_counter()
        self.seq = seq
        self.kind = kind
        self.fields = fields

    def as_dict(self) -> Dict[str, object]:
        return {"seq": self.seq, "ts_unix": self.ts_unix,
                "t_mono": self.t_mono, "kind": self.kind, **self.fields}

    def __repr__(self) -> str:
        kv = " ".join(f"{k}={v}" for k, v in self.fields.items())
        return f"[{self.seq}] {self.kind}" + (f" {kv}" if kv else "")


class EventJournal:
    """Thread-safe bounded journal with per-kind counters."""

    def __init__(self, capacity: int = 4096, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._events: "collections.deque[Event]" = collections.deque(
            maxlen=int(capacity))
        self._counts: Dict[str, int] = {}
        self._seq = 0

    def emit(self, kind: str, /, **fields) -> None:
        """Append one event. Cheap (one lock, one deque append) and
        reentrancy-free: never calls producer code, so it is safe under
        any producer lock."""
        if not self.enabled:
            return
        with self._lock:
            self._seq += 1
            self._events.append(Event(self._seq, kind, fields))
            self._counts[kind] = self._counts.get(kind, 0) + 1

    def tail(self, n: int = 20) -> List[Event]:
        """The ``n`` most recent events, oldest first."""
        with self._lock:
            evs = list(self._events)
        return evs[-int(n):]

    def events(self, kind: Optional[str] = None, **match) -> List[Event]:
        """Every retained event, optionally filtered by ``kind`` and by
        field equality (``events("compaction.success", version=3)``)."""
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        for k, v in match.items():
            evs = [e for e in evs if e.fields.get(k) == v]
        return evs

    def counts(self) -> Dict[str, int]:
        """Cumulative per-kind emit counts (survive ring eviction)."""
        with self._lock:
            return dict(self._counts)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._counts.clear()
            self._seq = 0
