"""Unified observability layer (DESIGN.md §14).

Three process-wide defaults, one switch:

* :data:`REGISTRY` — the metrics registry (counters / gauges /
  log-scale histograms; JSON ``snapshot()`` + Prometheus
  ``render_prom()`` exporters);
* :data:`TRACER` — per-request span trees with a sampling knob and a
  bounded store;
* :data:`JOURNAL` — the bounded structured event journal (compactions,
  faults, degradations, invalidations, epoch bumps, engine traces).

``set_enabled(False)`` turns all three into no-op branches — the
baseline the overhead benchmark (``benchmarks/obs_overhead.py``)
compares against.

The ``on_*`` helpers below are the ONLY thing production code calls:
each is one function call at the instrumentation seam, early-outs when
disabled, and owns the mapping from a domain event to instrument
updates + journal records. Keeping the mapping here (rather than at the
call sites) keeps engine/catalogue/serving code one line per seam and
makes the full instrument inventory reviewable in one file.

Label/metric naming: every metric is ``repro_``-prefixed; label axes
mirror the compile-cache axes (``engine``, ``bucket``, ``sign``) plus
the admission axes (``rung``, ``budget_bucket``) so a dashboard slices
along the same lines the system specialises along.
"""

from __future__ import annotations

from repro.obs.events import Event, EventJournal
from repro.obs.metrics import (
    Counter,
    FRACTION_BUCKETS,
    GAP_BUCKETS,
    Gauge,
    Histogram,
    LATENCY_BUCKETS_US,
    MetricsRegistry,
    SECONDS_BUCKETS,
    SIZE_BUCKETS,
    log2_buckets,
    parse_prom_text,
    validate_snapshot,
)
from repro.obs.schema import (
    MUTATION_STATS_SCHEMA,
    StatField,
    build_mutation_stats,
)
from repro.obs.trace import Span, Trace, Tracer

__all__ = [
    "REGISTRY", "JOURNAL", "TRACER", "set_enabled", "enabled", "reset",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "EventJournal",
    "Event", "Tracer", "Trace", "Span", "log2_buckets",
    "validate_snapshot", "parse_prom_text", "build_mutation_stats",
    "MUTATION_STATS_SCHEMA", "StatField",
    "LATENCY_BUCKETS_US", "SECONDS_BUCKETS", "FRACTION_BUCKETS",
    "GAP_BUCKETS", "SIZE_BUCKETS",
]

#: process-wide defaults — the engine/catalogue/serving seams record here
REGISTRY = MetricsRegistry()
JOURNAL = EventJournal(capacity=4096)
TRACER = Tracer(capacity=256, sample_rate=1.0)


def set_enabled(on: bool) -> None:
    """Master switch for the default registry, tracer and journal."""
    REGISTRY.enabled = TRACER.enabled = JOURNAL.enabled = bool(on)


def enabled() -> bool:
    return REGISTRY.enabled


def reset() -> None:
    """Clear every default store (instrument definitions survive) —
    test/benchmark isolation."""
    REGISTRY.reset()
    JOURNAL.clear()
    TRACER.clear()


# ---------------------------------------------------------------------------
# Instrument inventory
# ---------------------------------------------------------------------------

ENGINE_TRACES = REGISTRY.counter(
    "repro_engine_traces_total",
    "Executor traces (compiles) observed at jit trace time, per engine.",
    labels=("engine",))
QUERIES = REGISTRY.counter(
    "repro_queries_total", "Queries served, per engine that ran.",
    labels=("engine",))
SCORED = REGISTRY.counter(
    "repro_scored_total",
    "Candidate scores computed (the paper's cost metric), per engine.",
    labels=("engine",))
DEPTH = REGISTRY.counter(
    "repro_depth_total", "Scan depth consumed (list rows), per engine.",
    labels=("engine",))
SCORED_FRACTION = REGISTRY.histogram(
    "repro_scored_fraction",
    "Per-batch mean fraction of the live catalogue scored — the "
    "pruning-efficiency claim, live.",
    labels=("engine",), buckets=FRACTION_BUCKETS)
BATCH_LATENCY = REGISTRY.histogram(
    "repro_batch_latency_us",
    "Per-query microseconds of one served batch (dispatch->harvest).",
    labels=("engine",), buckets=LATENCY_BUCKETS_US)
REQUEST_LATENCY = REGISTRY.histogram(
    "repro_request_latency_us",
    "Per-request enqueue->result microseconds (queue wait included).",
    labels=("engine",), buckets=LATENCY_BUCKETS_US)
QUEUE_WAIT = REGISTRY.histogram(
    "repro_queue_wait_us",
    "Microseconds a request waited in the coalescing queue before its "
    "micro-batch formed.",
    labels=(), buckets=LATENCY_BUCKETS_US)
BATCH_SIZE = REGISTRY.histogram(
    "repro_batch_size", "Coalesced micro-batch sizes (exact, pre-pad).",
    labels=(), buckets=SIZE_BUCKETS)
SIGN_BATCHES = REGISTRY.counter(
    "repro_sign_batches_total",
    "Batches served per sign bucket (the DESIGN.md §11 compile axis).",
    labels=("engine", "sign"))
DEGRADATIONS = REGISTRY.counter(
    "repro_degradations_total",
    "Admission-ladder downgrades, per REQUESTED engine and rung.",
    labels=("engine", "rung"))
SHED = REGISTRY.counter(
    "repro_shed_total", "Requests shed (sentinel results).", labels=())
UNCERTIFIED = REGISTRY.counter(
    "repro_uncertified_total",
    "Queries whose result carried >= 1 uncertified slot.",
    labels=("engine",))
CERTIFIED_FRACTION = REGISTRY.histogram(
    "repro_certified_fraction",
    "Per-batch fraction of result slots provably in the true top-K "
    "(certificate gap <= 0), per engine and budget bucket.",
    labels=("engine", "budget_bucket"), buckets=FRACTION_BUCKETS)
UNCERTIFIED_GAP = REGISTRY.histogram(
    "repro_uncertified_gap",
    "Per-batch mean certificate gap over UNCERTIFIED slots (score "
    "units; how far from provable the halted scan stopped).",
    labels=("engine", "budget_bucket"), buckets=GAP_BUCKETS)
CACHE_LOOKUPS = REGISTRY.counter(
    "repro_cache_lookups_total", "Result-cache lookups by outcome.",
    labels=("outcome",))
CACHE_INVALIDATIONS = REGISTRY.counter(
    "repro_cache_invalidations_total",
    "Result-cache full invalidations (catalogue listener).", labels=())
COMPACTIONS = REGISTRY.counter(
    "repro_compaction_events_total",
    "Compaction state-machine transitions (start/success/fail/retry/"
    "retry_scheduled/forced_sync/stuck).",
    labels=("event",))
COMPACTION_SECONDS = REGISTRY.histogram(
    "repro_compaction_seconds", "Successful compaction build seconds.",
    labels=(), buckets=SECONDS_BUCKETS)
EPOCH_BUMPS = REGISTRY.counter(
    "repro_epoch_bumps_total",
    "Mutation-epoch bumps by kind (insert/update/delete/swap).",
    labels=("kind",))
FAULTS_FIRED = REGISTRY.counter(
    "repro_faults_fired_total", "Armed fault-seam triggers, per point.",
    labels=("point",))
COST_TABLE_US = REGISTRY.gauge(
    "repro_cost_table_us",
    "Measured per-query cost EWMA, per (engine, batch bucket, sign) — "
    "the serving router's table, exported live.",
    labels=("engine", "bucket", "sign"))


# ---------------------------------------------------------------------------
# Wiring helpers (the one-liners production seams call)
# ---------------------------------------------------------------------------

def on_engine_trace(engine: str, bcfg: tuple = ()) -> None:
    """An executor traced (compiled) — engines._note_trace seam."""
    if not REGISTRY.enabled:
        return
    ENGINE_TRACES.inc(engine=engine)
    JOURNAL.emit("engine.trace", engine=engine,
                 sign=str(bcfg) if bcfg else "")


def on_batch_served(engine: str, n: int, n_scored: int, depth_sum: int,
                    m_live: int, per_query_us: float,
                    sign_label: str = "") -> None:
    """One batch harvested: pruning-efficiency + latency metrics."""
    if not REGISTRY.enabled:
        return
    QUERIES.inc(n, engine=engine)
    SCORED.inc(n_scored, engine=engine)
    DEPTH.inc(depth_sum, engine=engine)
    if m_live > 0 and n > 0:
        SCORED_FRACTION.observe(n_scored / (n * m_live), engine=engine)
    BATCH_LATENCY.observe(per_query_us, engine=engine)
    if sign_label:
        SIGN_BATCHES.inc(engine=engine, sign=sign_label)


def on_request_done(engine: str, us: float) -> None:
    if not REGISTRY.enabled:
        return
    REQUEST_LATENCY.observe(us, engine=engine)


def on_queue_wait(us: float) -> None:
    if not REGISTRY.enabled:
        return
    QUEUE_WAIT.observe(us)


def on_batch_formed(n: int) -> None:
    if not REGISTRY.enabled:
        return
    BATCH_SIZE.observe(n)


def on_degradation(engine: str, rung: str) -> None:
    """An admission-ladder downgrade decision (recorded under the
    REQUESTED engine, same accounting as ``ServeStats.degradations``)."""
    if not REGISTRY.enabled:
        return
    DEGRADATIONS.inc(engine=engine, rung=rung)
    if rung == "shed":
        SHED.inc()
    JOURNAL.emit("admission.degrade", engine=engine, rung=rung)


def on_uncertified(engine: str, n: int) -> None:
    if not REGISTRY.enabled or n <= 0:
        return
    UNCERTIFIED.inc(n, engine=engine)


def on_certificates(engine: str, budget_bucket: int,
                    certified_fraction: float,
                    mean_uncertified_gap: float,
                    any_uncertified: bool) -> None:
    """One budgeted batch's certificate summary (pinned against
    ``certificate_gaps`` ground truth by tests/test_obs.py)."""
    if not REGISTRY.enabled:
        return
    b = str(int(budget_bucket))
    CERTIFIED_FRACTION.observe(certified_fraction, engine=engine,
                               budget_bucket=b)
    if any_uncertified:
        UNCERTIFIED_GAP.observe(mean_uncertified_gap, engine=engine,
                                budget_bucket=b)


def on_cache_lookup(hit: bool) -> None:
    if not REGISTRY.enabled:
        return
    CACHE_LOOKUPS.inc(outcome="hit" if hit else "miss")


def on_cache_invalidated() -> None:
    """Result-cache flush. May run under the catalogue lock (the
    invalidation-listener path) — journal emission is lock-safe."""
    if not REGISTRY.enabled:
        return
    CACHE_INVALIDATIONS.inc()
    JOURNAL.emit("cache.invalidate")


def on_compaction(event: str, **fields) -> None:
    """One compaction state-machine transition; ``fields`` carry the
    join keys the producer knows (version, epoch, chain_len, ...)."""
    if not REGISTRY.enabled:
        return
    COMPACTIONS.inc(event=event)
    if event == "success" and "duration_s" in fields:
        COMPACTION_SECONDS.observe(fields["duration_s"])
    JOURNAL.emit(f"compaction.{event}", **fields)


def on_epoch_bump(kind: str, version: int, epoch: int) -> None:
    """A visible mutation bumped the epoch (called under the catalogue
    lock — emission must stay reentrancy-free, which it is)."""
    if not REGISTRY.enabled:
        return
    EPOCH_BUMPS.inc(kind=kind)
    JOURNAL.emit("epoch.bump", mutation=kind, version=version,
                 epoch=epoch)


def on_fault_fired(point: str) -> None:
    if not REGISTRY.enabled:
        return
    FAULTS_FIRED.inc(point=point)
    JOURNAL.emit("fault.fired", point=point)


def on_cost_observation(engine: str, bucket: int, label: str,
                        per_query_s: float) -> None:
    """CostTable EWMA update — exported live as a gauge."""
    if not REGISTRY.enabled:
        return
    COST_TABLE_US.set(1e6 * per_query_s, engine=engine,
                      bucket=str(int(bucket)), sign=label)
