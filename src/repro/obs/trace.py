"""Per-request trace spans for the serving pipeline.

A *trace* is the story of one request: a tree of named *spans*, each
with a monotonic-clock start/end and a small attribute dict. The async
pipeline (``repro.serving.pipeline``) opens a trace at ``submit()`` and
threads it through every stage, so a sampled request yields

::

    topk.request 1843us  engine=bta version=3 epoch=17
      queue_wait 612us
      coalesce 48us
      route 21us  engine=bta cost_entry=bta|8| predicted_us=310
      dispatch 95us  batch_size=5 bucket=8 sign=nonneg
      device 988us
      harvest 41us
      merge 9us

The (snapshot version, mutation epoch) attributes are the JOIN KEYS
into the event journal (``repro.obs.events``): the compaction event
that produced version ``v`` and the spans that ran against ``v`` share
the value, so "why was this request slow" can be answered against the
catalogue state it actually saw (DESIGN.md §14).

Overhead model: cheap counters are ALWAYS on (the metrics registry);
full span trees are SAMPLED (``Tracer.sample_rate``). An unsampled
request costs one lock + one comparison at submit and nothing
afterwards — ``start_trace`` returns ``None`` and every stage guards on
that. Span timestamps come from ``time.perf_counter()``; stages that
measured a boundary once per micro-batch pass explicit ``start=`` /
``end=`` instead of re-reading the clock per request.

The span store is BOUNDED (``capacity`` finished traces, oldest
evicted) so a long-lived server never grows its tracing footprint.
"""

from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Span", "Trace", "Tracer"]

_ids = itertools.count(1)


class Span:
    """One named, timed node in a trace tree."""

    __slots__ = ("name", "span_id", "parent_id", "t_start", "t_end",
                 "attrs")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 t_start: float):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.t_end: Optional[float] = None
        self.attrs: Dict[str, object] = {}

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, at: Optional[float] = None) -> "Span":
        self.t_end = time.perf_counter() if at is None else at
        return self

    @property
    def duration_s(self) -> float:
        end = self.t_end if self.t_end is not None else time.perf_counter()
        return end - self.t_start

    @property
    def duration_us(self) -> float:
        return 1e6 * self.duration_s

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_us:.0f}us, "
                f"attrs={self.attrs})")


class Trace:
    """A span tree for one request. ``spans[0]`` is the root; children
    link to parents by span id. Built by exactly one thread at a time
    (pipeline stages hand the request off through a queue), so no lock
    is needed on the spans list itself."""

    __slots__ = ("trace_id", "name", "spans", "_tracer")

    def __init__(self, name: str, trace_id: int, tracer: "Tracer",
                 start: Optional[float] = None):
        self.trace_id = trace_id
        self.name = name
        self._tracer = tracer
        root = Span(name, next(_ids), None,
                    time.perf_counter() if start is None else start)
        self.spans: List[Span] = [root]

    @property
    def root(self) -> Span:
        return self.spans[0]

    def span(self, name: str, start: Optional[float] = None,
             end: Optional[float] = None, parent: Optional[Span] = None,
             **attrs) -> Span:
        """Add a child span (of the root unless ``parent`` is given).
        With ``end=`` the span is recorded already-closed — the pipeline
        measures stage boundaries once per micro-batch and stamps them
        onto every traced request in the batch."""
        p = self.root if parent is None else parent
        s = Span(name, next(_ids), p.span_id,
                 time.perf_counter() if start is None else start)
        if attrs:
            s.attrs.update(attrs)
        if end is not None:
            s.t_end = end
        self.spans.append(s)
        return s

    def find(self, name: str) -> Optional[Span]:
        for s in self.spans:
            if s.name == name:
                return s
        return None

    def finish(self) -> "Trace":
        """Close the root (if still open), close any still-open child
        spans at the root's end, and hand the trace to the tracer's
        bounded store."""
        if self.root.t_end is None:
            self.root.end()
        for s in self.spans[1:]:
            if s.t_end is None:
                s.t_end = self.root.t_end
        self._tracer._store(self)
        return self

    @property
    def duration_us(self) -> float:
        return self.root.duration_us

    def format_tree(self) -> str:
        """Human-readable indented tree (the example prints this)."""
        children: Dict[Optional[int], List[Span]] = {}
        for s in self.spans:
            children.setdefault(s.parent_id, []).append(s)

        lines: List[str] = []

        def walk(span: Span, depth: int) -> None:
            attrs = " ".join(f"{k}={v}" for k, v in span.attrs.items())
            lines.append(f"{'  ' * depth}{span.name} "
                         f"{span.duration_us:.0f}us"
                         + (f"  {attrs}" if attrs else ""))
            for c in sorted(children.get(span.span_id, []),
                            key=lambda s: s.t_start):
                walk(c, depth + 1)

        walk(self.root, 0)
        return "\n".join(lines)


class Tracer:
    """Sampling trace factory + bounded in-memory store of finished
    traces."""

    def __init__(self, capacity: int = 256, sample_rate: float = 1.0,
                 enabled: bool = True):
        self.enabled = bool(enabled)
        self.sample_rate = float(sample_rate)
        self._lock = threading.Lock()
        self._done: "collections.deque[Trace]" = collections.deque(
            maxlen=int(capacity))
        self.n_started = 0     # requests seen (sampled or not)
        self.n_sampled = 0

    def start_trace(self, name: str, start: Optional[float] = None,
                    **attrs) -> Optional[Trace]:
        """Begin a trace, or return ``None`` when this request is not
        sampled (deterministic every-Nth sampling: ``sample_rate=0.1``
        keeps exactly every 10th request, not a coin flip — replayable
        and starvation-free at any rate)."""
        if not self.enabled:
            return None
        rate = self.sample_rate
        with self._lock:
            self.n_started += 1
            n = self.n_started
            keep = rate > 0.0 and int(n * rate) > int((n - 1) * rate)
            if keep:
                self.n_sampled += 1
        if not keep:
            return None
        t = Trace(name, n, self, start=start)
        if attrs:
            t.root.attrs.update(attrs)
        return t

    def _store(self, trace: Trace) -> None:
        with self._lock:
            self._done.append(trace)

    def traces(self) -> List[Trace]:
        with self._lock:
            return list(self._done)

    def slowest(self) -> Optional[Trace]:
        with self._lock:
            if not self._done:
                return None
            return max(self._done, key=lambda t: t.duration_us)

    def clear(self) -> None:
        with self._lock:
            self._done.clear()
            self.n_started = 0
            self.n_sampled = 0
