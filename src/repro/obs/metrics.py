"""Unified metrics registry: counters, gauges, log-scale histograms.

One registry replaces the repo's scattered telemetry (ad-hoc
``ServeStats`` deques, ``trace_totals()`` dicts, fault counters) with a
single primitive family sharing a schema and two exporters:

* :meth:`MetricsRegistry.snapshot` — a JSON-able dict, validated
  against the checked-in ``snapshot.schema.json`` (CI's obs job);
* :meth:`MetricsRegistry.render_prom` — Prometheus text exposition
  (``# HELP`` / ``# TYPE`` / sample lines), scrape-ready.

Design constraints (DESIGN.md §14):

* **Thread-safe, low-overhead recording.** Every instrument guards its
  series map with one lock; a recording is a lock + two dict ops. The
  whole layer must cost < 10% of saturated serving throughput
  (``benchmarks/obs_overhead.py`` gates this), so there is no string
  formatting, no timestamping, and no allocation beyond the first
  observation of a label set on the hot path.
* **Fixed-bucket log-scale histograms.** Latency-shaped quantities span
  four orders of magnitude; power-of-two bucket bounds (the same
  bucketing the compile cache uses for batch sizes) keep the bucket
  count small and the export stable. A histogram can additionally keep
  a bounded ring of raw samples for EXACT percentiles — that ring is
  what the ``ServeStats`` façade's ``p50_us``/``p95_us``/``p99_us``
  read, so migrating the old deques onto this primitive changed no
  observable number.
* **Labels.** Instruments declare label NAMES once (engine, bucket,
  sign, method, ...); recordings pass values as keywords. A label set
  is one series; unknown labels are ignored, missing ones default to
  ``""`` — recording sites stay one-liners.
* **Disable switch.** ``registry.enabled = False`` turns every
  registry-owned instrument into a no-op branch (the overhead
  benchmark's baseline). Standalone instruments (constructed directly,
  e.g. the per-server ``ServeStats`` rings) always record — they ARE
  the pre-obs behaviour the baseline preserves.
"""

from __future__ import annotations

import bisect
import collections
import json
import re
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "log2_buckets",
    "LATENCY_BUCKETS_US", "SECONDS_BUCKETS", "FRACTION_BUCKETS",
    "GAP_BUCKETS", "SIZE_BUCKETS", "validate_snapshot",
    "parse_prom_text",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def log2_buckets(lo: float, hi: float) -> Tuple[float, ...]:
    """Power-of-two bucket bounds from ``lo`` doubling past ``hi``."""
    if not lo > 0 or not hi > lo:
        raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
    bounds: List[float] = []
    b = float(lo)
    while b < hi:
        bounds.append(b)
        b *= 2.0
    bounds.append(b)
    return tuple(bounds)


#: 1us .. ~16.8s — serving latencies (per-batch and per-request)
LATENCY_BUCKETS_US = log2_buckets(1.0, float(1 << 24))
#: ~61us .. 64s — compaction builds and other wall-clock seconds
SECONDS_BUCKETS = log2_buckets(2.0 ** -14, 64.0)
#: ~1e-6 .. 1 — ratios (scored fraction, certified fraction)
FRACTION_BUCKETS = tuple(2.0 ** -i for i in range(20, -1, -1))
#: ~1e-3 .. 1024 — certificate bound gaps (score units)
GAP_BUCKETS = log2_buckets(2.0 ** -10, 1024.0)
#: 1 .. 1024 — batch sizes and other small counts
SIZE_BUCKETS = log2_buckets(1.0, 1024.0)


class _Instrument:
    """Shared plumbing: name/help/label validation, series keying."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (), _registry=None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = str(help)
        self.label_names: Tuple[str, ...] = tuple(labels)
        self._lock = threading.Lock()
        self._registry = _registry

    def _recording(self) -> bool:
        reg = self._registry
        return reg is None or reg.enabled

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if not self.label_names:
            return ()
        return tuple(str(labels.get(n, "")) for n in self.label_names)

    def _label_dict(self, key: Tuple[str, ...]) -> Dict[str, str]:
        return dict(zip(self.label_names, key))


class Counter(_Instrument):
    """Monotonically increasing per-series float."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (), _registry=None):
        super().__init__(name, help, labels, _registry)
        self._values: Dict[Tuple[str, ...], float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not self._recording():
            return
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def total(self) -> float:
        """Sum across every label set."""
        with self._lock:
            return sum(self._values.values())

    def _series(self) -> List[dict]:
        with self._lock:
            items = sorted(self._values.items())
        return [{"labels": self._label_dict(k), "value": v}
                for k, v in items]

    def _reset(self) -> None:
        with self._lock:
            self._values.clear()


class Gauge(Counter):
    """Last-set per-series float (``set``; ``inc`` also works)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not self._recording():
            return
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)


class _HistSeries:
    __slots__ = ("counts", "count", "sum", "ring")

    def __init__(self, n_buckets: int, ring: int):
        self.counts = [0] * (n_buckets + 1)       # +1: the +Inf bucket
        self.count = 0
        self.sum = 0.0
        self.ring = (collections.deque(maxlen=ring) if ring else None)


class Histogram(_Instrument):
    """Fixed-bucket log-scale histogram, optionally ring-backed.

    ``buckets`` are ascending upper bounds (Prometheus ``le``
    semantics); an implicit ``+Inf`` bucket tops them off. ``ring > 0``
    keeps the last ``ring`` raw observations per series so
    :meth:`percentile` is EXACT over the recent window (the
    ``ServeStats`` façade's contract); with ``ring=0`` percentiles are
    estimated from the bucket upper bounds (export-only histograms).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS_US,
                 ring: int = 0, _registry=None):
        super().__init__(name, help, labels, _registry)
        self.buckets: Tuple[float, ...] = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"buckets must be strictly ascending for "
                             f"{name!r}")
        self.ring_len = int(ring)
        self._data: Dict[Tuple[str, ...], _HistSeries] = {}

    def _get(self, key: Tuple[str, ...]) -> _HistSeries:
        s = self._data.get(key)
        if s is None:
            s = self._data[key] = _HistSeries(len(self.buckets),
                                              self.ring_len)
        return s

    def observe(self, value: float, **labels) -> None:
        if not self._recording():
            return
        v = float(value)
        key = self._key(labels)
        idx = bisect.bisect_left(self.buckets, v)
        with self._lock:
            s = self._get(key)
            s.counts[idx] += 1
            s.count += 1
            s.sum += v
            if s.ring is not None:
                s.ring.append(v)

    def count(self, **labels) -> int:
        with self._lock:
            s = self._data.get(self._key(labels))
            return 0 if s is None else s.count

    def sum(self, **labels) -> float:
        with self._lock:
            s = self._data.get(self._key(labels))
            return 0.0 if s is None else s.sum

    def mean(self, **labels) -> float:
        with self._lock:
            s = self._data.get(self._key(labels))
            if s is None or s.count == 0:
                return 0.0
            return s.sum / s.count

    def ring_values(self, **labels) -> Tuple[float, ...]:
        """Locked snapshot of the raw-sample ring (empty if ``ring=0``)."""
        with self._lock:
            s = self._data.get(self._key(labels))
            return () if s is None or s.ring is None else tuple(s.ring)

    def ring(self, **labels):
        """The live ring deque itself (legacy façade access: the old
        ``ServeStats.lat_us_ring`` attribute was this deque). Appending
        to it directly bypasses the bucket counts — supported for
        back-compat, not recommended."""
        if self.ring_len == 0:
            raise ValueError(f"histogram {self.name!r} keeps no ring")
        with self._lock:
            return self._get(self._key(labels)).ring

    def percentile(self, q: float, **labels) -> float:
        """q-th percentile (0-100). Exact over the ring window when a
        ring is kept; bucket-upper-bound estimate otherwise; 0.0 when
        the series is empty (matching the old empty-ring contract)."""
        with self._lock:
            s = self._data.get(self._key(labels))
            if s is None:
                return 0.0
            if s.ring is not None:
                # the ring, not s.count, decides emptiness here: legacy
                # callers may append to the deque directly via ring()
                vals = sorted(s.ring)
                if not vals:
                    return 0.0
                # linear-interpolated rank, matching np.percentile
                rank = (q / 100.0) * (len(vals) - 1)
                lo = int(rank)
                hi = min(lo + 1, len(vals) - 1)
                frac = rank - lo
                return vals[lo] * (1.0 - frac) + vals[hi] * frac
            if s.count == 0:
                return 0.0
            need = (q / 100.0) * s.count
            cum = 0
            for i, c in enumerate(s.counts):
                cum += c
                if cum >= need and c:
                    return (self.buckets[i] if i < len(self.buckets)
                            else self.buckets[-1])
            return self.buckets[-1]

    def _series(self) -> List[dict]:
        with self._lock:
            items = sorted(self._data.items())
            out = []
            for k, s in items:
                out.append({
                    "labels": self._label_dict(k),
                    "count": s.count,
                    "sum": s.sum,
                    "buckets": {_fmt_bound(b): c for b, c in
                                zip((*self.buckets, float("inf")),
                                    s.counts)},
                })
        return out

    def _reset(self) -> None:
        with self._lock:
            self._data.clear()


def _fmt_bound(b: float) -> str:
    if b == float("inf"):
        return "+Inf"
    if b == int(b) and abs(b) < 1e15:
        return str(int(b))
    return repr(b)


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


class MetricsRegistry:
    """Named instruments + the two exporters. ``counter``/``gauge``/
    ``histogram`` are get-or-create (idempotent at import time; a kind
    or label mismatch on re-registration raises)."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: "collections.OrderedDict[str, _Instrument]" = \
            collections.OrderedDict()

    def _register(self, cls, name: str, help: str,
                  labels: Sequence[str], **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls) or m.kind != cls.kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}")
                if m.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} label mismatch: "
                        f"{m.label_names} vs {tuple(labels)}")
                return m
            m = cls(name, help, labels, _registry=self, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_US,
                  ring: int = 0) -> Histogram:
        return self._register(Histogram, name, help, labels,
                              buckets=buckets, ring=ring)

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return list(self._metrics)

    def reset(self) -> None:
        """Clear every series (instruments stay registered) — test and
        benchmark isolation."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m._reset()

    # -- exporters -----------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able view of every instrument and series (the shape the
        checked-in ``snapshot.schema.json`` pins)."""
        with self._lock:
            metrics = list(self._metrics.values())
        out: Dict[str, dict] = {}
        for m in metrics:
            out[m.name] = {
                "kind": m.kind,
                "help": m.help,
                "labels": list(m.label_names),
                "series": m._series(),
            }
        return {"metrics": out}

    def render_prom(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        with self._lock:
            metrics = list(self._metrics.values())
        lines: List[str] = []
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                for s in m._series():
                    base = _prom_labels(s["labels"])
                    cum = 0
                    for bound, c in s["buckets"].items():
                        cum += c
                        lab = _prom_labels({**s["labels"], "le": bound})
                        lines.append(f"{m.name}_bucket{lab} {cum}")
                    lines.append(f"{m.name}_sum{base} {_num(s['sum'])}")
                    lines.append(f"{m.name}_count{base} {s['count']}")
            else:
                for s in m._series():
                    lab = _prom_labels(s["labels"])
                    lines.append(f"{m.name}{lab} {_num(s['value'])}")
        return "\n".join(lines) + "\n"


def _num(v: float) -> str:
    return str(int(v)) if v == int(v) and abs(v) < 1e15 else repr(v)


def _prom_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"'
                     for k, v in labels.items())
    return "{" + inner + "}"


# ---------------------------------------------------------------------------
# Snapshot-schema validation + exposition smoke parser (CI's obs job)
# ---------------------------------------------------------------------------

def _check(cond: bool, path: str, msg: str) -> None:
    if not cond:
        raise ValueError(f"snapshot schema violation at {path}: {msg}")


_TYPES = {"object": dict, "array": list, "string": str,
          "boolean": bool, "number": (int, float), "integer": int}


def _validate(value, schema: dict, path: str) -> None:
    """Minimal JSON-Schema-subset validator: ``type``, ``required``,
    ``properties``, ``additionalProperties`` (a schema), ``items``,
    ``enum``. Enough to pin the snapshot shape without a dependency."""
    t = schema.get("type")
    if t is not None:
        py = _TYPES[t]
        if t == "number":
            _check(isinstance(value, (int, float))
                   and not isinstance(value, bool), path,
                   f"expected number, got {type(value).__name__}")
        elif t == "integer":
            _check(isinstance(value, int) and not isinstance(value, bool),
                   path, f"expected integer, got {type(value).__name__}")
        else:
            _check(isinstance(value, py), path,
                   f"expected {t}, got {type(value).__name__}")
    if "enum" in schema:
        _check(value in schema["enum"], path,
               f"{value!r} not in {schema['enum']}")
    if isinstance(value, dict):
        for req in schema.get("required", ()):
            _check(req in value, path, f"missing required key {req!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for k, v in value.items():
            if k in props:
                _validate(v, props[k], f"{path}.{k}")
            elif isinstance(extra, dict):
                _validate(v, extra, f"{path}.{k}")
    if isinstance(value, list) and "items" in schema:
        for i, v in enumerate(value):
            _validate(v, schema["items"], f"{path}[{i}]")


def validate_snapshot(snap: dict, schema: Optional[dict] = None) -> dict:
    """Validate a :meth:`MetricsRegistry.snapshot` dict against the
    checked-in schema (``src/repro/obs/snapshot.schema.json`` by
    default). Returns ``snap``; raises ``ValueError`` on violation."""
    if schema is None:
        import importlib.resources as _res
        schema = json.loads(
            _res.files("repro.obs").joinpath("snapshot.schema.json")
            .read_text())
    _validate(snap, schema, "$")
    return snap


def parse_prom_text(text: str) -> Dict[str, float]:
    """Smoke-parse a Prometheus exposition: every non-comment line must
    be ``name[{labels}] value``. Returns ``{sample_name: value}`` (the
    last value wins); raises ``ValueError`` on a malformed line."""
    sample_re = re.compile(
        r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'
        r'(\{(?:[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*",?)*\})?'
        r' (-?(?:[0-9.e+-]+|Inf|NaN))$')
    out: Dict[str, float] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line or line.startswith("#"):
            continue
        m = sample_re.match(line)
        if m is None:
            raise ValueError(f"malformed exposition line {ln}: {line!r}")
        out[m.group(1) + (m.group(2) or "")] = float(
            m.group(3).replace("Inf", "inf"))
    return out
