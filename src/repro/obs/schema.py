"""Declared schemas for dict-shaped stats surfaces.

``TopKServer.mutation_stats`` grew one key per PR and ended up mixing
ints, floats, numpy scalars, bools-as-ints and derived ratios with no
declared types — harness code downstream (benchmarks, CI gates,
dashboards) had to guess. The schema now lives HERE, once:
:data:`MUTATION_STATS_SCHEMA` names every key, its type and its
meaning, and :func:`build_mutation_stats` is the single constructor —
it checks the produced dict carries EXACTLY the declared keys and
coerces each value to its declared Python type (so a numpy ``int64``
or a ``bool`` can never leak into a JSON artifact again). Adding a key
without documenting it is now a hard error, not a drift.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["StatField", "MUTATION_STATS_SCHEMA", "build_mutation_stats"]


@dataclasses.dataclass(frozen=True)
class StatField:
    """One declared key: its coerced Python type and its meaning."""

    type: type
    doc: str


#: The one place the ``mutation_stats`` shape is defined. Keys are
#: grouped the way the serving docs discuss them; every value is
#: coerced to ``type`` by :func:`build_mutation_stats`.
MUTATION_STATS_SCHEMA: Dict[str, StatField] = {
    # -- mutation traffic ---------------------------------------------------
    "n_inserts": StatField(int, "rows streamed in via add_targets"),
    "n_deletes": StatField(int, "rows tombstoned via delete_targets"),
    "n_updates": StatField(int, "rows replaced via update_targets"),
    # -- delta / tombstone occupancy ---------------------------------------
    "delta_occupancy": StatField(
        int, "rows currently in the active delta + sealed L0 chain"),
    "max_delta_occupancy": StatField(
        int, "high-water mark of delta occupancy since boot"),
    "n_tombstones": StatField(
        int, "dead rows currently visible (base + segments)"),
    "num_live": StatField(int, "live rows currently visible"),
    "snapshot_version": StatField(
        int, "current base snapshot version (bumps on every swap; one "
             "half of the cache token / span join key)"),
    # -- compaction ---------------------------------------------------------
    "n_compactions": StatField(
        int, "successful compaction swaps since boot"),
    "n_failed_compactions": StatField(
        int, "compaction builds that raised (chain retained, no loss)"),
    "compaction_s_total": StatField(
        float, "wall-clock seconds spent in successful builds"),
    "last_compaction_s": StatField(
        float, "wall-clock seconds of the most recent successful build"),
    "engine_compiles_total": StatField(
        int, "engine traces charged to compaction builds (0 for warmed "
             "same-bucket compactions — the DESIGN.md §10 contract)"),
    "engine_compiles_per_compaction": StatField(
        float, "engine_compiles_total / max(n_compactions, 1) — the "
               "compile-free-compaction gate reads this"),
    "headroom_compiles_total": StatField(
        int, "traces spent pre-warming the NEXT M-bucket (an investment "
             "for a future crossing, separated from per-build cost)"),
    # -- recovery machinery (DESIGN.md §12) ---------------------------------
    "n_build_retries": StatField(
        int, "build attempts made after >= 1 consecutive failure"),
    "n_forced_sync_compactions": StatField(
        int, "chain-cap back-pressure builds run inline in the mutating "
             "caller"),
    "n_stuck_builds": StatField(
        int, "watchdog detections of an over-deadline in-flight build"),
    "max_l0_chain": StatField(
        int, "longest sealed-segment chain ever observed"),
    "l0_chain_len": StatField(
        int, "sealed segments currently awaiting compaction"),
    "consecutive_build_failures": StatField(
        int, "current failure streak (0 on a healthy server)"),
    "current_backoff_s": StatField(
        float, "backoff the next automatic retry is waiting out"),
    "retry_pending": StatField(
        int, "1 while an automatic post-failure retry timer is armed"),
    # -- LSM ladder (DESIGN.md §15) -----------------------------------------
    # all zero on the single-level catalogue (the base-class hooks
    # return neutral values), so one schema covers both catalogues
    "n_shards": StatField(
        int, "L1 shard-run count (0: single-level, no L1 tier)"),
    "l1_rows": StatField(
        int, "live rows currently resident in the per-shard L1 tier"),
    "n_l1_folds": StatField(
        int, "successful L0 -> L1 folds (the cheap moves that replace "
             "most full base rebuilds)"),
    "n_failed_l1_folds": StatField(
        int, "folds that raised (chain retained + queryable, no loss)"),
    "n_l1_fold_retries": StatField(
        int, "fold attempts made after >= 1 consecutive fold failure"),
    "l1_fold_s_total": StatField(
        float, "wall-clock seconds spent in successful folds"),
    "consecutive_fold_failures": StatField(
        int, "current L0 -> L1 fold failure streak (0 when healthy)"),
    "fold_backoff_s": StatField(
        float, "backoff the next ordinary fold retry is waiting out"),
}


def build_mutation_stats(values: Dict[str, object]) -> Dict[str, object]:
    """Validate ``values`` against :data:`MUTATION_STATS_SCHEMA` and
    coerce every entry to its declared type. Raises ``KeyError`` when a
    key is missing or undeclared — the schema and the producer can
    never silently diverge."""
    missing = MUTATION_STATS_SCHEMA.keys() - values.keys()
    extra = values.keys() - MUTATION_STATS_SCHEMA.keys()
    if missing or extra:
        raise KeyError(
            f"mutation_stats schema mismatch: missing={sorted(missing)} "
            f"undeclared={sorted(extra)} — update "
            f"repro.obs.schema.MUTATION_STATS_SCHEMA")
    return {k: MUTATION_STATS_SCHEMA[k].type(values[k])
            for k in MUTATION_STATS_SCHEMA}
