"""Top-K query serving: the paper's inference engine as a service layer.

``TopKServer`` owns a SEP-LR catalogue plus a shared
:class:`repro.core.engines.EngineContext` and serves batched queries
through ANY engine in the registry (``naive`` / ``ta`` / ``bta`` /
``norm`` / ``norm_sharded`` / ``pallas`` / ``fagin`` / ``partial`` /
``auto`` — see ``repro.core.engines``), addressed by registry name; the
context also owns the catalogue LAYOUTS each engine declares
(``repro.core.layout``: contiguous list prefixes for ``ta``/``bta``, the
norm-major tile order for ``norm``/``pallas``, the round-robin-dealt
sharded norm order for ``norm_sharded``), so one server process serves a
multi-device mesh by simply passing ``method="norm_sharded"``. Requests are micro-batched; per-query pruning statistics
(scores computed, depth) are aggregated PER REGISTRY ENGINE for the
benchmark harness — matching the paper's evaluation axis (query
efficiency). ``method="auto"`` resolves per batch via
:func:`repro.core.engines.select_engine`, and its traffic is accounted to
the engine that actually ran.

``TwoStageRanker`` is the production recsys pattern from DESIGN.md §3:
exact SEP-LR top-N retrieval (where the paper's algorithms apply) followed
by full-model re-ranking of the N retrieved candidates (where they don't).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SepLRModel, TopKIndex
from repro.core.engines import (
    Engine,
    EngineContext,
    engine_names,
    get_engine,
    select_engine,
)

Array = jnp.ndarray


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    n_scored: int = 0
    total_time_s: float = 0.0
    depth_sum: int = 0

    @property
    def scores_per_query(self) -> float:
        return self.n_scored / max(self.n_queries, 1)

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.total_time_s / max(self.n_queries, 1)


class TopKServer:
    def __init__(self, model: SepLRModel, max_batch: int = 64,
                 block_size: int = 256):
        self.model = model
        self.ctx = EngineContext(model.targets, block_size=block_size)
        self.max_batch = max_batch
        self.block_size = block_size
        self.stats: Dict[str, ServeStats] = {}

    @property
    def index(self) -> TopKIndex:
        return self.ctx.index

    @staticmethod
    def available_engines() -> List[str]:
        """Registry names accepted by :meth:`query`'s ``method=``."""
        return engine_names()

    def warmup(self, k: int, batch_sizes=None, engines=None) -> "TopKServer":
        """Populate the per-engine compiled-executable cache ahead of
        traffic (DESIGN.md §6). After warmup, same-shape queries hit the
        cache with zero new traces (``self.ctx.trace_counts`` proves it).
        """
        sizes = tuple(batch_sizes) if batch_sizes else (1, self.max_batch)
        self.ctx.warmup(k, batch_sizes=sizes, engines=engines)
        return self

    def _record(self, method: str, res, dt: float, n: int):
        s = self.stats.setdefault(method, ServeStats())
        s.n_queries += n
        s.n_scored += int(np.sum(np.asarray(res.n_scored)))
        s.depth_sum += int(np.sum(np.asarray(res.depth)))
        s.total_time_s += dt

    def query(self, U: Array, k: int, method: str = "bta"):
        """U: [B, R] (or [R]). Returns TopKResult batched like U.

        ``method`` is any registry name (or alias) from
        :meth:`available_engines`; unknown names raise ``ValueError``.
        ``auto`` dispatch reads its sparsity statistic from the incoming
        HOST array — engine selection never enqueues work on the device
        query stream.
        """
        engine: Engine = get_engine(method)
        # Keep the batch wherever the caller had it: host inputs are
        # sliced and dispatched as numpy (auto's nnz statistic never
        # touches the device), device-resident inputs stay on device with
        # no round-trip (select_engine reads them back once per chunk
        # only when method="auto").
        if isinstance(U, jax.Array):
            U_all = jnp.atleast_2d(U)
        else:
            U_all = np.atleast_2d(np.asarray(U, np.float32))
        outs = []
        for i in range(0, U_all.shape[0], self.max_batch):
            chunk = U_all[i: i + self.max_batch]
            eng = (select_engine(self.ctx, chunk)
                   if engine.name == "auto" else engine)
            t0 = time.perf_counter()
            res = jax.tree_util.tree_map(
                np.asarray, eng.run(self.ctx, chunk, k))
            dt = time.perf_counter() - t0
            self._record(eng.name, res, dt, chunk.shape[0])
            outs.append(res)
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *outs)


class TwoStageRanker:
    """Exact SEP-LR retrieval -> full-model re-rank (DESIGN.md §3).

    retrieval_model: SEP-LR over the candidate catalogue (u = query tower).
    rerank_fn(query_batch, candidate_ids) -> scores of the retrieved set.
    The retrieval engine is addressed by registry name, same as
    :meth:`TopKServer.query`.
    """

    def __init__(self, retrieval: TopKServer,
                 rerank_fn: Callable[[Dict, np.ndarray], np.ndarray],
                 retrieve_n: int = 100):
        self.retrieval = retrieval
        self.rerank_fn = rerank_fn
        self.retrieve_n = retrieve_n

    def rank(self, query_batch: Dict, U: Array, k: int,
             method: str = "bta"):
        get_engine(method)  # fail fast on unknown engine names
        res = self.retrieval.query(U, self.retrieve_n, method=method)
        cand = np.asarray(res.indices)                       # [B, N]
        rerank = self.rerank_fn(query_batch, cand)           # [B, N]
        order = np.argsort(-rerank, axis=1)[:, :k]
        return (np.take_along_axis(cand, order, axis=1),
                np.take_along_axis(rerank, order, axis=1))
