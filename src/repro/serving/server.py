"""Top-K query serving: the paper's inference engine as a service layer.

``TopKServer`` owns a SEP-LR catalogue plus a shared
:class:`repro.core.engines.EngineContext` and serves batched queries
through ANY engine in the registry (``naive`` / ``ta`` / ``bta`` /
``norm`` / ``norm_sharded`` / ``pallas`` / ``fagin`` / ``partial`` /
``auto`` — see ``repro.core.engines``), addressed by registry name; the
context also owns the catalogue LAYOUTS each engine declares
(``repro.core.layout``: contiguous list prefixes for ``ta``/``bta``, the
norm-major tile order for ``norm``/``pallas``, the round-robin-dealt
sharded norm order for ``norm_sharded``), so one server process serves a
multi-device mesh by simply passing ``method="norm_sharded"``. Requests are micro-batched; per-query pruning statistics
(scores computed, depth) are aggregated PER REGISTRY ENGINE for the
benchmark harness — matching the paper's evaluation axis (query
efficiency). ``method="auto"`` resolves per batch via
:func:`repro.core.engines.select_engine`, and its traffic is accounted to
the engine that actually ran.

``TwoStageRanker`` is the production recsys pattern from DESIGN.md §3:
exact SEP-LR top-N retrieval (where the paper's algorithms apply) followed
by full-model re-ranking of the N retrieved candidates (where they don't).

**Streaming mutations** (DESIGN.md §9): the server's catalogue is a
:class:`repro.core.segments.SegmentedCatalogue` — an immutable base
snapshot (the EngineContext every engine runs against) plus a delta
buffer and tombstones. :meth:`TopKServer.add_targets` /
:meth:`delete_targets` / :meth:`update_targets` mutate it without an
index rebuild and without giving up exactness; a threshold-triggered
compaction folds the mutations into a fresh snapshot under a new
version. A never-mutated server serves the identical code path (and the
identical compiled executables) as before the streaming layer existed.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import SepLRModel, TopKIndex
from repro.core.engines import (
    CostTable,
    Engine,
    EngineContext,
    batch_bucket,
    engine_names,
    get_engine,
    note_pruning_metrics,
    select_engine,
)
from repro.core.lsm import ShardedLsmCatalogue
from repro.core.naive import TopKResult
from repro.core.segments import SegmentedCatalogue
from repro.core.strategies import sign_bucket_label

Array = jnp.ndarray

#: Ring-buffer length for per-batch latency percentiles: enough batches
#: for stable p99 at serving rates, bounded so a long-lived server never
#: grows its stats footprint.
LATENCY_RING = 512


def _batch_hist() -> obs.Histogram:
    return obs.Histogram("serve_batch_latency_us",
                         "per-query us of one served batch",
                         buckets=obs.LATENCY_BUCKETS_US,
                         ring=LATENCY_RING)


def _request_hist() -> obs.Histogram:
    return obs.Histogram("serve_request_latency_us",
                         "enqueue->result us of one caller request",
                         buckets=obs.LATENCY_BUCKETS_US,
                         ring=LATENCY_RING)


@dataclasses.dataclass
class ServeStats:
    """Per-engine serving statistics.

    Latency is tracked three ways: the lifetime mean (``us_per_query``,
    exact over every query ever served), percentiles over a BOUNDED
    ring of recent per-batch latencies (``p50_us``/``p95_us``/``p99_us``
    — each entry is one batch's per-query microseconds, so tail entries
    reflect stragglers like a post-mutation retrace or a compaction
    swap), and percentiles over a ring of per-REQUEST latencies
    (``req_p50_us``/``req_p95_us``/``req_p99_us`` — enqueue→result wall
    time for one caller request, the number an SLO is written against).
    The per-batch and per-request views DIVERGE under micro-batching:
    a request coalesced into a shared batch waits in the queue before
    its batch dispatches, time the per-batch column never sees — which
    is exactly why both columns exist (DESIGN.md §13).
    ``delta_scored`` counts scores spent on the streaming delta
    segments, separating mutation-induced work from base-scan work.
    ``sign_batches`` counts served batches per sign bucket (the compile
    specialisation axis of the batched list scan, DESIGN.md §11) — a
    bucket label appearing here that :meth:`TopKServer.warmup` did not
    warm explains a one-off trace straggler in the latency ring.

    Since the observability layer landed (DESIGN.md §14) the two rings
    are :class:`repro.obs.Histogram` instances — the registry's shared
    primitive, with log-scale buckets for export AND the bounded raw
    ring the exact percentiles read. The public API above is a façade
    over them and is UNCHANGED: ``lat_us_ring``/``req_lat_us_ring``
    still expose the underlying deques, percentiles still match
    ``np.percentile`` over the ring. Counter updates go through a lock
    (`record_batch`) so concurrent recording threads never lose
    increments.
    """

    n_queries: int = 0
    n_scored: int = 0
    total_time_s: float = 0.0
    depth_sum: int = 0
    delta_scored: int = 0
    #: per-batch per-query-us histogram (the obs shared primitive;
    #: its bounded ring backs the exact ``p50_us``/``p95_us``/``p99_us``)
    lat_hist: obs.Histogram = dataclasses.field(
        default_factory=_batch_hist, repr=False, compare=False)
    #: per-REQUEST enqueue→result histogram (one entry per caller
    #: request; honest under coalescing, unlike the per-batch ring)
    req_lat_hist: obs.Histogram = dataclasses.field(
        default_factory=_request_hist, repr=False, compare=False)
    sign_batches: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: degradation-ladder decisions taken while serving THIS method
    #: (keyed by rung: "to_norm" / "to_budgeted" / "shed"), recorded on
    #: the REQUESTED method's stats — the ladder is an admission story,
    #: so its accounting follows what the caller asked for, while the
    #: raw serve counters above follow the engine that actually ran
    degradations: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: queries whose result carried at least one UNCERTIFIED slot
    #: (certificate gap > 0 — possible under a step budget, never on the
    #: exact path); the CI degradation smoke gates on this being honest
    n_uncertified: int = 0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    # -- legacy ring façade --------------------------------------------------

    @property
    def lat_us_ring(self):
        """The per-batch latency ring (the histogram's raw-sample
        deque) — the pre-§14 attribute, kept for callers."""
        return self.lat_hist.ring()

    @property
    def req_lat_us_ring(self):
        return self.req_lat_hist.ring()

    @property
    def scores_per_query(self) -> float:
        return self.n_scored / max(self.n_queries, 1)

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.total_time_s / max(self.n_queries, 1)

    def record_batch(self, n: int, n_scored: int, depth_sum: int,
                     dt_s: float, delta_scored: int = 0,
                     sign_label: str = "") -> None:
        """Fold one served batch in (thread-safe: the async pipeline's
        harvester and the sync path may both record concurrently)."""
        with self._lock:
            self.n_queries += n
            self.n_scored += n_scored
            self.depth_sum += depth_sum
            self.total_time_s += dt_s
            self.delta_scored += delta_scored
            if sign_label:
                self.sign_batches[sign_label] = (
                    self.sign_batches.get(sign_label, 0) + 1)
        self.lat_hist.observe(1e6 * dt_s / max(n, 1))

    def bump_degradation(self, rung: str) -> None:
        with self._lock:
            self.degradations[rung] = self.degradations.get(rung, 0) + 1

    def note_uncertified(self, n: int) -> None:
        with self._lock:
            self.n_uncertified += n

    def latency_percentile(self, q: float) -> float:
        """q-th percentile (0-100) of recent per-batch latencies, in us."""
        return self.lat_hist.percentile(q)

    @property
    def p50_us(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95_us(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99_us(self) -> float:
        return self.latency_percentile(99.0)

    def record_request_latency(self, us: float) -> None:
        """One caller request completed ``us`` microseconds after it was
        submitted (enqueue→result, queue wait included)."""
        self.req_lat_hist.observe(float(us))

    def request_percentile(self, q: float) -> float:
        """q-th percentile (0-100) of recent per-REQUEST latencies, us."""
        return self.req_lat_hist.percentile(q)

    @property
    def req_p50_us(self) -> float:
        return self.request_percentile(50.0)

    @property
    def req_p95_us(self) -> float:
        return self.request_percentile(95.0)

    @property
    def req_p99_us(self) -> float:
        return self.request_percentile(99.0)


@dataclasses.dataclass
class AdmissionPolicy:
    """Load/deadline policy for :meth:`TopKServer.query` (DESIGN.md §12).

    When a deadline is in force, each chunk walks an explicit
    degradation ladder instead of queueing unboundedly: the PREFERRED
    engine if its predicted cost fits the remaining time, else ``norm``
    (the cheapest exact scan), else a BUDGETED ``norm`` scan whose
    result carries per-item certificates (``TopKResult.upper``), else —
    deadline already blown or the server over ``max_inflight`` — the
    chunk is SHED: sentinel values (``-inf`` scores, ``-1`` ids, ``+inf``
    certificate gaps, i.e. nothing certified), never a silent partial
    answer pretending to be exact. Every downgrade/shed decision lands
    in :attr:`ServeStats.degradations` under the requested method.
    """

    #: default per-query deadline (None = no deadline: never degrade);
    #: ``query(deadline_ms=...)`` overrides per call
    deadline_ms: Optional[float] = None
    #: concurrent chunks in flight before overload shedding kicks in
    max_inflight: int = 8
    #: scan budget (list rows) used at the "budgeted" ladder rung
    degrade_budget: int = 64
    #: shed on overload/expiry (False = serve anyway, just record it)
    shed_on_overload: bool = True


class TopKServer:
    def __init__(self, model: SepLRModel, max_batch: int = 64,
                 block_size: int = 256, delta_capacity: int = 256,
                 compact_async: bool = False,
                 policy: Optional[AdmissionPolicy] = None,
                 n_shards: int = 0,
                 l1_capacity: Optional[int] = None,
                 max_tombstones: Optional[int] = None,
                 cost_table: Optional[CostTable] = None):
        self.model = model
        # per-(engine, batch-bucket, sign-bucket) measured serve cost:
        # the serving router's table (select_engine consults it through
        # the context) and the admission ladder's fallback. Passed into
        # the catalogue's ctx_kwargs so every compaction-built context
        # SHARES it — measurements survive snapshot swaps. A caller may
        # hand in a pre-measured table (CostTable.load) so a RESTARTED
        # server routes by measured costs before its first observation.
        self.cost_table = cost_table if cost_table is not None \
            else CostTable()
        # n_shards > 0 fronts the model with the LSM ladder
        # (DESIGN.md §15): per-shard L1 runs absorb most compactions as
        # cheap folds, full base rebuilds only on tier overflow
        # max_tombstones=None keeps the catalogue default
        # (2 * delta_capacity); large catalogues want an absolute cap
        # sized to M — the §9 over-fetch costs O(n_dead) per query while
        # a tombstone-triggered rebuild costs O(M), so at M >> capacity
        # the default forces full rebuilds to clear a vanishing dead
        # fraction
        tomb = {} if max_tombstones is None \
            else {"max_tombstones": max_tombstones}
        if n_shards > 0:
            self.catalogue: SegmentedCatalogue = ShardedLsmCatalogue(
                model.targets, n_shards=n_shards, l1_capacity=l1_capacity,
                delta_capacity=delta_capacity,
                compact_async=compact_async, block_size=block_size,
                cost_table=self.cost_table, **tomb)
        else:
            self.catalogue = SegmentedCatalogue(
                model.targets, delta_capacity=delta_capacity,
                compact_async=compact_async, block_size=block_size,
                cost_table=self.cost_table, **tomb)
        self.max_batch = max_batch
        self.block_size = block_size
        self.stats: Dict[str, ServeStats] = {}
        self.policy = policy if policy is not None else AdmissionPolicy()
        # per-engine EWMA of per-query serve seconds: the ladder's FIRST
        # cost source (tests set entries directly to make admission
        # decisions deterministic); when an engine has no entry here the
        # ladder falls back to the shared :attr:`cost_table` (primed by
        # warmup), and only an engine absent from BOTH predicts the
        # optimistic 0.
        self._cost_ewma: Dict[str, float] = {}
        self._admit_lock = threading.Lock()
        self._inflight = 0

    @property
    def ctx(self) -> EngineContext:
        """The CURRENT base snapshot's engine context (compaction swaps
        in a fresh one under the next version — hold :attr:`catalogue`
        if you need a stable reference across mutations)."""
        return self.catalogue.snapshot.ctx

    @property
    def index(self) -> TopKIndex:
        return self.ctx.index

    @property
    def trace_counts(self) -> Dict[str, int]:
        """Engine traces (current snapshot) + segmented-tail traces."""
        return {**self.ctx.trace_counts, **self.catalogue.trace_counts}

    @staticmethod
    def available_engines() -> List[str]:
        """Registry names accepted by :meth:`query`'s ``method=``."""
        return engine_names()

    def warmup(self, k: int, batch_sizes=None, engines=None,
               m_buckets=None, budgets=None) -> "TopKServer":
        """Populate the per-engine compiled-executable cache ahead of
        traffic (DESIGN.md §6/§10). After warmup, same-shape queries hit
        the cache with zero new traces (``self.ctx.trace_counts`` proves
        it).

        **Warmup over M-buckets** (DESIGN.md §10): argument-passing
        executors are traced per CATALOGUE bucket, so this also warms
        ``m_buckets`` — by default the current bucket plus the next one
        (one doubling of headroom). A streaming catalogue that grows
        across its next power-of-two boundary then compacts with ZERO
        engine retraces, exactly like a same-bucket compaction; pass
        more buckets for more growth headroom, or ``(ctx.m_bucket,)``
        to warm only the current size.

        Also warms the streaming layer: the segmented tail is compiled
        for EVERY delta-capacity bucket (DESIGN.md §9), so the first
        query after any insert dispatches cached executables — 0 new
        traces — and records the warm spec so compaction readies each
        replacement snapshot before swapping it in (compile-free for
        warmed buckets).

        ``budgets`` additionally warms each budget-capable engine's
        BUDGETED variants (the budget joins the executor config, so each
        distinct budget is its own cache entry — DESIGN.md §12); warmed
        budgets then stay compile-free across compactions exactly like
        the unbudgeted path, including the degradation ladder's
        ``policy.degrade_budget``.
        """
        sizes = tuple(batch_sizes) if batch_sizes else (1, self.max_batch)
        if m_buckets is None:
            mb = self.ctx.m_bucket
            m_buckets = (mb, 2 * mb)
        self.ctx.warmup(k, batch_sizes=sizes, engines=engines,
                        m_buckets=m_buckets, budgets=budgets)
        self.catalogue.warm(k, batch_sizes=sizes, engines=engines,
                            m_buckets=m_buckets, budgets=budgets)
        # compactions renew the headroom iff the boot warmup established
        # any (each build then pre-traces ITS next bucket, keeping every
        # future crossing compile-free, not just the first)
        headroom = any(int(b) > self.ctx.m_bucket for b in m_buckets)
        self.catalogue.set_warm_spec(k, sizes, engines, headroom=headroom,
                                     budgets=budgets)
        return self

    # -- streaming mutations (DESIGN.md §9) ---------------------------------

    def add_targets(self, rows) -> np.ndarray:
        """Stream new items into the catalogue; returns their global ids."""
        return self.catalogue.add_targets(rows)

    def delete_targets(self, gids) -> None:
        """Tombstone items; queries exclude them immediately and exactly."""
        self.catalogue.delete_targets(gids)

    def update_targets(self, gids, rows) -> None:
        """Replace item factors in place (same global ids)."""
        self.catalogue.update_targets(gids, rows)

    @property
    def mutation_stats(self) -> Dict[str, float]:
        """Delta/compaction counters for the bench harness and dashboards.

        The key set and types are declared ONCE, in
        :data:`repro.obs.schema.MUTATION_STATS_SCHEMA` (each key
        documented there); this property just supplies the values —
        :func:`repro.obs.build_mutation_stats` raises on any drift
        between the two, so the schema cannot silently rot.
        """
        cat = self.catalogue
        return obs.build_mutation_stats({
            "n_inserts": cat.stats.n_inserts,
            "n_deletes": cat.stats.n_deletes,
            "n_updates": cat.stats.n_updates,
            "n_compactions": cat.stats.n_compactions,
            "n_failed_compactions": cat.stats.n_failed_compactions,
            "max_delta_occupancy": cat.stats.max_delta_occupancy,
            "delta_occupancy": cat.delta_occupancy,
            "n_tombstones": cat.n_tombstones,
            "snapshot_version": cat.version,
            "num_live": cat.num_live,
            # argument-passing contract (DESIGN.md §10): engine traces
            # observed during compaction builds — 0 for compactions whose
            # M-bucket was warmed — and the builds' wall-clock
            "engine_compiles_total": cat.stats.engine_compiles_total,
            "engine_compiles_per_compaction": (
                cat.stats.engine_compiles_total
                / max(cat.stats.n_compactions, 1)),
            "headroom_compiles_total": cat.stats.headroom_compiles_total,
            "compaction_s_total": cat.stats.compaction_s_total,
            "last_compaction_s": cat.stats.last_compaction_s,
            # recovery machinery (DESIGN.md §12): retry/backoff state,
            # chain-cap pressure, and watchdog flags — all zero on a
            # healthy server
            "n_build_retries": cat.stats.n_build_retries,
            "n_forced_sync_compactions": cat.stats.n_forced_sync_compactions,
            "n_stuck_builds": cat.stats.n_stuck_builds,
            "max_l0_chain": cat.stats.max_l0_chain,
            "l0_chain_len": cat.l0_chain_len,
            "consecutive_build_failures": cat.consecutive_build_failures,
            "current_backoff_s": cat.current_backoff_s,
            "retry_pending": int(cat.retry_pending),
            # LSM ladder (DESIGN.md §15): all zero on the single-level
            # catalogue — the base-class hooks return the neutral values
            "n_shards": cat.n_shards,
            "l1_rows": cat.l1_rows,
            "n_l1_folds": cat.stats.n_l1_folds,
            "n_failed_l1_folds": cat.stats.n_failed_l1_folds,
            "n_l1_fold_retries": cat.stats.n_l1_fold_retries,
            "l1_fold_s_total": cat.stats.l1_fold_s_total,
            "consecutive_fold_failures": cat.consecutive_fold_failures,
            "fold_backoff_s": cat.fold_backoff_s,
        })

    def _record(self, method: str, res, dt: float, n: int,
                delta_scored: int = 0, sign_label: str = ""):
        s = self.stats.setdefault(method, ServeStats())
        n_scored = int(np.sum(np.asarray(res.n_scored)))
        depth_sum = int(np.sum(np.asarray(res.depth)))
        s.record_batch(n, n_scored, depth_sum, dt,
                       int(delta_scored) * n, sign_label)
        # mirror into the process-wide registry: the live
        # pruning-efficiency metrics (scored fraction vs the live M)
        # plus the exported latency histograms (DESIGN.md §14)
        note_pruning_metrics(method, n, n_scored, depth_sum,
                             self.catalogue.num_live,
                             1e6 * dt / max(n, 1), sign_label)

    def _note_certificates(self, req_stats: ServeStats, engine_name: str,
                           bud: int, res) -> None:
        """Certificate accounting for one budgeted batch: the legacy
        per-request ``n_uncertified`` counter PLUS the live registry
        metrics (certified fraction and mean uncertified gap per
        (engine, budget-bucket)) — both derived from the same
        ``upper - values`` gaps :func:`repro.core.certificate_gaps`
        defines, which tests/test_obs.py pins against."""
        upper = np.asarray(res.upper)
        vals = np.asarray(res.values)
        ids = np.asarray(res.indices)
        valid = ids >= 0
        gaps = upper[:, None] - vals
        unc = np.logical_and(gaps > 0, valid)
        n_unc_queries = int(np.sum(np.any(unc, axis=1)))
        req_stats.note_uncertified(n_unc_queries)
        n_valid = int(np.sum(valid))
        n_unc = int(np.sum(unc))
        frac = 1.0 - n_unc / max(n_valid, 1)
        mean_gap = float(gaps[unc].mean()) if n_unc else 0.0
        obs.on_uncertified(engine_name, n_unc_queries)
        obs.on_certificates(engine_name, batch_bucket(int(bud)), frac,
                            mean_gap, n_unc > 0)

    def _shed_result(self, n: int, k: int) -> TopKResult:
        """Sentinel result for a shed chunk: explicitly nothing — ``-inf``
        scores, ``-1`` ids, ``+inf`` certificate gaps (no slot certified),
        never a partial answer pretending to be exact."""
        return TopKResult(
            np.full((n, k), -np.inf, np.float32),
            np.full((n, k), -1, np.int32),
            np.zeros((n,), np.int32),
            np.zeros((n,), np.int32),
            upper=np.full((n,), np.inf, np.float32))

    def _admit(self, eng: Engine, n: int,
               remaining_s: Optional[float]):
        """Pick the degradation-ladder rung for one ``n``-query chunk.

        Returns ``(engine_or_None, budget, rung)`` — ``None`` engine
        means shed. Cost predictions come from the per-engine EWMA of
        observed per-query seconds (:attr:`_cost_ewma`), falling back to
        the measured :attr:`cost_table` at this chunk's batch bucket
        (warmup primes it, so a freshly warmed server admits from
        measurements); only an engine absent from both predicts 0
        (optimistic: admit, then learn).
        """
        pol = self.policy
        if remaining_s is None:
            return eng, None, "full"
        bucket = batch_bucket(max(n, 1))

        def cost(name: str) -> float:
            c = self._cost_ewma.get(name)
            if c is None:
                c = self.cost_table.predict(name, bucket, "")
            return (c or 0.0) * n

        if remaining_s <= 0.0:
            if pol.shed_on_overload:
                return None, None, "shed"
            return get_engine("norm"), pol.degrade_budget, "to_budgeted"
        if cost(eng.name) <= remaining_s:
            return eng, None, "full"
        if eng.name != "norm" and cost("norm") <= remaining_s:
            return get_engine("norm"), None, "to_norm"
        return get_engine("norm"), pol.degrade_budget, "to_budgeted"

    def query(self, U: Array, k: int, method: str = "bta",
              budget: Optional[int] = None,
              deadline_ms: Optional[float] = None):
        """U: [B, R] (or [R]). Returns TopKResult batched like U.

        ``method`` is any registry name (or alias) from
        :meth:`available_engines`; unknown names raise ``ValueError``.
        ``auto`` dispatch reads its sparsity/batch-size statistics from
        the incoming HOST array — engine selection never enqueues work
        on the device query stream. Batch-specialised engines also
        record each chunk's sign bucket in
        :attr:`ServeStats.sign_batches` (the DESIGN.md §11 compile
        axis), again a host-side read of input VALUES only. Once the
        catalogue has streamed mutations, results
        carry GLOBAL item ids and reflect every mutation exactly (the
        segmented query path, DESIGN.md §9); a never-mutated server runs
        the raw engine path unchanged.

        **Budgeted queries** (DESIGN.md §12): ``budget`` caps the scan
        depth (list rows) of budget-capable engines. The result's
        ``upper`` field then bounds every un-scanned item;
        :func:`repro.core.certificate_gaps` ≤ 0 marks the slots that are
        PROVABLY in the true top-``k`` (always a prefix). Exact engines
        return ``upper = -inf`` (everything certified).

        **Deadlines** (``deadline_ms``, or ``policy.deadline_ms``): each
        chunk walks the admission ladder (:class:`AdmissionPolicy`) —
        preferred engine → ``norm`` → budgeted ``norm`` → shed — based
        on the EWMA cost model and the time remaining; decisions are
        recorded in :attr:`ServeStats.degradations` under the REQUESTED
        method. Over ``policy.max_inflight`` concurrent chunks, new
        chunks shed immediately instead of queueing.

        Validation: non-positive ``k``/``budget``, negative
        ``deadline_ms``, wrong-rank or >2-D ``U``, and non-finite HOST
        query values raise ``ValueError`` (device-resident inputs skip
        the finiteness scan — reading them back would break the
        no-round-trip contract above).
        """
        engine: Engine = get_engine(method)
        if int(k) <= 0:
            raise ValueError(f"k must be a positive int, got {k!r}")
        if budget is not None and int(budget) <= 0:
            raise ValueError(
                f"budget must be a positive int or None, got {budget!r}")
        if deadline_ms is not None and float(deadline_ms) < 0:
            raise ValueError(
                f"deadline_ms must be >= 0 or None, got {deadline_ms!r}")
        # Keep the batch wherever the caller had it: host inputs are
        # sliced and dispatched as numpy (auto's nnz statistic never
        # touches the device), device-resident inputs stay on device with
        # no round-trip (select_engine reads them back once per chunk
        # only when method="auto").
        if isinstance(U, jax.Array):
            U_all = jnp.atleast_2d(U)
        else:
            U_all = np.atleast_2d(np.asarray(U, np.float32))
        if U_all.ndim != 2:
            raise ValueError(
                f"U must be [B, R] or [R], got shape {U_all.shape}")
        rank = self.catalogue.rank
        if U_all.shape[1] != rank:
            raise ValueError(
                f"query rank {U_all.shape[1]} != catalogue rank {rank}")
        if isinstance(U_all, np.ndarray) and not np.all(np.isfinite(U_all)):
            bad = int(np.argwhere(~np.isfinite(U_all).all(axis=1))[0, 0])
            raise ValueError(f"query row {bad} contains NaN/Inf values")
        if deadline_ms is None:
            deadline_ms = self.policy.deadline_ms
        t_admit = time.perf_counter()
        req_stats = self.stats.setdefault(engine.name, ServeStats())
        outs = []
        for i in range(0, U_all.shape[0], self.max_batch):
            chunk = U_all[i: i + self.max_batch]
            n = chunk.shape[0]
            eng = (select_engine(self.ctx, chunk)
                   if engine.name == "auto" else engine)
            # admission: overload first (cheap counter check), then the
            # deadline ladder on the time this query has left
            with self._admit_lock:
                overloaded = (self._inflight >= self.policy.max_inflight
                              and self.policy.shed_on_overload)
                self._inflight += 1
            try:
                if overloaded:
                    run_eng, bud, rung = None, None, "shed"
                else:
                    remaining = None if deadline_ms is None else (
                        deadline_ms / 1e3
                        - (time.perf_counter() - t_admit))
                    run_eng, bud, rung = self._admit(eng, n, remaining)
                if rung != "full":
                    req_stats.bump_degradation(rung)
                    obs.on_degradation(engine.name, rung)
                if run_eng is None:
                    res = self._shed_result(n, int(k))
                    req_stats.note_uncertified(n)
                    obs.on_uncertified(engine.name, n)
                    outs.append(res)
                    continue
                if bud is None:
                    bud = budget  # explicit caller budget, not a downgrade
                # sign bucket of this chunk, for the per-bucket serve
                # stats — only engines with batch specialisation pay the
                # (host-side, input-value-only) read; it mirrors the
                # bucket the dispatch itself computes for the compile key
                # (DESIGN.md §11)
                label = (sign_bucket_label(
                            run_eng.batch_config(self.ctx, chunk))
                         if run_eng.batch_config is not None else "")
                t0 = time.perf_counter()
                res, info = self.catalogue.query(run_eng, chunk, k,
                                                 budget=bud)
                res = jax.tree_util.tree_map(np.asarray, res)
                dt = time.perf_counter() - t0
            finally:
                with self._admit_lock:
                    self._inflight -= 1
            if res.upper is None:
                # legacy/sharded paths carry no bound; they are exact, so
                # the vacuous bound (everything certified) is the truth —
                # and it keeps chunk results concatenable
                res = res._replace(upper=np.full(
                    (np.asarray(res.values).shape[0],), -np.inf,
                    np.float32))
            if bud is not None:
                self._note_certificates(req_stats, run_eng.name, bud, res)
            # cost model: learn per-query seconds per (engine, budgeted?)
            key = run_eng.name if bud is None else f"{run_eng.name}@budget"
            prev = self._cost_ewma.get(key)
            per_q = dt / max(n, 1)
            self._cost_ewma[key] = (per_q if prev is None
                                    else 0.8 * prev + 0.2 * per_q)
            # ... and granularly per (engine, batch-bucket, sign) in the
            # shared table the serving router reads (DESIGN.md §13)
            self.cost_table.observe(key, batch_bucket(n), label, per_q)
            self._record(run_eng.name, res, dt, n,
                         info.delta_scored, sign_label=label)
            outs.append(res)
        req_us = 1e6 * (time.perf_counter() - t_admit)
        req_stats.record_request_latency(req_us)
        obs.on_request_done(engine.name, req_us)
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *outs)


class TwoStageRanker:
    """Exact SEP-LR retrieval -> full-model re-rank (DESIGN.md §3).

    retrieval_model: SEP-LR over the candidate catalogue (u = query tower).
    rerank_fn(query_batch, candidate_ids) -> scores of the retrieved set.
    The retrieval engine is addressed by registry name, same as
    :meth:`TopKServer.query`.
    """

    def __init__(self, retrieval: TopKServer,
                 rerank_fn: Callable[[Dict, np.ndarray], np.ndarray],
                 retrieve_n: int = 100):
        self.retrieval = retrieval
        self.rerank_fn = rerank_fn
        self.retrieve_n = retrieve_n

    def rank(self, query_batch: Dict, U: Array, k: int,
             method: str = "bta"):
        get_engine(method)  # fail fast on unknown engine names
        res = self.retrieval.query(U, self.retrieve_n, method=method)
        cand = np.asarray(res.indices)                       # [B, N]
        rerank = self.rerank_fn(query_batch, cand)           # [B, N]
        order = np.argsort(-rerank, axis=1)[:, :k]
        return (np.take_along_axis(cand, order, axis=1),
                np.take_along_axis(rerank, order, axis=1))
