"""Top-K query serving: the paper's inference engine as a service layer.

``TopKServer`` owns a SEP-LR catalogue plus a shared
:class:`repro.core.engines.EngineContext` and serves batched queries
through ANY engine in the registry (``naive`` / ``ta`` / ``bta`` /
``norm`` / ``norm_sharded`` / ``pallas`` / ``fagin`` / ``partial`` /
``auto`` — see ``repro.core.engines``), addressed by registry name; the
context also owns the catalogue LAYOUTS each engine declares
(``repro.core.layout``: contiguous list prefixes for ``ta``/``bta``, the
norm-major tile order for ``norm``/``pallas``, the round-robin-dealt
sharded norm order for ``norm_sharded``), so one server process serves a
multi-device mesh by simply passing ``method="norm_sharded"``. Requests are micro-batched; per-query pruning statistics
(scores computed, depth) are aggregated PER REGISTRY ENGINE for the
benchmark harness — matching the paper's evaluation axis (query
efficiency). ``method="auto"`` resolves per batch via
:func:`repro.core.engines.select_engine`, and its traffic is accounted to
the engine that actually ran.

``TwoStageRanker`` is the production recsys pattern from DESIGN.md §3:
exact SEP-LR top-N retrieval (where the paper's algorithms apply) followed
by full-model re-ranking of the N retrieved candidates (where they don't).

**Streaming mutations** (DESIGN.md §9): the server's catalogue is a
:class:`repro.core.segments.SegmentedCatalogue` — an immutable base
snapshot (the EngineContext every engine runs against) plus a delta
buffer and tombstones. :meth:`TopKServer.add_targets` /
:meth:`delete_targets` / :meth:`update_targets` mutate it without an
index rebuild and without giving up exactness; a threshold-triggered
compaction folds the mutations into a fresh snapshot under a new
version. A never-mutated server serves the identical code path (and the
identical compiled executables) as before the streaming layer existed.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SepLRModel, TopKIndex
from repro.core.engines import (
    Engine,
    EngineContext,
    engine_names,
    get_engine,
    select_engine,
)
from repro.core.segments import SegmentedCatalogue
from repro.core.strategies import sign_bucket_label

Array = jnp.ndarray

#: Ring-buffer length for per-batch latency percentiles: enough batches
#: for stable p99 at serving rates, bounded so a long-lived server never
#: grows its stats footprint.
LATENCY_RING = 512


@dataclasses.dataclass
class ServeStats:
    """Per-engine serving statistics.

    Latency is tracked two ways: the lifetime mean (``us_per_query``,
    exact over every query ever served) and percentiles over a BOUNDED
    ring of recent per-batch latencies (``p50_us``/``p95_us``/``p99_us``
    — each entry is one batch's per-query microseconds, so tail entries
    reflect stragglers like a post-mutation retrace or a compaction
    swap). ``delta_scored`` counts scores spent on the streaming delta
    segments, separating mutation-induced work from base-scan work.
    ``sign_batches`` counts served batches per sign bucket (the compile
    specialisation axis of the batched list scan, DESIGN.md §11) — a
    bucket label appearing here that :meth:`TopKServer.warmup` did not
    warm explains a one-off trace straggler in the latency ring.
    """

    n_queries: int = 0
    n_scored: int = 0
    total_time_s: float = 0.0
    depth_sum: int = 0
    delta_scored: int = 0
    lat_us_ring: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_RING))
    sign_batches: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def scores_per_query(self) -> float:
        return self.n_scored / max(self.n_queries, 1)

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.total_time_s / max(self.n_queries, 1)

    def latency_percentile(self, q: float) -> float:
        """q-th percentile (0-100) of recent per-batch latencies, in us."""
        if not self.lat_us_ring:
            return 0.0
        return float(np.percentile(np.asarray(self.lat_us_ring), q))

    @property
    def p50_us(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95_us(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99_us(self) -> float:
        return self.latency_percentile(99.0)


class TopKServer:
    def __init__(self, model: SepLRModel, max_batch: int = 64,
                 block_size: int = 256, delta_capacity: int = 256,
                 compact_async: bool = False):
        self.model = model
        self.catalogue = SegmentedCatalogue(
            model.targets, delta_capacity=delta_capacity,
            compact_async=compact_async, block_size=block_size)
        self.max_batch = max_batch
        self.block_size = block_size
        self.stats: Dict[str, ServeStats] = {}

    @property
    def ctx(self) -> EngineContext:
        """The CURRENT base snapshot's engine context (compaction swaps
        in a fresh one under the next version — hold :attr:`catalogue`
        if you need a stable reference across mutations)."""
        return self.catalogue.snapshot.ctx

    @property
    def index(self) -> TopKIndex:
        return self.ctx.index

    @property
    def trace_counts(self) -> Dict[str, int]:
        """Engine traces (current snapshot) + segmented-tail traces."""
        return {**self.ctx.trace_counts, **self.catalogue.trace_counts}

    @staticmethod
    def available_engines() -> List[str]:
        """Registry names accepted by :meth:`query`'s ``method=``."""
        return engine_names()

    def warmup(self, k: int, batch_sizes=None, engines=None,
               m_buckets=None) -> "TopKServer":
        """Populate the per-engine compiled-executable cache ahead of
        traffic (DESIGN.md §6/§10). After warmup, same-shape queries hit
        the cache with zero new traces (``self.ctx.trace_counts`` proves
        it).

        **Warmup over M-buckets** (DESIGN.md §10): argument-passing
        executors are traced per CATALOGUE bucket, so this also warms
        ``m_buckets`` — by default the current bucket plus the next one
        (one doubling of headroom). A streaming catalogue that grows
        across its next power-of-two boundary then compacts with ZERO
        engine retraces, exactly like a same-bucket compaction; pass
        more buckets for more growth headroom, or ``(ctx.m_bucket,)``
        to warm only the current size.

        Also warms the streaming layer: the segmented tail is compiled
        for EVERY delta-capacity bucket (DESIGN.md §9), so the first
        query after any insert dispatches cached executables — 0 new
        traces — and records the warm spec so compaction readies each
        replacement snapshot before swapping it in (compile-free for
        warmed buckets).
        """
        sizes = tuple(batch_sizes) if batch_sizes else (1, self.max_batch)
        if m_buckets is None:
            mb = self.ctx.m_bucket
            m_buckets = (mb, 2 * mb)
        self.ctx.warmup(k, batch_sizes=sizes, engines=engines,
                        m_buckets=m_buckets)
        self.catalogue.warm(k, batch_sizes=sizes, engines=engines,
                            m_buckets=m_buckets)
        # compactions renew the headroom iff the boot warmup established
        # any (each build then pre-traces ITS next bucket, keeping every
        # future crossing compile-free, not just the first)
        headroom = any(int(b) > self.ctx.m_bucket for b in m_buckets)
        self.catalogue.set_warm_spec(k, sizes, engines, headroom=headroom)
        return self

    # -- streaming mutations (DESIGN.md §9) ---------------------------------

    def add_targets(self, rows) -> np.ndarray:
        """Stream new items into the catalogue; returns their global ids."""
        return self.catalogue.add_targets(rows)

    def delete_targets(self, gids) -> None:
        """Tombstone items; queries exclude them immediately and exactly."""
        self.catalogue.delete_targets(gids)

    def update_targets(self, gids, rows) -> None:
        """Replace item factors in place (same global ids)."""
        self.catalogue.update_targets(gids, rows)

    @property
    def mutation_stats(self) -> Dict[str, float]:
        """Delta/compaction counters for the bench harness and dashboards."""
        cat = self.catalogue
        return {
            "n_inserts": cat.stats.n_inserts,
            "n_deletes": cat.stats.n_deletes,
            "n_updates": cat.stats.n_updates,
            "n_compactions": cat.stats.n_compactions,
            "n_failed_compactions": cat.stats.n_failed_compactions,
            "max_delta_occupancy": cat.stats.max_delta_occupancy,
            "delta_occupancy": cat.delta_occupancy,
            "n_tombstones": cat.n_tombstones,
            "snapshot_version": cat.version,
            "num_live": cat.num_live,
            # argument-passing contract (DESIGN.md §10): engine traces
            # observed during compaction builds — 0 for compactions whose
            # M-bucket was warmed — and the builds' wall-clock
            "engine_compiles_total": cat.stats.engine_compiles_total,
            "engine_compiles_per_compaction": (
                cat.stats.engine_compiles_total
                / max(cat.stats.n_compactions, 1)),
            "headroom_compiles_total": cat.stats.headroom_compiles_total,
            "compaction_s_total": cat.stats.compaction_s_total,
            "last_compaction_s": cat.stats.last_compaction_s,
        }

    def _record(self, method: str, res, dt: float, n: int,
                delta_scored: int = 0, sign_label: str = ""):
        s = self.stats.setdefault(method, ServeStats())
        s.n_queries += n
        s.n_scored += int(np.sum(np.asarray(res.n_scored)))
        s.depth_sum += int(np.sum(np.asarray(res.depth)))
        s.total_time_s += dt
        s.delta_scored += int(delta_scored) * n
        s.lat_us_ring.append(1e6 * dt / max(n, 1))
        if sign_label:
            s.sign_batches[sign_label] = s.sign_batches.get(sign_label,
                                                            0) + 1

    def query(self, U: Array, k: int, method: str = "bta"):
        """U: [B, R] (or [R]). Returns TopKResult batched like U.

        ``method`` is any registry name (or alias) from
        :meth:`available_engines`; unknown names raise ``ValueError``.
        ``auto`` dispatch reads its sparsity/batch-size statistics from
        the incoming HOST array — engine selection never enqueues work
        on the device query stream. Batch-specialised engines also
        record each chunk's sign bucket in
        :attr:`ServeStats.sign_batches` (the DESIGN.md §11 compile
        axis), again a host-side read of input VALUES only. Once the
        catalogue has streamed mutations, results
        carry GLOBAL item ids and reflect every mutation exactly (the
        segmented query path, DESIGN.md §9); a never-mutated server runs
        the raw engine path unchanged.
        """
        engine: Engine = get_engine(method)
        # Keep the batch wherever the caller had it: host inputs are
        # sliced and dispatched as numpy (auto's nnz statistic never
        # touches the device), device-resident inputs stay on device with
        # no round-trip (select_engine reads them back once per chunk
        # only when method="auto").
        if isinstance(U, jax.Array):
            U_all = jnp.atleast_2d(U)
        else:
            U_all = np.atleast_2d(np.asarray(U, np.float32))
        outs = []
        for i in range(0, U_all.shape[0], self.max_batch):
            chunk = U_all[i: i + self.max_batch]
            eng = (select_engine(self.ctx, chunk)
                   if engine.name == "auto" else engine)
            # sign bucket of this chunk, for the per-bucket serve stats —
            # only engines with batch specialisation pay the (host-side,
            # input-value-only) read; it mirrors the bucket the dispatch
            # itself computes for the compile key (DESIGN.md §11)
            label = sign_bucket_label(eng.batch_config(self.ctx, chunk)) \
                if eng.batch_config is not None else ""
            t0 = time.perf_counter()
            res, info = self.catalogue.query(eng, chunk, k)
            res = jax.tree_util.tree_map(np.asarray, res)
            dt = time.perf_counter() - t0
            self._record(eng.name, res, dt, chunk.shape[0],
                         info.delta_scored, sign_label=label)
            outs.append(res)
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *outs)


class TwoStageRanker:
    """Exact SEP-LR retrieval -> full-model re-rank (DESIGN.md §3).

    retrieval_model: SEP-LR over the candidate catalogue (u = query tower).
    rerank_fn(query_batch, candidate_ids) -> scores of the retrieved set.
    The retrieval engine is addressed by registry name, same as
    :meth:`TopKServer.query`.
    """

    def __init__(self, retrieval: TopKServer,
                 rerank_fn: Callable[[Dict, np.ndarray], np.ndarray],
                 retrieve_n: int = 100):
        self.retrieval = retrieval
        self.rerank_fn = rerank_fn
        self.retrieve_n = retrieve_n

    def rank(self, query_batch: Dict, U: Array, k: int,
             method: str = "bta"):
        get_engine(method)  # fail fast on unknown engine names
        res = self.retrieval.query(U, self.retrieve_n, method=method)
        cand = np.asarray(res.indices)                       # [B, N]
        rerank = self.rerank_fn(query_batch, cand)           # [B, N]
        order = np.argsort(-rerank, axis=1)[:, :k]
        return (np.take_along_axis(cand, order, axis=1),
                np.take_along_axis(rerank, order, axis=1))
