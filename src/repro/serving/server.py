"""Top-K query serving: the paper's inference engine as a service layer.

``TopKServer`` owns a SEP-LR catalogue + its sorted-list index and serves
batched queries through any of the exact engines (naive / TA / BTA /
norm-pruned / sharded). Requests are micro-batched; per-query pruning
statistics (scores computed, depth) are aggregated for the benchmark
harness — matching the paper's evaluation axis (query efficiency).

``TwoStageRanker`` is the production recsys pattern from DESIGN.md §3:
exact SEP-LR top-N retrieval (where the paper's algorithms apply) followed
by full-model re-ranking of the N retrieved candidates (where they don't).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SepLRModel,
    TopKIndex,
    blocked_topk_batched,
    build_index,
    naive_topk,
    norm_pruned_topk,
)

Array = jnp.ndarray


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    n_scored: int = 0
    total_time_s: float = 0.0
    depth_sum: int = 0

    @property
    def scores_per_query(self) -> float:
        return self.n_scored / max(self.n_queries, 1)

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.total_time_s / max(self.n_queries, 1)


class TopKServer:
    def __init__(self, model: SepLRModel, max_batch: int = 64,
                 block_size: int = 256):
        self.model = model
        self.index: TopKIndex = build_index(model.targets)
        self.max_batch = max_batch
        self.block_size = block_size
        self.stats: Dict[str, ServeStats] = {}

    def _record(self, method: str, res, dt: float, n: int):
        s = self.stats.setdefault(method, ServeStats())
        s.n_queries += n
        s.n_scored += int(np.sum(np.asarray(res.n_scored)))
        s.depth_sum += int(np.sum(np.asarray(res.depth)))
        s.total_time_s += dt

    def query(self, U: Array, k: int, method: str = "bta"):
        """U: [B, R] (or [R]). Returns TopKResult batched like U."""
        U = jnp.atleast_2d(U)
        outs = []
        t0 = time.perf_counter()
        for i in range(0, U.shape[0], self.max_batch):
            chunk = U[i: i + self.max_batch]
            if method == "naive":
                res = naive_topk(self.model.targets, chunk, k)
            elif method == "bta":
                res = blocked_topk_batched(self.model.targets, self.index,
                                           chunk, k, self.block_size)
            elif method == "norm":
                res = jax.vmap(
                    lambda u: norm_pruned_topk(
                        self.model.targets, self.index.norm_order,
                        self.index.norms_sorted, u, k, self.block_size)
                )(chunk)
            else:
                raise ValueError(method)
            outs.append(jax.tree_util.tree_map(np.asarray, res))
        dt = time.perf_counter() - t0
        res = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *outs)
        self._record(method, res, dt, U.shape[0])
        return res


class TwoStageRanker:
    """Exact SEP-LR retrieval -> full-model re-rank (DESIGN.md §3).

    retrieval_model: SEP-LR over the candidate catalogue (u = query tower).
    rerank_fn(query_batch, candidate_ids) -> scores of the retrieved set.
    """

    def __init__(self, retrieval: TopKServer,
                 rerank_fn: Callable[[Dict, np.ndarray], np.ndarray],
                 retrieve_n: int = 100):
        self.retrieval = retrieval
        self.rerank_fn = rerank_fn
        self.retrieve_n = retrieve_n

    def rank(self, query_batch: Dict, U: Array, k: int,
             method: str = "bta"):
        res = self.retrieval.query(U, self.retrieve_n, method=method)
        cand = np.asarray(res.indices)                       # [B, N]
        rerank = self.rerank_fn(query_batch, cand)           # [B, N]
        order = np.argsort(-rerank, axis=1)[:, :k]
        return (np.take_along_axis(cand, order, axis=1),
                np.take_along_axis(rerank, order, axis=1))
