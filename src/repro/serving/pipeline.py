"""Async micro-batching serving pipeline (DESIGN.md §13).

The batched-native scans (DESIGN.md §11) are 5-11x cheaper per query at
B >= 8 — but only when someone HANDS the server a large batch. This
module manufactures those batches from independent request traffic:

``AsyncTopKServer`` wraps a :class:`repro.serving.server.TopKServer`
with

* a thread-safe request queue that COALESCES arrivals into the power-
  of-two batch buckets the compile cache already keys on. A request
  waits at most its flush deadline (``flush_ms``, capped at half its
  remaining admission-deadline headroom) before its partial bucket
  dispatches — and does not wait AT ALL while the device pipeline is
  idle, so the p99 at low offered load stays a single-query scan, not
  a single-query scan plus ``flush_ms``;
* a two-stage pipeline that overlaps HOST work (queue pop, cache
  probe, sign-bucketing, batch assembly, result unpadding) with the
  DEVICE scan of the previous micro-batch: the dispatcher thread fires
  ``catalogue.query`` and moves on — jax's async dispatch returns
  device futures — while the harvester thread is the only place that
  calls ``np.asarray``/``block_until_ready``. A bounded harvest queue
  (``pipeline_depth``) back-pressures the dispatcher so at most that
  many micro-batches are ever in flight;
* MEASURED-COST dispatch: engine choice per micro-batch comes from the
  shared :class:`repro.core.engines.CostTable` (one timed run per
  warmed (engine, bucket, sign) config primes it; serving keeps it
  fresh) through :func:`repro.core.engines.select_engine` — the PR-7
  EWMA generalised from a degradation-ladder input into the primary
  router. The nnz heuristic remains only as the cold fallback;
* a head-query RESULT CACHE keyed ``(query bytes, k, cache token)``
  where the token is the catalogue's ``(snapshot version, mutation
  epoch)`` pair captured BEFORE the scan dispatches. Any visible
  mutation changes the token, so a cached entry can only ever be
  served while the catalogue contents it was computed against are
  still the visible contents — compaction/tombstone events additionally
  fire an invalidation listener that empties the cache outright.

PR-7 semantics are preserved: the admission/deadline ladder
(:class:`repro.serving.server.AdmissionPolicy`) runs at DISPATCH time
per micro-batch against the batch's tightest remaining deadline, every
served result is exact or carries its certificate (a shed batch returns
the explicit sentinel, never a silent partial answer), and queue-formed
buckets only dispatch warmed (bucket, sign, engine) configs — zero
engine compiles across compactions, pinned by tests/test_pipeline.py.
"""

from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.core import SepLRModel
from repro.core.engines import (
    auto_candidates,
    batch_bucket,
    get_engine,
    select_engine,
)
from repro.core.naive import TopKResult
from repro.core.strategies import sign_bucket_label
from repro.serving.server import AdmissionPolicy, ServeStats, TopKServer

#: default time a request may sit in a partial bucket before it flushes
DEFAULT_FLUSH_MS = 2.0
#: micro-batches in flight (dispatched, not yet harvested) before the
#: dispatcher blocks — stage overlap needs 2; more only adds queue delay
DEFAULT_PIPELINE_DEPTH = 2
#: result-cache entries kept (LRU); one entry is one (query, k) row
DEFAULT_CACHE_CAPACITY = 4096


class ResultCache:
    """LRU cache of per-request exact results, token-scoped.

    Keys are ``(query bytes, k, token)`` with ``token`` the catalogue's
    ``(version, epoch)`` :meth:`~repro.core.segments.SegmentedCatalogue.
    cache_token` captured before the scan that produced the value was
    dispatched. Because every visible mutation changes the token, a
    lookup under the CURRENT token can only hit entries whose contents
    are the current contents — the cache cannot serve across a snapshot
    version bump (or a delta append, which bumps the epoch half). The
    catalogue's invalidation listener additionally calls
    :meth:`invalidate` so dead-token entries do not linger in memory.

    Thread-safe; only EXACT results are inserted (a degraded or
    budgeted answer is a statement about one moment's load, not about
    the query).
    """

    def __init__(self, capacity: int = DEFAULT_CACHE_CAPACITY):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._data: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.n_invalidations = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def lookup(self, key: tuple) -> Optional[tuple]:
        with self._lock:
            row = self._data.get(key)
            if row is None:
                self.misses += 1
            else:
                self._data.move_to_end(key)
                self.hits += 1
        obs.on_cache_lookup(row is not None)
        return row

    def insert(self, key: tuple, row: tuple) -> None:
        with self._lock:
            self._data[key] = row
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def invalidate(self) -> None:
        """Drop everything. Runs as the catalogue's invalidation
        listener — possibly under the catalogue lock (synchronous
        compaction), so it must not call back into the catalogue.
        (The obs journal emission below holds only the journal's own
        lock, so it keeps that guarantee.)"""
        with self._lock:
            self._data.clear()
            self.n_invalidations += 1
        obs.on_cache_invalidated()


class _Request:
    """One submitted query riding the pipeline."""

    __slots__ = ("u", "k", "method", "budget", "deadline_s", "t_enqueue",
                 "flush_by", "event", "row", "error", "trace")

    def __init__(self, u: np.ndarray, k: int, method: str,
                 budget: Optional[int], deadline_ms: Optional[float],
                 flush_ms: float):
        now = time.perf_counter()
        self.u = u
        self.k = int(k)
        self.method = method
        self.budget = budget
        self.deadline_s = (None if deadline_ms is None
                           else now + float(deadline_ms) / 1e3)
        self.t_enqueue = now
        # a deadline halves the coalescing allowance: the request must
        # keep headroom to actually RUN after its flush fires
        wait = flush_ms / 1e3
        if deadline_ms is not None:
            wait = min(wait, 0.5 * float(deadline_ms) / 1e3)
        self.flush_by = now + wait
        self.event = threading.Event()
        self.row: Optional[tuple] = None
        self.error: Optional[BaseException] = None
        #: sampled obs trace (a :class:`repro.obs.Trace`) or None — set
        #: by submit(); stage threads stamp spans onto it as the request
        #: rides the pipeline
        self.trace = None

    def fulfill(self, row: tuple) -> None:
        self.row = row
        self.event.set()

    def fail(self, exc: BaseException) -> None:
        self.error = exc
        self.event.set()


class PendingResult:
    """Handle returned by :meth:`AsyncTopKServer.submit`."""

    def __init__(self, req: _Request):
        self._req = req

    def done(self) -> bool:
        return self._req.event.is_set()

    def result(self, timeout: Optional[float] = None) -> TopKResult:
        """Block until the request completes; returns a ``[1, k]``
        batched :class:`TopKResult` (same shape contract as
        ``TopKServer.query`` on a single query)."""
        if not self._req.event.wait(timeout):
            raise TimeoutError("result not ready within timeout")
        if self._req.error is not None:
            raise self._req.error
        vals, ids, nsc, depth, upper = self._req.row
        return TopKResult(vals[None], ids[None], nsc[None], depth[None],
                          upper=upper[None])


class PipelineStats:
    """Counters for the queue/pipeline layer (engine-level serve stats
    stay on :attr:`AsyncTopKServer.stats`, per requested method)."""

    def __init__(self) -> None:
        self.n_requests = 0
        self.n_batches = 0
        self.n_cached = 0
        self.n_shed = 0
        #: dispatched micro-batch sizes, keyed by EXACT coalesced size
        #: (the bucket it padded into is ``batch_bucket(size)``)
        self.batch_size_hist: Dict[int, int] = {}

    @property
    def mean_batch_size(self) -> float:
        n = sum(self.batch_size_hist.values())
        tot = sum(b * c for b, c in self.batch_size_hist.items())
        return tot / max(n, 1)

    def as_dict(self) -> Dict[str, object]:
        return {
            "n_requests": self.n_requests,
            "n_batches": self.n_batches,
            "n_cached": self.n_cached,
            "n_shed": self.n_shed,
            "mean_batch_size": self.mean_batch_size,
            "batch_size_hist": {str(kk): v for kk, v
                                in sorted(self.batch_size_hist.items())},
        }


class AsyncTopKServer:
    """Micro-batching front-end over :class:`TopKServer` (see module
    docstring for the design; DESIGN.md §13 for the contracts).

    Use as a context manager or call :meth:`close` — two daemon threads
    (dispatcher, harvester) run between :meth:`start` and then.

    ``method="auto"`` (the default) is the measured-cost router; any
    explicit registry name pins the engine exactly like the synchronous
    server. ``flush_ms`` bounds coalescing delay; ``pipeline_depth``
    bounds in-flight micro-batches (2 = classic double buffering).
    """

    def __init__(self, model: SepLRModel, max_batch: int = 64,
                 flush_ms: float = DEFAULT_FLUSH_MS,
                 pipeline_depth: int = DEFAULT_PIPELINE_DEPTH,
                 cache_capacity: int = DEFAULT_CACHE_CAPACITY,
                 method: str = "auto",
                 block_size: int = 256, delta_capacity: int = 256,
                 compact_async: bool = False,
                 policy: Optional[AdmissionPolicy] = None,
                 n_shards: int = 0, l1_capacity: Optional[int] = None,
                 cost_table=None):
        # n_shards > 0 fronts the async pipeline with the sharded LSM
        # ladder; cost_table accepts a pre-measured CostTable.load so a
        # restarted pipeline routes before its first observation
        self.server = TopKServer(model, max_batch=max_batch,
                                 block_size=block_size,
                                 delta_capacity=delta_capacity,
                                 compact_async=compact_async,
                                 policy=policy, n_shards=n_shards,
                                 l1_capacity=l1_capacity,
                                 cost_table=cost_table)
        self.max_batch = batch_bucket(max(int(max_batch), 1))
        self.flush_ms = float(flush_ms)
        self.pipeline_depth = max(int(pipeline_depth), 1)
        self.method = method
        get_engine(method)                    # fail fast on unknown names
        self.cache = ResultCache(cache_capacity)
        self.server.catalogue.add_invalidation_listener(
            self.cache.invalidate)
        self.pipeline_stats = PipelineStats()
        self._queue: collections.deque = collections.deque()
        self._cond = threading.Condition()
        self._harvest: "queue.Queue" = queue.Queue(
            maxsize=self.pipeline_depth)
        self._inflight_batches = 0
        self._stop = False
        self._dispatcher: Optional[threading.Thread] = None
        self._harvester: Optional[threading.Thread] = None

    # -- delegation ----------------------------------------------------------

    @property
    def catalogue(self):
        return self.server.catalogue

    @property
    def ctx(self):
        return self.server.ctx

    @property
    def stats(self) -> Dict[str, ServeStats]:
        return self.server.stats

    @property
    def cost_table(self):
        return self.server.cost_table

    @property
    def trace_counts(self) -> Dict[str, int]:
        return self.server.trace_counts

    @property
    def mutation_stats(self) -> Dict[str, float]:
        return self.server.mutation_stats

    def add_targets(self, rows) -> np.ndarray:
        return self.server.add_targets(rows)

    def delete_targets(self, gids) -> None:
        self.server.delete_targets(gids)

    def update_targets(self, gids, rows) -> None:
        self.server.update_targets(gids, rows)

    def warmup(self, k: int, batch_sizes=None, engines=None,
               m_buckets=None, budgets=None) -> "AsyncTopKServer":
        """Warm EVERY power-of-two bucket up to ``max_batch`` (plus any
        explicit ``batch_sizes``): queue-formed micro-batches land in
        whatever bucket the traffic produced — a half-full flush at
        B=13 pads into bucket 16 — so the async zero-compile guarantee
        needs the full ladder warmed, not just the endpoints the
        synchronous server warms. Each warmed (engine, bucket, sign)
        config also gets one timed run into the shared cost table
        (:meth:`repro.core.engines.EngineContext.warmup`), which is what
        arms the measured-cost router before the first real query.

        ``engines=None`` warms exactly the engines this pipeline can
        DISPATCH — the auto-router candidates, the pinned ``method``,
        and the ladder's ``norm`` fallback — not the whole registry:
        the compaction readiness pass replays this warm set on every
        new snapshot, and warming a per-context (closure-compiled)
        engine there would charge its unavoidable retrace to every
        compaction, breaking the zero-compile guarantee for engines the
        queue never dispatches anyway."""
        sizes = {1 << i for i in range(self.max_batch.bit_length())
                 if (1 << i) <= self.max_batch}
        sizes.add(self.max_batch)
        if batch_sizes:
            sizes.update(batch_bucket(int(b)) for b in batch_sizes)
        if engines is None:
            engines = sorted({*auto_candidates(), "norm"}
                             | ({self.method} - {"auto"}))
        self.server.warmup(k, batch_sizes=tuple(sorted(sizes)),
                           engines=engines, m_buckets=m_buckets,
                           budgets=budgets)
        return self

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "AsyncTopKServer":
        if self._dispatcher is not None:
            return self
        self._stop = False
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="topk-dispatch", daemon=True)
        self._harvester = threading.Thread(
            target=self._harvest_loop, name="topk-harvest", daemon=True)
        self._dispatcher.start()
        self._harvester.start()
        return self

    def close(self) -> None:
        """Drain and stop both pipeline threads (idempotent)."""
        if self._dispatcher is None:
            return
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._dispatcher.join()
        self._harvest.put(None)
        self._harvester.join()
        self._dispatcher = None
        self._harvester = None

    def __enter__(self) -> "AsyncTopKServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ----------------------------------------------------------

    def submit(self, u, k: int, method: Optional[str] = None,
               budget: Optional[int] = None,
               deadline_ms: Optional[float] = None) -> PendingResult:
        """Enqueue ONE query ``u`` ([R]); returns immediately with a
        :class:`PendingResult`. Validation failures raise here, in the
        caller's thread, not on the pipeline."""
        if self._dispatcher is None:
            raise RuntimeError("AsyncTopKServer not started "
                               "(use `with server:` or .start())")
        if int(k) <= 0:
            raise ValueError(f"k must be a positive int, got {k!r}")
        if budget is not None and int(budget) <= 0:
            raise ValueError(
                f"budget must be a positive int or None, got {budget!r}")
        if deadline_ms is not None and float(deadline_ms) < 0:
            raise ValueError(
                f"deadline_ms must be >= 0 or None, got {deadline_ms!r}")
        row = np.ascontiguousarray(np.asarray(u, np.float32)).reshape(-1)
        rank = self.catalogue.rank
        if row.shape[0] != rank:
            raise ValueError(
                f"query rank {row.shape[0]} != catalogue rank {rank}")
        if not np.all(np.isfinite(row)):
            raise ValueError("query contains NaN/Inf values")
        m = method if method is not None else self.method
        get_engine(m)
        if deadline_ms is None:
            deadline_ms = self.server.policy.deadline_ms
        req = _Request(row, int(k), m, budget, deadline_ms, self.flush_ms)
        # sampled full-span tracing (cheap counters stay always-on);
        # start is the enqueue timestamp so queue wait is span 1
        req.trace = obs.TRACER.start_trace(
            "topk.request", start=req.t_enqueue, k=int(k), method=m,
            budget=budget if budget is None else int(budget))
        with self._cond:
            self._queue.append(req)
            self.pipeline_stats.n_requests += 1
            self._cond.notify_all()
        return PendingResult(req)

    def query(self, U, k: int, method: Optional[str] = None,
              budget: Optional[int] = None,
              deadline_ms: Optional[float] = None) -> TopKResult:
        """Synchronous convenience: submit every row of ``U`` as an
        independent request and block for the batched result. Rows may
        coalesce with each other AND with concurrent submitters."""
        U2 = np.atleast_2d(np.asarray(U, np.float32))
        handles = [self.submit(U2[i], k, method=method, budget=budget,
                               deadline_ms=deadline_ms)
                   for i in range(U2.shape[0])]
        outs = [h.result() for h in handles]
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *outs)

    # -- stage 1: the dispatcher (host side) ---------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                while not self._stop and not self._flushable_locked():
                    self._cond.wait(self._wait_s_locked())
                if self._stop and not self._queue:
                    return
                batch = self._form_batch_locked()
            if batch:
                try:
                    self._dispatch_batch(batch)
                except BaseException as exc:   # noqa: BLE001 — relayed
                    for r in batch:
                        r.fail(exc)

    def _flushable_locked(self) -> bool:
        """Head-of-queue flush test (lock held): fire when the pipeline
        is IDLE (coalescing would trade latency for nothing), when a
        full bucket is waiting, or when the oldest request's flush
        deadline has passed."""
        if not self._queue:
            return False
        if self._inflight_batches == 0:
            return True
        if len(self._queue) >= self.max_batch:
            return True
        return time.perf_counter() >= self._queue[0].flush_by

    def _wait_s_locked(self) -> Optional[float]:
        if not self._queue:
            return None
        return max(self._queue[0].flush_by - time.perf_counter(), 0.0)

    def _form_batch_locked(self) -> List[_Request]:
        """Pop the head request plus every queued COMPATIBLE request —
        same (k, method, budget), the static axes of one engine dispatch
        — preserving arrival order, up to ``max_batch``."""
        if not self._queue:
            return []
        head = self._queue[0]
        sig = (head.k, head.method, head.budget)
        batch, keep = [], collections.deque()
        while self._queue:
            r = self._queue.popleft()
            if len(batch) < self.max_batch \
                    and (r.k, r.method, r.budget) == sig:
                batch.append(r)
            else:
                keep.append(r)
        self._queue = keep
        return batch

    def _dispatch_batch(self, batch: List[_Request]) -> None:
        """Host stage for one micro-batch: cache probe, admission
        ladder, batch assembly, sign-bucketing — then fire the device
        scan WITHOUT waiting on it and hand the futures to the
        harvester. Runs concurrently with the device scan of the
        previous micro-batch."""
        srv = self.server
        t_pop = time.perf_counter()
        k, method = batch[0].k, batch[0].method
        budget = batch[0].budget
        req_name = get_engine(method).name
        # the token is captured BEFORE the scan dispatches: a mutation
        # landing mid-scan bumps the live token, so whatever this scan
        # returns is inserted under a token no future lookup can match
        token = self.catalogue.cache_token()
        misses: List[_Request] = []
        for r in batch:
            obs.on_queue_wait(1e6 * (t_pop - r.t_enqueue))
            row = (None if budget is not None
                   else self.cache.lookup((r.u.tobytes(), r.k, token)))
            if row is not None:
                self.pipeline_stats.n_cached += 1
                if r.trace is not None:
                    r.trace.span("queue_wait", start=r.t_enqueue,
                                 end=t_pop)
                    r.trace.span("cache_hit", start=t_pop,
                                 version=token[0], epoch=token[1])
                self._finish_request(r, method, row)
            else:
                misses.append(r)
        if not misses:
            return
        n = len(misses)
        obs.on_batch_formed(n)
        U = np.stack([r.u for r in misses])
        t_asm = time.perf_counter()
        req_stats = srv.stats.setdefault(req_name, ServeStats())
        eng = (select_engine(self.ctx, U) if method == "auto"
               else get_engine(method))
        # admission at dispatch time (PR-7 ladder, per micro-batch):
        # judged against the TIGHTEST deadline riding in the batch
        deadlines = [r.deadline_s for r in misses
                     if r.deadline_s is not None]
        remaining = (min(deadlines) - time.perf_counter()
                     if deadlines else None)
        run_eng, bud, rung = srv._admit(eng, n, remaining)
        t_route = time.perf_counter()
        if rung != "full":
            req_stats.bump_degradation(rung)
            obs.on_degradation(req_name, rung)
        if run_eng is None:
            res = srv._shed_result(n, k)
            req_stats.note_uncertified(n)
            obs.on_uncertified(req_name, n)
            self.pipeline_stats.n_shed += n
            for r in misses:
                if r.trace is not None:
                    r.trace.span("queue_wait", start=r.t_enqueue,
                                 end=t_pop)
                    r.trace.span("route", start=t_asm, end=t_route,
                                 rung=rung)
            self._fulfill(misses, method, res, cache_token=None)
            self.pipeline_stats.n_batches += 1
            self.pipeline_stats.batch_size_hist[n] = \
                self.pipeline_stats.batch_size_hist.get(n, 0) + 1
            return
        if bud is None:
            bud = budget
        label = (sign_bucket_label(run_eng.batch_config(self.ctx, U))
                 if run_eng.batch_config is not None else "")
        # span annotations are assembled once per batch, only when at
        # least one rider is traced (sampling keeps this off the common
        # path): the cost-table entry the router consulted plus the
        # stage timestamps the harvester turns into child spans
        tinfo = None
        if any(r.trace is not None for r in misses):
            bucket = batch_bucket(n)
            key = run_eng.name if bud is None else f"{run_eng.name}@budget"
            pred = srv.cost_table.predict(key, bucket, label)
            tinfo = {
                "t_pop": t_pop, "t_asm": t_asm, "t_route": t_route,
                "engine": run_eng.name, "rung": rung,
                "cost_entry": f"{key}|{bucket}|{label}",
                "predicted_us": (None if pred is None else 1e6 * pred),
                "sign": label, "batch_size": n,
                "version": token[0], "epoch": token[1],
            }
        t0 = time.perf_counter()
        res, info = self.catalogue.query(run_eng, U, k, budget=bud)
        # NO np.asarray here: the result is a device future; blocking is
        # the harvester's job. This put() back-pressures the dispatcher
        # once `pipeline_depth` micro-batches are unharvested.
        with self._cond:
            self._inflight_batches += 1
        self.pipeline_stats.n_batches += 1
        self.pipeline_stats.batch_size_hist[n] = \
            self.pipeline_stats.batch_size_hist.get(n, 0) + 1
        self._harvest.put((misses, method, run_eng, bud, rung, label,
                           res, info, t0, token, tinfo))

    # -- stage 2: the harvester (device sync side) ---------------------------

    def _harvest_loop(self) -> None:
        while True:
            item = self._harvest.get()
            if item is None:
                return
            (misses, method, run_eng, bud, rung, label,
             res, info, t0, token, tinfo) = item
            try:
                res = jax.tree_util.tree_map(np.asarray, res)  # blocks
                t_harvested = time.perf_counter()
                dt = t_harvested - t0
                n = len(misses)
                if res.upper is None:
                    res = res._replace(upper=np.full(
                        (np.asarray(res.values).shape[0],), -np.inf,
                        np.float32))
                req_stats = self.stats.setdefault(
                    get_engine(method).name, ServeStats())
                if bud is not None:
                    self.server._note_certificates(
                        req_stats, run_eng.name, bud, res)
                key = (run_eng.name if bud is None
                       else f"{run_eng.name}@budget")
                per_q = dt / max(n, 1)
                prev = self.server._cost_ewma.get(key)
                self.server._cost_ewma[key] = (
                    per_q if prev is None else 0.8 * prev + 0.2 * per_q)
                self.cost_table.observe(key, batch_bucket(n), label, per_q)
                self.server._record(run_eng.name, res, dt, n,
                                    info.delta_scored, sign_label=label)
                if tinfo is not None:
                    t_done = time.perf_counter()
                    for r in misses:
                        if r.trace is None:
                            continue
                        r.trace.root.set(engine=tinfo["engine"],
                                         version=tinfo["version"],
                                         epoch=tinfo["epoch"])
                        r.trace.span("queue_wait", start=r.t_enqueue,
                                     end=tinfo["t_pop"])
                        r.trace.span("coalesce", start=tinfo["t_pop"],
                                     end=tinfo["t_asm"],
                                     batch_size=tinfo["batch_size"])
                        r.trace.span("route", start=tinfo["t_asm"],
                                     end=tinfo["t_route"],
                                     engine=tinfo["engine"],
                                     rung=tinfo["rung"],
                                     cost_entry=tinfo["cost_entry"],
                                     predicted_us=tinfo["predicted_us"])
                        r.trace.span("dispatch", start=tinfo["t_route"],
                                     end=t0)
                        r.trace.span("device", start=t0,
                                     end=t_harvested,
                                     engine=tinfo["engine"],
                                     sign=tinfo["sign"],
                                     version=tinfo["version"],
                                     epoch=tinfo["epoch"])
                        r.trace.span("harvest", start=t_harvested,
                                     end=t_done)
                # only the EXACT path populates the cache (bud is the
                # effective budget: a ladder downgrade never caches)
                self._fulfill(misses, method, res,
                              cache_token=None if bud is not None
                              else token)
            except BaseException as exc:       # noqa: BLE001 — relayed
                for r in misses:
                    r.fail(exc)
            finally:
                with self._cond:
                    self._inflight_batches -= 1
                    self._cond.notify_all()

    def _fulfill(self, batch: List[_Request], method: str,
                 res: TopKResult, cache_token: Optional[tuple]) -> None:
        """Unpad a batched result into per-request rows, fulfil the
        futures, and (exact results only) populate the cache."""
        vals = np.asarray(res.values)
        ids = np.asarray(res.indices)
        nsc = np.asarray(res.n_scored)
        depth = np.asarray(res.depth)
        upper = (np.full((vals.shape[0],), -np.inf, np.float32)
                 if res.upper is None else np.asarray(res.upper))
        t_merge = time.perf_counter()
        for i, r in enumerate(batch):
            row = (vals[i], ids[i], nsc[i], depth[i], upper[i])
            if cache_token is not None:
                self.cache.insert((r.u.tobytes(), r.k, cache_token), row)
            if r.trace is not None:
                r.trace.span("merge", start=t_merge)
            self._finish_request(r, method, row)

    def _finish_request(self, r: _Request, method: str,
                        row: tuple) -> None:
        name = get_engine(method).name
        stats = self.stats.setdefault(name, ServeStats())
        us = 1e6 * (time.perf_counter() - r.t_enqueue)
        stats.record_request_latency(us)
        obs.on_request_done(name, us)
        if r.trace is not None:
            r.trace.finish()
            # drop the request's reference: callers hold the
            # PendingResult (hence the _Request) for as long as they
            # like, and at high sample rates retaining every span tree
            # through it is real GC pressure — finished traces live
            # only in the tracer's bounded store
            r.trace = None
        r.fulfill(row)
