"""Sorted-list index for TA / Fagin / BTA.

The paper's algorithms consume R sorted lists L_1..L_R, where L_r orders the
catalogue by t_r(y) descending. The lists are query-independent (built once,
``O(R M log M)``) except for their *direction*: a negative query weight
``u_r(x) < 0`` walks list r ascending instead of descending (paper Section 2,
sign-transfer argument). We therefore store the descending order and flip
per-query with an O(1) view change.

On top of the paper's index we add a norm-ordered block index used by the
TPU-native blocked kernel: items permuted by decreasing L2 norm with a
per-block max norm so that the Cauchy-Schwarz bound
``s(x, y) <= ||u|| * max_norm(block)`` prunes whole blocks.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TopKIndex:
    """Pre-sorted per-dimension lists plus norm-block metadata.

    Attributes:
      order_desc: ``[R, M]`` int32 — item ids sorted by t_r descending.
      t_sorted_desc: ``[R, M]`` — ``T[order_desc[r], r]`` (bound lookups
        without a gather).
      rank_desc: ``[R, M]`` int32 — inverse permutations of ``order_desc``
        (``rank_desc[r, order_desc[r, d]] == d``). These are the per-list
        cursors the blocked strategies use to answer "is this slot the
        first enumeration of its item?" by pure arithmetic instead of an
        O(M) visited bitmap carried through the scan loop (DESIGN.md §6).
      norm_order: ``[M]`` int32 — item ids by decreasing L2 norm.
      norms_sorted: ``[M]`` — norms in that order.
      targets_by_norm: ``[M, R]`` — the catalogue permuted into
        decreasing-norm order, so a norm block is a contiguous slice (the
        Pallas kernel's DMA layout, reused by the XLA norm engine).
    """

    order_desc: Array
    t_sorted_desc: Array
    rank_desc: Array
    norm_order: Array
    norms_sorted: Array
    targets_by_norm: Array

    @property
    def num_targets(self) -> int:
        return int(self.order_desc.shape[1])

    @property
    def rank(self) -> int:
        return int(self.order_desc.shape[0])

    def query_views(self, u: Array):
        """Per-query list direction: dimension r walks ASCENDING when
        ``u_r < 0``.

        Returns ``(order_desc, t_sorted_desc, neg)`` where ``neg`` is the
        ``[R]`` bool direction flag. The strategies resolve the direction
        by INDEX ARITHMETIC (walk position d reads column ``M-1-d`` when
        ``neg[r]``) — no ``[R, M]`` flipped copies of either array are
        materialised per query (they used to be, via ``jnp.where`` over
        the full index: two O(R*M) copies on every negative-weight
        query).
        """
        return self.order_desc, self.t_sorted_desc, u < 0


def build_index(T) -> TopKIndex:
    """Build the sorted-list index (offline, ``O(R M log M)``)."""
    T_np = np.asarray(T)
    M, R = T_np.shape
    # stable descending sort; ties broken by lower item id first (the
    # paper's Table 1 list convention).
    order_desc = np.argsort(-T_np, axis=0, kind="stable").T.astype(np.int32)  # [R, M]
    t_sorted_desc = np.take_along_axis(T_np.T, order_desc, axis=1)  # [R, M]
    rank_desc = np.empty_like(order_desc)
    np.put_along_axis(rank_desc, order_desc,
                      np.broadcast_to(np.arange(M, dtype=np.int32), (R, M)),
                      axis=1)
    norms = np.linalg.norm(T_np, axis=1)
    norm_order = np.argsort(-norms, kind="stable").astype(np.int32)
    return TopKIndex(
        order_desc=jnp.asarray(np.ascontiguousarray(order_desc)),
        t_sorted_desc=jnp.asarray(np.ascontiguousarray(t_sorted_desc.astype(np.float32))),
        rank_desc=jnp.asarray(np.ascontiguousarray(rank_desc)),
        norm_order=jnp.asarray(norm_order),
        norms_sorted=jnp.asarray(norms[norm_order].astype(np.float32)),
        targets_by_norm=jnp.asarray(
            np.ascontiguousarray(T_np[norm_order].astype(np.float32))),
    )
