"""Catalogue layouts: how the catalogue is materialised in memory (DESIGN.md §7).

The paper's algorithms are *enumeration orders* (per-dimension sorted
lists, decreasing-norm blocks); what makes them fast or slow on real
hardware is the MEMORY LAYOUT those orders read through. PR 2 left the
list engines gather-bound — every TA/BTA step fetched ``R * B`` scattered
catalogue rows — while the norm engine's contiguous ``targets_by_norm``
tiles made it the wall-clock winner. This module makes the layout a
first-class, swappable object so every engine *declares* the layout it
consumes (``Engine.layout``) and :class:`repro.core.engines.EngineContext`
builds and caches layouts lazily, exactly like the sorted-list index.

Three single-host layouts plus one sharded layout:

``row_major``
    The catalogue as given — ``targets[ids]`` gathers. The naive engine's
    layout, and every other layout's fallback.

``norm_major``
    The decreasing-L2-norm permutation (``targets_by_norm``): a norm
    block is a contiguous ``[block, R]`` slice — the Pallas kernel's DMA
    layout, shared with the XLA norm engine.

``list_major``
    Per-dimension list PREFIXES materialised contiguously: for every
    dimension r, the catalogue rows in ``order_desc[r]`` order up to a
    configurable prefix depth P — ``head_rows[R, P, R]`` — plus the same
    for the ASCENDING walk (``tail_rows``, what a negative query weight
    reads), the walk-order ids, and the transposed inverse permutations
    ``rank_by_item[M, R]``. In the hot prefix, where virtually every scan
    terminates, TA/BTA read contiguous ``[block, R]`` tiles instead of
    scattered gathers — and per-query freshness needs only an
    ``O(R * P)`` scatter instead of the old ``O(R * M)`` key precompute.
    Past the prefix the strategies fall back to gathers (rows from
    ``targets``, first-occurrence keys from ``rank_by_item``), so
    exactness and the sequential score counts are unchanged at ANY
    prefix depth. Footprint: ``4 * R * P * R * 4`` bytes of prefix tiles
    (head + tail row tiles, float32, plus the same-shape int32 rank
    tiles) + ``M * R * 4`` for ``rank_by_item`` + the id tables — the
    full memory/speed trade-off is documented in DESIGN.md §7.

``norm_sharded``
    The norm-major layout dealt round-robin across a device mesh: global
    norm rank i lives on shard ``i % n`` at local position ``i // n``, so
    every shard's local norm spectrum mirrors the global one (no shard
    gets stuck scanning the whole head). Consumed by the ``norm_sharded``
    engine (:func:`repro.core.sharded.sharded_norm_topk`).

Layouts holding only jax arrays are registered as pytrees (static config
in the aux data) so they can cross ``jax.jit`` boundaries.

**Layouts as runtime arguments** (DESIGN.md §10): since PR 5 the engine
executors take layouts as runtime pytree ARGUMENTS rather than closing
over them as jit constants, and catalogue-shaped arrays are padded to a
power-of-two M-bucket (:func:`repro.core.engines.m_bucket`) so that a
compacted snapshot of the same bucket re-dispatches every existing trace
— compile-free compaction. The pad-row convention every padded array
follows: pad TARGET rows are zero, pad NORM entries carry norm ``0`` and
id ``-1`` (they sort last, so norm-order prefixes are untouched), and
pad LIST entries sit past the real list ends at their own padded
position (``rank == position``), which makes them unreachable to the
``m_real``-clamped index arithmetic in :mod:`repro.core.strategies`.
:func:`pad_rank_by_item` and the ``m_total`` parameter of
:func:`build_norm_sharded` implement that convention here; each layout's
docstring states its own pad-row semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

#: Default list-prefix depth (rows per dimension). Calibrated on the
#: benchmark catalogues: exact TA/BTA terminate at list depth ~200-600
#: for M up to 256k, so 2048 covers virtually every scan while costing
#: 4*R*2048*R words of prefix tiles (~34 MB at R=32: row AND rank
#: tiles, head + tail each) plus M*R int32 for ``rank_by_item``.
DEFAULT_PREFIX_DEPTH = 2048

#: Smallest catalogue for which the list_major layout is enabled BY
#: DEFAULT. The prefix trades ~2x streamed bytes (head + tail direction
#: tiles) for zero gathers; below this size the whole catalogue is
#: cache-resident and the plain gather path is faster (measured at
#: M=8k: layout 2x slower; at M=32k it already wins). An explicit
#: ``EngineContext(prefix_depth=...)`` overrides the threshold.
LIST_LAYOUT_MIN_TARGETS = 32768


@dataclasses.dataclass(frozen=True)
class RowMajorLayout:
    """The catalogue exactly as given; scoring a block is a row gather.

    Pad/compile-key note (DESIGN.md §10): the ``naive`` engine pads
    ``targets`` to the M-bucket with zero rows and masks their scores to
    −∞ before the merge — its compile key is the bucket, never M.
    """

    targets: Array

    name = "row_major"


@dataclasses.dataclass(frozen=True)
class NormMajorLayout:
    """Decreasing-norm permutation: a norm block is a contiguous slice.

    Pad/compile-key note (DESIGN.md §10): the ``norm`` engine pads all
    three arrays to the M-bucket — zero rows, norm ``0``, id ``-1`` —
    which sort to the END of the norm order, so the real prefix (and
    every Cauchy-Schwarz bound a scan can reach before its
    ``m_real``-capped stop) is untouched. The padded shapes are the
    layout's whole contribution to the executor's compile key.
    """

    norm_order: Array       # [M] int32 — item ids by decreasing L2 norm
    norms_sorted: Array     # [M] — norms in that order
    targets_by_norm: Array  # [M, R] — catalogue permuted into that order

    name = "norm_major"


@dataclasses.dataclass(frozen=True)
class ListMajorLayout:
    """Contiguous list prefixes for gather-free TA/BTA (DESIGN.md §7).

    Attributes:
      head_rows: ``[R, P, R]`` — ``targets[order_desc[r, p]]`` for
        p < P: the DESCENDING walk's prefix, contiguous per dimension.
      tail_rows: ``[R, P, R]`` — the ASCENDING walk's prefix
        (``targets[order_desc[r, M-1-p]]``), what a negative query
        weight reads.
      head_ids / tail_ids: ``[R, P]`` int32 — the walk-order item ids
        (slicing these replaces the per-step ``take_along_axis`` id
        gather inside the prefix).
      head_ranks / tail_ranks: ``[R, P, R]`` int32 —
        ``rank_by_item[head_ids]`` / ``rank_by_item[tail_ids]``: each
        prefix item's positions in ALL lists, materialised offline in
        walk order. Freshness inside the prefix is then a contiguous
        slice + vectorised min per step — no per-query scatter, no
        per-candidate gather (both measured to dominate the scan
        otherwise).
      rank_by_item: ``[M, R]`` int32 — ``rank_desc`` transposed so one
        item's positions in ALL lists are a contiguous row; the
        post-prefix freshness fallback gathers these instead of
        depending on an O(R*M) per-query key precompute. The engine
        layer hands the executors a copy padded to the M-bucket via
        :func:`pad_rank_by_item` (pad rank == pad position, so pads can
        never test fresh).
      prefix_depth: P (static — lives in the pytree aux data, so it is
        automatically the "layout-shape" component of the
        argument-passing compile key, DESIGN.md §10; at the adaptive
        default it is the constant 2048 for every catalogue ≥ 32k).

    **Single-sided variants** (DESIGN.md §11): either direction's tiles
    may be ``None`` (``build_list_major(sides=("head",))`` or
    :meth:`sided`), halving the prefix footprint for deployments whose
    queries are known single-sign (e.g. non-negative CF similarity
    weights). The batched sign-bucket dispatch serves the matching
    bucket from the remaining side and falls back to the gather path for
    buckets that would need the missing one; ``rank_by_item`` is always
    present. The None-ness is pytree STRUCTURE, so it is part of the
    executor compile key automatically.
    """

    head_rows: Optional[Array]
    tail_rows: Optional[Array]
    head_ids: Optional[Array]
    tail_ids: Optional[Array]
    head_ranks: Optional[Array]
    tail_ranks: Optional[Array]
    rank_by_item: Array
    prefix_depth: int

    name = "list_major"

    def prefix_steps(self, block_size: int) -> int:
        """Whole blocks of ``block_size`` covered by the prefix."""
        return self.prefix_depth // max(block_size, 1)

    @property
    def sides(self) -> tuple:
        """The prefix directions this layout materialised."""
        out = ()
        if self.head_rows is not None:
            out += ("head",)
        if self.tail_rows is not None:
            out += ("tail",)
        return out

    @property
    def two_sided(self) -> bool:
        return self.head_rows is not None and self.tail_rows is not None

    def serves_sign(self, sign: int) -> bool:
        """Can the prefix serve a batch of this sign bucket? (``0`` —
        mixed — needs both directions.)"""
        if sign > 0:
            return self.head_rows is not None
        if sign < 0:
            return self.tail_rows is not None
        return self.two_sided

    def sided(self, side: str) -> "ListMajorLayout":
        """Drop the other direction's tiles (halve the prefix footprint)."""
        if side not in ("head", "tail"):
            raise ValueError(f"side must be 'head' or 'tail', got {side!r}")
        drop = dict.fromkeys(
            ("tail_rows", "tail_ids", "tail_ranks") if side == "head"
            else ("head_rows", "head_ids", "head_ranks"))
        return dataclasses.replace(self, **drop)


@dataclasses.dataclass(frozen=True)
class ShardedNormLayout:
    """Round-robin-dealt norm-major layout over a mesh axis.

    The arrays are shard-major: rows ``[s*m_local, (s+1)*m_local)`` are
    shard s's slab, itself in decreasing-norm order (a strided deal of
    the global norm order, so every shard sees the global spectrum
    decimated — per-shard Cauchy-Schwarz bounds stay tight everywhere).
    Slabs are padded to equal length with zero rows carrying id -1 — the
    same rows the engine layer's M-bucket padding appends
    (``build_norm_sharded(m_total=bucket)``, DESIGN.md §10), so the
    sharded scan needs exactly one pad convention: mask ``id < 0`` and
    stop at the per-slab real-row cap. The slab shapes (set by
    ``m_total``/``n_shards``) are this layout's compile-key
    contribution.
    """

    targets_sharded: Array  # [n*m_local, R]
    norms_sharded: Array    # [n*m_local]
    ids_sharded: Array      # [n*m_local] int32; -1 marks padding
    n_shards: int

    name = "norm_sharded"


def _register(cls, static_fields):
    array_fields = [f.name for f in dataclasses.fields(cls)
                    if f.name not in static_fields]

    def flatten(obj):
        return ([getattr(obj, f) for f in array_fields],
                tuple(getattr(obj, f) for f in static_fields))

    def unflatten(aux, children):
        return cls(**dict(zip(array_fields, children)),
                   **dict(zip(static_fields, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


_register(RowMajorLayout, ())
_register(NormMajorLayout, ())
_register(ListMajorLayout, ("prefix_depth",))
_register(ShardedNormLayout, ("n_shards",))


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def pad_zero_rows(arr: Array, m_bucket: int) -> Array:
    """Pad a catalogue-shaped array (leading axis M) to ``m_bucket`` with
    zeros — THE zero-pad convention of DESIGN.md §10 (pad target rows
    are zero, pad norms are 0), shared by every engine-args builder so
    the invariant lives in one place. No-op when already at the bucket.
    """
    m = arr.shape[0]
    if m_bucket <= m:
        return arr
    return jnp.concatenate(
        [arr, jnp.zeros((m_bucket - m,) + arr.shape[1:], arr.dtype)],
        axis=0)


def pad_rank_by_item(rank_by_item: Array, m_bucket: int) -> Array:
    """Pad ``rank_by_item [M, R]`` rows up to ``m_bucket`` (DESIGN.md §10).

    Pad item ``j`` gets rank ``j`` in EVERY list — i.e. pads extend each
    sorted list past its real end in id order, preserving the
    order/rank inverse-permutation invariant over the padded arrays. A
    pad rank is ``>= m_real`` by construction, so no ``m_real``-clamped
    walk position, freshness key, or bound lookup can ever resolve to a
    pad entry.
    """
    m, r = rank_by_item.shape
    if m_bucket <= m:
        return rank_by_item
    pad = jnp.broadcast_to(
        jnp.arange(m, m_bucket, dtype=rank_by_item.dtype)[:, None],
        (m_bucket - m, r))
    return jnp.concatenate([rank_by_item, pad], axis=0)


def build_row_major(targets, index=None, **_) -> RowMajorLayout:
    return RowMajorLayout(targets=jnp.asarray(targets, jnp.float32))


def build_norm_major(targets, index=None, **_) -> NormMajorLayout:
    """Norm-major layout; reuses the index's norm arrays when available."""
    if index is not None:
        return NormMajorLayout(
            norm_order=index.norm_order,
            norms_sorted=index.norms_sorted,
            targets_by_norm=index.targets_by_norm)
    T_np = np.asarray(targets, np.float32)
    norms = np.linalg.norm(T_np, axis=1)
    order = np.argsort(-norms, kind="stable").astype(np.int32)
    return NormMajorLayout(
        norm_order=jnp.asarray(order),
        norms_sorted=jnp.asarray(norms[order].astype(np.float32)),
        targets_by_norm=jnp.asarray(
            np.ascontiguousarray(T_np[order].astype(np.float32))))


def build_list_major(targets, index, prefix_depth: Optional[int] = None,
                     sides: tuple = ("head", "tail"),
                     **_) -> ListMajorLayout:
    """Materialise the list prefixes (offline, ``O(R * P * R)`` copy).

    ``sides`` selects which walk directions get prefix tiles; dropping
    one halves the footprint for single-sign deployments (DESIGN.md §11
    — the sign-bucket dispatch falls back to the gather path for
    buckets the remaining side cannot serve).
    """
    if not sides or any(s not in ("head", "tail") for s in sides):
        raise ValueError(f"sides must be a non-empty subset of "
                         f"('head', 'tail'), got {sides!r}")
    T_np = np.asarray(targets, np.float32)
    M, R = T_np.shape
    P = int(min(M, DEFAULT_PREFIX_DEPTH if prefix_depth is None
                else prefix_depth))
    P = max(P, 1)
    od = np.asarray(index.order_desc)                       # [R, M]
    rank_by_item = np.ascontiguousarray(np.asarray(index.rank_desc).T)

    def _side(ids):
        ids = np.ascontiguousarray(ids)
        return (jnp.asarray(np.ascontiguousarray(T_np[ids])),
                jnp.asarray(ids),
                jnp.asarray(np.ascontiguousarray(rank_by_item[ids])))

    head = _side(od[:, :P]) if "head" in sides else (None, None, None)
    tail = _side(od[:, ::-1][:, :P]) if "tail" in sides else (None,) * 3
    return ListMajorLayout(
        head_rows=head[0], head_ids=head[1], head_ranks=head[2],
        tail_rows=tail[0], tail_ids=tail[1], tail_ranks=tail[2],
        rank_by_item=jnp.asarray(rank_by_item),
        prefix_depth=P,
    )


def build_norm_sharded(targets, index, n_shards: int, mesh=None,
                       axis_name: str = "data",
                       m_total: Optional[int] = None,
                       **_) -> ShardedNormLayout:
    """Deal the norm order round-robin over ``n_shards`` equal slabs.

    ``m_total`` pads the GLOBAL item count before dealing (the engine
    layer passes the M-bucket, DESIGN.md §10): slabs are sized
    ``ceil(m_total / n_shards)`` and the extra rows are the standard
    slab padding (zero rows, norm 0, id ``-1``) the sharded scan already
    masks — so every snapshot of a bucket produces identically shaped
    slab arrays and the sharded executor's compile key is
    bucket-granular, not M-granular.
    """
    T_np = np.asarray(targets, np.float32)
    M, R = T_np.shape
    if index is not None:
        order = np.asarray(index.norm_order)
        norms = np.asarray(index.norms_sorted)
    else:
        n = np.linalg.norm(T_np, axis=1)
        order = np.argsort(-n, kind="stable").astype(np.int32)
        norms = n[order]
    m_local = -(-max(M, m_total or M) // n_shards)
    T_sh = np.zeros((n_shards * m_local, R), np.float32)
    norms_sh = np.zeros((n_shards * m_local,), np.float32)
    ids_sh = np.full((n_shards * m_local,), -1, np.int32)
    for s in range(n_shards):
        ids_s = order[s::n_shards]
        T_sh[s * m_local: s * m_local + len(ids_s)] = T_np[ids_s]
        norms_sh[s * m_local: s * m_local + len(ids_s)] = norms[s::n_shards]
        ids_sh[s * m_local: s * m_local + len(ids_s)] = ids_s
    arrays = (jnp.asarray(T_sh), jnp.asarray(norms_sh), jnp.asarray(ids_sh))
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P_
        row_spec = NamedSharding(mesh, P_(axis_name))
        mat_spec = NamedSharding(mesh, P_(axis_name, None))
        arrays = (jax.device_put(arrays[0], mat_spec),
                  jax.device_put(arrays[1], row_spec),
                  jax.device_put(arrays[2], row_spec))
    return ShardedNormLayout(targets_sharded=arrays[0],
                             norms_sharded=arrays[1],
                             ids_sharded=arrays[2], n_shards=n_shards)


def round_robin_shares(n: int, n_shards: int, start: int = 0) -> np.ndarray:
    """Rows each shard receives when ``n`` items are dealt round-robin
    starting at cursor position ``start`` — the same strided deal
    :func:`build_norm_sharded` uses for its slabs, reused by the LSM
    catalogue's L0 -> L1 fold (fit check and the deal itself) so the two
    shard conventions can never diverge. Returns ``[n_shards] int64``.
    """
    shares = np.full((n_shards,), n // n_shards, np.int64)
    for i in range(n % n_shards):
        shares[(start + i) % n_shards] += 1
    return shares


_BUILDERS = {
    "row_major": build_row_major,
    "norm_major": build_norm_major,
    "list_major": build_list_major,
    "norm_sharded": build_norm_sharded,
}


def layout_names():
    return sorted(_BUILDERS)


def build_layout(name: str, targets, index=None, **params):
    """Name-keyed layout construction (the registry's single entry point)."""
    if name not in _BUILDERS:
        raise ValueError(
            f"unknown layout {name!r}; known: {layout_names()}")
    return _BUILDERS[name](targets, index, **params)
