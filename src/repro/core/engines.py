"""Engine registry: every top-K engine behind one name-keyed interface.

The serving layer, the benchmark harness, and tests all dispatch through
this registry (DESIGN.md §1) instead of hand-rolled ``if/elif`` chains.
An :class:`Engine` bundles a batched-executable factory with capability
metadata (exact? needs the sorted-list index? batched? which backend
executes it?) so callers can enumerate, filter, and sweep engines they
have never heard of — which is how future engines (LEMP-style per-bucket
bounds, sharded variants, approximate modes) become reachable from every
layer by adding one ``register`` call.

Engines run against an :class:`EngineContext` — the catalogue plus lazily
built derived state (sorted-list index, Pallas catalogue) shared across
queries, so a server builds it once and every engine reuses it.

**Compilation cache** (DESIGN.md §6): ``Engine.run`` dispatches through a
persistent per-context ``jax.jit`` cache keyed by
``(engine, k, batch-bucket)``. Batch sizes are bucketed to the next power
of two (queries are padded by repeating the last row, results sliced
back), so a serving process compiles each engine a handful of times total
instead of re-tracing ``vmap`` closures on every call.
:meth:`EngineContext.warmup` populates the cache ahead of traffic, and
:attr:`EngineContext.trace_counts` counts actual traces per engine so
tests can assert the cache is hit (0 new traces after warmup).

Every engine also declares the :mod:`repro.core.layout` it consumes
(``Engine.layout``); :meth:`EngineContext.layout` builds layouts lazily
and caches them per context, exactly like the sorted-list index. A
``traffic`` estimator per engine turns measured ``n_scored``/``depth``
into memory-traffic terms (rows gathered vs contiguous rows read,
estimated bytes moved) for the benchmark sweep.

Registered engines:

================  =====  ===========  ========  ===========  ==================================
name              exact  needs_index  backend   layout       algorithm
================  =====  ===========  ========  ===========  ==================================
``naive``         yes    no           jax       row_major    full matmul + top_k
``ta``            yes    yes          jax       list_major   chunked TA rounds (count-faithful)
``bta``           yes    yes          jax       list_major   Block Threshold Algorithm
``norm``          yes    yes          jax       norm_major   Cauchy-Schwarz norm-block scan
``norm_sharded``  yes    yes          jax       norm_sharded shared-tile norm scan under
                                                             shard_map, cross-shard pmax bounds
``pallas``        yes    yes          pallas    norm_major   norm-block scan as a TPU kernel
``fagin``         yes    yes          numpy     row_major    Fagin's Algorithm (host oracle)
``partial``       yes    yes          numpy     row_major    Partial TA, Alg. 3 (host oracle)
``auto``          yes    yes          dispatch  —            picks per batch (see below)
================  =====  ===========  ========  ===========  ==================================

The two ``numpy`` rows are the paper-faithful host oracles: exact,
host-only, never jitted or batched (``host_only=True``,
``make_batched=None`` — they run as dispatch loops). Registering them
makes ``list_engines()`` cover every implemented algorithm; the
benchmark sweep skips ``backend="numpy"`` rows when timing.

``auto`` picks per query batch: sparse batches go to ``ta`` (zero-weight
lists are never walked, so TA's per-round work collapses to nnz(u)); dense
batches over catalogues whose norm spectrum decays go to the norm scan
(``pallas`` on TPU, ``norm`` elsewhere); flat-spectrum dense batches go to
``bta``. The sparsity statistic is computed HOST-side from the incoming
array — dispatch never enqueues work (or a sync) on the device query
stream.

Aliases accepted by :func:`get_engine`: ``threshold -> ta``,
``blocked -> bta``, ``norm_pruned -> norm``, ``topk_mips -> pallas``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import (
    blocked_topk,
    chunked_ta_topk,
    norm_pruned_topk,
    norm_pruned_topk_batched,
)
from repro.core.index import TopKIndex, build_index
from repro.core.layout import (DEFAULT_PREFIX_DEPTH,
                               LIST_LAYOUT_MIN_TARGETS,
                               build_layout)
from repro.core.naive import TopKResult, naive_topk

Array = jnp.ndarray


def batch_bucket(n: int) -> int:
    """Next power of two >= n — the compile-cache batch granularity."""
    return 1 << max(0, int(n) - 1).bit_length()


def pad_to_bucket(U: "Array") -> "Array":
    """Pad a ``[B, R]`` batch to its power-of-two bucket.

    Padding repeats the LAST query row — never zeros: an all-zero query
    deactivates every list and would drag a vmapped lockstep scan to its
    worst case. Shared by the engine compile cache and the segmented
    query path (:mod:`repro.core.segments`), so the two can never
    diverge on padding semantics.
    """
    b = U.shape[0]
    bucket = batch_bucket(b)
    if bucket == b:
        return U
    pad = jnp.broadcast_to(U[b - 1:b], (bucket - b, U.shape[1]))
    return jnp.concatenate([U, pad], axis=0)


class EngineContext:
    """Catalogue + lazily built per-engine state, shared across queries.

    Args:
      targets: ``[M, R]`` catalogue factors.
      index: optional prebuilt :class:`TopKIndex` (built lazily otherwise).
      block_size: depth/block granularity handed to blocked engines.
      max_blocks: uniform halting budget (``-1`` = run to exactness).
      interpret: Pallas execution mode (``None`` = autodetect by backend).
      ta_chunk: rounds gathered per chunked-TA step (`ta` engine).
      prefix_depth: ``list_major`` layout prefix rows per dimension.
        ``None`` (default) is ADAPTIVE — the layout turns on at
        ``DEFAULT_PREFIX_DEPTH`` once ``M >= LIST_LAYOUT_MIN_TARGETS``
        and stays off below that (the cache-resident gather path is
        faster there); ``0`` disables the layout path entirely; any
        other value is honoured as given (clamped to ``M``). See
        :attr:`resolved_prefix_depth`.
      version: snapshot version of the catalogue this context was built
        from (DESIGN.md §9). The streaming layer
        (:mod:`repro.core.segments`) builds one context per immutable
        base snapshot under a monotonically increasing version; the
        version participates in the compile-cache key so executables
        compiled against one snapshot's pytrees can never be dispatched
        against another's, even if a context object were ever shared
        across snapshots.
    """

    def __init__(self, targets, index: Optional[TopKIndex] = None,
                 block_size: int = 256, max_blocks: int = -1,
                 interpret=None, ta_chunk: int = 32,
                 prefix_depth: Optional[int] = None, version: int = 0):
        self.targets = jnp.asarray(targets, dtype=jnp.float32)
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.interpret = interpret
        self.ta_chunk = ta_chunk
        # list_major prefix depth; None -> DEFAULT_PREFIX_DEPTH, 0 disables
        # the layout path entirely (list engines fall back to gathers)
        self.prefix_depth = prefix_depth
        self.version = int(version)
        self._index = index
        self._catalog = None
        self._norm_decay = None
        self._layouts: Dict[str, object] = {}
        # persistent compiled-executable cache: (engine, k, batch-bucket,
        # snapshot version) -> jitted batched callable. trace_counts counts
        # actual traces per engine name (bumped at trace time, so a cache
        # hit adds nothing).
        self._compiled: Dict[Tuple[str, int, int, int], Callable] = {}
        self.trace_counts: Dict[str, int] = {}

    @property
    def resolved_prefix_depth(self) -> int:
        """The list_major prefix depth this context builds (0 = disabled).

        ``prefix_depth=None`` is adaptive: the layout only turns on once
        the catalogue outgrows cache (``LIST_LAYOUT_MIN_TARGETS``) —
        below that the plain gather path is faster and the default stays
        on it. An explicit ``prefix_depth`` is always honoured.
        """
        if self.prefix_depth is None:
            if self.num_targets < LIST_LAYOUT_MIN_TARGETS:
                return 0
            return int(min(self.num_targets, DEFAULT_PREFIX_DEPTH))
        return int(min(self.num_targets, self.prefix_depth))

    def layout(self, name: str):
        """The named catalogue layout, built lazily and cached per context.

        ``list_major`` resolves the context's ``prefix_depth``;
        ``norm_sharded`` deals the norm order over all visible devices on
        a 1-axis ``("data",)`` mesh (a 1-device mesh is valid — the
        sharded engine then degenerates to the single-host scan).
        """
        lay = self._layouts.get(name)
        if lay is None:
            params = {}
            if name == "list_major":
                params["prefix_depth"] = self.resolved_prefix_depth
            elif name == "norm_sharded":
                mesh = self.mesh
                params["n_shards"] = mesh.devices.size
                params["mesh"] = mesh
            index = None if name == "row_major" else self.index
            lay = build_layout(name, self.targets, index, **params)
            self._layouts[name] = lay
        return lay

    @property
    def mesh(self):
        """1-axis ``("data",)`` mesh over all visible devices."""
        if getattr(self, "_mesh", None) is None:
            devs = np.asarray(jax.devices())
            self._mesh = jax.sharding.Mesh(devs, ("data",))
        return self._mesh

    @property
    def num_targets(self) -> int:
        return int(self.targets.shape[0])

    @property
    def index(self) -> TopKIndex:
        if self._index is None:
            self._index = build_index(self.targets)
        return self._index

    @property
    def catalog(self):
        """Norm-ordered Pallas catalogue (built on first pallas query)."""
        if self._catalog is None:
            from repro.kernels.ops import MIPSCatalog
            self._catalog = MIPSCatalog(np.asarray(self.targets),
                                        block_m=self.block_size)
        return self._catalog

    @property
    def norm_decay(self) -> float:
        """Norm at the 10th-percentile depth over the head norm (<= 1).

        A catalogue constant, cached so per-batch `auto` dispatch does not
        re-transfer the norm spectrum from device on every query chunk.
        """
        if self._norm_decay is None:
            norms = np.asarray(self.index.norms_sorted)
            head = max(float(norms[0]), 1e-12)
            decayed = float(
                norms[min(len(norms) - 1, max(1, len(norms) // 10))])
            self._norm_decay = decayed / head
        return self._norm_decay

    # -- compilation cache ---------------------------------------------------

    def compiled(self, engine: "Engine", k: int, batch: int) -> Callable:
        """The persistent jitted executable for
        (engine, k, batch-bucket, snapshot version).

        Built once per key: the engine's ``make_batched`` factory is called
        EAGERLY (so lazy context state — index, Pallas catalogue — is
        constructed outside the trace) and the result is wrapped in a
        ``jax.jit`` that survives across queries. The wrapper bumps
        ``trace_counts[engine]`` at trace time only.
        """
        key = (engine.name, int(k), int(batch), self.version)
        fn = self._compiled.get(key)
        if fn is None:
            if engine.make_batched is None:
                raise ValueError(
                    f"engine {engine.name!r} is dispatch-only and has no "
                    "batched executable to compile")
            batched = engine.make_batched(self, int(k))
            name = engine.name

            def traced(U, _inner=batched, _name=name):
                self.trace_counts[_name] = self.trace_counts.get(_name, 0) + 1
                return _inner(U)

            fn = jax.jit(traced)
            self._compiled[key] = fn
        return fn

    def run_engine(self, engine: "Engine", U: Array, k: int) -> TopKResult:
        """Bucket the batch, pad, run the cached executable, slice back.

        Padding repeats the LAST query row (never zeros: an all-zero query
        deactivates every list and would drag a vmapped lockstep scan to
        its worst case); padded rows are dropped before returning, so
        per-query statistics are untouched.
        """
        if not (isinstance(U, jax.Array) and U.ndim == 2
                and U.dtype == self.targets.dtype):
            U = jnp.atleast_2d(jnp.asarray(U, self.targets.dtype))
        b = U.shape[0]
        bucket = batch_bucket(b)
        fn = self.compiled(engine, k, bucket)
        if bucket != b:
            U = pad_to_bucket(U)
        res = fn(U)
        if bucket != b:
            res = jax.tree_util.tree_map(lambda a: a[:b], res)
        return res

    def warmup(self, k: int, batch_sizes=(1, 8, 64),
               engines: Optional[List[str]] = None) -> "EngineContext":
        """Compile (engine, k, bucket) executables ahead of traffic.

        Runs one representative batch per bucket through each non-dispatch
        engine so the first real query hits a compiled executable. Returns
        self for chaining.
        """
        names = list(engines) if engines is not None else [
            e.name for e in list_engines() if e.make_batched is not None]
        r = int(self.targets.shape[1])
        for name in names:
            eng = get_engine(name)
            for b in batch_sizes:
                bucket = batch_bucket(b)
                U = jnp.ones((bucket, r), self.targets.dtype)
                res = self.compiled(eng, int(k), bucket)(U)
                jax.block_until_ready(res.values)
        return self


@dataclasses.dataclass(frozen=True)
class Engine:
    """A registered engine: batched-executable factory + capability metadata.

    ``make_batched(ctx, k)`` returns a pure ``U [B, R] -> TopKResult``
    callable (trace-safe; any host-side setup such as index construction
    happens inside the factory, eagerly). ``run`` dispatches through the
    context's compilation cache. Dispatch pseudo-engines (``auto``) and
    host-only reference oracles (``fagin``, ``partial``) set ``dispatch``
    instead and route per batch — host oracles are never jitted.

    ``layout`` names the :mod:`repro.core.layout` the engine consumes
    (built via :meth:`EngineContext.layout`); ``traffic`` estimates the
    engine's memory traffic for a measured :class:`TopKResult` (per-query
    means: rows gathered, contiguous rows read, bytes moved) — the
    benchmark sweep records it so layout wins show up in the perf
    trajectory, not just wall-clock.
    """

    name: str
    make_batched: Optional[
        Callable[["EngineContext", int], Callable[[Array], TopKResult]]
    ] = None
    dispatch: Optional[
        Callable[["EngineContext", Array, int], TopKResult]] = None
    exact: bool = True
    needs_index: bool = True
    supports_batch: bool = True
    backend: str = "jax"
    layout: Optional[str] = None
    host_only: bool = False
    traffic: Optional[
        Callable[["EngineContext", TopKResult], Dict[str, float]]] = None
    description: str = ""

    def run(self, ctx: EngineContext, U: Array, k: int) -> TopKResult:
        if self.dispatch is not None:
            return self.dispatch(ctx, U, k)
        return ctx.run_engine(self, U, k)


_REGISTRY: Dict[str, Engine] = {}
_ALIASES: Dict[str, str] = {
    "threshold": "ta",
    "blocked": "bta",
    "norm_pruned": "norm",
    "topk_mips": "pallas",
}


def register_engine(engine: Engine) -> Engine:
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> Engine:
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def engine_names() -> List[str]:
    return sorted(_REGISTRY)


def list_engines(exact: Optional[bool] = None,
                 backend: Optional[str] = None,
                 needs_index: Optional[bool] = None) -> List[Engine]:
    out = []
    for name in engine_names():
        e = _REGISTRY[name]
        if exact is not None and e.exact != exact:
            continue
        if backend is not None and e.backend != backend:
            continue
        if needs_index is not None and e.needs_index != needs_index:
            continue
        out.append(e)
    return out


# ---------------------------------------------------------------------------
# Built-in engines
# ---------------------------------------------------------------------------


def _naive_batched(ctx: EngineContext, k: int):
    targets = ctx.targets

    def fn(U):
        return naive_topk(targets, U, k)

    return fn


def _list_layout(ctx: EngineContext):
    """The list_major layout, or None when the context disables it."""
    return ctx.layout("list_major") if ctx.resolved_prefix_depth > 0 \
        else None


def _ta_batched(ctx: EngineContext, k: int):
    # chunked TA: block-shaped work per step, sequential-round accounting
    # (count-faithful to the paper's Algorithm 2). With the list_major
    # layout the rounds inside the prefix are gather-free (DESIGN.md §7).
    idx = ctx.index
    targets = ctx.targets
    chunk = ctx.ta_chunk
    max_rounds = ctx.max_blocks
    layout = _list_layout(ctx)
    # gather-fused Pallas tail scoring only pays on real TPU backends
    tail_pallas = jax.default_backend() == "tpu" and layout is not None

    def one(u):
        return chunked_ta_topk(targets, idx.order_desc, idx.t_sorted_desc,
                               idx.rank_desc, u, k, chunk=chunk,
                               max_rounds=max_rounds, layout=layout,
                               tail_pallas=tail_pallas)

    return jax.vmap(one)


def _bta_batched(ctx: EngineContext, k: int):
    idx = ctx.index
    targets = ctx.targets
    block_size, max_blocks = ctx.block_size, ctx.max_blocks
    layout = _list_layout(ctx)
    tail_pallas = jax.default_backend() == "tpu" and layout is not None

    def one(u):
        return blocked_topk(targets, idx.order_desc, idx.t_sorted_desc, u,
                            k, block_size, max_blocks,
                            rank_desc=idx.rank_desc, layout=layout,
                            tail_pallas=tail_pallas)

    return jax.vmap(one)


def _norm_batched(ctx: EngineContext, k: int):
    lay = ctx.layout("norm_major")
    targets = ctx.targets
    block_size, max_blocks = ctx.block_size, ctx.max_blocks
    if targets.shape[0] >= block_size:
        # batched-native scan: every query walks the SAME norm-ordered
        # prefix, so one shared tile slice + one [B,R]@[R,block] matmul
        # serves the whole batch (no per-query gathers)
        def fn(U):
            return norm_pruned_topk_batched(
                lay.targets_by_norm, lay.norm_order, lay.norms_sorted, U,
                k, block_size, max_blocks)

        return fn

    def one(u):
        return norm_pruned_topk(targets, lay.norm_order, lay.norms_sorted,
                                u, k, block_size, max_blocks,
                                targets_by_norm=lay.targets_by_norm)

    return jax.vmap(one)


def _norm_sharded_batched(ctx: EngineContext, k: int):
    from repro.core.sharded import sharded_norm_topk
    lay = ctx.layout("norm_sharded")
    mesh = ctx.mesh
    block_size, max_blocks = ctx.block_size, ctx.max_blocks
    scan = sharded_norm_topk(mesh, ("data",))

    def fn(U):
        return scan(lay.targets_sharded, lay.norms_sharded,
                    lay.ids_sharded, U, k, block_size, max_blocks)

    return fn


def _pallas_batched(ctx: EngineContext, k: int):
    cat = ctx.catalog       # built eagerly, outside the trace
    interpret = ctx.interpret
    block_m = jnp.int32(cat.block_m)

    def fn(U):
        vals, ids, stats = cat.query_batch(U, k, interpret=interpret)
        # stats = (rows scored incl. block padding, blocks visited, loaded)
        return TopKResult(vals, ids, stats[:, 0], stats[:, 1] * block_m)

    return fn


def _host_nnz_frac(U) -> float:
    """Batch sparsity, computed on the HOST.

    numpy/list inputs never touch the device; a jax Array input is read
    back once (it is an input *value*, not a pending computation, so no
    work — and no blocking reduction — is enqueued on the device query
    stream the engines are using).
    """
    arr = U if isinstance(U, np.ndarray) else np.asarray(U)
    return float(np.count_nonzero(arr)) / max(arr.size, 1)


def select_engine(ctx: EngineContext, U) -> Engine:
    """The ``auto`` policy: pick an engine for this query batch.

    Decides from two cheap HOST-side statistics: batch sparsity ``nnz(u)``
    (sparse queries make TA's per-round cost collapse to the active lists)
    and the catalogue norm spectrum (a decaying spectrum lets the
    Cauchy-Schwarz scan certify after a few contiguous blocks — the Pallas
    kernel's best case; a flat spectrum makes it a full scan, so BTA wins).
    """
    if _host_nnz_frac(U) < 0.25:
        return get_engine("ta")
    if ctx.norm_decay < 0.5:
        return get_engine(
            "pallas" if jax.default_backend() == "tpu" else "norm")
    return get_engine("bta")


def auto_candidates():
    """Engine names :func:`select_engine` can resolve to on this backend.

    Warming exactly this set covers every dispatch ``auto`` can make;
    warming beyond it (``norm_sharded`` in particular, whose layout build
    copies the whole catalogue) is wasted startup work.
    """
    return ["ta", "bta",
            "pallas" if jax.default_backend() == "tpu" else "norm"]


def _auto_dispatch(ctx: EngineContext, U, k: int) -> TopKResult:
    return select_engine(ctx, U).run(ctx, U, k)


# ---------------------------------------------------------------------------
# Host-only reference oracles (paper Algorithms 1 and 3) as engines
# ---------------------------------------------------------------------------


def _host_oracle_dispatch(one_query):
    """Wrap a numpy oracle ``(T, order_desc, u, k) -> (v, i, n, d)``."""

    def dispatch(ctx: EngineContext, U, k: int) -> TopKResult:
        T = np.asarray(ctx.targets)
        od = np.asarray(ctx.index.order_desc)
        U_np = np.atleast_2d(np.asarray(U, np.float32))
        k_eff = min(int(k), T.shape[0])
        vals = np.full((U_np.shape[0], k_eff), float("-inf"), np.float32)
        ids = np.full((U_np.shape[0], k_eff), -1, np.int32)
        ns = np.zeros((U_np.shape[0],), np.int32)
        dep = np.zeros((U_np.shape[0],), np.int32)
        for b, u in enumerate(U_np):
            v, i, n, d = one_query(T, od, u, k_eff)
            vals[b, :len(v)] = v
            ids[b, :len(i)] = i
            ns[b], dep[b] = n, d
        return TopKResult(jnp.asarray(vals), jnp.asarray(ids),
                          jnp.asarray(ns), jnp.asarray(dep))

    return dispatch


def _fagin_one(T, od, u, k):
    from repro.core.fagin import fagin_topk_np
    v, i, st = fagin_topk_np(T, od, u, k)
    return v, i, st.n_scored, st.depth


def _partial_one(T, od, u, k):
    from repro.core.partial import partial_threshold_topk_np
    v, i, st = partial_threshold_topk_np(T, od, u, k)
    # n_items_touched == TA's n_scored (Theorem 4 logic: same item set)
    return v, i, st.n_items_touched, st.depth


# ---------------------------------------------------------------------------
# Memory-traffic estimators (per-query means, from measured counts)
# ---------------------------------------------------------------------------


def _traffic_dict(ctx: EngineContext, rows_gathered, rows_contiguous):
    r = int(ctx.targets.shape[1])
    total = rows_gathered + rows_contiguous
    return {
        "rows_gathered": float(rows_gathered),
        "rows_contiguous": float(rows_contiguous),
        "est_bytes_moved": float(total * r * 4),
        "gather_fraction": float(rows_gathered / total) if total else 0.0,
    }


def _naive_traffic(ctx, res):
    return _traffic_dict(ctx, 0.0, float(ctx.num_targets))


def _list_traffic(ctx, res):
    """TA/BTA: depth (list-depth units) splits at the layout prefix.

    Inside the prefix each of the R lists reads its depth range from BOTH
    direction tiles (head + tail, then a select) — contiguous, 2x rows.
    Past the prefix every candidate costs a scattered target row PLUS a
    same-shape ``rank_by_item`` row for freshness. With the layout off
    (``resolved_prefix_depth == 0``, the adaptive default below
    ``LIST_LAYOUT_MIN_TARGETS``) the engines run the plain gather path:
    ONE target row per candidate, and freshness comes from the O(R*M)
    first-occurrence key precompute — a contiguous stream of the
    ``[R, M]`` int32 rank array, M row-equivalents of bytes per query.
    """
    r = int(ctx.targets.shape[1])
    p = ctx.resolved_prefix_depth
    depth = float(np.mean(np.asarray(res.depth)))
    if p == 0:
        return _traffic_dict(ctx, depth * r, float(ctx.num_targets))
    contig = 2.0 * min(depth, p) * r
    gathered = 2.0 * max(depth - p, 0.0) * r
    return _traffic_dict(ctx, gathered, contig)


def _norm_traffic(ctx, res):
    # depth is rows enumerated in norm order — all contiguous tile reads
    return _traffic_dict(ctx, 0.0, float(np.mean(np.asarray(res.depth))))


def _host_traffic(ctx, res):
    # item-at-a-time oracles: every scored row is a random access
    return _traffic_dict(ctx, float(np.mean(np.asarray(res.n_scored))), 0.0)


register_engine(Engine(
    name="naive", make_batched=_naive_batched, exact=True, needs_index=False,
    supports_batch=True, backend="jax", layout="row_major",
    traffic=_naive_traffic,
    description="full matmul + lax.top_k (strongest wall-clock baseline)"))
register_engine(Engine(
    name="ta", make_batched=_ta_batched, exact=True, needs_index=True,
    supports_batch=True, backend="jax", layout="list_major",
    traffic=_list_traffic,
    description="Threshold Algorithm rounds (paper Alg. 2; chunked "
                "execution, sequential-round accounting, contiguous "
                "list-prefix tiles)"))
register_engine(Engine(
    name="bta", make_batched=_bta_batched, exact=True, needs_index=True,
    supports_batch=True, backend="jax", layout="list_major",
    traffic=_list_traffic,
    description="Block Threshold Algorithm (MXU-shaped TA, contiguous "
                "list-prefix tiles)"))
register_engine(Engine(
    name="norm", make_batched=_norm_batched, exact=True, needs_index=True,
    supports_batch=True, backend="jax", layout="norm_major",
    traffic=_norm_traffic,
    description="Cauchy-Schwarz norm-ordered block scan"))
register_engine(Engine(
    name="norm_sharded", make_batched=_norm_sharded_batched, exact=True,
    needs_index=True, supports_batch=True, backend="jax",
    layout="norm_sharded", traffic=_norm_traffic,
    description="shared-tile norm scan under shard_map with cross-shard "
                "pmax threshold tightening (row-sharded catalogue)"))
register_engine(Engine(
    name="pallas", make_batched=_pallas_batched, exact=True, needs_index=True,
    supports_batch=True, backend="pallas", layout="norm_major",
    traffic=_norm_traffic,
    description="norm-ordered block scan as a Pallas TPU kernel with "
                "two-level DMA-skipping bounds (interpret-mode on CPU)"))
register_engine(Engine(
    name="fagin", dispatch=_host_oracle_dispatch(_fagin_one), exact=True,
    needs_index=True, supports_batch=False, backend="numpy",
    layout="row_major", host_only=True, traffic=_host_traffic,
    description="Fagin's Algorithm (paper Alg. 1; host-only numpy "
                "reference, no jit)"))
register_engine(Engine(
    name="partial", dispatch=_host_oracle_dispatch(_partial_one), exact=True,
    needs_index=True, supports_batch=False, backend="numpy",
    layout="row_major", host_only=True, traffic=_host_traffic,
    description="Partial Threshold Algorithm (paper Alg. 3 / Eq. 4; "
                "host-only numpy reference, no jit)"))
register_engine(Engine(
    name="auto", dispatch=_auto_dispatch, exact=True, needs_index=True,
    supports_batch=True, backend="dispatch",
    description="per-batch pick from host-side nnz(u) + catalogue norm "
                "spectrum"))
