"""Engine registry: every top-K engine behind one name-keyed interface.

The serving layer, the benchmark harness, and tests all dispatch through
this registry (DESIGN.md §1) instead of hand-rolled ``if/elif`` chains.
An :class:`Engine` bundles a batched-executable factory with capability
metadata (exact? needs the sorted-list index? batched? which backend
executes it?) so callers can enumerate, filter, and sweep engines they
have never heard of — which is how future engines (LEMP-style per-bucket
bounds, sharded variants, approximate modes) become reachable from every
layer by adding one ``register`` call.

Engines run against an :class:`EngineContext` — the catalogue plus lazily
built derived state (sorted-list index, layouts, Pallas catalogue) shared
across queries, so a server builds it once and every engine reuses it.

**Argument-passing compilation contract** (DESIGN.md §10). Engines come
in two kinds, distinguished by which :class:`Engine` fields they set:

* **Argument-passing engines** (``run_args`` + ``make_args``; ``naive``,
  ``ta``, ``bta``, ``norm``, ``norm_sharded``): the compiled function is
  a MODULE-LEVEL ``jax.jit`` executor shared by every context in the
  process. Everything snapshot-shaped — catalogue rows, index arrays,
  :mod:`repro.core.layout` pytrees — flows in as runtime ARGUMENTS
  (built once per context by ``make_args``, padded to the power-of-two
  M-bucket :func:`m_bucket`, cached by :meth:`EngineContext.engine_args`),
  together with a traced ``m_real`` scalar carrying the real catalogue
  size. The effective compile key is therefore
  ``(engine, k, batch-bucket, M-bucket, layout-shape, config)`` — NO
  snapshot version, no array identity — so a compacted snapshot of the
  same bucket re-dispatches every existing trace: compaction is
  compile-free (the streaming win this layer exists for, DESIGN.md §9).
  Pad rows follow the conventions stated in :mod:`repro.core.layout`
  and are never walked, scored, or counted (the ``m_real`` index
  arithmetic in :mod:`repro.core.strategies`), so results and the
  paper's ``n_scored``/``depth`` metrics are bit-identical to the
  unpadded scan.

* **Closure engines** (``make_batched``; ``pallas`` only): the factory
  closes over context state that cannot yet cross a jit boundary as an
  argument (the Pallas ``MIPSCatalog`` does host-side per-query block
  pre-screening and owns the kernel grid), so the executable lives in a
  per-context cache keyed ``(engine, k, batch-bucket, snapshot
  version)`` — the PR-4 contract, retained only here. A compaction
  serving ``pallas`` re-traces it; on-TPU argument-passing for the
  kernel path is future work (ROADMAP).

Batch sizes are bucketed to the next power of two by both kinds
(:func:`batch_bucket`; queries padded by repeating the last row, results
sliced back). :meth:`EngineContext.warmup` populates the caches ahead of
traffic — optionally for LARGER M-buckets than the current catalogue's
(``m_buckets=``), so a growing streaming catalogue crosses its next
bucket boundary without a single new trace. :func:`trace_totals` exposes
the process-wide per-engine trace counters the executors bump at trace
time; :attr:`EngineContext.trace_counts` attributes deltas of those
counters to the context whose call triggered them, so tests can assert
cache hits (0 new traces after warmup, 0 across a same-bucket
compaction).

Registered engines:

================  =====  ===========  ========  ===========  ==================================
name              exact  needs_index  backend   layout       algorithm
================  =====  ===========  ========  ===========  ==================================
``naive``         yes    no           jax       row_major    full matmul + top_k
``ta``            yes    yes          jax       list_major   chunked TA rounds (count-faithful)
``bta``           yes    yes          jax       list_major   Block Threshold Algorithm
``norm``          yes    yes          jax       norm_major   Cauchy-Schwarz norm-block scan
``norm_sharded``  yes    yes          jax       norm_sharded shared-tile norm scan under
                                                             shard_map, cross-shard pmax bounds
``pallas``        yes    yes          pallas    norm_major   norm-block scan as a TPU kernel
``fagin``         yes    yes          numpy     row_major    Fagin's Algorithm (host oracle)
``partial``       yes    yes          numpy     row_major    Partial TA, Alg. 3 (host oracle)
``auto``          yes    yes          dispatch  —            picks per batch (see below)
================  =====  ===========  ========  ===========  ==================================

The two ``numpy`` rows are the paper-faithful host oracles: exact,
host-only, never jitted or batched (``host_only=True``, no executable —
they run as dispatch loops). Registering them makes ``list_engines()``
cover every implemented algorithm; the benchmark sweep skips
``backend="numpy"`` rows when timing.

``auto`` picks per query batch: sparse batches go to ``ta`` (zero-weight
lists are never walked, so TA's per-round work collapses to nnz(u)); dense
batches over catalogues whose norm spectrum decays go to the norm scan
(``pallas`` on TPU, ``norm`` elsewhere); flat-spectrum dense batches go to
``bta``. The sparsity statistic is computed HOST-side from the incoming
array — dispatch never enqueues work (or a sync) on the device query
stream.

Aliases accepted by :func:`get_engine`: ``threshold -> ta``,
``blocked -> bta``, ``norm_pruned -> norm``, ``topk_mips -> pallas``.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.blocked import (
    blocked_topk,
    blocked_topk_batched_native,
    chunked_ta_topk,
    chunked_ta_topk_batched_native,
    norm_pruned_topk_batched,
)
from repro.core.driver import NEG_INF
from repro.core.index import TopKIndex, build_index
from repro.core.layout import (DEFAULT_PREFIX_DEPTH,
                               LIST_LAYOUT_MIN_TARGETS,
                               build_layout, pad_rank_by_item,
                               pad_zero_rows)
from repro.core.naive import TopKResult
from repro.core.strategies import sign_bucket, sign_bucket_label

Array = jnp.ndarray


def batch_bucket(n: int) -> int:
    """Next power of two >= n — the compile-cache batch granularity."""
    return 1 << max(0, int(n) - 1).bit_length()


def m_bucket(m: int) -> int:
    """Next power of two >= m — the compile-cache CATALOGUE granularity.

    Argument-passing engines pad every catalogue-shaped array to this
    bucket (DESIGN.md §10), so any two snapshots whose sizes share a
    bucket share every compiled executable. Same arithmetic as
    :func:`batch_bucket`, named separately because the two axes bucket
    independently.
    """
    return batch_bucket(m)


def pad_to_bucket(U: "Array") -> "Array":
    """Pad a ``[B, R]`` batch to its power-of-two bucket.

    Padding repeats the LAST query row — never zeros: an all-zero query
    deactivates every list and would drag a vmapped lockstep scan to its
    worst case. Shared by the engine compile cache and the segmented
    query path (:mod:`repro.core.segments`), so the two can never
    diverge on padding semantics.
    """
    b = U.shape[0]
    bucket = batch_bucket(b)
    if bucket == b:
        return U
    pad = jnp.broadcast_to(U[b - 1:b], (bucket - b, U.shape[1]))
    return jnp.concatenate([U, pad], axis=0)


# ---------------------------------------------------------------------------
# Process-wide trace accounting + the shared argument-passing executors
# ---------------------------------------------------------------------------

#: Process-wide trace counters, bumped by every executor AT TRACE TIME
#: (a jit cache hit adds nothing). Keyed by engine name. Contexts
#: attribute deltas of these to their own ``trace_counts``; the streaming
#: layer reads the totals around a compaction build to report
#: ``engine_compiles_per_compaction`` (DESIGN.md §10).
_TRACE_TOTALS: Dict[str, int] = {}

#: Per-sign-bucket trace counters: ``(engine, batch-cfg tuple) -> count``.
#: The batch cfg is the sign bucket for the list engines, ``()`` for
#: engines without batch specialisation — so this resolves exactly which
#: sign-specialised variants have been compiled (DESIGN.md §11).
_TRACE_DETAIL: Dict[Tuple[str, tuple], int] = {}


def _note_trace(name: str, bcfg: tuple = ()) -> None:
    _TRACE_TOTALS[name] = _TRACE_TOTALS.get(name, 0) + 1
    key = (name, bcfg)
    _TRACE_DETAIL[key] = _TRACE_DETAIL.get(key, 0) + 1
    # observability seam: a trace is always an anomaly worth journaling
    # (it only happens off the warmed path), so it carries an event as
    # well as the counter (DESIGN.md §14)
    obs.on_engine_trace(name, bcfg)


def trace_totals() -> Dict[str, int]:
    """Snapshot of the process-wide per-engine trace counters."""
    return dict(_TRACE_TOTALS)


def trace_detail() -> Dict[Tuple[str, tuple], int]:
    """Snapshot of the per-(engine, sign-bucket) trace counters."""
    return dict(_TRACE_DETAIL)


def note_pruning_metrics(engine: str, n: int, n_scored: int,
                         depth_sum: int, m_live: int,
                         per_query_us: float,
                         sign_label: str = "") -> None:
    """Record one harvested batch's pruning-efficiency metrics into the
    observability registry: ``n_scored`` and ``depth`` totals plus the
    scored FRACTION vs the live catalogue size — the paper's efficiency
    claim as a live metric instead of an offline bench column
    (DESIGN.md §14). Called by the serving layer after it materialises
    a result host-side (never from inside an executor: results on the
    dispatch path are device futures and must stay unblocked)."""
    obs.on_batch_served(engine, n, n_scored, depth_sum, m_live,
                        per_query_us, sign_label)


class CostTable:
    """Measured per-(engine, batch-bucket, sign-bucket) serve cost.

    An EWMA (default ``alpha=0.2``) of observed per-QUERY seconds, keyed
    by the same axes the compile cache specialises on — engine name,
    power-of-two batch bucket, sign-bucket label — so a router can ask
    "what does THIS engine cost for THIS batch shape" instead of
    guessing from nnz alone. Engines without batch specialisation record
    under the empty label. ``engine_cost`` aggregates across shapes (an
    EWMA over every observation for the engine) — the admission ladder's
    coarse view; :meth:`predict` is the granular one the serving router
    uses, falling back label -> engine-aggregate unless
    ``granular_only=True`` (routing must not substitute a B=64 cost for
    a B=1 decision).

    Thread-safe: the serving pipeline's harvester thread records while
    dispatchers read. Budgeted variants record under the
    ``"<engine>@budget"`` name, same convention as the PR-7 ladder.

    :meth:`EngineContext.warmup` PRIMES the table — one timed run per
    warmed (engine, bucket, sign) AFTER its compile — so the first real
    queries after a warmup are routed and admitted from measurements,
    never from the "optimistic when unseen" default.
    """

    def __init__(self, alpha: float = 0.2):
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._ewma: Dict[Tuple[str, int, str], float] = {}
        self._engine: Dict[str, float] = {}
        self.n_observations = 0

    def observe(self, engine: str, bucket: int, label: str,
                per_query_s: float) -> None:
        """Fold one measured per-query latency into the table."""
        key = (engine, int(bucket), label)
        a = self.alpha
        with self._lock:
            prev = self._ewma.get(key)
            ewma = (per_query_s if prev is None
                    else (1 - a) * prev + a * per_query_s)
            self._ewma[key] = ewma
            prev_e = self._engine.get(engine)
            self._engine[engine] = (per_query_s if prev_e is None
                                    else (1 - a) * prev_e + a * per_query_s)
            self.n_observations += 1
        # export the folded EWMA (not the raw sample) so the gauge IS
        # the router's current belief for this (engine, bucket, sign)
        obs.on_cost_observation(engine, bucket, label, ewma)

    def predict(self, engine: str, bucket: int, label: str,
                granular_only: bool = False) -> Optional[float]:
        """Predicted per-query seconds, or None when nothing relevant was
        ever measured. Falls back (engine, bucket, label) ->
        (engine, bucket, "") -> engine aggregate unless granular_only."""
        with self._lock:
            c = self._ewma.get((engine, int(bucket), label))
            if c is None:
                c = self._ewma.get((engine, int(bucket), ""))
            if c is None and not granular_only:
                c = self._engine.get(engine)
            return c

    def engine_cost(self, engine: str) -> Optional[float]:
        """Shape-agnostic per-query seconds for ``engine`` (EWMA over
        every observation), or None if never measured."""
        with self._lock:
            return self._engine.get(engine)

    def snapshot(self) -> Dict[str, float]:
        """``"engine|bucket|label" -> seconds`` view for artifacts."""
        with self._lock:
            return {f"{e}|{b}|{lbl}": v
                    for (e, b, lbl), v in sorted(self._ewma.items())}

    def save(self, path) -> None:
        """Persist the measured state to ``path`` as JSON (ROADMAP 2b).

        Entries are stored as nested lists — ``[engine, bucket, label,
        seconds]`` — not the ``"|"``-joined display keys of
        :meth:`snapshot`, so engine names and sign labels never need
        un-parsing. A restarted server hands the loaded table to
        ``TopKServer(cost_table=...)`` and routes by these measurements
        BEFORE its first observation, instead of cold-starting on the
        heuristic.
        """
        with self._lock:
            payload = {
                "alpha": self.alpha,
                "n_observations": self.n_observations,
                "ewma": [[e, int(b), lbl, float(v)]
                         for (e, b, lbl), v in sorted(self._ewma.items())],
                "engine": {e: float(v)
                           for e, v in sorted(self._engine.items())},
            }
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")

    @classmethod
    def load(cls, path) -> "CostTable":
        """Reconstruct a table saved by :meth:`save`. The loaded EWMAs
        are live priors: new observations keep folding into them."""
        with open(path) as fh:
            payload = json.load(fh)
        table = cls(alpha=float(payload.get("alpha", 0.2)))
        with table._lock:
            for e, b, lbl, v in payload.get("ewma", []):
                table._ewma[(str(e), int(b), str(lbl))] = float(v)
            table._engine = {str(e): float(v)
                             for e, v in payload.get("engine", {}).items()}
            table.n_observations = int(payload.get("n_observations", 0))
        return table


#: engine name -> the module-level jitted executor
#: ``(args, U, *, k, cfg) -> TopKResult``. ONE executor per engine for
#: the whole process: jax's own trace cache (keyed by arg shapes/dtypes/
#: treedefs + the static ``k``/``cfg``) IS the compile cache, which is
#: what makes it snapshot- and context-free. ``cfg`` is the triple
#: ``(arg_config(ctx), batch_config(ctx, U), budget)`` — the second
#: component is the per-BATCH static bucket (the sign bucket for the
#: list engines, DESIGN.md §11), the third the per-query halting budget
#: (``None`` = run to exactness, DESIGN.md §12). Both join the compile
#: key without touching the snapshot-free arguments — budgeted variants
#: stay compile-free across compactions just like exact ones.
_ARG_EXECUTORS: Dict[str, Callable] = {}


def _make_arg_executor(name: str, run_args: Callable) -> Callable:
    def run(args, U, k, cfg):
        _note_trace(name, cfg[1])
        return run_args(args, U, k, cfg)

    return jax.jit(run, static_argnames=("k", "cfg"))


class EngineContext:
    """Catalogue + lazily built per-engine state, shared across queries.

    Args:
      targets: ``[M, R]`` catalogue factors.
      index: optional prebuilt :class:`TopKIndex` (built lazily otherwise).
      block_size: depth/block granularity handed to blocked engines.
      max_blocks: uniform halting budget (``-1`` = run to exactness).
      interpret: Pallas execution mode (``None`` = autodetect by backend).
      ta_chunk: rounds gathered per chunked-TA step (`ta` engine).
      prefix_depth: ``list_major`` layout prefix rows per dimension.
        ``None`` (default) is ADAPTIVE — the layout turns on at
        ``DEFAULT_PREFIX_DEPTH`` once ``M >= LIST_LAYOUT_MIN_TARGETS``
        and stays off below that (the cache-resident gather path is
        faster there); ``0`` disables the layout path entirely; any
        other value is honoured as given (clamped to ``M``). See
        :attr:`resolved_prefix_depth`.
      version: snapshot version of the catalogue this context was built
        from (DESIGN.md §9). Bookkeeping for the streaming layer
        (:mod:`repro.core.segments`), which builds one context per
        immutable base snapshot under a monotonically increasing
        version. Since the argument-passing refactor (DESIGN.md §10) the
        version participates ONLY in the legacy closure-engine compile
        key (``pallas``); argument-passing executors are deliberately
        version-free — that is what makes compaction compile-free.
    """

    def __init__(self, targets, index: Optional[TopKIndex] = None,
                 block_size: int = 256, max_blocks: int = -1,
                 interpret=None, ta_chunk: int = 32,
                 prefix_depth: Optional[int] = None, version: int = 0,
                 cost_table: Optional["CostTable"] = None):
        self.targets = jnp.asarray(targets, dtype=jnp.float32)
        # measured-cost table shared ACROSS contexts (the serving tier
        # passes one table through every compaction-built snapshot, so
        # observations survive snapshot swaps); select_engine consults
        # it when present and falls back to the cold heuristic otherwise
        self.cost_table = cost_table
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.interpret = interpret
        self.ta_chunk = ta_chunk
        # list_major prefix depth; None -> DEFAULT_PREFIX_DEPTH, 0 disables
        # the layout path entirely (list engines fall back to gathers)
        self.prefix_depth = prefix_depth
        self.version = int(version)
        self._index = index
        self._catalog = None
        self._norm_decay = None
        self._layouts: Dict[str, object] = {}
        # (engine name, M-bucket) -> the runtime-args pytree handed to the
        # shared executor. Built once per context; the arrays inside are
        # the padded snapshot state (DESIGN.md §10).
        self._engine_args: Dict[Tuple[str, int], Any] = {}
        self._padded_index: Dict[int, Dict[str, Array]] = {}
        # legacy per-context compiled cache, CLOSURE engines only
        # (pallas): (engine, k, batch-bucket, snapshot version) -> jitted
        # batched callable.
        self._compiled: Dict[Tuple[str, int, int, int], Callable] = {}
        # traces ATTRIBUTED to this context: closure engines bump it
        # directly at trace time; argument-passing calls add the delta of
        # the process-wide totals their dispatch caused (a cache hit adds
        # nothing — the compile-freeness assertions read exactly this).
        self.trace_counts: Dict[str, int] = {}

    @property
    def resolved_prefix_depth(self) -> int:
        """The list_major prefix depth this context builds (0 = disabled).

        ``prefix_depth=None`` is adaptive: the layout only turns on once
        the catalogue outgrows cache (``LIST_LAYOUT_MIN_TARGETS``) —
        below that the plain gather path is faster and the default stays
        on it. An explicit ``prefix_depth`` is always honoured.

        Compile-key note (DESIGN.md §10): the resolved depth sets the
        ``[R, P, R]`` prefix-tile shapes and is therefore the
        "layout-shape" component of the argument-passing compile key.
        At the adaptive default it is a constant (2048) for every
        catalogue ≥ 32k, so compaction never changes it; an explicit
        ``prefix_depth`` > the real size degrades gracefully (clamped,
        at the cost of one retrace per distinct clamp).
        """
        if self.prefix_depth is None:
            if self.num_targets < LIST_LAYOUT_MIN_TARGETS:
                return 0
            return int(min(self.num_targets, DEFAULT_PREFIX_DEPTH))
        return int(min(self.num_targets, self.prefix_depth))

    def layout(self, name: str):
        """The named catalogue layout, built lazily and cached per context.

        ``list_major`` resolves the context's ``prefix_depth``;
        ``norm_sharded`` deals the norm order over all visible devices on
        a 1-axis ``("data",)`` mesh (a 1-device mesh is valid — the
        sharded engine then degenerates to the single-host scan), with
        slabs sized for the M-bucket so the sharded executor's compile
        key is bucket-granular.
        """
        lay = self._layouts.get(name)
        if lay is None:
            params = {}
            if name == "list_major":
                params["prefix_depth"] = self.resolved_prefix_depth
            elif name == "norm_sharded":
                mesh = self.mesh
                params["n_shards"] = mesh.devices.size
                params["mesh"] = mesh
                params["m_total"] = self.m_bucket
            index = None if name == "row_major" else self.index
            lay = build_layout(name, self.targets, index, **params)
            self._layouts[name] = lay
        return lay

    @property
    def mesh(self):
        """1-axis ``("data",)`` mesh over all visible devices."""
        if getattr(self, "_mesh", None) is None:
            devs = np.asarray(jax.devices())
            self._mesh = jax.sharding.Mesh(devs, ("data",))
        return self._mesh

    @property
    def num_targets(self) -> int:
        return int(self.targets.shape[0])

    @property
    def m_bucket(self) -> int:
        """The catalogue's power-of-two M-bucket (DESIGN.md §10)."""
        return m_bucket(self.num_targets)

    @property
    def index(self) -> TopKIndex:
        if self._index is None:
            self._index = build_index(self.targets)
        return self._index

    @property
    def catalog(self):
        """Norm-ordered Pallas catalogue (built on first pallas query)."""
        if self._catalog is None:
            from repro.kernels.ops import MIPSCatalog
            self._catalog = MIPSCatalog(np.asarray(self.targets),
                                        block_m=self.block_size)
        return self._catalog

    @property
    def norm_decay(self) -> float:
        """Norm at the 10th-percentile depth over the head norm (<= 1).

        A catalogue constant, cached so per-batch `auto` dispatch does not
        re-transfer the norm spectrum from device on every query chunk.
        """
        if self._norm_decay is None:
            norms = np.asarray(self.index.norms_sorted)
            head = max(float(norms[0]), 1e-12)
            decayed = float(
                norms[min(len(norms) - 1, max(1, len(norms) // 10))])
            self._norm_decay = decayed / head
        return self._norm_decay

    # -- argument-passing machinery (DESIGN.md §10) --------------------------

    @property
    def m_real(self) -> Array:
        """The real catalogue size as a traced int32 scalar (the runtime
        companion of every M-bucket-padded argument array)."""
        return jnp.int32(self.num_targets)

    def padded_index_arrays(self, bucket: int) -> Dict[str, Array]:
        """The sorted-list index + catalogue, padded to ``bucket`` rows.

        The pad convention (DESIGN.md §10, shared with
        :func:`repro.core.layout.pad_rank_by_item`): pad TARGET rows are
        zero; each sorted list is extended past its real end with the
        pad ids in id order (so ``rank[r, order[r, d]] == d`` holds over
        the whole padded array); ``t_sorted_desc`` pad columns repeat
        the last real value (monotone, and unread — every bound lookup
        is ``m_real``-clamped). Cached per bucket.
        """
        arrs = self._padded_index.get(bucket)
        if arrs is None:
            idx = self.index
            m = self.num_targets
            pad = bucket - m
            if pad < 0:
                raise ValueError(
                    f"bucket {bucket} smaller than catalogue ({m})")
            if pad == 0:
                arrs = {"targets": self.targets,
                        "order_desc": idx.order_desc,
                        "t_sorted_desc": idx.t_sorted_desc,
                        "rank_desc": idx.rank_desc}
            else:
                r = int(self.targets.shape[1])
                pad_ids = jnp.arange(m, bucket, dtype=jnp.int32)
                pad_cols = jnp.broadcast_to(pad_ids[None, :], (r, pad))
                arrs = {
                    "targets": pad_zero_rows(self.targets, bucket),
                    "order_desc": jnp.concatenate(
                        [idx.order_desc, pad_cols], axis=1),
                    "t_sorted_desc": jnp.concatenate(
                        [idx.t_sorted_desc,
                         jnp.broadcast_to(idx.t_sorted_desc[:, -1:],
                                          (r, pad))], axis=1),
                    "rank_desc": jnp.concatenate(
                        [idx.rank_desc, pad_cols], axis=1),
                }
            self._padded_index[bucket] = arrs
        return arrs

    def engine_args(self, engine: "Engine", bucket: Optional[int] = None,
                    cache: bool = True):
        """The runtime-args pytree for ``engine`` at an M-bucket.

        ``bucket`` defaults to the catalogue's own :attr:`m_bucket`;
        warmup may request a LARGER bucket to pre-compile for future
        growth (``cache=False`` then avoids pinning the oversized arrays
        in this context). Cached per (engine, bucket).
        """
        bucket = self.m_bucket if bucket is None else int(bucket)
        if bucket < self.num_targets:
            raise ValueError(
                f"bucket {bucket} smaller than catalogue "
                f"({self.num_targets})")
        key = (engine.name, bucket)
        args = self._engine_args.get(key)
        if args is None:
            if engine.make_args is None:
                raise ValueError(
                    f"engine {engine.name!r} is not argument-passing")
            args = engine.make_args(self, bucket)
            if cache:
                self._engine_args[key] = args
        return args

    def _dispatch_args(self, engine: "Engine", args, U: Array,
                      k: int, budget: Optional[int] = None) -> TopKResult:
        """Run the shared executor, attributing any trace to this context.

        The static cfg is the triple ``(arg_config(ctx),
        batch_config(ctx, U), budget)``: the second component — the
        batch's sign bucket for the list engines — is computed host-side
        per dispatch (one ``np.asarray`` read of the query VALUES; for
        device-resident batches that is a transfer of an input, never a
        sync on pending device work) and joins the compile key, selecting
        the sign-specialised trace (DESIGN.md §11). ``budget`` (list-depth
        rows; ``None`` = exact) is the third static component — budgeted
        variants are ordinary compile-key entries, carrying no snapshot
        identity (DESIGN.md §12)."""
        acfg = engine.arg_config(self) if engine.arg_config is not None \
            else ()
        bcfg = engine.batch_config(self, U) \
            if engine.batch_config is not None else ()
        fn = _ARG_EXECUTORS[engine.name]
        before = _TRACE_TOTALS.get(engine.name, 0)
        bud = None if budget is None else int(budget)
        res = fn(args, U, k=int(k), cfg=(acfg, bcfg, bud))
        delta = _TRACE_TOTALS.get(engine.name, 0) - before
        if delta:
            self.trace_counts[engine.name] = (
                self.trace_counts.get(engine.name, 0) + delta)
        return res

    # -- legacy closure compilation cache (pallas only) ----------------------

    def compiled(self, engine: "Engine", k: int, batch: int) -> Callable:
        """A compiled ``U -> TopKResult`` for (engine, k, batch-bucket).

        Argument-passing engines return a thin binding of the shared
        module-level executor to this context's cached args (nothing is
        compiled per context). Closure engines (pallas) keep the PR-4
        per-context cache keyed ``(engine, k, batch-bucket, snapshot
        version)``: the factory is called EAGERLY (so lazy context state
        — index, Pallas catalogue — is constructed outside the trace)
        and the result wrapped in a ``jax.jit`` that survives across
        queries, bumping ``trace_counts[engine]`` at trace time only.
        """
        if engine.run_args is not None:
            args = self.engine_args(engine)

            def bound_fn(U, _eng=engine, _args=args, _k=int(k)):
                return self._dispatch_args(_eng, _args, U, _k)

            return bound_fn
        key = (engine.name, int(k), int(batch), self.version)
        fn = self._compiled.get(key)
        if fn is None:
            if engine.make_batched is None:
                raise ValueError(
                    f"engine {engine.name!r} is dispatch-only and has no "
                    "batched executable to compile")
            batched = engine.make_batched(self, int(k))
            name = engine.name

            def traced(U, _inner=batched, _name=name):
                self.trace_counts[_name] = self.trace_counts.get(_name, 0) + 1
                _note_trace(_name)
                return _inner(U)

            fn = jax.jit(traced)
            self._compiled[key] = fn
        return fn

    def run_engine(self, engine: "Engine", U: Array, k: int,
                   budget: Optional[int] = None) -> TopKResult:
        """Bucket the batch, pad, run the cached executable, slice back.

        Padding repeats the LAST query row (never zeros: an all-zero query
        deactivates every list and would drag a vmapped lockstep scan to
        its worst case); padded rows are dropped before returning, so
        per-query statistics are untouched. ``budget`` (list-depth rows)
        selects the halted certified variant (DESIGN.md §12); only
        argument-passing engines support it.
        """
        if not (isinstance(U, jax.Array) and U.ndim == 2
                and U.dtype == self.targets.dtype):
            U = jnp.atleast_2d(jnp.asarray(U, self.targets.dtype))
        b = U.shape[0]
        bucket = batch_bucket(b)
        if bucket != b:
            U = pad_to_bucket(U)
        if engine.run_args is not None:
            res = self._dispatch_args(engine, self.engine_args(engine),
                                      U, k, budget=budget)
        else:
            if budget is not None:
                raise ValueError(
                    f"engine {engine.name!r} is closure-compiled and does "
                    "not support budgeted queries")
            res = self.compiled(engine, k, bucket)(U)
        if bucket != b:
            res = jax.tree_util.tree_map(lambda a: a[:b], res)
        return res

    def warmup(self, k: int, batch_sizes=(1, 8, 64),
               engines: Optional[List[str]] = None,
               m_buckets=None, budgets=None,
               cost_table: Optional["CostTable"] = None
               ) -> "EngineContext":
        """Compile (engine, k, batch-bucket, M-bucket) executables ahead
        of traffic.

        Runs one representative batch per bucket through each executable
        engine so the first real query hits a compiled executable.
        ``m_buckets`` optionally lists CATALOGUE buckets to warm beyond
        the current one (values below it are clamped up): argument-
        passing traces are keyed by bucket, not by size, so warming the
        next bucket now makes the compaction that eventually crosses
        into it compile-free too (the streaming serving pattern,
        DESIGN.md §10). Oversized buckets are padded views built
        transiently — they are not pinned in this context's args cache.

        **Sign buckets** (DESIGN.md §11): engines with batch
        specialisation (``ta``/``bta`` once the list layout is on) are
        warmed with one representative batch per common sign bucket —
        nonneg-dense, nonpos-dense, mixed, and nonneg-sparse (the bucket
        ``auto``'s sparse→TA route produces) — so serving any of those
        buckets adds 0 retraces; the rare nonpos-sparse bucket pays its
        one trace lazily.

        ``budgets`` optionally lists halting budgets (list-depth rows) to
        warm BESIDES the exact ``None`` variant: each budget is one more
        static cfg entry per (engine, batch, sign) combination, so a
        server that degrades to budgeted certified scans under load never
        compiles on the hot path — and, like every other argument-passing
        variant, the budgeted traces survive compaction (DESIGN.md §12).

        ``cost_table`` (default: the context's own, if any) is PRIMED
        while warming: each warmed (engine, batch-bucket, sign) config at
        the CURRENT M-bucket gets one extra timed run AFTER its compile,
        recorded as that config's measured per-query cost — so the
        serving router and the admission ladder start from measurements
        instead of the optimistic unseen default. Returns self for
        chaining.
        """
        names = list(engines) if engines is not None else [
            e.name for e in list_engines() if e.has_executable]
        r = int(self.targets.shape[1])
        own = self.m_bucket
        if m_buckets is None:
            buckets_m = [own]
        else:
            buckets_m = sorted({max(int(x), own) for x in m_buckets})
        budget_list = [None] + [int(x) for x in (budgets or ())]
        ct = cost_table if cost_table is not None else self.cost_table
        for name in names:
            eng = get_engine(name)
            if eng.run_args is not None:
                buds = budget_list if eng.supports_budget else [None]
                for mb in buckets_m:
                    args = self.engine_args(eng, mb, cache=(mb == own))
                    for b in batch_sizes:
                        bucket = batch_bucket(b)
                        for U in self._warm_batches(eng, bucket, r):
                            for bud in buds:
                                res = self._dispatch_args(eng, args, U, k,
                                                          budget=bud)
                                jax.block_until_ready(res.values)
                                if ct is not None and mb == own:
                                    self._time_into(ct, eng, args, U, k,
                                                    bud, bucket)
            else:
                for b in batch_sizes:
                    bucket = batch_bucket(b)
                    U = jnp.ones((bucket, r), self.targets.dtype)
                    fn = self.compiled(eng, int(k), bucket)
                    jax.block_until_ready(fn(U).values)
                    if ct is not None:
                        t0 = time.perf_counter()
                        jax.block_until_ready(fn(U).values)
                        ct.observe(eng.name, bucket, "",
                                   (time.perf_counter() - t0) / bucket)
        return self

    def _time_into(self, ct: "CostTable", eng: "Engine", args, U, k,
                   bud, bucket: int) -> None:
        """One timed (post-compile) run, folded into the cost table under
        the same (engine, bucket, sign-label) key serving records use —
        budgeted variants under the ladder's ``"<name>@budget"`` name."""
        t0 = time.perf_counter()
        res = self._dispatch_args(eng, args, U, k, budget=bud)
        jax.block_until_ready(res.values)
        dt = time.perf_counter() - t0
        name = eng.name if bud is None else f"{eng.name}@budget"
        ct.observe(name, bucket, cost_label(eng, self, U), dt / bucket)

    def _warm_batches(self, eng: "Engine", bucket: int, r: int) -> list:
        """Representative warm batches: one per sign bucket the engine
        specialises on, or just the all-ones batch for engines without
        batch specialisation (see :meth:`warmup`)."""
        ones = jnp.ones((bucket, r), self.targets.dtype)
        if eng.batch_config is None or not eng.batch_config(self, ones):
            return [ones]
        dt = np.dtype(self.targets.dtype)
        mixed = np.ones((bucket, r), dt)
        mixed[:, 1::2] = -1.0
        sparse = np.ones((bucket, r), dt)
        sparse[:, 1::2] = 0.0
        # buckets: (1,True), (-1,True), (0,False), (1,False)
        return [ones, -ones, jnp.asarray(mixed), jnp.asarray(sparse)]


@dataclasses.dataclass(frozen=True)
class Engine:
    """A registered engine: executable factory + capability metadata.

    Exactly one of three execution styles (DESIGN.md §10):

    * ``run_args`` + ``make_args`` (+ optional ``arg_config``) — an
      ARGUMENT-PASSING engine. ``make_args(ctx, m_bucket)`` returns the
      runtime pytree of padded snapshot state; ``run_args(args, U, k,
      cfg)`` is the pure batched body the module-level shared executor
      jits (``k`` and the hashable ``cfg`` from ``arg_config(ctx)`` are
      static). Its compile key carries no snapshot identity — every
      same-bucket snapshot shares every trace.
    * ``make_batched(ctx, k)`` — a CLOSURE engine: returns a pure
      ``U [B, R] -> TopKResult`` callable that closes over context state
      (trace-safe; any host-side setup such as index construction
      happens inside the factory, eagerly), compiled per context with
      the snapshot version in the key.
    * ``dispatch(ctx, U, k)`` — dispatch pseudo-engines (``auto``) and
      host-only reference oracles (``fagin``, ``partial``), routed per
      batch, never jitted.

    ``layout`` names the :mod:`repro.core.layout` the engine consumes
    (built via :meth:`EngineContext.layout`); ``traffic`` estimates the
    engine's memory traffic for a measured :class:`TopKResult` (per-query
    means: rows gathered, contiguous rows read, bytes moved) — the
    benchmark sweep records it so layout wins show up in the perf
    trajectory, not just wall-clock.
    """

    name: str
    make_batched: Optional[
        Callable[["EngineContext", int], Callable[[Array], TopKResult]]
    ] = None
    dispatch: Optional[
        Callable[["EngineContext", Array, int], TopKResult]] = None
    make_args: Optional[Callable[["EngineContext", int], Any]] = None
    run_args: Optional[
        Callable[[Any, Array, int, tuple], TopKResult]] = None
    arg_config: Optional[Callable[["EngineContext"], tuple]] = None
    #: optional ``(ctx, U) -> tuple``: a HOST-computed static bucket of
    #: the query batch's VALUES that joins the executor compile key (the
    #: sign bucket for the list engines, DESIGN.md §11). Must be cheap,
    #: hashable, and (); for engines without batch specialisation.
    batch_config: Optional[Callable[["EngineContext", Any], tuple]] = None
    exact: bool = True
    needs_index: bool = True
    supports_batch: bool = True
    #: True for engines that honour ``run(..., budget=)`` — a list-depth
    #: halting budget joining the executor compile key, with the halted
    #: result carrying a per-item certificate bound (DESIGN.md §12).
    supports_budget: bool = False
    backend: str = "jax"
    layout: Optional[str] = None
    host_only: bool = False
    traffic: Optional[
        Callable[["EngineContext", TopKResult], Dict[str, float]]] = None
    description: str = ""

    @property
    def has_executable(self) -> bool:
        """True for engines with a compiled batched body (everything but
        the dispatch pseudo-engines and the host oracles)."""
        return self.run_args is not None or self.make_batched is not None

    def run(self, ctx: EngineContext, U: Array, k: int,
            budget: Optional[int] = None) -> TopKResult:
        if budget is not None and not self.supports_budget:
            raise ValueError(
                f"engine {self.name!r} does not support budgeted queries; "
                "use one of "
                f"{[e.name for e in list_engines() if e.supports_budget]}")
        if self.dispatch is not None:
            if budget is not None:
                return self.dispatch(ctx, U, k, budget)
            return self.dispatch(ctx, U, k)
        return ctx.run_engine(self, U, k, budget=budget)


_REGISTRY: Dict[str, Engine] = {}
_ALIASES: Dict[str, str] = {
    "threshold": "ta",
    "blocked": "bta",
    "norm_pruned": "norm",
    "topk_mips": "pallas",
}


def register_engine(engine: Engine) -> Engine:
    _REGISTRY[engine.name] = engine
    if engine.run_args is not None:
        _ARG_EXECUTORS[engine.name] = _make_arg_executor(engine.name,
                                                         engine.run_args)
    return engine


def get_engine(name: str) -> Engine:
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def engine_names() -> List[str]:
    return sorted(_REGISTRY)


def list_engines(exact: Optional[bool] = None,
                 backend: Optional[str] = None,
                 needs_index: Optional[bool] = None) -> List[Engine]:
    out = []
    for name in engine_names():
        e = _REGISTRY[name]
        if exact is not None and e.exact != exact:
            continue
        if backend is not None and e.backend != backend:
            continue
        if needs_index is not None and e.needs_index != needs_index:
            continue
        out.append(e)
    return out


# ---------------------------------------------------------------------------
# Built-in engines
# ---------------------------------------------------------------------------


def _naive_args(ctx: EngineContext, bucket: int):
    return {"targets": pad_zero_rows(ctx.targets, bucket),
            "m_real": ctx.m_real}


def _naive_run(args, U, k, cfg):
    T, m = args["targets"], args["m_real"]
    mb = T.shape[0]
    scores = U @ T.T
    # pad rows are zero rows: mask them to -inf so they can never outrank
    # a real (possibly all-negative) score
    scores = jnp.where(jnp.arange(mb, dtype=jnp.int32)[None, :] < m,
                       scores, NEG_INF)
    vals, ids = jax.lax.top_k(scores, min(k, mb))
    ids = jnp.where(jnp.isneginf(vals), -1, ids)
    b = U.shape[0]
    # a full scan leaves nothing unenumerated: the bound on unseen items
    # is vacuous (-inf), so every returned slot is certified
    return TopKResult(vals, ids,
                      jnp.broadcast_to(m, (b,)).astype(jnp.int32),
                      jnp.zeros((b,), jnp.int32),
                      upper=jnp.full((b,), NEG_INF, vals.dtype))


def _list_layout(ctx: EngineContext):
    """The list_major layout, or None when the context disables it."""
    return ctx.layout("list_major") if ctx.resolved_prefix_depth > 0 \
        else None


def _list_batch_cfg(ctx: EngineContext, U) -> tuple:
    """Sign bucket of the query batch, joined to the compile key.

    With the list layout off the batched-native prefix scan never runs,
    so the bucket is dropped from the key — every batch shares ONE
    traced variant, exactly the PR-5 behaviour (and the small-M trace
    count tests stay valid).
    """
    if ctx.resolved_prefix_depth <= 0:
        return ()
    return sign_bucket(U)


def _tail_pallas(ctx: EngineContext) -> bool:
    # gather-fused Pallas tail scoring only pays on real TPU backends
    return (jax.default_backend() == "tpu"
            and ctx.resolved_prefix_depth > 0)


def _list_args(ctx: EngineContext, bucket: int):
    """Shared args for the list engines: padded index + padded layout."""
    args = dict(ctx.padded_index_arrays(bucket))
    lay = _list_layout(ctx)
    if lay is not None:
        lay = dataclasses.replace(
            lay, rank_by_item=pad_rank_by_item(lay.rank_by_item, bucket))
    args["layout"] = lay
    args["m_real"] = ctx.m_real
    return args


def _ta_cfg(ctx: EngineContext) -> tuple:
    return (ctx.ta_chunk, ctx.max_blocks, _tail_pallas(ctx))


def _ta_run(args, U, k, cfg):
    # chunked TA: block-shaped work per step, sequential-round accounting
    # (count-faithful to the paper's Algorithm 2). With the list_major
    # layout the rounds inside the prefix are gather-free (DESIGN.md §7),
    # and a sign-bucketed batch takes the batched-native prefix scan —
    # ONE shared tile enumeration for the whole batch (DESIGN.md §11).
    # TA's round unit IS list depth, so a budget caps rounds directly.
    (chunk, max_rounds, tail_pallas), bcfg, budget = cfg
    if budget is not None:
        max_rounds = budget if max_rounds < 0 else min(max_rounds, budget)
    lay = args["layout"]

    if bcfg and lay is not None and lay.serves_sign(bcfg[0]) \
            and lay.prefix_steps(chunk) > 0:
        sign, dense = bcfg
        return chunked_ta_topk_batched_native(
            args["targets"], args["order_desc"], args["t_sorted_desc"],
            U, k, chunk=chunk, max_rounds=max_rounds, layout=lay,
            sign=sign, dense=dense, tail_pallas=tail_pallas,
            m_real=args["m_real"])

    # vmapped fallback; a single-sided layout cannot feed the per-query
    # (both-direction) prefix path, so it degrades to the gather scan
    lay_pq = lay if (lay is not None and lay.two_sided) else None

    def one(u):
        return chunked_ta_topk(args["targets"], args["order_desc"],
                               args["t_sorted_desc"], args["rank_desc"],
                               u, k, chunk=chunk, max_rounds=max_rounds,
                               layout=lay_pq,
                               tail_pallas=tail_pallas,
                               m_real=args["m_real"])

    return jax.vmap(one)(U)


def _bta_cfg(ctx: EngineContext) -> tuple:
    return (ctx.block_size, ctx.max_blocks, _tail_pallas(ctx))


def _bta_run(args, U, k, cfg):
    (block_size, max_blocks, tail_pallas), bcfg, budget = cfg
    if budget is not None:
        # budget is list-depth rows; BTA halts at block granularity
        bb = max(1, -(-budget // block_size))
        max_blocks = bb if max_blocks < 0 else min(max_blocks, bb)
    lay = args["layout"]

    if bcfg and lay is not None and lay.serves_sign(bcfg[0]) \
            and lay.prefix_steps(block_size) > 0:
        sign, dense = bcfg
        return blocked_topk_batched_native(
            args["targets"], args["order_desc"], args["t_sorted_desc"],
            U, k, block_size=block_size, max_blocks=max_blocks,
            layout=lay, sign=sign, dense=dense, tail_pallas=tail_pallas,
            m_real=args["m_real"])

    lay_pq = lay if (lay is not None and lay.two_sided) else None

    def one(u):
        return blocked_topk(args["targets"], args["order_desc"],
                            args["t_sorted_desc"], u, k, block_size,
                            max_blocks, rank_desc=args["rank_desc"],
                            layout=lay_pq,
                            tail_pallas=tail_pallas,
                            m_real=args["m_real"])

    return jax.vmap(one)(U)


def _norm_args(ctx: EngineContext, bucket: int):
    lay = ctx.layout("norm_major")
    m = ctx.num_targets
    pad = bucket - m
    if pad == 0:
        return {"targets_by_norm": lay.targets_by_norm,
                "norm_order": lay.norm_order,
                "norms_sorted": lay.norms_sorted,
                "m_real": ctx.m_real}
    # pad rows: zero rows with norm 0 and id -1 — they sort last, so the
    # real norm-order prefix (and every Cauchy-Schwarz bound the scan can
    # reach) is untouched
    return {
        "targets_by_norm": pad_zero_rows(lay.targets_by_norm, bucket),
        "norm_order": jnp.concatenate(
            [lay.norm_order, jnp.full((pad,), -1, jnp.int32)]),
        "norms_sorted": pad_zero_rows(lay.norms_sorted, bucket),
        "m_real": ctx.m_real,
    }


def _norm_cfg(ctx: EngineContext) -> tuple:
    return (ctx.block_size, ctx.max_blocks)


def _norm_run(args, U, k, cfg):
    (block_size, max_blocks), _, budget = cfg
    if budget is not None:
        # budget is rows enumerated in norm order, i.e. blocks * block
        bb = max(1, -(-budget // block_size))
        max_blocks = bb if max_blocks < 0 else min(max_blocks, bb)
    mb = args["targets_by_norm"].shape[0]
    # batched-native scan: every query walks the SAME norm-ordered
    # prefix, so one shared tile slice + one [B,R]@[R,block] matmul
    # serves the whole batch (no per-query gathers). Tiny catalogues
    # shrink the block to the bucket so the slice stays in bounds.
    return norm_pruned_topk_batched(
        args["targets_by_norm"], args["norm_order"], args["norms_sorted"],
        U, k, min(block_size, mb), max_blocks, m_real=args["m_real"])


def _norm_sharded_args(ctx: EngineContext, bucket: int):
    if bucket == ctx.m_bucket:
        lay = ctx.layout("norm_sharded")
    else:
        mesh = ctx.mesh
        lay = build_layout("norm_sharded", ctx.targets, ctx.index,
                          n_shards=mesh.devices.size, mesh=mesh,
                          m_total=bucket)
    return {"targets_sharded": lay.targets_sharded,
            "norms_sharded": lay.norms_sharded,
            "ids_sharded": lay.ids_sharded}


def _norm_sharded_cfg(ctx: EngineContext) -> tuple:
    return (ctx.block_size, ctx.max_blocks, ctx.mesh)


def _norm_sharded_run(args, U, k, cfg):
    from repro.core.sharded import sharded_norm_topk
    # budget unsupported (supports_budget=False): cfg[2] is always None
    (block_size, max_blocks, mesh), _, _ = cfg
    scan = sharded_norm_topk(mesh, ("data",))
    return scan(args["targets_sharded"], args["norms_sharded"],
                args["ids_sharded"], U, k, block_size, max_blocks)


def _pallas_batched(ctx: EngineContext, k: int):
    cat = ctx.catalog       # built eagerly, outside the trace
    interpret = ctx.interpret
    block_m = jnp.int32(cat.block_m)

    def fn(U):
        vals, ids, stats = cat.query_batch(U, k, interpret=interpret)
        # stats = (rows scored incl. block padding, blocks visited, loaded)
        # exact kernel: vacuous -inf bound => fully certified result, and
        # the pytree structure matches the argument-passing engines so
        # mixed-engine chunk results concatenate cleanly
        return TopKResult(vals, ids, stats[:, 0], stats[:, 1] * block_m,
                          upper=jnp.full((U.shape[0],), NEG_INF,
                                         vals.dtype))

    return fn


def _host_nnz_frac(U) -> float:
    """Batch sparsity, computed on the HOST.

    numpy/list inputs never touch the device; a jax Array input is read
    back once (it is an input *value*, not a pending computation, so no
    work — and no blocking reduction — is enqueued on the device query
    stream the engines are using).
    """
    arr = U if isinstance(U, np.ndarray) else np.asarray(U)
    return float(np.count_nonzero(arr)) / max(arr.size, 1)


#: COLD-START batch size at which the batched-native list scan is assumed
#: to amortise its shared tile enumeration well enough to prefer the list
#: engines (DESIGN.md §11). Once a :class:`CostTable` has measurements
#: for every auto candidate at the batch's (bucket, sign), the measured
#: costs replace this constant entirely (ROADMAP item 3c).
BATCHED_LIST_MIN_B = 8


def cost_label(eng: Engine, ctx: EngineContext, U) -> str:
    """The sign-bucket label ``eng`` would serve ``U`` under — the
    third axis of every :class:`CostTable` key, shared by warm-time
    priming and serve-time recording so the two can never disagree.
    Empty for engines without batch specialisation (and for the list
    engines while the layout is off, where every batch shares one
    trace)."""
    if eng.batch_config is None:
        return ""
    bcfg = eng.batch_config(ctx, U)
    return sign_bucket_label(bcfg) if bcfg else ""


def _select_by_cost(ctx: EngineContext, arr, bucket: int,
                    ct: CostTable) -> Optional[Engine]:
    """Measured-cost route: the cheapest auto candidate at this batch's
    (bucket, sign) — or None unless EVERY candidate has a granular
    measurement (an unmeasured engine is an unwarmed engine; dispatching
    to it on a hunch would compile on the hot path, and comparing a
    measurement against the optimistic unseen default is not a
    comparison)."""
    best, best_c = None, None
    for name in auto_candidates():
        eng = get_engine(name)
        c = ct.predict(name, bucket, cost_label(eng, ctx, arr),
                       granular_only=True)
        if c is None:
            return None
        if best_c is None or c < best_c:
            best, best_c = eng, c
    return best


def select_engine(ctx: EngineContext, U,
                  cost_table: Optional[CostTable] = None) -> Engine:
    """The ``auto`` policy: pick an engine for this query batch.

    MEASURED route first: when a :class:`CostTable` (the explicit
    argument, or the context's own) has an observed per-query cost for
    every auto candidate at this batch's (power-of-two bucket, sign
    bucket), the cheapest measured engine wins — the constant below
    never fires on a warmed serving path.

    COLD fallback: decides from three cheap HOST-side statistics — batch
    sparsity ``nnz(u)`` (sparse queries make TA's per-round cost
    collapse to the active lists), the BATCH SIZE (the batched-native
    list scan shares one prefix-tile enumeration across the batch, so
    the list engines' per-query cost collapses at
    ``B >= BATCHED_LIST_MIN_B`` — below that they pay the per-query
    lockstep scan), and the catalogue norm spectrum (a decaying spectrum
    lets the Cauchy-Schwarz scan certify after a few contiguous blocks —
    the Pallas kernel's best case; a flat spectrum makes it a full scan,
    so BTA wins when the batched list path is live).
    """
    arr = U if isinstance(U, np.ndarray) else np.asarray(U)
    b = 1 if arr.ndim < 2 else arr.shape[0]
    ct = cost_table if cost_table is not None else ctx.cost_table
    if ct is not None:
        eng = _select_by_cost(ctx, arr, batch_bucket(b), ct)
        if eng is not None:
            return eng
    batched_lists = (ctx.resolved_prefix_depth > 0
                     and batch_bucket(b) >= BATCHED_LIST_MIN_B)
    if _host_nnz_frac(arr) < 0.25 and \
            (batched_lists or ctx.resolved_prefix_depth <= 0):
        # sparse queries: TA's rounds collapse to the active lists.
        # With the layout ON but the batch too small to amortise the
        # batched scan, the per-query lockstep loop would dominate —
        # fall through to the contiguous norm scan instead.
        return get_engine("ta")
    if ctx.norm_decay < 0.5 or not batched_lists:
        return get_engine(
            "pallas" if jax.default_backend() == "tpu" else "norm")
    return get_engine("bta")


def auto_candidates():
    """Engine names :func:`select_engine` can resolve to on this backend.

    Warming exactly this set covers every dispatch ``auto`` can make
    (including the small-batch routes that prefer the shared-tile norm
    scan over the per-query list loop); warming beyond it
    (``norm_sharded`` in particular, whose layout build copies the whole
    catalogue) is wasted startup work.

    ``naive`` is a candidate for the MEASURED route only (the cold
    heuristic never picks it): the full ``[B,R]@[R,M]`` matmul batches
    through one sgemm, so past B~32 on CPU its per-query cost collapses
    ~10x from B=1 while the pruned engines' shared scans amortise only
    2-4x — the enumeration is shared but each lane's depth is driven by
    the batch's worst lane. Whether the scan's skipped scores beat the
    matmul's raw throughput at a given (bucket, sign) is exactly the
    question the cost table answers with measurements.
    """
    return ["ta", "bta", "naive",
            "pallas" if jax.default_backend() == "tpu" else "norm"]


def _auto_dispatch(ctx: EngineContext, U, k: int,
                   budget: Optional[int] = None) -> TopKResult:
    eng = select_engine(ctx, U)
    if budget is not None and not eng.supports_budget:
        # every budget-capable fallback walks the same contiguous norm
        # order, so it is the natural degraded target (DESIGN.md §12)
        eng = get_engine("norm")
    return eng.run(ctx, U, k, budget=budget)


# ---------------------------------------------------------------------------
# Host-only reference oracles (paper Algorithms 1 and 3) as engines
# ---------------------------------------------------------------------------


def _host_oracle_dispatch(one_query):
    """Wrap a numpy oracle ``(T, order_desc, u, k) -> (v, i, n, d)``."""

    def dispatch(ctx: EngineContext, U, k: int) -> TopKResult:
        T = np.asarray(ctx.targets)
        od = np.asarray(ctx.index.order_desc)
        U_np = np.atleast_2d(np.asarray(U, np.float32))
        k_eff = min(int(k), T.shape[0])
        vals = np.full((U_np.shape[0], k_eff), float("-inf"), np.float32)
        ids = np.full((U_np.shape[0], k_eff), -1, np.int32)
        ns = np.zeros((U_np.shape[0],), np.int32)
        dep = np.zeros((U_np.shape[0],), np.int32)
        for b, u in enumerate(U_np):
            v, i, n, d = one_query(T, od, u, k_eff)
            vals[b, :len(v)] = v
            ids[b, :len(i)] = i
            ns[b], dep[b] = n, d
        return TopKResult(jnp.asarray(vals), jnp.asarray(ids),
                          jnp.asarray(ns), jnp.asarray(dep),
                          upper=jnp.full((U_np.shape[0],), float("-inf"),
                                         jnp.float32))

    return dispatch


def _fagin_one(T, od, u, k):
    from repro.core.fagin import fagin_topk_np
    v, i, st = fagin_topk_np(T, od, u, k)
    return v, i, st.n_scored, st.depth


def _partial_one(T, od, u, k):
    from repro.core.partial import partial_threshold_topk_np
    v, i, st = partial_threshold_topk_np(T, od, u, k)
    # n_items_touched == TA's n_scored (Theorem 4 logic: same item set)
    return v, i, st.n_items_touched, st.depth


# ---------------------------------------------------------------------------
# Memory-traffic estimators (per-query means, from measured counts)
# ---------------------------------------------------------------------------


def _traffic_dict(ctx: EngineContext, rows_gathered, rows_contiguous):
    r = int(ctx.targets.shape[1])
    total = rows_gathered + rows_contiguous
    return {
        "rows_gathered": float(rows_gathered),
        "rows_contiguous": float(rows_contiguous),
        "est_bytes_moved": float(total * r * 4),
        "gather_fraction": float(rows_gathered / total) if total else 0.0,
    }


def _naive_traffic(ctx, res):
    return _traffic_dict(ctx, 0.0, float(ctx.num_targets))


def _list_traffic(ctx, res):
    """TA/BTA: depth (list-depth units) splits at the layout prefix.

    Inside the prefix each of the R lists reads its depth range from BOTH
    direction tiles (head + tail, then a select) — contiguous, 2x rows.
    Past the prefix every candidate costs a scattered target row PLUS a
    same-shape ``rank_by_item`` row for freshness. With the layout off
    (``resolved_prefix_depth == 0``, the adaptive default below
    ``LIST_LAYOUT_MIN_TARGETS``) the engines run the plain gather path:
    ONE target row per candidate, and freshness comes from the O(R*M)
    first-occurrence key precompute — a contiguous stream of the
    ``[R, M]`` int32 rank array, M row-equivalents of bytes per query.
    """
    r = int(ctx.targets.shape[1])
    p = ctx.resolved_prefix_depth
    depth = float(np.mean(np.asarray(res.depth)))
    if p == 0:
        return _traffic_dict(ctx, depth * r, float(ctx.num_targets))
    contig = 2.0 * min(depth, p) * r
    gathered = 2.0 * max(depth - p, 0.0) * r
    return _traffic_dict(ctx, gathered, contig)


def _norm_traffic(ctx, res):
    # depth is rows enumerated in norm order — all contiguous tile reads
    return _traffic_dict(ctx, 0.0, float(np.mean(np.asarray(res.depth))))


def _host_traffic(ctx, res):
    # item-at-a-time oracles: every scored row is a random access
    return _traffic_dict(ctx, float(np.mean(np.asarray(res.n_scored))), 0.0)


register_engine(Engine(
    name="naive", make_args=_naive_args, run_args=_naive_run,
    exact=True, needs_index=False,
    supports_batch=True, supports_budget=True,  # budget ignored: one matmul
    backend="jax", layout="row_major",
    traffic=_naive_traffic,
    description="full matmul + lax.top_k (strongest wall-clock baseline)"))
register_engine(Engine(
    name="ta", make_args=_list_args, run_args=_ta_run, arg_config=_ta_cfg,
    batch_config=_list_batch_cfg,
    exact=True, needs_index=True,
    supports_batch=True, supports_budget=True, backend="jax",
    layout="list_major",
    traffic=_list_traffic,
    description="Threshold Algorithm rounds (paper Alg. 2; chunked "
                "execution, sequential-round accounting, batched-native "
                "sign-specialised list-prefix tiles)"))
register_engine(Engine(
    name="bta", make_args=_list_args, run_args=_bta_run,
    arg_config=_bta_cfg, batch_config=_list_batch_cfg,
    exact=True, needs_index=True,
    supports_batch=True, supports_budget=True, backend="jax",
    layout="list_major",
    traffic=_list_traffic,
    description="Block Threshold Algorithm (MXU-shaped TA, batched-native "
                "sign-specialised list-prefix tiles)"))
register_engine(Engine(
    name="norm", make_args=_norm_args, run_args=_norm_run,
    arg_config=_norm_cfg, exact=True, needs_index=True,
    supports_batch=True, supports_budget=True, backend="jax",
    layout="norm_major",
    traffic=_norm_traffic,
    description="Cauchy-Schwarz norm-ordered block scan"))
register_engine(Engine(
    name="norm_sharded", make_args=_norm_sharded_args,
    run_args=_norm_sharded_run, arg_config=_norm_sharded_cfg, exact=True,
    needs_index=True, supports_batch=True, backend="jax",
    layout="norm_sharded", traffic=_norm_traffic,
    description="shared-tile norm scan under shard_map with cross-shard "
                "pmax threshold tightening (row-sharded catalogue)"))
register_engine(Engine(
    name="pallas", make_batched=_pallas_batched, exact=True, needs_index=True,
    supports_batch=True, backend="pallas", layout="norm_major",
    traffic=_norm_traffic,
    description="norm-ordered block scan as a Pallas TPU kernel with "
                "two-level DMA-skipping bounds (interpret-mode on CPU; "
                "closure-compiled — the one engine whose compile key "
                "still carries the snapshot version)"))
register_engine(Engine(
    name="fagin", dispatch=_host_oracle_dispatch(_fagin_one), exact=True,
    needs_index=True, supports_batch=False, backend="numpy",
    layout="row_major", host_only=True, traffic=_host_traffic,
    description="Fagin's Algorithm (paper Alg. 1; host-only numpy "
                "reference, no jit)"))
register_engine(Engine(
    name="partial", dispatch=_host_oracle_dispatch(_partial_one), exact=True,
    needs_index=True, supports_batch=False, backend="numpy",
    layout="row_major", host_only=True, traffic=_host_traffic,
    description="Partial Threshold Algorithm (paper Alg. 3 / Eq. 4; "
                "host-only numpy reference, no jit)"))
register_engine(Engine(
    name="auto", dispatch=_auto_dispatch, exact=True, needs_index=True,
    supports_batch=True, supports_budget=True, backend="dispatch",
    description="per-batch pick from host-side nnz(u) + catalogue norm "
                "spectrum"))
