"""Engine registry: every top-K engine behind one name-keyed interface.

The serving layer, the benchmark harness, and tests all dispatch through
this registry (DESIGN.md §1) instead of hand-rolled ``if/elif`` chains.
An :class:`Engine` bundles the callable with capability metadata (exact?
needs the sorted-list index? batched? which backend executes it?) so
callers can enumerate, filter, and sweep engines they have never heard of
— which is how future engines (LEMP-style per-bucket bounds, sharded
variants, approximate modes) become reachable from every layer by adding
one ``register`` call.

Engines run against an :class:`EngineContext` — the catalogue plus lazily
built derived state (sorted-list index, Pallas catalogue) shared across
queries, so a server builds it once and every engine reuses it.

Registered engines:

==========  =======  ===========  ========  ==================================
name        exact    needs_index  backend   algorithm
==========  =======  ===========  ========  ==================================
``naive``   yes      no           jax       full matmul + top_k
``ta``      yes      yes          jax       TA rounds (blocked strategy, B=1)
``bta``     yes      yes          jax       Block Threshold Algorithm
``norm``    yes      yes          jax       Cauchy-Schwarz norm-block scan
``pallas``  yes      yes          pallas    norm-block scan as a TPU kernel
``auto``    yes      yes          dispatch  picks per batch (see below)
==========  =======  ===========  ========  ==================================

``auto`` picks per query batch: sparse batches go to ``ta`` (zero-weight
lists are never walked, so TA's per-round work collapses to nnz(u)); dense
batches over catalogues whose norm spectrum decays go to the norm scan
(``pallas`` on TPU, ``norm`` elsewhere); flat-spectrum dense batches go to
``bta``.

Aliases accepted by :func:`get_engine`: ``threshold -> ta``,
``blocked -> bta``, ``norm_pruned -> norm``, ``topk_mips -> pallas``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import blocked_topk_batched, norm_pruned_topk
from repro.core.index import TopKIndex, build_index
from repro.core.naive import TopKResult, naive_topk

Array = jnp.ndarray


class EngineContext:
    """Catalogue + lazily built per-engine state, shared across queries.

    Args:
      targets: ``[M, R]`` catalogue factors.
      index: optional prebuilt :class:`TopKIndex` (built lazily otherwise).
      block_size: depth/block granularity handed to blocked engines.
      max_blocks: uniform halting budget (``-1`` = run to exactness).
      interpret: Pallas execution mode (``None`` = autodetect by backend).
    """

    def __init__(self, targets, index: Optional[TopKIndex] = None,
                 block_size: int = 256, max_blocks: int = -1,
                 interpret=None):
        self.targets = jnp.asarray(targets, dtype=jnp.float32)
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.interpret = interpret
        self._index = index
        self._catalog = None
        self._norm_decay = None

    @property
    def num_targets(self) -> int:
        return int(self.targets.shape[0])

    @property
    def index(self) -> TopKIndex:
        if self._index is None:
            self._index = build_index(self.targets)
        return self._index

    @property
    def catalog(self):
        """Norm-ordered Pallas catalogue (built on first pallas query)."""
        if self._catalog is None:
            from repro.kernels.ops import MIPSCatalog
            self._catalog = MIPSCatalog(np.asarray(self.targets),
                                        block_m=self.block_size)
        return self._catalog

    @property
    def norm_decay(self) -> float:
        """Norm at the 10th-percentile depth over the head norm (<= 1).

        A catalogue constant, cached so per-batch `auto` dispatch does not
        re-transfer the norm spectrum from device on every query chunk.
        """
        if self._norm_decay is None:
            norms = np.asarray(self.index.norms_sorted)
            head = max(float(norms[0]), 1e-12)
            decayed = float(
                norms[min(len(norms) - 1, max(1, len(norms) // 10))])
            self._norm_decay = decayed / head
        return self._norm_decay


@dataclasses.dataclass(frozen=True)
class Engine:
    """A registered engine: callable + capability metadata."""

    name: str
    run: Callable[[EngineContext, Array, int], TopKResult]  # (ctx, U[B,R], k)
    exact: bool = True
    needs_index: bool = True
    supports_batch: bool = True
    backend: str = "jax"
    description: str = ""


_REGISTRY: Dict[str, Engine] = {}
_ALIASES: Dict[str, str] = {
    "threshold": "ta",
    "blocked": "bta",
    "norm_pruned": "norm",
    "topk_mips": "pallas",
}


def register_engine(engine: Engine) -> Engine:
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> Engine:
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def engine_names() -> List[str]:
    return sorted(_REGISTRY)


def list_engines(exact: Optional[bool] = None,
                 backend: Optional[str] = None,
                 needs_index: Optional[bool] = None) -> List[Engine]:
    out = []
    for name in engine_names():
        e = _REGISTRY[name]
        if exact is not None and e.exact != exact:
            continue
        if backend is not None and e.backend != backend:
            continue
        if needs_index is not None and e.needs_index != needs_index:
            continue
        out.append(e)
    return out


# ---------------------------------------------------------------------------
# Built-in engines
# ---------------------------------------------------------------------------


def _naive_run(ctx: EngineContext, U: Array, k: int) -> TopKResult:
    return naive_topk(ctx.targets, U, k)


def _ta_run(ctx: EngineContext, U: Array, k: int) -> TopKResult:
    # blocked strategy at block_size=1 is id-for-id the paper's TA rounds
    # (and stays O(R) memory per query under vmap, unlike flipped views)
    return blocked_topk_batched(ctx.targets, ctx.index, U, k, block_size=1,
                                max_blocks=ctx.max_blocks)


def _bta_run(ctx: EngineContext, U: Array, k: int) -> TopKResult:
    return blocked_topk_batched(ctx.targets, ctx.index, U, k,
                                ctx.block_size, ctx.max_blocks)


def _norm_run(ctx: EngineContext, U: Array, k: int) -> TopKResult:
    idx = ctx.index

    def one(u):
        return norm_pruned_topk(ctx.targets, idx.norm_order,
                                idx.norms_sorted, u, k, ctx.block_size,
                                ctx.max_blocks)

    return jax.vmap(one)(U)


def _pallas_run(ctx: EngineContext, U: Array, k: int) -> TopKResult:
    cat = ctx.catalog
    vals, ids, stats = cat.query_batch(U, k, interpret=ctx.interpret)
    # stats = (rows scored incl. block padding, blocks visited)
    return TopKResult(vals, ids, stats[:, 0],
                      stats[:, 1] * jnp.int32(cat.block_m))


def select_engine(ctx: EngineContext, U: Array) -> Engine:
    """The ``auto`` policy: pick an engine for this query batch.

    Decides from two cheap statistics: batch sparsity ``nnz(u)`` (sparse
    queries make TA's per-round cost collapse to the active lists) and the
    catalogue norm spectrum (a decaying spectrum lets the Cauchy-Schwarz
    scan certify after a few contiguous blocks — the Pallas kernel's best
    case; a flat spectrum makes it a full scan, so BTA wins).
    """
    U = jnp.atleast_2d(U)
    nnz_frac = float(jnp.mean((U != 0).astype(jnp.float32)))
    if nnz_frac < 0.25:
        return get_engine("ta")
    if ctx.norm_decay < 0.5:
        return get_engine(
            "pallas" if jax.default_backend() == "tpu" else "norm")
    return get_engine("bta")


def _auto_run(ctx: EngineContext, U: Array, k: int) -> TopKResult:
    return select_engine(ctx, U).run(ctx, U, k)


register_engine(Engine(
    name="naive", run=_naive_run, exact=True, needs_index=False,
    supports_batch=True, backend="jax",
    description="full matmul + lax.top_k (strongest wall-clock baseline)"))
register_engine(Engine(
    name="ta", run=_ta_run, exact=True, needs_index=True,
    supports_batch=True, backend="jax",
    description="Threshold Algorithm rounds (paper Alg. 2; blocked "
                "strategy at block_size=1)"))
register_engine(Engine(
    name="bta", run=_bta_run, exact=True, needs_index=True,
    supports_batch=True, backend="jax",
    description="Block Threshold Algorithm (MXU-shaped TA)"))
register_engine(Engine(
    name="norm", run=_norm_run, exact=True, needs_index=True,
    supports_batch=True, backend="jax",
    description="Cauchy-Schwarz norm-ordered block scan"))
register_engine(Engine(
    name="pallas", run=_pallas_run, exact=True, needs_index=True,
    supports_batch=True, backend="pallas",
    description="norm-ordered block scan as a Pallas TPU kernel "
                "(interpret-mode on CPU)"))
register_engine(Engine(
    name="auto", run=_auto_run, exact=True, needs_index=True,
    supports_batch=True, backend="dispatch",
    description="per-batch pick from nnz(u) + catalogue norm spectrum"))
