"""Engine registry: every top-K engine behind one name-keyed interface.

The serving layer, the benchmark harness, and tests all dispatch through
this registry (DESIGN.md §1) instead of hand-rolled ``if/elif`` chains.
An :class:`Engine` bundles a batched-executable factory with capability
metadata (exact? needs the sorted-list index? batched? which backend
executes it?) so callers can enumerate, filter, and sweep engines they
have never heard of — which is how future engines (LEMP-style per-bucket
bounds, sharded variants, approximate modes) become reachable from every
layer by adding one ``register`` call.

Engines run against an :class:`EngineContext` — the catalogue plus lazily
built derived state (sorted-list index, Pallas catalogue) shared across
queries, so a server builds it once and every engine reuses it.

**Compilation cache** (DESIGN.md §6): ``Engine.run`` dispatches through a
persistent per-context ``jax.jit`` cache keyed by
``(engine, k, batch-bucket)``. Batch sizes are bucketed to the next power
of two (queries are padded by repeating the last row, results sliced
back), so a serving process compiles each engine a handful of times total
instead of re-tracing ``vmap`` closures on every call.
:meth:`EngineContext.warmup` populates the cache ahead of traffic, and
:attr:`EngineContext.trace_counts` counts actual traces per engine so
tests can assert the cache is hit (0 new traces after warmup).

Registered engines:

==========  =======  ===========  ========  ==================================
name        exact    needs_index  backend   algorithm
==========  =======  ===========  ========  ==================================
``naive``   yes      no           jax       full matmul + top_k
``ta``      yes      yes          jax       chunked TA rounds (count-faithful)
``bta``     yes      yes          jax       Block Threshold Algorithm
``norm``    yes      yes          jax       Cauchy-Schwarz norm-block scan
``pallas``  yes      yes          pallas    norm-block scan as a TPU kernel
``auto``    yes      yes          dispatch  picks per batch (see below)
==========  =======  ===========  ========  ==================================

``auto`` picks per query batch: sparse batches go to ``ta`` (zero-weight
lists are never walked, so TA's per-round work collapses to nnz(u)); dense
batches over catalogues whose norm spectrum decays go to the norm scan
(``pallas`` on TPU, ``norm`` elsewhere); flat-spectrum dense batches go to
``bta``. The sparsity statistic is computed HOST-side from the incoming
array — dispatch never enqueues work (or a sync) on the device query
stream.

Aliases accepted by :func:`get_engine`: ``threshold -> ta``,
``blocked -> bta``, ``norm_pruned -> norm``, ``topk_mips -> pallas``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import (
    blocked_topk,
    chunked_ta_topk,
    norm_pruned_topk,
    norm_pruned_topk_batched,
)
from repro.core.index import TopKIndex, build_index
from repro.core.naive import TopKResult, naive_topk

Array = jnp.ndarray


def batch_bucket(n: int) -> int:
    """Next power of two >= n — the compile-cache batch granularity."""
    return 1 << max(0, int(n) - 1).bit_length()


class EngineContext:
    """Catalogue + lazily built per-engine state, shared across queries.

    Args:
      targets: ``[M, R]`` catalogue factors.
      index: optional prebuilt :class:`TopKIndex` (built lazily otherwise).
      block_size: depth/block granularity handed to blocked engines.
      max_blocks: uniform halting budget (``-1`` = run to exactness).
      interpret: Pallas execution mode (``None`` = autodetect by backend).
      ta_chunk: rounds gathered per chunked-TA step (`ta` engine).
    """

    def __init__(self, targets, index: Optional[TopKIndex] = None,
                 block_size: int = 256, max_blocks: int = -1,
                 interpret=None, ta_chunk: int = 32):
        self.targets = jnp.asarray(targets, dtype=jnp.float32)
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.interpret = interpret
        self.ta_chunk = ta_chunk
        self._index = index
        self._catalog = None
        self._norm_decay = None
        # persistent compiled-executable cache: (engine, k, batch-bucket)
        # -> jitted batched callable. trace_counts counts actual traces per
        # engine name (bumped at trace time, so a cache hit adds nothing).
        self._compiled: Dict[Tuple[str, int, int], Callable] = {}
        self.trace_counts: Dict[str, int] = {}

    @property
    def num_targets(self) -> int:
        return int(self.targets.shape[0])

    @property
    def index(self) -> TopKIndex:
        if self._index is None:
            self._index = build_index(self.targets)
        return self._index

    @property
    def catalog(self):
        """Norm-ordered Pallas catalogue (built on first pallas query)."""
        if self._catalog is None:
            from repro.kernels.ops import MIPSCatalog
            self._catalog = MIPSCatalog(np.asarray(self.targets),
                                        block_m=self.block_size)
        return self._catalog

    @property
    def norm_decay(self) -> float:
        """Norm at the 10th-percentile depth over the head norm (<= 1).

        A catalogue constant, cached so per-batch `auto` dispatch does not
        re-transfer the norm spectrum from device on every query chunk.
        """
        if self._norm_decay is None:
            norms = np.asarray(self.index.norms_sorted)
            head = max(float(norms[0]), 1e-12)
            decayed = float(
                norms[min(len(norms) - 1, max(1, len(norms) // 10))])
            self._norm_decay = decayed / head
        return self._norm_decay

    # -- compilation cache ---------------------------------------------------

    def compiled(self, engine: "Engine", k: int, batch: int) -> Callable:
        """The persistent jitted executable for (engine, k, batch-bucket).

        Built once per key: the engine's ``make_batched`` factory is called
        EAGERLY (so lazy context state — index, Pallas catalogue — is
        constructed outside the trace) and the result is wrapped in a
        ``jax.jit`` that survives across queries. The wrapper bumps
        ``trace_counts[engine]`` at trace time only.
        """
        key = (engine.name, int(k), int(batch))
        fn = self._compiled.get(key)
        if fn is None:
            if engine.make_batched is None:
                raise ValueError(
                    f"engine {engine.name!r} is dispatch-only and has no "
                    "batched executable to compile")
            batched = engine.make_batched(self, int(k))
            name = engine.name

            def traced(U, _inner=batched, _name=name):
                self.trace_counts[_name] = self.trace_counts.get(_name, 0) + 1
                return _inner(U)

            fn = jax.jit(traced)
            self._compiled[key] = fn
        return fn

    def run_engine(self, engine: "Engine", U: Array, k: int) -> TopKResult:
        """Bucket the batch, pad, run the cached executable, slice back.

        Padding repeats the LAST query row (never zeros: an all-zero query
        deactivates every list and would drag a vmapped lockstep scan to
        its worst case); padded rows are dropped before returning, so
        per-query statistics are untouched.
        """
        if not (isinstance(U, jax.Array) and U.ndim == 2
                and U.dtype == self.targets.dtype):
            U = jnp.atleast_2d(jnp.asarray(U, self.targets.dtype))
        b = U.shape[0]
        bucket = batch_bucket(b)
        fn = self.compiled(engine, k, bucket)
        if bucket != b:
            pad = jnp.broadcast_to(U[b - 1:b], (bucket - b, U.shape[1]))
            U = jnp.concatenate([U, pad], axis=0)
        res = fn(U)
        if bucket != b:
            res = jax.tree_util.tree_map(lambda a: a[:b], res)
        return res

    def warmup(self, k: int, batch_sizes=(1, 8, 64),
               engines: Optional[List[str]] = None) -> "EngineContext":
        """Compile (engine, k, bucket) executables ahead of traffic.

        Runs one representative batch per bucket through each non-dispatch
        engine so the first real query hits a compiled executable. Returns
        self for chaining.
        """
        names = list(engines) if engines is not None else [
            e.name for e in list_engines() if e.backend != "dispatch"]
        r = int(self.targets.shape[1])
        for name in names:
            eng = get_engine(name)
            for b in batch_sizes:
                bucket = batch_bucket(b)
                U = jnp.ones((bucket, r), self.targets.dtype)
                res = self.compiled(eng, int(k), bucket)(U)
                jax.block_until_ready(res.values)
        return self


@dataclasses.dataclass(frozen=True)
class Engine:
    """A registered engine: batched-executable factory + capability metadata.

    ``make_batched(ctx, k)`` returns a pure ``U [B, R] -> TopKResult``
    callable (trace-safe; any host-side setup such as index construction
    happens inside the factory, eagerly). ``run`` dispatches through the
    context's compilation cache. Dispatch pseudo-engines (``auto``) set
    ``dispatch`` instead and route per batch.
    """

    name: str
    make_batched: Optional[
        Callable[["EngineContext", int], Callable[[Array], TopKResult]]
    ] = None
    dispatch: Optional[
        Callable[["EngineContext", Array, int], TopKResult]] = None
    exact: bool = True
    needs_index: bool = True
    supports_batch: bool = True
    backend: str = "jax"
    description: str = ""

    def run(self, ctx: EngineContext, U: Array, k: int) -> TopKResult:
        if self.dispatch is not None:
            return self.dispatch(ctx, U, k)
        return ctx.run_engine(self, U, k)


_REGISTRY: Dict[str, Engine] = {}
_ALIASES: Dict[str, str] = {
    "threshold": "ta",
    "blocked": "bta",
    "norm_pruned": "norm",
    "topk_mips": "pallas",
}


def register_engine(engine: Engine) -> Engine:
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> Engine:
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def engine_names() -> List[str]:
    return sorted(_REGISTRY)


def list_engines(exact: Optional[bool] = None,
                 backend: Optional[str] = None,
                 needs_index: Optional[bool] = None) -> List[Engine]:
    out = []
    for name in engine_names():
        e = _REGISTRY[name]
        if exact is not None and e.exact != exact:
            continue
        if backend is not None and e.backend != backend:
            continue
        if needs_index is not None and e.needs_index != needs_index:
            continue
        out.append(e)
    return out


# ---------------------------------------------------------------------------
# Built-in engines
# ---------------------------------------------------------------------------


def _naive_batched(ctx: EngineContext, k: int):
    targets = ctx.targets

    def fn(U):
        return naive_topk(targets, U, k)

    return fn


def _ta_batched(ctx: EngineContext, k: int):
    # chunked TA: block-shaped gather+matvec per step, sequential-round
    # accounting (count-faithful to the paper's Algorithm 2)
    idx = ctx.index
    targets = ctx.targets
    chunk = ctx.ta_chunk
    max_rounds = ctx.max_blocks

    def one(u):
        return chunked_ta_topk(targets, idx.order_desc, idx.t_sorted_desc,
                               idx.rank_desc, u, k, chunk=chunk,
                               max_rounds=max_rounds)

    return jax.vmap(one)


def _bta_batched(ctx: EngineContext, k: int):
    idx = ctx.index
    targets = ctx.targets
    block_size, max_blocks = ctx.block_size, ctx.max_blocks

    def one(u):
        return blocked_topk(targets, idx.order_desc, idx.t_sorted_desc, u,
                            k, block_size, max_blocks,
                            rank_desc=idx.rank_desc)

    return jax.vmap(one)


def _norm_batched(ctx: EngineContext, k: int):
    idx = ctx.index
    targets = ctx.targets
    block_size, max_blocks = ctx.block_size, ctx.max_blocks
    if targets.shape[0] >= block_size:
        # batched-native scan: every query walks the SAME norm-ordered
        # prefix, so one shared tile slice + one [B,R]@[R,block] matmul
        # serves the whole batch (no per-query gathers)
        def fn(U):
            return norm_pruned_topk_batched(
                idx.targets_by_norm, idx.norm_order, idx.norms_sorted, U,
                k, block_size, max_blocks)

        return fn

    def one(u):
        return norm_pruned_topk(targets, idx.norm_order, idx.norms_sorted,
                                u, k, block_size, max_blocks,
                                targets_by_norm=idx.targets_by_norm)

    return jax.vmap(one)


def _pallas_batched(ctx: EngineContext, k: int):
    cat = ctx.catalog       # built eagerly, outside the trace
    interpret = ctx.interpret
    block_m = jnp.int32(cat.block_m)

    def fn(U):
        vals, ids, stats = cat.query_batch(U, k, interpret=interpret)
        # stats = (rows scored incl. block padding, blocks visited, loaded)
        return TopKResult(vals, ids, stats[:, 0], stats[:, 1] * block_m)

    return fn


def _host_nnz_frac(U) -> float:
    """Batch sparsity, computed on the HOST.

    numpy/list inputs never touch the device; a jax Array input is read
    back once (it is an input *value*, not a pending computation, so no
    work — and no blocking reduction — is enqueued on the device query
    stream the engines are using).
    """
    arr = U if isinstance(U, np.ndarray) else np.asarray(U)
    return float(np.count_nonzero(arr)) / max(arr.size, 1)


def select_engine(ctx: EngineContext, U) -> Engine:
    """The ``auto`` policy: pick an engine for this query batch.

    Decides from two cheap HOST-side statistics: batch sparsity ``nnz(u)``
    (sparse queries make TA's per-round cost collapse to the active lists)
    and the catalogue norm spectrum (a decaying spectrum lets the
    Cauchy-Schwarz scan certify after a few contiguous blocks — the Pallas
    kernel's best case; a flat spectrum makes it a full scan, so BTA wins).
    """
    if _host_nnz_frac(U) < 0.25:
        return get_engine("ta")
    if ctx.norm_decay < 0.5:
        return get_engine(
            "pallas" if jax.default_backend() == "tpu" else "norm")
    return get_engine("bta")


def _auto_dispatch(ctx: EngineContext, U, k: int) -> TopKResult:
    return select_engine(ctx, U).run(ctx, U, k)


register_engine(Engine(
    name="naive", make_batched=_naive_batched, exact=True, needs_index=False,
    supports_batch=True, backend="jax",
    description="full matmul + lax.top_k (strongest wall-clock baseline)"))
register_engine(Engine(
    name="ta", make_batched=_ta_batched, exact=True, needs_index=True,
    supports_batch=True, backend="jax",
    description="Threshold Algorithm rounds (paper Alg. 2; chunked "
                "execution, sequential-round accounting)"))
register_engine(Engine(
    name="bta", make_batched=_bta_batched, exact=True, needs_index=True,
    supports_batch=True, backend="jax",
    description="Block Threshold Algorithm (MXU-shaped TA)"))
register_engine(Engine(
    name="norm", make_batched=_norm_batched, exact=True, needs_index=True,
    supports_batch=True, backend="jax",
    description="Cauchy-Schwarz norm-ordered block scan"))
register_engine(Engine(
    name="pallas", make_batched=_pallas_batched, exact=True, needs_index=True,
    supports_batch=True, backend="pallas",
    description="norm-ordered block scan as a Pallas TPU kernel with "
                "two-level DMA-skipping bounds (interpret-mode on CPU)"))
register_engine(Engine(
    name="auto", dispatch=_auto_dispatch, exact=True, needs_index=True,
    supports_batch=True, backend="dispatch",
    description="per-batch pick from host-side nnz(u) + catalogue norm "
                "spectrum"))
