"""Core: exact top-K inference for SEP-LR models (the paper's contribution).

Public API:
  SepLRModel, build_index, TopKIndex
  naive_topk                      — baseline (matmul + top_k)
  threshold_topk / *_np           — the Threshold Algorithm (Alg. 2)
  fagin_topk_np                   — Fagin's Algorithm (Alg. 1)
  partial_threshold_topk_np       — Partial TA (Alg. 3)
  blocked_topk (+batched)         — TPU-native Block Threshold Algorithm
  norm_pruned_topk                — Cauchy-Schwarz norm screening (beyond paper)
  sharded_naive_topk / sharded_blocked_topk / hierarchical_merge_topk

Engine layer (DESIGN.md):
  pruned_block_scan, ScanStrategy — the unified driver every engine runs on
  ta_round_strategy / blocked_lists_strategy / norm_block_strategy
  Engine, EngineContext, register_engine, get_engine, list_engines,
  engine_names, select_engine     — the name-keyed engine registry
  SegmentedCatalogue              — streaming (base + delta + tombstone)
                                    exact top-K over a mutating catalogue
"""

from repro.core.blocked import (
    blocked_topk,
    blocked_topk_batched,
    chunked_ta_topk,
    chunked_ta_topk_batched,
    norm_pruned_topk,
)
from repro.core.driver import (
    ScanState,
    ScanStrategy,
    merge_topk_sorted,
    pruned_block_scan,
)
from repro.core.engines import (
    CostTable,
    Engine,
    EngineContext,
    batch_bucket,
    engine_names,
    get_engine,
    list_engines,
    m_bucket,
    register_engine,
    select_engine,
    trace_totals,
)
from repro.core.fagin import FaginStats, fagin_topk_np
from repro.core.index import TopKIndex, build_index
from repro.core.layout import (
    DEFAULT_PREFIX_DEPTH,
    ListMajorLayout,
    NormMajorLayout,
    RowMajorLayout,
    ShardedNormLayout,
    build_layout,
    layout_names,
)
from repro.core import faults
from repro.core.lsm import (DEFAULT_L1_CAPACITY_FACTOR,
                            ShardedLsmCatalogue)
from repro.core.naive import (TopKResult, certificate_gaps,
                              certified_counts, naive_topk)
from repro.core.segments import (
    DEFAULT_DELTA_CAPACITY,
    DeltaSegment,
    QueryInfo,
    SegmentStats,
    SegmentedCatalogue,
    Snapshot,
    delta_bucket,
)
from repro.core.partial import PartialTAStats, partial_threshold_topk_np
from repro.core.seplr import (
    SepLRModel,
    from_cosine_similarity,
    from_linear_multilabel,
    from_matrix_factorization,
    from_pairwise_kronecker,
    kronecker_query,
    normalize_query,
    random_model,
)
from repro.core.sharded import (
    compat_shard_map,
    hierarchical_merge_topk,
    sharded_blocked_topk,
    sharded_naive_topk,
    sharded_norm_topk,
)
from repro.core.strategies import (
    blocked_lists_strategy,
    list_prefix_strategy,
    norm_block_strategy,
    rank_gather_first_keys,
    ta_round_strategy,
)
from repro.core.threshold import (
    TAStats,
    threshold_topk,
    threshold_topk_from_index,
    threshold_topk_np,
)

__all__ = [
    "SepLRModel", "TopKIndex", "TopKResult", "TAStats", "FaginStats",
    "PartialTAStats", "build_index", "naive_topk", "threshold_topk",
    "threshold_topk_from_index", "threshold_topk_np", "fagin_topk_np",
    "partial_threshold_topk_np", "blocked_topk", "blocked_topk_batched",
    "chunked_ta_topk", "chunked_ta_topk_batched",
    "norm_pruned_topk", "sharded_naive_topk", "sharded_blocked_topk",
    "hierarchical_merge_topk", "from_cosine_similarity",
    "from_matrix_factorization", "from_linear_multilabel",
    "from_pairwise_kronecker", "kronecker_query", "normalize_query",
    "random_model",
    "sharded_norm_topk", "compat_shard_map",
    # engine layer
    "ScanState", "ScanStrategy", "pruned_block_scan", "merge_topk_sorted",
    "ta_round_strategy", "blocked_lists_strategy", "list_prefix_strategy",
    "rank_gather_first_keys", "norm_block_strategy",
    "Engine", "EngineContext", "register_engine", "get_engine",
    "CostTable",
    "list_engines", "engine_names", "select_engine", "batch_bucket",
    # layout subsystem
    "RowMajorLayout", "NormMajorLayout", "ListMajorLayout",
    "ShardedNormLayout", "build_layout", "layout_names",
    "DEFAULT_PREFIX_DEPTH",
    # streaming catalogue subsystem
    "SegmentedCatalogue", "Snapshot", "DeltaSegment", "QueryInfo",
    "SegmentStats", "delta_bucket", "DEFAULT_DELTA_CAPACITY",
    # LSM ladder (DESIGN.md §15)
    "ShardedLsmCatalogue", "DEFAULT_L1_CAPACITY_FACTOR",
    # robustness layer (DESIGN.md §12)
    "certificate_gaps", "certified_counts", "faults",
]
