"""The Threshold Algorithm (paper Algorithm 2) — faithful oracle + JAX form.

Two implementations with identical semantics:

* :func:`threshold_topk_np` — the paper-faithful, item-at-a-time oracle in
  numpy. Counts exactly the number of score evaluations (the paper's cost
  metric). Used by the figure/table benchmarks and as the exactness oracle
  in tests.
* :func:`threshold_topk` — a ``jax.lax.while_loop`` round-synchronous form
  (one depth per iteration, all R lists popped together, exactly the
  pseudo-code's round structure). jit-compatible, vmap-able over queries.

Round semantics follow Algorithm 2 precisely: within round d the R heads at
depth d are popped and scored (deduplicated against ``calculated``); the
upper bound for the round is ``sum_r u_r * t_r(y_{L_r(d)})`` (Eq. 3); the
loop continues while ``lowerBound < upperBound``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import TopKIndex
from repro.core.naive import TopKResult

Array = jnp.ndarray

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Paper-faithful numpy oracle
# ---------------------------------------------------------------------------


class TAStats(NamedTuple):
    n_scored: int          # number of full score evaluations s(x, y)
    depth: int             # list depth at termination
    lower_bounds: np.ndarray  # lower bound trajectory per round (Fig. 3)
    upper_bounds: np.ndarray  # upper bound trajectory per round
    found_at: int          # first round at which the final top-K set was held


def _query_order_np(order_desc: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Flip list direction for negative query weights."""
    order = order_desc.copy()
    for r in range(order.shape[0]):
        if u[r] < 0:
            order[r] = order[r][::-1]
    return order


def threshold_topk_np(
    T: np.ndarray,
    order_desc: np.ndarray,
    u: np.ndarray,
    k: int,
    track_trajectory: bool = False,
) -> Tuple[np.ndarray, np.ndarray, TAStats]:
    """Faithful TA. Returns (values[k], indices[k], stats).

    Sparse queries: lists whose query weight is exactly zero are never
    walked (their Eq. 3 bound terms are zero), per the paper's Section 2
    sparse-data discussion — this is what makes memory-based CF queries
    orders of magnitude cheaper than their nominal R suggests.
    """
    M, R = T.shape
    k = min(k, M)
    order = _query_order_np(order_desc, u)
    active = np.nonzero(u)[0]

    calculated = np.zeros(M, dtype=bool)
    top_vals = np.full(k, NEG_INF)
    top_ids = np.full(k, -1, dtype=np.int64)
    n_scored = 0
    lower, upper = NEG_INF, np.inf
    lbs, ubs = [], []
    # trajectory of the current top-K set to find "correct top found" round
    sets_per_round = [] if track_trajectory else None

    d = 0
    while lower < upper and d < M:
        upper = 0.0
        for r in active:
            y = order[r, d]
            upper += u[r] * T[y, r]
            if not calculated[y]:
                calculated[y] = True
                score = float(u @ T[y])
                n_scored += 1
                if score > top_vals[-1]:
                    # insert keeping descending order (heap in the paper; the
                    # asymptotics are identical for our purposes)
                    pos = np.searchsorted(-top_vals, -score)
                    top_vals = np.insert(top_vals, pos, score)[:k]
                    top_ids = np.insert(top_ids, pos, y)[:k]
        lower = top_vals[-1]
        lbs.append(lower)
        ubs.append(upper)
        if sets_per_round is not None:
            sets_per_round.append(frozenset(top_ids.tolist()))
        d += 1

    found_at = d
    if sets_per_round is not None:
        final = sets_per_round[-1]
        for i, s in enumerate(sets_per_round):
            if s == final:
                found_at = i + 1
                break
    stats = TAStats(
        n_scored=n_scored,
        depth=d,
        lower_bounds=np.asarray(lbs),
        upper_bounds=np.asarray(ubs),
        found_at=found_at,
    )
    return top_vals, top_ids, stats


# ---------------------------------------------------------------------------
# JAX while_loop implementation (round-synchronous, jit/vmap friendly)
# ---------------------------------------------------------------------------


class _TAState(NamedTuple):
    d: Array
    top_vals: Array     # [K]
    top_ids: Array      # [K]
    visited: Array      # [M] bool
    n_scored: Array
    lower: Array
    upper: Array


def _dedup_first_occurrence(ids: Array, m: int) -> Array:
    """Boolean mask: True where ids[i] is the first occurrence of that id.

    Scatter-min of positions — O(|ids|) work, O(M) memory, jit-friendly.
    """
    n = ids.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    first_pos = jnp.full((m,), n, dtype=jnp.int32).at[ids].min(pos)
    return first_pos[ids] == pos


@functools.partial(jax.jit, static_argnames=("k", "max_rounds"))
def threshold_topk(
    targets: Array,
    order: Array,
    t_sorted: Array,
    u: Array,
    k: int,
    max_rounds: int = -1,
) -> TopKResult:
    """TA as a lax.while_loop. One list depth per iteration.

    Args:
      targets: ``[M, R]``.
      order / t_sorted: the per-query views from
        :meth:`TopKIndex.query_views` — ``[R, M]`` each.
      u: ``[R]`` query vector.
      k: top-K size (static).
      max_rounds: optional round budget (static); ``-1`` = exact TA,
        ``> 0`` = the *halted* threshold algorithm (paper Section 4.3).
    """
    M, R = targets.shape
    k = min(k, M)
    depth_cap = M if max_rounds < 0 else min(max_rounds, M)

    def cond(s: _TAState):
        return jnp.logical_and(s.d < depth_cap, s.lower < s.upper)

    active = u != 0  # sparse queries: zero-weight lists are never walked

    def body(s: _TAState):
        ids = jax.lax.dynamic_slice_in_dim(order, s.d, 1, axis=1)[:, 0]  # [R]
        t_at_d = jax.lax.dynamic_slice_in_dim(t_sorted, s.d, 1, axis=1)[:, 0]
        new_upper = jnp.sum(u * t_at_d)
        # inactive-list entries get sentinel id M so they never shadow an
        # active occurrence of the same item in the dedup pass
        ids_eff = jnp.where(active, ids, M)
        fresh = jnp.logical_and(_dedup_first_occurrence(ids_eff, M + 1),
                                jnp.logical_and(active, ~s.visited[ids]))
        scores = targets[ids] @ u                          # [R]
        masked = jnp.where(fresh, scores, NEG_INF)
        cand_vals = jnp.concatenate([s.top_vals, masked])
        cand_ids = jnp.concatenate([s.top_ids, ids])
        top_vals, pos = jax.lax.top_k(cand_vals, k)
        top_ids = cand_ids[pos]
        # only entries popped from ACTIVE lists become visited
        visited = s.visited.at[ids].max(active)
        return _TAState(
            d=s.d + 1,
            top_vals=top_vals,
            top_ids=top_ids,
            visited=visited,
            n_scored=s.n_scored + jnp.sum(fresh).astype(jnp.int32),
            lower=top_vals[k - 1],
            upper=new_upper,
        )

    init = _TAState(
        d=jnp.int32(0),
        top_vals=jnp.full((k,), NEG_INF, dtype=targets.dtype),
        top_ids=jnp.full((k,), -1, dtype=jnp.int32),
        visited=jnp.zeros((M,), dtype=bool),
        n_scored=jnp.int32(0),
        lower=jnp.asarray(NEG_INF, dtype=targets.dtype),
        upper=jnp.asarray(jnp.inf, dtype=targets.dtype),
    )
    final = jax.lax.while_loop(cond, body, init)
    return TopKResult(final.top_vals, final.top_ids, final.n_scored, final.d)


def threshold_topk_from_index(
    targets: Array, index: TopKIndex, u: Array, k: int, max_rounds: int = -1
) -> TopKResult:
    order, t_sorted = index.query_views(u)
    return threshold_topk(targets, order, t_sorted, u, k, max_rounds)
