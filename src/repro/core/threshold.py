"""The Threshold Algorithm (paper Algorithm 2) — faithful oracle + JAX form.

Two implementations with identical semantics:

* :func:`threshold_topk_np` — the paper-faithful, item-at-a-time oracle in
  numpy. Counts exactly the number of score evaluations (the paper's cost
  metric). Used by the figure/table benchmarks and as the exactness oracle
  in tests.
* :func:`threshold_topk` — the :func:`repro.core.driver.pruned_block_scan`
  driver running the :func:`repro.core.strategies.ta_round_strategy`
  (one list depth per step, all R lists popped together, exactly the
  pseudo-code's round structure). jit-compatible, vmap-able over queries.

Round semantics follow Algorithm 2 precisely: within round d the R heads at
depth d are popped and scored (deduplicated against ``calculated``); the
upper bound for the round is ``sum_r u_r * t_r(y_{L_r(d)})`` (Eq. 3); the
loop continues while ``lowerBound < upperBound``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.driver import _dedup_first_occurrence  # noqa: F401  (re-export)
from repro.core.driver import pruned_block_scan
from repro.core.index import TopKIndex
from repro.core.naive import TopKResult
from repro.core.strategies import ta_round_strategy

Array = jnp.ndarray

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Paper-faithful numpy oracle
# ---------------------------------------------------------------------------


class TAStats(NamedTuple):
    n_scored: int          # number of full score evaluations s(x, y)
    depth: int             # list depth at termination
    lower_bounds: np.ndarray  # lower bound trajectory per round (Fig. 3)
    upper_bounds: np.ndarray  # upper bound trajectory per round
    found_at: int          # first round at which the final top-K set was held


def _query_order_np(order_desc: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Flip list direction for negative query weights."""
    order = order_desc.copy()
    for r in range(order.shape[0]):
        if u[r] < 0:
            order[r] = order[r][::-1]
    return order


def threshold_topk_np(
    T: np.ndarray,
    order_desc: np.ndarray,
    u: np.ndarray,
    k: int,
    track_trajectory: bool = False,
) -> Tuple[np.ndarray, np.ndarray, TAStats]:
    """Faithful TA. Returns (values[k], indices[k], stats).

    Sparse queries: lists whose query weight is exactly zero are never
    walked (their Eq. 3 bound terms are zero), per the paper's Section 2
    sparse-data discussion — this is what makes memory-based CF queries
    orders of magnitude cheaper than their nominal R suggests.
    """
    M, R = T.shape
    k = min(k, M)
    order = _query_order_np(order_desc, u)
    active = np.nonzero(u)[0]

    calculated = np.zeros(M, dtype=bool)
    top_vals = np.full(k, NEG_INF)
    top_ids = np.full(k, -1, dtype=np.int64)
    n_scored = 0
    lower, upper = NEG_INF, np.inf
    lbs, ubs = [], []
    # trajectory of the current top-K set to find "correct top found" round
    sets_per_round = [] if track_trajectory else None

    d = 0
    while lower < upper and d < M:
        upper = 0.0
        for r in active:
            y = order[r, d]
            upper += u[r] * T[y, r]
            if not calculated[y]:
                calculated[y] = True
                score = float(u @ T[y])
                n_scored += 1
                if score > top_vals[-1]:
                    # insert keeping descending order (heap in the paper; the
                    # asymptotics are identical for our purposes)
                    pos = np.searchsorted(-top_vals, -score)
                    top_vals = np.insert(top_vals, pos, score)[:k]
                    top_ids = np.insert(top_ids, pos, y)[:k]
        lower = top_vals[-1]
        lbs.append(lower)
        ubs.append(upper)
        if sets_per_round is not None:
            sets_per_round.append(frozenset(top_ids.tolist()))
        d += 1

    found_at = d
    if sets_per_round is not None:
        final = sets_per_round[-1]
        for i, s in enumerate(sets_per_round):
            if s == final:
                found_at = i + 1
                break
    stats = TAStats(
        n_scored=n_scored,
        depth=d,
        lower_bounds=np.asarray(lbs),
        upper_bounds=np.asarray(ubs),
        found_at=found_at,
    )
    return top_vals, top_ids, stats


# ---------------------------------------------------------------------------
# JAX implementation: ta_round_strategy over the shared driver
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "max_rounds"))
def threshold_topk(
    targets: Array,
    order: Array,
    t_sorted: Array,
    u: Array,
    k: int,
    max_rounds: int = -1,
    rank_desc: Array = None,
) -> TopKResult:
    """TA via the unified driver. One list depth per driver step.

    Args:
      targets: ``[M, R]``.
      order / t_sorted: the query-independent DESCENDING index arrays
        (``order_desc`` / ``t_sorted_desc``). Negative query weights are
        resolved inside the strategy by index arithmetic — no per-query
        flipped copies are materialised (the old pre-flipped views cost
        two O(R*M) copies per negative-weight query).
      u: ``[R]`` query vector.
      k: top-K size (static).
      max_rounds: optional round budget (static); ``-1`` = exact TA,
        ``> 0`` = the *halted* threshold algorithm (paper Section 4.3).
      rank_desc: optional inverse permutations
        (:attr:`TopKIndex.rank_desc`): dedup by cursor arithmetic instead
        of the O(M) visited bitmap (DESIGN.md §6) — same results, same
        counts, cheaper loop carry.

    The `ta` REGISTRY engine does not run this form: it runs the chunked
    variant (:func:`repro.core.blocked.chunked_ta_topk`), which gathers
    ``chunk`` rounds per step and recovers these exact round semantics by
    prefix masking. This one-depth-per-step form is kept as the directly
    paper-shaped reference.
    """
    strategy = ta_round_strategy(order, t_sorted, u, rank_desc=rank_desc)
    # driver steps ARE rounds for this strategy, so depth needs no remap
    return pruned_block_scan(targets, u, strategy, k, max_steps=max_rounds)


def threshold_topk_from_index(
    targets: Array, index: TopKIndex, u: Array, k: int, max_rounds: int = -1
) -> TopKResult:
    order, t_sorted, _ = index.query_views(u)   # direction handled in-strategy
    return threshold_topk(targets, order, t_sorted, u, k, max_rounds,
                          rank_desc=index.rank_desc)


def threshold_topk_batched_from_index(
    targets: Array, index: TopKIndex, U: Array, k: int,
    chunk: int = 1, max_rounds: int = -1, layout=None,
) -> TopKResult:
    """Batched TA entry point: batched-native scan when a prefix layout
    is given, vmapped per-query TA otherwise.

    The batched-native path (DESIGN.md §11) enumerates ONE shared
    prefix-tile slice per step for the whole batch, specialised on the
    batch's sign bucket (host-computed from the query VALUES), with
    per-query freshness masks and liveness gating keeping
    ``n_scored``/``depth`` identical to the sequential-round semantics
    of :func:`threshold_topk_np`. The REGISTRY ``ta`` engine routes
    through the same machinery with compile-key management on top —
    prefer :class:`repro.core.engines.EngineContext` for serving; this
    wrapper is the direct, context-free form.
    """
    U = jnp.atleast_2d(jnp.asarray(U, targets.dtype))
    if layout is not None and layout.prefix_steps(max(chunk, 1)) > 0:
        # function-level import: strategies imports this module's oracle
        from repro.core.blocked import chunked_ta_topk_batched_native
        from repro.core.strategies import sign_bucket
        sign, dense = sign_bucket(U)
        if layout.serves_sign(sign):
            return chunked_ta_topk_batched_native(
                targets, index.order_desc, index.t_sorted_desc, U, k,
                chunk=max(chunk, 1), max_rounds=max_rounds, layout=layout,
                sign=sign, dense=dense)
    return jax.vmap(
        lambda u: threshold_topk_from_index(targets, index, u, k,
                                            max_rounds))(U)
