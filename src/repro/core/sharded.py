"""Distributed exact top-K over a sharded catalogue (pod-scale serving).

The catalogue ``T`` is row-sharded over one or more mesh axes (DESIGN.md §5).
Four exact strategies, all returning the identical set as the unsharded
algorithms (global top-K is always contained in the union of per-shard
top-Ks):

1. ``sharded_naive_topk`` — per-shard matmul + local ``lax.top_k(K)``,
   then all-gather of ``P*K`` (value, global-id) candidates and a final
   merge. Wire bytes: ``P*K*8`` instead of ``M*4`` — the communication-
   optimal exact merge.

2. ``sharded_blocked_topk`` — per-shard BTA with **cross-shard threshold
   tightening**: after every block, the per-shard lower bounds are
   ``pmax``-combined so each shard prunes against the *global* K-th best,
   not its local one. Shards therefore stop as soon as the globally-found
   top-K certifies their remaining blocks irrelevant. This is the paper's
   "parallel extensions can be easily implemented" remark made concrete
   for a TPU mesh.

3. ``hierarchical_merge`` — tree merge over multiple mesh axes (pod, data)
   so the cross-DCI hop only ever carries ``K`` candidates per pod.

4. ``sharded_norm_topk`` — the shared-tile batched norm scan
   (DESIGN.md §6) run per shard over a round-robin-dealt norm layout
   (:class:`repro.core.layout.ShardedNormLayout`), with cross-shard
   ``pmax`` threshold tightening after every block: each shard prunes
   against the GLOBAL K-th best, so all shards stop as soon as the
   globally-found top-K certifies their remaining norm blocks
   irrelevant. Backs the ``norm_sharded`` registry engine.

All functions are written with ``shard_map`` (via :func:`compat_shard_map`,
which bridges the ``jax.shard_map`` / ``jax.experimental.shard_map`` API
split across jax versions) and are used by the serving layer
(`repro.serving`) and the retrieval_cand dry-run cells.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.driver import (_dedup_first_occurrence,
                               merge_block_into_carry_batched)
from repro.core.naive import TopKResult

Array = jnp.ndarray
NEG_INF = float("-inf")


def shard_fold_topk(carry_vals: Array, carry_ids: Array,
                    scores: Array, gids: Array, k: int):
    """Two-level exact merge of shard-major stacked score blocks — the
    mesh-free counterpart of :func:`hierarchical_merge_topk`, used inside
    a host-side scan loop (the LSM catalogue's L1 tier, DESIGN.md §15).

    ``scores [S, B, C]`` are one dense block per shard over the SAME
    query batch; ``gids [S, C]`` (or per-lane ``[S, B, C]``) carry
    global ids with ``-1`` marking dead/padding lanes (already masked to
    ``-inf`` in ``scores`` by the caller). Level 1 cuts each shard's
    block to ``K`` candidates (the block-local ``top_k`` inside
    :func:`repro.core.driver.merge_block_into_carry_batched`); level 2
    folds the per-shard candidate lists through the O(K) sorted merge —
    so only ``K`` candidates per shard ever cross the merge boundary,
    the same communication shape the mesh version's all-gather carries.
    Exact for the same reason as every sharded strategy here: the global
    top-K is contained in the union of per-shard top-Ks.
    """
    for s in range(scores.shape[0]):
        carry_vals, carry_ids = merge_block_into_carry_batched(
            carry_vals, carry_ids, scores[s], gids[s], k)
    return carry_vals, carry_ids


def compat_shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across the jax API split.

    Newer jax exposes ``jax.shard_map`` (replication checking flag
    ``check_vma``); 0.4.x only has ``jax.experimental.shard_map.shard_map``
    (flag ``check_rep``). Checking is disabled either way: every function
    here all-gathers before returning, so outputs are replicated by
    construction.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def _axis_size(axis_names: Sequence[str]) -> Array:
    size = 1
    for a in axis_names:
        size = size * jax.lax.axis_size(a)
    return size


def _axis_index(axis_names: Sequence[str]) -> Array:
    """Linearised index over (possibly multiple) mesh axes."""
    idx = jnp.int32(0)
    for a in axis_names:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def sharded_naive_topk(mesh, T_spec: P, axis_names: Sequence[str]):
    """Build a jit-able exact sharded top-K: ``f(T, U, k) -> TopKResult``.

    Args:
      mesh: the device mesh.
      T_spec: PartitionSpec of the catalogue, rows sharded over
        ``axis_names`` (e.g. ``P(('data',), None)``).
      axis_names: mesh axes the catalogue rows are split over.
    """
    axis_names = tuple(axis_names)

    def fn(T: Array, U: Array, k: int) -> TopKResult:
        @functools.partial(
            compat_shard_map, mesh=mesh,
            in_specs=(T_spec, P()),
            out_specs=(P(), P(), P(), P()),
        )
        def _local(T_local, U_rep):
            m_local = T_local.shape[0]
            shard = _axis_index(axis_names)
            scores = jnp.einsum("br,mr->bm", U_rep, T_local,
                                preferred_element_type=jnp.float32)
            vals, idx = jax.lax.top_k(scores, min(k, m_local))
            gidx = idx + shard * m_local
            # all-gather K candidates per shard over every sharded axis
            for a in axis_names:
                vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
                gidx = jax.lax.all_gather(gidx, a, axis=1, tiled=True)
            fvals, fpos = jax.lax.top_k(vals, k)
            fidx = jnp.take_along_axis(gidx, fpos, axis=1)
            b = U_rep.shape[0]
            n = jnp.full((b,), T_local.shape[0], jnp.int32) * _axis_size(axis_names)
            return fvals, fidx, n, jnp.zeros((b,), jnp.int32)

        return TopKResult(*_local(T, U))

    return fn


def sharded_blocked_topk(mesh, specs, axis_names: Sequence[str]):
    """Sharded BTA with cross-shard threshold tightening.

    ``specs``: PartitionSpecs for ``(T, order_desc, t_sorted_desc)`` —
    the index arrays are sharded along their item axis (axis=1) with the
    same layout as T's rows.

    Per-shard ids are *local*; the final merge converts to global ids.
    All shards iterate in lockstep (the while_loop condition is a
    collective ``any shard still active``), so the collectives inside the
    body stay congruent.
    """
    axis_names = tuple(axis_names)
    T_spec, order_spec, tsorted_spec = specs

    def fn(T, order_desc, t_sorted_desc, U, k: int, block_size: int = 512):
        @functools.partial(
            compat_shard_map, mesh=mesh,
            in_specs=(T_spec, order_spec, tsorted_spec, P()),
            out_specs=(P(), P(), P(), P()),
        )
        def _local(T_l, order_l, tsort_l, U_rep):
            m_local, r = T_l.shape
            bq = U_rep.shape[0]
            kk = min(k, m_local)
            n_blocks = -(-m_local // block_size)
            shard = _axis_index(axis_names)
            neg = U_rep < 0  # [B, R]

            def one_query_init():
                return (
                    jnp.full((bq, kk), NEG_INF, T_l.dtype),
                    jnp.full((bq, kk), -1, jnp.int32),
                    jnp.zeros((bq, m_local), bool),
                    jnp.zeros((bq,), jnp.int32),
                    jnp.full((bq,), NEG_INF, T_l.dtype),   # global lower
                    jnp.full((bq,), jnp.inf, T_l.dtype),   # local upper
                )

            def cond(state):
                b, *_ , active = state
                return active

            def body(state):
                b, vals, ids_k, visited, n_scored, lower, upper, _ = state
                d0 = b * block_size
                cols = jnp.minimum(d0 + jnp.arange(block_size, dtype=jnp.int32),
                                   m_local - 1)

                def per_query(u_q, neg_q, vals_q, ids_q, vis_q, ns_q):
                    cols_eff = jnp.where(neg_q[:, None],
                                         m_local - 1 - cols[None, :],
                                         cols[None, :])
                    cand = jnp.take_along_axis(order_l, cols_eff, axis=1).reshape(-1)
                    fresh = jnp.logical_and(
                        _dedup_first_occurrence(cand, m_local), ~vis_q[cand])
                    scores = jnp.where(fresh, T_l[cand] @ u_q, NEG_INF)
                    mv, pos = jax.lax.top_k(
                        jnp.concatenate([vals_q, scores]), kk)
                    mi = jnp.concatenate([ids_q, cand])[pos]
                    end = jnp.minimum(d0 + block_size - 1, m_local - 1)
                    end_eff = jnp.where(neg_q, m_local - 1 - end, end)
                    t_end = tsort_l[jnp.arange(r), end_eff]
                    ub = jnp.sum(u_q * t_end)
                    return (mv, mi, vis_q.at[cand].set(True),
                            ns_q + jnp.sum(fresh).astype(jnp.int32), ub)

                vals, ids_k, visited, n_scored, upper = jax.vmap(per_query)(
                    U_rep, neg, vals, ids_k, visited, n_scored)
                # cross-shard threshold tightening: global K-th best
                local_kth = vals[:, kk - 1]
                lower = local_kth
                for a in axis_names:
                    # the true global K-th best is >= the max of local K-th
                    # bests, which is a valid (conservative) global lower
                    # bound for pruning.
                    lower = jax.lax.pmax(lower, a)
                shard_active = jnp.logical_and(b + 1 < n_blocks,
                                               jnp.any(lower < upper))
                any_active = shard_active
                for a in axis_names:
                    any_active = jax.lax.pmax(any_active, a)
                return (b + 1, vals, ids_k, visited, n_scored, lower, upper,
                        any_active)

            vals0, ids0, vis0, ns0, low0, up0 = one_query_init()
            state = (jnp.int32(0), vals0, ids0, vis0, ns0, low0, up0,
                     jnp.asarray(True))
            b, vals, ids_k, _, n_scored, _, _, _ = jax.lax.while_loop(
                cond, body, state)
            gids = jnp.where(ids_k >= 0, ids_k + shard * m_local, -1)
            for a in axis_names:
                vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
                gids = jax.lax.all_gather(gids, a, axis=1, tiled=True)
                n_scored = jax.lax.psum(n_scored, a)
            fvals, fpos = jax.lax.top_k(vals, k)
            fidx = jnp.take_along_axis(gids, fpos, axis=1)
            return fvals, fidx, n_scored, jnp.broadcast_to(b * block_size,
                                                           n_scored.shape)

        return TopKResult(*_local(T, order_desc, t_sorted_desc, U))

    return fn


def hierarchical_merge_topk(mesh, T_spec: P, inner_axes: Sequence[str],
                            outer_axes: Sequence[str]):
    """Two-level exact merge: all-gather K inside the pod (ICI), then only
    K candidates per pod cross the DCI (``outer_axes``). Communication-
    optimal for multi-pod serving."""
    inner_axes, outer_axes = tuple(inner_axes), tuple(outer_axes)
    all_axes = outer_axes + inner_axes

    def fn(T: Array, U: Array, k: int) -> TopKResult:
        @functools.partial(
            compat_shard_map, mesh=mesh,
            in_specs=(T_spec, P()),
            out_specs=(P(), P(), P(), P()),
        )
        def _local(T_local, U_rep):
            m_local = T_local.shape[0]
            shard = _axis_index(all_axes)
            scores = jnp.einsum("br,mr->bm", U_rep, T_local,
                                preferred_element_type=jnp.float32)
            vals, idx = jax.lax.top_k(scores, min(k, m_local))
            gidx = idx + shard * m_local
            # level 1: merge within the pod (fast ICI)
            for a in inner_axes:
                vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
                gidx = jax.lax.all_gather(gidx, a, axis=1, tiled=True)
            vals, pos = jax.lax.top_k(vals, k)
            gidx = jnp.take_along_axis(gidx, pos, axis=1)
            # level 2: only K cross the DCI per pod
            for a in outer_axes:
                vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
                gidx = jax.lax.all_gather(gidx, a, axis=1, tiled=True)
            fvals, fpos = jax.lax.top_k(vals, k)
            fidx = jnp.take_along_axis(gidx, fpos, axis=1)
            b = U_rep.shape[0]
            n = jnp.full((b,), m_local, jnp.int32) * _axis_size(all_axes)
            return fvals, fidx, n, jnp.zeros((b,), jnp.int32)

        return TopKResult(*_local(T, U))

    return fn


def sharded_norm_topk(mesh, axis_names: Sequence[str]):
    """Sharded shared-tile norm scan with cross-shard threshold tightening.

    Builder for the ``norm_sharded`` engine: returns
    ``f(T_sh, norms_sh, ids_sh, U, k, block_size, max_blocks)`` operating
    on a :class:`repro.core.layout.ShardedNormLayout`'s arrays (shard-major
    slabs of the round-robin-dealt norm order; rows with id -1 are
    padding). Per shard the loop is exactly the batched-native norm scan
    (one contiguous ``[block, R]`` tile + one ``[B, R] @ [R, block]``
    matmul per step for the whole batch, DESIGN.md §6); after every block
    the per-shard K-th-best lower bounds are ``pmax``-combined so each
    shard prunes against the GLOBAL K-th best. Because the deal is
    strided, every shard's local norm spectrum mirrors the global one and
    all shards certify at nearly the same block depth — the lockstep
    collective loop wastes almost nothing.

    Exactness: an item not yet enumerated on shard s is bounded by
    ``||u|| * next_local_norm(s) <= global lower bound`` at that shard's
    stop, so it cannot enter the global top-K; the final merge
    all-gathers only ``P * K`` candidates (values + GLOBAL catalogue
    ids), never rows.
    """
    axis_names = tuple(axis_names)

    def fn(T_sh: Array, norms_sh: Array, ids_sh: Array, U: Array, k: int,
           block_size: int = 256, max_blocks: int = -1) -> TopKResult:
        @functools.partial(
            compat_shard_map, mesh=mesh,
            in_specs=(P(axis_names, None), P(axis_names), P(axis_names),
                      P()),
            out_specs=(P(), P(), P(), P()),
        )
        def _local(T_l, norms_l, ids_l, U_rep):
            m_local, r = T_l.shape
            B = U_rep.shape[0]
            kk = min(k, m_local)
            blk = min(block_size, m_local)
            n_steps = -(-m_local // blk)
            cap = n_steps if max_blocks < 0 else min(max_blocks, n_steps)
            # pad rows (id -1: slab equalisation and the engine layer's
            # M-bucket padding, DESIGN.md §10) are a slab SUFFIX — cap
            # the loop at the real rows so a worst-case (never-certified)
            # query still stops where the unpadded scan would
            n_real_l = jnp.sum((ids_l >= 0).astype(jnp.int32))
            cap_rt = jnp.minimum(jnp.int32(cap), -(-n_real_l // blk))
            # the loop body contains collectives, so every shard must
            # enter it the same number of times: the INITIAL active flag
            # is pmax-combined (an all-padding shard — M_real < n_shards
            # — iterates with live all-False instead of skipping a loop
            # its peers are running collectives inside)
            active0 = cap_rt > 0
            for a in axis_names:
                active0 = jax.lax.pmax(active0, a)
            u_norms = jnp.linalg.norm(U_rep, axis=1)          # [B]
            next_starts = jnp.minimum(
                (jnp.arange(n_steps, dtype=jnp.int32) + 1) * blk,
                m_local - 1)
            bound_norms = norms_l[next_starts]                # [n_steps]
            offs = jnp.arange(blk, dtype=jnp.int32)
            neg_inf = jnp.asarray(NEG_INF, T_l.dtype)

            def cond(s):
                return s[-1]

            def body(s):
                step, tv, ti, ns, dp, lower, upper, _ = s
                # per-query liveness, gated on THIS shard's real-row cap:
                # the collective lockstep loop keeps running while any
                # shard is active, and a capped-out shard must not keep
                # accumulating depth over its pad suffix
                live = jnp.logical_and(lower < upper, step < cap_rt)  # [B]
                d0 = step * blk
                start = jnp.maximum(0, jnp.minimum(d0, m_local - blk))
                tile = jax.lax.dynamic_slice_in_dim(T_l, start, blk)
                scores = U_rep @ tile.T                       # [B, blk]
                rows = start + offs
                # tail block slides back (mask re-reads) + padding rows
                valid = jnp.logical_and(rows >= d0, ids_l[rows] >= 0)
                masked = jnp.where(valid[None, :], scores, neg_inf)
                nv, ni = merge_block_into_carry_batched(
                    tv, ti, masked, rows, kk)
                gate = live[:, None]
                tv = jnp.where(gate, nv, tv)
                ti = jnp.where(gate, ni, ti)
                ns = jnp.where(live,
                               ns + jnp.sum(valid).astype(jnp.int32), ns)
                dp = jnp.where(live, dp + 1, dp)
                upper = jnp.where(live, u_norms * bound_norms[step], upper)
                # cross-shard tightening: the global K-th best >= the max
                # of local K-th bests — a valid (conservative) global
                # lower bound for every shard's pruning test
                local_kth = tv[:, kk - 1]
                glob = local_kth
                for a in axis_names:
                    glob = jax.lax.pmax(glob, a)
                lower = jnp.maximum(lower, glob)
                shard_active = jnp.logical_and(step + 1 < cap_rt,
                                               jnp.any(lower < upper))
                any_active = shard_active
                for a in axis_names:
                    any_active = jax.lax.pmax(any_active, a)
                return (step + 1, tv, ti, ns, dp, lower, upper, any_active)

            state = (jnp.int32(0),
                     jnp.full((B, kk), NEG_INF, T_l.dtype),
                     jnp.full((B, kk), -1, jnp.int32),
                     jnp.zeros((B,), jnp.int32),
                     jnp.zeros((B,), jnp.int32),
                     jnp.full((B,), NEG_INF, T_l.dtype),
                     jnp.full((B,), jnp.inf, T_l.dtype),
                     active0)
            _, tv, ti, ns, dp, _, _, _ = jax.lax.while_loop(cond, body,
                                                            state)
            # local rows -> GLOBAL catalogue ids, then the P*K merge
            gids = jnp.where(ti >= 0,
                             ids_l[jnp.clip(ti, 0, m_local - 1)], -1)
            vals = tv
            for a in axis_names:
                vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
                gids = jax.lax.all_gather(gids, a, axis=1, tiled=True)
                ns = jax.lax.psum(ns, a)
                dp = jax.lax.psum(dp, a)
            width = vals.shape[1]
            if width < k:
                vals = jnp.concatenate(
                    [vals, jnp.full((B, k - width), NEG_INF, vals.dtype)], 1)
                gids = jnp.concatenate(
                    [gids, jnp.full((B, k - width), -1, gids.dtype)], 1)
            fvals, fpos = jax.lax.top_k(vals, k)
            fidx = jnp.take_along_axis(gids, fpos, axis=1)
            return fvals, fidx, ns, dp * blk

        return TopKResult(*_local(T_sh, norms_sh, ids_sh, U))

    return fn
