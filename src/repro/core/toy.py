"""The paper's Table 1 toy dataset (and Table 2 adversarial set).

Kept as data so tests and the table1 benchmark reproduce the paper's
worked example byte-for-byte: query u = (0.1, 2.5, 1, 0.5), best item 6
(1-indexed in the paper; 5 zero-indexed), Fagin terminates at depth 5
scoring 9 items, TA terminates after 2 rounds scoring 5 items.
"""

import numpy as np

# Paper Table 1 (items 1..10 -> rows 0..9).
TOY_T = np.array(
    [
        [-0.5, -1.4, -0.8, -1.0],
        [0.9, -1.9, -0.3, 0.5],
        [-0.8, -0.4, -0.1, 0.9],
        [-0.7, -1.7, 0.2, -2.5],
        [0.8, 0.2, 0.0, 0.7],
        [1.0, 1.6, 0.9, -0.6],
        [0.1, 0.4, -0.6, -2.0],
        [-2.4, 0.6, 0.4, -0.4],
        [-1.6, 0.2, 1.0, 0.3],
        [0.0, 1.0, -0.6, 1.4],
    ],
    dtype=np.float32,
)
TOY_U = np.array([0.1, 2.5, 1.0, 0.5], dtype=np.float32)
TOY_SCORES = TOY_T @ TOY_U  # [-4.85, -4.71, -0.73, -5.37, 0.93, 4.7, -0.59, 1.46, 1.49, 2.6]
TOY_BEST_ITEM = 5           # zero-indexed (paper's item 6)


def table2_adversarial(m: int = 1000):
    """Paper Table 2: Fagin needs M/2 rounds, TA needs 2, for u = (1, 1).

    t_1 decreases with index; t_2 increases; middle items tie at 0.5.
    """
    T = np.full((m, 2), 0.5, dtype=np.float32)
    T[0] = (1.1, 0.1)
    T[-1] = (0.1, 1.0)
    # strictly ordered interiors so the sort is unambiguous (paper notes ties
    # can be removed with a more complicated construction; epsilon does it)
    eps = 1e-4
    T[1:-1, 0] = 0.5 - eps * np.arange(1, m - 1, dtype=np.float32) / m
    T[1:-1, 1] = 0.5 - eps * (m - np.arange(1, m - 1, dtype=np.float32)) / m
    u = np.array([1.0, 1.0], dtype=np.float32)
    return T, u
