"""The three built-in scan strategies (DESIGN.md §2).

Each constructor closes over the catalogue index arrays and one query and
returns a :class:`repro.core.driver.ScanStrategy` for
:func:`repro.core.driver.pruned_block_scan`:

* :func:`ta_round_strategy` — the paper's Algorithm 2 round structure over
  the per-query *flipped views* (one list depth per step).
* :func:`blocked_lists_strategy` — the Block Threshold Algorithm: a depth
  block of ``B`` entries from all R lists per step, with the sign flip
  applied on the gather side (``block_size=1`` recovers TA rounds exactly,
  id-for-id and bound-for-bound).
* :func:`norm_block_strategy` — contiguous blocks in decreasing-norm order
  bounded by Cauchy-Schwarz (the layout the Pallas backend consumes).

All three leave ``ScanStrategy.score`` as the default dense gather +
matvec; a future partial-scoring strategy (paper Alg. 3) plugs in there.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.driver import ScanStrategy

Array = jnp.ndarray


def _first_occurrence_keys(rank_desc: Array, u: Array) -> Array:
    """Per-item minimum enumeration key for cursor-based freshness.

    The sequential scan enumerates ROUND-major (depth d, then list r), so
    an item's first enumeration is the minimum of ``pos_r(y) * R + r``
    over its active lists, where ``pos_r`` is the walk position in list
    r's per-query view (``M-1-rank`` when ``u_r < 0`` — the same flip
    ``query_views`` applies). Inactive (zero-weight) lists are masked to
    int32 max. A slot ``(r, d)`` is fresh iff ``first_key[id] == d*R+r``.
    This invariant is load-bearing for count-faithfulness — both list
    strategies must share it.
    """
    R, M = rank_desc.shape
    pos = jnp.where((u < 0)[:, None], M - 1 - rank_desc, rank_desc)
    key = pos * R + jnp.arange(R, dtype=jnp.int32)[:, None]
    key = jnp.where((u != 0)[:, None], key, jnp.iinfo(jnp.int32).max)
    return jnp.min(key, axis=0)                                  # [M]


def ta_round_strategy(order: Array, t_sorted: Array, u: Array,
                      rank_desc: Optional[Array] = None) -> ScanStrategy:
    """Paper-faithful TA rounds over pre-flipped per-query views.

    Args:
      order / t_sorted: ``[R, M]`` views from
        :meth:`repro.core.index.TopKIndex.query_views` — already walking in
        decreasing ``u_r * t_r`` order for every list.
      u: ``[R]`` query.
      rank_desc: optional ``[R, M]`` inverse permutations
        (:attr:`repro.core.index.TopKIndex.rank_desc`). When given,
        freshness runs on cursor arithmetic (same round-major key as the
        blocked strategy) and the driver drops the O(M) visited bitmap
        from the loop carry — identical results and counts.
    """
    R, M = order.shape
    active = u != 0  # sparse queries: zero-weight lists are never walked

    def candidates(step):
        ids = jax.lax.dynamic_slice_in_dim(order, step, 1, axis=1)[:, 0]
        return ids, active

    def bound(step):
        # Eq. 3 at the depth just consumed
        t_at = jax.lax.dynamic_slice_in_dim(t_sorted, step, 1, axis=1)[:, 0]
        return jnp.sum(u * t_at)

    fresh_mask = None
    if rank_desc is not None:
        first_key = _first_occurrence_keys(rank_desc, u)
        slot_r = jnp.arange(R, dtype=jnp.int32)

        def fresh_mask(step, ids, active_slots):
            return jnp.logical_and(active_slots,
                                   first_key[ids] == step * R + slot_r)

    return ScanStrategy(candidates=candidates, bound=bound, num_steps=M,
                        track_visited=True, fresh_mask=fresh_mask)


def blocked_lists_strategy(
    order_desc: Array,
    t_sorted_desc: Array,
    u: Array,
    block_size: int,
    rank_desc: Optional[Array] = None,
    ta_rounds: bool = False,
) -> ScanStrategy:
    """BTA enumeration: ``R * block_size`` candidates per step.

    Negative query weights are handled without materialising per-query
    flipped lists: depth ``d`` in list ``r`` reads position ``M-1-d`` when
    ``u_r < 0`` (a gather-side index transform, not a data transform) —
    which is why this strategy, unlike :func:`ta_round_strategy`, stays
    O(R*B) memory per query under ``vmap``.

    Args:
      rank_desc: optional ``[R, M]`` inverse permutations
        (:attr:`repro.core.index.TopKIndex.rank_desc`). When given,
        freshness is answered by per-list cursor arithmetic — an item's
        first enumeration position is computed once per query from the
        cursors, so the driver drops the O(M) visited bitmap from its loop
        carry (DESIGN.md §6).
      ta_rounds: treat each of the ``block_size`` depths as its own
        sequential TA round (chunked TA): per-round Eq. 3 bounds and the
        driver's prefix masking keep ``n_scored``/``depth`` identical to
        the item-at-a-time paper algorithm while the gather + matvec stay
        block-shaped. Requires ``rank_desc``.
    """
    R, M = order_desc.shape
    neg = u < 0
    active = u != 0
    active_rep = jnp.repeat(active, block_size,
                            total_repeat_length=R * block_size)
    offs = jnp.arange(block_size, dtype=jnp.int32)

    def candidates(step):
        d0 = step * block_size
        cols = jnp.minimum(d0 + offs, M - 1)
        cols_eff = jnp.where(neg[:, None], M - 1 - cols[None, :],
                             cols[None, :])
        ids = jnp.take_along_axis(order_desc, cols_eff, axis=1).reshape(-1)
        return ids, active_rep

    def block_bound(step):
        # bound at the block's last processed depth — valid for every unseen
        # item because the lists are monotone (Eq. 3 holds at any depth)
        end = jnp.minimum(step * block_size + block_size - 1, M - 1)
        end_eff = jnp.where(neg, M - 1 - end, end)
        t_end = t_sorted_desc[jnp.arange(R), end_eff]
        return jnp.sum(u * t_end)

    def round_bounds(step):
        # Eq. 3 at EVERY depth of the block — the chunked-TA driver stops
        # mid-block at exactly the sequential algorithm's round
        d = jnp.minimum(step * block_size + offs, M - 1)            # [B]
        d_eff = jnp.where(neg[:, None], M - 1 - d[None, :], d[None, :])
        t_at = jnp.take_along_axis(t_sorted_desc, d_eff, axis=1)    # [R, B]
        return jnp.sum(u[:, None] * t_at, axis=0)                   # [B]

    fresh_mask = None
    if rank_desc is not None:
        # Round-major first-occurrence keys: also the slot the sequential
        # oracle scores an item at (this matters for chunked TA's
        # per-round counts; for the block-granular scan any slot of the
        # item's first block would do, and the minimum is in that block
        # either way).
        first_key = _first_occurrence_keys(rank_desc, u)
        slot_r = jnp.repeat(jnp.arange(R, dtype=jnp.int32), block_size,
                            total_repeat_length=R * block_size)
        slot_depth = jnp.tile(offs, R)                               # [R*B]

        def fresh_mask(step, ids, active_slots):
            d = step * block_size + slot_depth      # unclamped true depth
            sk = d * R + slot_r
            return jnp.logical_and(
                jnp.logical_and(active_slots, first_key[ids] == sk), d < M)

    if ta_rounds and block_size > 1:
        # block_size == 1 falls through: one round per step IS the plain
        # blocked strategy, and the driver's scalar-bound path handles it.
        if rank_desc is None:
            raise ValueError("ta_rounds (chunked TA) requires rank_desc")
        return ScanStrategy(candidates=candidates, bound=round_bounds,
                            num_steps=-(-M // block_size),
                            track_visited=False, fresh_mask=fresh_mask,
                            rounds_per_step=block_size, num_rounds=M)
    return ScanStrategy(candidates=candidates, bound=block_bound,
                        num_steps=-(-M // block_size),
                        track_visited=fresh_mask is None,
                        fresh_mask=fresh_mask)


def norm_block_strategy(
    norm_order: Array,
    norms_sorted: Array,
    u: Array,
    block_size: int,
    targets_by_norm: Optional[Array] = None,
) -> ScanStrategy:
    """Decreasing-norm contiguous blocks with Cauchy-Schwarz bounds.

    Block ``b`` covers items ``norm_order[b*B:(b+1)*B]`` (a contiguous
    gather); every unseen score is bounded by ``||u|| * norms_sorted[(b+1)*B]``.
    Items never repeat across blocks, so the driver skips visited tracking.

    When ``targets_by_norm`` (the catalogue pre-permuted into decreasing-
    norm order, :attr:`repro.core.index.TopKIndex.targets_by_norm`) is
    given, the whole block step goes memory-layout native (DESIGN.md §6):
    scoring is a contiguous ``dynamic_slice`` + matvec instead of a row
    gather (the Pallas kernel's DMA layout, in pure XLA), candidate ids
    are the norm-ordered ROW numbers (an iota — no id gather in the loop;
    the caller maps rows back to catalogue ids once, after the scan, via
    ``norm_order``), and the per-block Cauchy-Schwarz bounds are one
    precomputed vector indexed per step. The tail block slides back to
    stay in bounds; rows re-entering from the previous block are masked
    inactive, so counts are unchanged.
    """
    M = norm_order.shape[0]
    u_norm = jnp.linalg.norm(u)
    offs = jnp.arange(block_size, dtype=jnp.int32)
    use_slices = targets_by_norm is not None and M >= block_size
    n_steps = -(-M // block_size)
    # bound after step b = ||u|| * norm of the first unseen row; one
    # vectorised precompute, one dynamic index per step
    next_starts = jnp.minimum(
        (jnp.arange(n_steps, dtype=jnp.int32) + 1) * block_size, M - 1)
    block_bounds = u_norm * norms_sorted[next_starts]

    def candidates(step):
        d0 = step * block_size
        if use_slices:
            start = jnp.maximum(0, jnp.minimum(d0, M - block_size))
            rows = start + offs
            valid = rows >= d0      # mask rows the previous block scored
            return rows, valid     # local rows; caller remaps after scan
        rows = jnp.minimum(d0 + offs, M - 1)
        valid = (d0 + offs) < M
        return norm_order[rows], valid

    score = None
    if use_slices:
        def score(step, ids, active):
            d0 = step * block_size
            start = jnp.maximum(0, jnp.minimum(d0, M - block_size))
            tile = jax.lax.dynamic_slice_in_dim(targets_by_norm, start,
                                                block_size)
            return tile @ u

    def bound(step):
        return block_bounds[step]

    return ScanStrategy(candidates=candidates, bound=bound,
                        num_steps=n_steps, track_visited=False,
                        score=score)
