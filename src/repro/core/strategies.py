"""The built-in scan strategies (DESIGN.md §2).

Each constructor closes over the catalogue index/layout arrays and one
query and returns a :class:`repro.core.driver.ScanStrategy` for
:func:`repro.core.driver.pruned_block_scan`:

* :func:`ta_round_strategy` — the paper's Algorithm 2 round structure
  (one list depth per step). Negative query weights are resolved by
  INDEX ARITHMETIC (depth d of list r reads column ``M-1-d`` when
  ``u_r < 0``), never by materialising flipped ``[R, M]`` copies.
* :func:`blocked_lists_strategy` — the Block Threshold Algorithm: a depth
  block of ``B`` entries from all R lists per step, with the sign flip
  applied on the gather side (``block_size=1`` recovers TA rounds exactly,
  id-for-id and bound-for-bound).
* :func:`list_prefix_strategy` — the same enumeration over the
  contiguous :class:`repro.core.layout.ListMajorLayout` prefix: scoring
  is a ``[R, B, R]`` slice + matmul (no row gathers), candidate ids are
  slices of the walk-order id tables, and freshness comes from one
  O(R*P) per-query scatter instead of the O(R*M) key precompute. Covers
  depths ``< prefix_depth``; a scan that outlives the prefix chains into
  a gather-side :func:`blocked_lists_strategy` tail (DESIGN.md §7).
* :func:`norm_block_strategy` — contiguous blocks in decreasing-norm order
  bounded by Cauchy-Schwarz (the layout the Pallas backend consumes).

The list strategies leave ``ScanStrategy.score`` as the default dense
gather + matvec unless a layout or an explicit ``score_fn`` (e.g. the
Pallas gather-fused kernel) supplies a cheaper path.

**Pad-aware index arithmetic** (DESIGN.md §10): every strategy accepts an
optional ``m_real`` — a TRACED scalar carrying the real catalogue size
when the index/layout arrays have been padded to an M-bucket (so one
compiled executable serves every snapshot of the bucket). All walk
positions, direction flips (``m - 1 - d``), Eq. 3 bound lookups,
freshness keys, and the dynamic step/round caps the driver consumes
(`ScanStrategy.num_steps_dynamic` / ``num_rounds_dynamic``) are computed
against ``m_real``, never against the padded array length — pad rows are
therefore never enumerated, never scored, and never counted, and results
are bit-identical to the unpadded scan. ``m_real=None`` (the default)
keeps the static-shape behaviour.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.driver import BatchedScanStrategy, ScanStrategy

Array = jnp.ndarray

_INT_MAX = 2147483647


def sign_bucket(U) -> tuple:
    """Host-side sign bucket of a query batch: ``(sign, dense)``.

    ``sign`` is ``+1`` when every weight in the batch is >= 0 (the scan
    only ever walks HEAD prefixes), ``-1`` when every weight is <= 0
    (tail prefixes only), ``0`` otherwise (mixed — per-(query, list)
    direction select). ``dense`` is True when NO weight is zero, which
    lets the single-sign batched strategies share ONE freshness-key tile
    across the whole batch (the keys become query-independent); the
    mixed bucket always reports ``dense=False`` — its keys are per-query
    regardless, so fewer buckets means fewer compiles.

    This is a HOST read of the query values (``np.asarray``). Query
    batches are host-origin in the serving path; a device-resident batch
    pays one transfer, never a trace.
    """
    arr = np.asarray(U)
    if arr.size == 0:
        return (0, False)
    has_neg = bool((arr < 0).any())
    has_pos = bool((arr > 0).any())
    if has_neg and has_pos:
        return (0, False)
    dense = not bool((arr == 0).any())
    return ((-1, dense) if has_neg else (1, dense))


def sign_bucket_label(bucket: tuple) -> str:
    """Readable label for a :func:`sign_bucket` value (stats/artifacts)."""
    if not bucket:
        return "unbucketed"
    sign, dense = bucket
    name = {1: "nonneg", -1: "nonpos", 0: "mixed"}[sign]
    return f"{name}-{'dense' if dense else 'sparse'}"


def _keys_from_ranks(ranks: Array, u: Array, m: int) -> Array:
    """Round-major first-occurrence keys from a ``[..., R]`` rank array.

    THE single implementation of the freshness-key formula. The
    sequential scan enumerates ROUND-major (depth d, then list r), so an
    item's first enumeration is the minimum of ``pos_r(y) * R + r`` over
    its active lists, where ``pos_r`` is the walk position in list r's
    per-query view (``m-1-rank`` when ``u_r < 0`` — the same flip
    ``query_views`` reports). Inactive (zero-weight) lists are masked to
    int32 max. A slot ``(r, d)`` is fresh iff ``first_key[id] == d*R+r``.
    This invariant is load-bearing for count-faithfulness: every
    freshness path — the O(R*M) per-query precompute, the tail's
    per-block row gather, and the prefix's offline rank tiles — must
    compute bit-identical keys, so they all route through here.
    """
    R = ranks.shape[-1]
    shape = (1,) * (ranks.ndim - 1) + (R,)
    pos = jnp.where((u < 0).reshape(shape), m - 1 - ranks, ranks)
    keys = pos * R + jnp.arange(R, dtype=jnp.int32).reshape(shape)
    keys = jnp.where((u != 0).reshape(shape), keys, _INT_MAX)
    return jnp.min(keys, axis=-1)                                # [...]


def _first_occurrence_keys(rank_desc: Array, u: Array,
                           m_real=None) -> Array:
    """Per-item keys for the whole catalogue (O(R*M) per-query precompute,
    the non-layout gather path's freshness table). ``m_real`` is the real
    (unpadded) catalogue size when the rank array is M-bucket padded."""
    R, M = rank_desc.shape
    m = M if m_real is None else m_real
    return _keys_from_ranks(rank_desc.T, u, m)                   # [M]


def rank_gather_first_keys(rank_by_item: Array, u: Array,
                           ids: Array, m_real=None) -> Array:
    """Keys for ONE block of candidates, by row gather.

    Computed only for the ``C`` candidates at hand from the transposed
    inverse permutations
    (:attr:`repro.core.layout.ListMajorLayout.rank_by_item`, ``[M, R]``):
    a ``[C, R]`` int gather per block instead of an O(R*M) per-query
    precompute. Used by the post-prefix tail of the layout path, where
    blocks are rare (DESIGN.md §7). ``m_real`` is the real catalogue
    size when ``rank_by_item`` is M-bucket padded.
    """
    M, R = rank_by_item.shape
    m = M if m_real is None else m_real
    return _keys_from_ranks(rank_by_item[ids], u, m)             # [C]


def ta_round_strategy(order_desc: Array, t_sorted_desc: Array, u: Array,
                      rank_desc: Optional[Array] = None,
                      m_real=None) -> ScanStrategy:
    """Paper-faithful TA rounds with gather-side direction resolution.

    Args:
      order_desc / t_sorted_desc: the query-independent ``[R, M]`` index
        arrays (:meth:`repro.core.index.TopKIndex.query_views` returns
        them untouched plus the direction flags). Walk depth ``d`` of
        list ``r`` reads column ``M-1-d`` when ``u_r < 0`` — an O(R)
        index transform per round, replacing the two O(R*M) flipped
        copies the pre-flip views used to materialise per query.
      u: ``[R]`` query.
      rank_desc: optional ``[R, M]`` inverse permutations
        (:attr:`repro.core.index.TopKIndex.rank_desc`). When given,
        freshness runs on cursor arithmetic (same round-major key as the
        blocked strategy) and the driver drops the O(M) visited bitmap
        from the loop carry — identical results and counts.
      m_real: optional traced real catalogue size (arrays M-bucket
        padded); walks, bounds, and the dynamic round cap use it.
    """
    R, M = order_desc.shape
    m = M if m_real is None else m_real
    neg = u < 0
    active = u != 0  # sparse queries: zero-weight lists are never walked
    rows_r = jnp.arange(R, dtype=jnp.int32)

    def candidates(step):
        cols = jnp.where(neg, m - 1 - step, step)
        ids = order_desc[rows_r, cols]
        return ids, active

    def bound(step):
        # Eq. 3 at the depth just consumed
        cols = jnp.where(neg, m - 1 - step, step)
        t_at = t_sorted_desc[rows_r, cols]
        return jnp.sum(u * t_at)

    fresh_mask = None
    if rank_desc is not None:
        first_key = _first_occurrence_keys(rank_desc, u, m_real)
        slot_r = jnp.arange(R, dtype=jnp.int32)

        def fresh_mask(step, ids, active_slots):
            return jnp.logical_and(active_slots,
                                   first_key[ids] == step * R + slot_r)

    return ScanStrategy(candidates=candidates, bound=bound, num_steps=M,
                        track_visited=True, fresh_mask=fresh_mask,
                        num_steps_dynamic=m_real)


def blocked_lists_strategy(
    order_desc: Array,
    t_sorted_desc: Array,
    u: Array,
    block_size: int,
    rank_desc: Optional[Array] = None,
    ta_rounds: bool = False,
    rank_by_item: Optional[Array] = None,
    score_fn: Optional[Callable[[Array], Array]] = None,
    m_real=None,
) -> ScanStrategy:
    """BTA enumeration: ``R * block_size`` candidates per step.

    Negative query weights are handled without materialising per-query
    flipped lists: depth ``d`` in list ``r`` reads position ``M-1-d`` when
    ``u_r < 0`` (a gather-side index transform, not a data transform) —
    which is why this strategy, unlike :func:`ta_round_strategy`, stays
    O(R*B) memory per query under ``vmap``.

    Args:
      rank_desc: optional ``[R, M]`` inverse permutations
        (:attr:`repro.core.index.TopKIndex.rank_desc`). When given,
        freshness is answered by per-list cursor arithmetic — an item's
        first enumeration position is computed once per query from the
        cursors, so the driver drops the O(M) visited bitmap from its loop
        carry (DESIGN.md §6).
      ta_rounds: treat each of the ``block_size`` depths as its own
        sequential TA round (chunked TA): per-round Eq. 3 bounds and the
        driver's prefix masking keep ``n_scored``/``depth`` identical to
        the item-at-a-time paper algorithm while the gather + matvec stay
        block-shaped. Requires ``rank_desc`` or ``rank_by_item``.
      rank_by_item: optional ``[M, R]`` transposed inverse permutations
        (:attr:`repro.core.layout.ListMajorLayout.rank_by_item`).
        Freshness then comes from a per-block ``[C, R]`` row gather
        (:func:`rank_gather_first_keys`) instead of the O(R*M) per-query
        key precompute — the right trade when this strategy is only the
        rare post-prefix TAIL of a layout scan (DESIGN.md §7). Takes
        precedence over ``rank_desc``.
      score_fn: optional ``ids -> scores`` override (e.g. the Pallas
        gather-fused scorer) replacing the default ``targets[ids] @ u``.
      m_real: optional traced real catalogue size (arrays M-bucket
        padded). Clamps, direction flips, bound lookups, freshness keys,
        and the dynamic step/round caps all use it, so pad entries past
        the real list ends are never walked.
    """
    R, M = order_desc.shape
    m = M if m_real is None else m_real
    neg = u < 0
    active = u != 0
    active_rep = jnp.repeat(active, block_size,
                            total_repeat_length=R * block_size)
    offs = jnp.arange(block_size, dtype=jnp.int32)

    def candidates(step):
        d0 = step * block_size
        cols = jnp.minimum(d0 + offs, m - 1)
        cols_eff = jnp.where(neg[:, None], m - 1 - cols[None, :],
                             cols[None, :])
        ids = jnp.take_along_axis(order_desc, cols_eff, axis=1).reshape(-1)
        return ids, active_rep

    def block_bound(step):
        # bound at the block's last processed depth — valid for every unseen
        # item because the lists are monotone (Eq. 3 holds at any depth)
        end = jnp.minimum(step * block_size + block_size - 1, m - 1)
        end_eff = jnp.where(neg, m - 1 - end, end)
        t_end = t_sorted_desc[jnp.arange(R), end_eff]
        return jnp.sum(u * t_end)

    def round_bounds(step):
        # Eq. 3 at EVERY depth of the block — the chunked-TA driver stops
        # mid-block at exactly the sequential algorithm's round
        d = jnp.minimum(step * block_size + offs, m - 1)            # [B]
        d_eff = jnp.where(neg[:, None], m - 1 - d[None, :], d[None, :])
        t_at = jnp.take_along_axis(t_sorted_desc, d_eff, axis=1)    # [R, B]
        return jnp.sum(u[:, None] * t_at, axis=0)                   # [B]

    fresh_mask = None
    if rank_by_item is not None or rank_desc is not None:
        # Round-major first-occurrence keys: also the slot the sequential
        # oracle scores an item at (this matters for chunked TA's
        # per-round counts; for the block-granular scan any slot of the
        # item's first block would do, and the minimum is in that block
        # either way).
        slot_r = jnp.repeat(jnp.arange(R, dtype=jnp.int32), block_size,
                            total_repeat_length=R * block_size)
        slot_depth = jnp.tile(offs, R)                               # [R*B]
        if rank_by_item is not None:
            def fresh_mask(step, ids, active_slots):
                fk = rank_gather_first_keys(rank_by_item, u, ids, m_real)
                d = step * block_size + slot_depth  # unclamped true depth
                sk = d * R + slot_r
                return jnp.logical_and(
                    jnp.logical_and(active_slots, fk == sk), d < m)
        else:
            first_key = _first_occurrence_keys(rank_desc, u, m_real)

            def fresh_mask(step, ids, active_slots):
                d = step * block_size + slot_depth  # unclamped true depth
                sk = d * R + slot_r
                return jnp.logical_and(
                    jnp.logical_and(active_slots, first_key[ids] == sk),
                    d < m)

    score = None
    if score_fn is not None:
        def score(step, ids, active_slots):
            return score_fn(ids)

    steps_dyn = None if m_real is None else -(-m_real // block_size)
    if ta_rounds and block_size > 1:
        # block_size == 1 falls through: one round per step IS the plain
        # blocked strategy, and the driver's scalar-bound path handles it.
        if fresh_mask is None:
            raise ValueError(
                "ta_rounds (chunked TA) requires rank_desc or rank_by_item")
        return ScanStrategy(candidates=candidates, bound=round_bounds,
                            num_steps=-(-M // block_size),
                            track_visited=False, fresh_mask=fresh_mask,
                            score=score,
                            rounds_per_step=block_size, num_rounds=M,
                            num_steps_dynamic=steps_dyn,
                            num_rounds_dynamic=m_real)
    return ScanStrategy(candidates=candidates, bound=block_bound,
                        num_steps=-(-M // block_size),
                        track_visited=fresh_mask is None,
                        fresh_mask=fresh_mask, score=score,
                        num_steps_dynamic=steps_dyn)


def list_prefix_strategy(
    layout,
    t_sorted_desc: Array,
    u: Array,
    block_size: int,
    ta_rounds: bool = False,
    m_real=None,
) -> ScanStrategy:
    """Gather-free TA/BTA enumeration over the contiguous list prefix.

    Block ``step`` covers depths ``[step*B, (step+1)*B)`` of every list —
    the same candidates, bounds, and freshness keys as
    :func:`blocked_lists_strategy`, but every memory access inside the
    prefix is CONTIGUOUS (DESIGN.md §7):

    * scoring slices ``[R, B, R]`` tiles of the layout's ``head_rows``
      (descending walks) and ``tail_rows`` (ascending walks, i.e.
      negative query weights), selects per-list by the direction flag,
      and runs one ``[R*B, R] @ [R]`` matvec — no row gather;
    * candidate ids are slices of the walk-order id tables;
    * freshness slices the pre-materialised rank tiles
      (``head_ranks``/``tail_ranks``: each prefix item's positions in
      ALL lists, in walk order) and reduces them to round-major
      first-occurrence keys with a vectorised min — per-STEP O(C*R)
      arithmetic on contiguous memory, replacing both the O(R*M)
      per-query key precompute and any scatter/gather (a batched
      scatter-min was measured to dominate the whole scan on XLA:CPU).

    Covers ``layout.prefix_steps(block_size)`` blocks; the caller chains
    a gather-side tail via the driver's ``init_state`` for the rare scan
    that outlives the prefix.

    Args:
      layout: a :class:`repro.core.layout.ListMajorLayout`.
      t_sorted_desc: ``[R, M]`` sorted values (bounds only).
      ta_rounds: chunked-TA mode, as in :func:`blocked_lists_strategy`
        (``num_rounds`` is capped at the prefix depth).
      m_real: optional traced real catalogue size when the layout's
        ``rank_by_item`` / the index arrays are M-bucket padded. The
        prefix TILES themselves are never padded (their shape is set by
        ``prefix_depth``, which is ≤ the real size by construction), so
        only the freshness keys and direction-flip bound lookups need
        the real size.
    """
    R, P = layout.head_ids.shape
    M = layout.rank_by_item.shape[0]
    m = M if m_real is None else m_real
    neg = u < 0
    active = u != 0
    n_steps = layout.prefix_steps(block_size)
    active_rep = jnp.repeat(active, block_size,
                            total_repeat_length=R * block_size)
    offs = jnp.arange(block_size, dtype=jnp.int32)

    def _dir_slice(head, tail, step):
        """[R, B, ...] walk-order tile: head for positive lists, tail for
        negative — two contiguous slices + one select, never a gather."""
        d0 = step * block_size
        sizes = (R, block_size) + head.shape[2:]
        h = jax.lax.dynamic_slice(head, (0, d0) + (0,) * (head.ndim - 2),
                                  sizes)
        t = jax.lax.dynamic_slice(tail, (0, d0) + (0,) * (tail.ndim - 2),
                                  sizes)
        return jnp.where(neg.reshape((R,) + (1,) * (head.ndim - 1)), t, h)

    def candidates(step):
        ids = _dir_slice(layout.head_ids, layout.tail_ids, step)
        return ids.reshape(-1), active_rep

    def score(step, ids, active_slots):
        tile = _dir_slice(layout.head_rows, layout.tail_rows, step)
        return tile.reshape(R * block_size, -1) @ u

    # round-major first-occurrence keys from the pre-materialised rank
    # tiles: ranks[r, j, r'] is candidate (r, j)'s position in list r'
    slot_key = (jnp.arange(block_size, dtype=jnp.int32)[None, :] * R
                + jnp.arange(R, dtype=jnp.int32)[:, None])      # [R, B]

    def fresh_mask(step, ids, active_slots):
        ranks = _dir_slice(layout.head_ranks, layout.tail_ranks, step)
        fk = _keys_from_ranks(ranks, u, m)                      # [R, B]
        d0 = step * block_size
        return jnp.logical_and(active[:, None],
                               fk == d0 * R + slot_key).reshape(-1)

    def block_bound(step):
        # prefix steps never clamp: d0 + B - 1 < P <= m
        end = step * block_size + block_size - 1
        end_eff = jnp.where(neg, m - 1 - end, end)
        t_end = t_sorted_desc[jnp.arange(R), end_eff]
        return jnp.sum(u * t_end)

    def round_bounds(step):
        d = step * block_size + offs                                # [B]
        d_eff = jnp.where(neg[:, None], m - 1 - d[None, :], d[None, :])
        t_at = jnp.take_along_axis(t_sorted_desc, d_eff, axis=1)    # [R, B]
        return jnp.sum(u[:, None] * t_at, axis=0)                   # [B]

    if ta_rounds and block_size > 1:
        return ScanStrategy(candidates=candidates, bound=round_bounds,
                            num_steps=n_steps, track_visited=False,
                            fresh_mask=fresh_mask, score=score,
                            rounds_per_step=block_size,
                            num_rounds=n_steps * block_size)
    return ScanStrategy(candidates=candidates, bound=block_bound,
                        num_steps=n_steps, track_visited=False,
                        fresh_mask=fresh_mask, score=score)


def batched_list_prefix_strategy(
    layout,
    t_sorted_desc: Array,
    U: Array,
    block_size: int,
    sign: int = 0,
    dense: bool = False,
    ta_rounds: bool = False,
    m_real=None,
) -> BatchedScanStrategy:
    """Batch-native :func:`list_prefix_strategy`: one shared tile per step.

    The whole batch consumes the SAME contiguous prefix block each step
    (the enumeration axis — walk depth — is query-independent), so the
    tile slice happens once and scoring is a single ``[C, R] @ [R, B]``
    matmul instead of B vmapped matvecs (DESIGN.md §11). What remains
    per-query is exactly what the sequential semantics require: scores,
    Eq. 3 bounds, and the freshness masks, all computed batched from the
    shared rank tiles via :func:`_keys_from_ranks` — never a scatter
    (standing XLA:CPU gotcha).

    ``sign`` is the STATIC sign bucket of the batch
    (:func:`sign_bucket`): ``+1`` (all weights >= 0) reads only the HEAD
    tiles, ``-1`` (all <= 0) only the TAIL tiles — halving prefix
    traffic and making candidate ids shared ``[C]`` vectors — while
    ``0`` (mixed) reads both and selects per (query, list). ``dense``
    (no zero weights, single-sign only) makes the freshness keys
    query-INDEPENDENT: with every list active and all flips identical,
    ``_keys_from_ranks`` collapses to one shared ``[R, B]`` key tile for
    the batch, evaluated with a constant direction surrogate so the keys
    are bit-identical to any dense query's of that sign.

    The caller guarantees the bucket matches the batch (host-side exact
    check in :func:`sign_bucket`); the bucket joins the engine executor
    compile key, so each variant traces once per process.
    """
    side_ids = layout.head_ids if sign >= 0 else layout.tail_ids
    R, P = side_ids.shape
    M = layout.rank_by_item.shape[0]
    m = M if m_real is None else m_real
    B = U.shape[0]
    C = R * block_size
    neg = U < 0                                                # [B, R]
    active = U != 0
    n_steps = layout.prefix_steps(block_size)
    offs = jnp.arange(block_size, dtype=jnp.int32)
    rows_r = jnp.arange(R, dtype=jnp.int32)
    # slot (r, j) lives at r*block_size + j; round-major key within block 0
    slot_key = offs[None, :] * R + rows_r[:, None]             # [R, Bk]

    def _slice(arr, step):
        d0 = step * block_size
        sizes = (R, block_size) + arr.shape[2:]
        return jax.lax.dynamic_slice(
            arr, (0, d0) + (0,) * (arr.ndim - 2), sizes)

    def _single_sign_block(step):
        if sign > 0:
            ids_a, rows_a, ranks_a = (layout.head_ids, layout.head_rows,
                                      layout.head_ranks)
        else:
            ids_a, rows_a, ranks_a = (layout.tail_ids, layout.tail_rows,
                                      layout.tail_ranks)
        ids = _slice(ids_a, step).reshape(-1)                  # [C] shared
        tile = _slice(rows_a, step).reshape(C, R)
        scores = (tile @ U.T).T                                # [B, C]
        ranks = _slice(ranks_a, step)                          # [R, Bk, R]
        abs_key = step * block_size * R + slot_key             # [R, Bk]
        if dense:
            # every list active, every flip identical -> the keys are
            # query-independent; evaluate them ONCE with a constant
            # direction surrogate of the bucket's sign
            u_dir = jnp.full((R,), float(sign), U.dtype)
            fk = _keys_from_ranks(ranks, u_dir, m)             # [R, Bk]
            fresh = jnp.broadcast_to(
                (fk == abs_key).reshape(1, C), (B, C))
        else:
            fk = jax.vmap(
                lambda uq: _keys_from_ranks(ranks, uq, m))(U)  # [B, R, Bk]
            fresh = jnp.logical_and(fk == abs_key[None],
                                    active[:, :, None]).reshape(B, C)
        return ids, scores, fresh

    def _mixed_block(step):
        h_ids = _slice(layout.head_ids, step)                  # [R, Bk]
        t_ids = _slice(layout.tail_ids, step)
        ids = jnp.where(neg[:, :, None], t_ids[None],
                        h_ids[None]).reshape(B, C)             # [B, C]
        h_tile = _slice(layout.head_rows, step).reshape(C, R)
        t_tile = _slice(layout.tail_rows, step).reshape(C, R)
        sh = (h_tile @ U.T).T                                  # [B, C]
        st = (t_tile @ U.T).T
        neg_rep = jnp.repeat(neg, block_size, axis=1,
                             total_repeat_length=C)
        scores = jnp.where(neg_rep, st, sh)
        h_rk = _slice(layout.head_ranks, step)                 # [R, Bk, R]
        t_rk = _slice(layout.tail_ranks, step)
        rk = jnp.where(neg[:, :, None, None], t_rk[None], h_rk[None])
        fk = jax.vmap(
            lambda rq, uq: _keys_from_ranks(rq, uq, m))(rk, U)  # [B, R, Bk]
        abs_key = step * block_size * R + slot_key
        fresh = jnp.logical_and(fk == abs_key[None],
                                active[:, :, None]).reshape(B, C)
        return ids, scores, fresh

    block = _single_sign_block if sign != 0 else _mixed_block

    def _t_head(step):
        """[R, Bk] sorted values at depths d0 .. d0+Bk-1 (never clamps:
        prefix blocks satisfy d0 + Bk <= P <= m)."""
        return jax.lax.dynamic_slice(
            t_sorted_desc, (0, step * block_size), (R, block_size))

    def _t_tail(step):
        """[R, Bk] sorted values at ASCENDING-walk depths: column j holds
        ``t[:, m-1-(d0+j)]``."""
        start = m - block_size - step * block_size
        sl = jax.lax.dynamic_slice(t_sorted_desc, (0, start),
                                   (R, block_size))
        return sl[:, ::-1]

    u_pos = jnp.where(neg, 0.0, U)                             # [B, R]
    u_neg = jnp.where(neg, U, 0.0)

    def round_bounds(step):
        # Eq. 3 at every depth of the block, per query: [B, Bk]
        if sign > 0:
            return U @ _t_head(step)
        if sign < 0:
            return U @ _t_tail(step)
        return u_pos @ _t_head(step) + u_neg @ _t_tail(step)

    def block_bound(step):
        # bound at the block's last depth only — one [R] column per side
        end = step * block_size + block_size - 1
        t_h = jax.lax.dynamic_slice(t_sorted_desc, (0, end), (R, 1))[:, 0]
        if sign > 0:
            return U @ t_h
        t_t = jax.lax.dynamic_slice(t_sorted_desc, (0, m - 1 - end),
                                    (R, 1))[:, 0]
        if sign < 0:
            return U @ t_t
        return u_pos @ t_h + u_neg @ t_t

    if ta_rounds and block_size > 1:
        return BatchedScanStrategy(block=block, bound=round_bounds,
                                   num_steps=n_steps,
                                   rounds_per_step=block_size,
                                   num_rounds=n_steps * block_size)
    return BatchedScanStrategy(block=block, bound=block_bound,
                               num_steps=n_steps)


def norm_block_strategy(
    norm_order: Array,
    norms_sorted: Array,
    u: Array,
    block_size: int,
    targets_by_norm: Optional[Array] = None,
    m_real=None,
) -> ScanStrategy:
    """Decreasing-norm contiguous blocks with Cauchy-Schwarz bounds.

    Block ``b`` covers items ``norm_order[b*B:(b+1)*B]`` (a contiguous
    gather); every unseen score is bounded by ``||u|| * norms_sorted[(b+1)*B]``.
    Items never repeat across blocks, so the driver skips visited tracking.

    When ``targets_by_norm`` (the catalogue pre-permuted into decreasing-
    norm order, :attr:`repro.core.index.TopKIndex.targets_by_norm`) is
    given, the whole block step goes memory-layout native (DESIGN.md §6):
    scoring is a contiguous ``dynamic_slice`` + matvec instead of a row
    gather (the Pallas kernel's DMA layout, in pure XLA), candidate ids
    are the norm-ordered ROW numbers (an iota — no id gather in the loop;
    the caller maps rows back to catalogue ids once, after the scan, via
    ``norm_order``), and the per-block Cauchy-Schwarz bounds are one
    precomputed vector indexed per step. The tail block slides back to
    stay in bounds; rows re-entering from the previous block are masked
    inactive, so counts are unchanged.

    ``m_real`` (traced) is the real catalogue size when the norm arrays
    are M-bucket padded (pad rows zero, norm 0, id -1 — sorted last by
    construction): the tail block then slides back against the REAL end,
    pad rows are masked out of scoring and counting, and the dynamic
    step cap stops the scan where the unpadded scan would.
    """
    M = norm_order.shape[0]
    m = M if m_real is None else m_real
    u_norm = jnp.linalg.norm(u)
    offs = jnp.arange(block_size, dtype=jnp.int32)
    use_slices = targets_by_norm is not None and M >= block_size
    n_steps = -(-M // block_size)
    # bound after step b = ||u|| * norm of the first unseen row; one
    # vectorised precompute, one dynamic index per step
    next_starts = jnp.minimum(
        (jnp.arange(n_steps, dtype=jnp.int32) + 1) * block_size, m - 1)
    block_bounds = u_norm * norms_sorted[next_starts]

    def candidates(step):
        d0 = step * block_size
        if use_slices:
            start = jnp.maximum(0, jnp.minimum(d0, m - block_size))
            rows = start + offs
            # mask rows the previous block scored, and pad rows
            valid = jnp.logical_and(rows >= d0, rows < m)
            return rows, valid     # local rows; caller remaps after scan
        rows = jnp.minimum(d0 + offs, m - 1)
        valid = (d0 + offs) < m
        return norm_order[rows], valid

    score = None
    if use_slices:
        def score(step, ids, active):
            d0 = step * block_size
            start = jnp.maximum(0, jnp.minimum(d0, m - block_size))
            tile = jax.lax.dynamic_slice_in_dim(targets_by_norm, start,
                                                block_size)
            return tile @ u

    def bound(step):
        return block_bounds[step]

    return ScanStrategy(candidates=candidates, bound=bound,
                        num_steps=n_steps, track_visited=False,
                        score=score,
                        num_steps_dynamic=(
                            None if m_real is None
                            else -(-m_real // block_size)))
