"""The three built-in scan strategies (DESIGN.md §2).

Each constructor closes over the catalogue index arrays and one query and
returns a :class:`repro.core.driver.ScanStrategy` for
:func:`repro.core.driver.pruned_block_scan`:

* :func:`ta_round_strategy` — the paper's Algorithm 2 round structure over
  the per-query *flipped views* (one list depth per step).
* :func:`blocked_lists_strategy` — the Block Threshold Algorithm: a depth
  block of ``B`` entries from all R lists per step, with the sign flip
  applied on the gather side (``block_size=1`` recovers TA rounds exactly,
  id-for-id and bound-for-bound).
* :func:`norm_block_strategy` — contiguous blocks in decreasing-norm order
  bounded by Cauchy-Schwarz (the layout the Pallas backend consumes).

All three leave ``ScanStrategy.score`` as the default dense gather +
matvec; a future partial-scoring strategy (paper Alg. 3) plugs in there.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.driver import ScanStrategy

Array = jnp.ndarray


def ta_round_strategy(order: Array, t_sorted: Array, u: Array) -> ScanStrategy:
    """Paper-faithful TA rounds over pre-flipped per-query views.

    Args:
      order / t_sorted: ``[R, M]`` views from
        :meth:`repro.core.index.TopKIndex.query_views` — already walking in
        decreasing ``u_r * t_r`` order for every list.
      u: ``[R]`` query.
    """
    R, M = order.shape
    active = u != 0  # sparse queries: zero-weight lists are never walked

    def candidates(step):
        ids = jax.lax.dynamic_slice_in_dim(order, step, 1, axis=1)[:, 0]
        return ids, active

    def bound(step):
        # Eq. 3 at the depth just consumed
        t_at = jax.lax.dynamic_slice_in_dim(t_sorted, step, 1, axis=1)[:, 0]
        return jnp.sum(u * t_at)

    return ScanStrategy(candidates=candidates, bound=bound, num_steps=M,
                        track_visited=True)


def blocked_lists_strategy(
    order_desc: Array,
    t_sorted_desc: Array,
    u: Array,
    block_size: int,
) -> ScanStrategy:
    """BTA enumeration: ``R * block_size`` candidates per step.

    Negative query weights are handled without materialising per-query
    flipped lists: depth ``d`` in list ``r`` reads position ``M-1-d`` when
    ``u_r < 0`` (a gather-side index transform, not a data transform) —
    which is why this strategy, unlike :func:`ta_round_strategy`, stays
    O(R*B) memory per query under ``vmap``.
    """
    R, M = order_desc.shape
    neg = u < 0
    active = u != 0
    active_rep = jnp.repeat(active, block_size,
                            total_repeat_length=R * block_size)
    offs = jnp.arange(block_size, dtype=jnp.int32)

    def candidates(step):
        d0 = step * block_size
        cols = jnp.minimum(d0 + offs, M - 1)
        cols_eff = jnp.where(neg[:, None], M - 1 - cols[None, :],
                             cols[None, :])
        ids = jnp.take_along_axis(order_desc, cols_eff, axis=1).reshape(-1)
        return ids, active_rep

    def bound(step):
        # bound at the block's last processed depth — valid for every unseen
        # item because the lists are monotone (Eq. 3 holds at any depth)
        end = jnp.minimum(step * block_size + block_size - 1, M - 1)
        end_eff = jnp.where(neg, M - 1 - end, end)
        t_end = t_sorted_desc[jnp.arange(R), end_eff]
        return jnp.sum(u * t_end)

    return ScanStrategy(candidates=candidates, bound=bound,
                        num_steps=-(-M // block_size), track_visited=True)


def norm_block_strategy(
    norm_order: Array,
    norms_sorted: Array,
    u: Array,
    block_size: int,
) -> ScanStrategy:
    """Decreasing-norm contiguous blocks with Cauchy-Schwarz bounds.

    Block ``b`` covers items ``norm_order[b*B:(b+1)*B]`` (a contiguous
    gather); every unseen score is bounded by ``||u|| * norms_sorted[(b+1)*B]``.
    Items never repeat across blocks, so the driver skips visited tracking.
    """
    M = norm_order.shape[0]
    u_norm = jnp.linalg.norm(u)
    offs = jnp.arange(block_size, dtype=jnp.int32)

    def candidates(step):
        d0 = step * block_size
        rows = jnp.minimum(d0 + offs, M - 1)
        valid = (d0 + offs) < M
        return norm_order[rows], valid

    def bound(step):
        next_start = jnp.minimum((step + 1) * block_size, M - 1)
        return u_norm * norms_sorted[next_start]

    return ScanStrategy(candidates=candidates, bound=bound,
                        num_steps=-(-M // block_size), track_visited=False)
