"""Sharded multi-level streaming catalogue: the LSM ladder (DESIGN.md §15).

:class:`repro.core.segments.SegmentedCatalogue` already gives exact,
compile-free streaming — but it is SINGLE-LEVEL: every compaction folds
the whole delta chain into a fresh base snapshot, and at catalogue sizes
the ROADMAP north-star cares about (millions of live targets) that full
rebuild (~1.1 s @ 131k, super-linear above) is the entire compaction
cost, paid every ``delta_capacity`` mutations. This module adds the two
missing LSM rungs so the expensive rebuild amortises:

* **L1 tier** — per-shard append runs (plain
  :class:`~repro.core.segments.DeltaSegment` instances, one per shard).
  A sealed L0 delta segment FOLDS into the tier by dealing its live rows
  round-robin across the shard runs — a few thousand ``numpy`` row
  copies under the catalogue lock, touching only the receiving shards'
  slabs. No index build, no layout build, no engine work: the fold
  replaces the full rebuild for the common trigger (delta full).
* **Promotion** — only when the L1 tier itself cannot absorb the next
  fold (or base tombstones cross the compaction threshold) do the runs
  seal and join the frozen chain, and ONE ordinary base rebuild — the
  inherited builder, with all its readiness/recovery machinery —
  flattens base + L1 + L0 into a fresh ``norm_sharded``-servable
  snapshot. With the default tier sizing (``4 * delta_capacity`` rows
  per shard) a ladder with S shards runs ``~4 S`` folds per rebuild, so
  rebuilds are ``~4 S`` times rarer than the single-level catalogue's
  at the same delta capacity (measured, not asserted, by
  ``benchmarks/streaming_lsm.py``).

**Exactness** is inherited, not re-argued: the ladder only moves rows
between tiers that are all FULLY dense-scored every query. The base
over-fetch ladder (§9) concerns base rows alone and is untouched; the
L1 tier scores every live slab row with one
``[B, R] x [S, C, R]`` einsum and folds through the two-level
:func:`repro.core.sharded.shard_fold_topk` merge (block-local
``top_k`` per shard, then the O(K) sorted merge), exactly like the
delta segments behind it — so any interleaving of folds and queries
returns precisely what a fresh rebuild would (the property harness in
``tests/test_streaming_properties.py`` replays randomized schedules
against that oracle).

**Compile-freedom** follows the §10 argument-passing contract: the
stacked tier device view is built from the runs' RAW storage arrays at
full per-shard capacity — ``(rows [S, C, R], gids [S, C],
live [S, C])`` — so the whole tier is ONE extra operand shape
``(n_shards, run_capacity)`` regardless of occupancy, pre-compiled by
:meth:`SegmentedCatalogue.warm` alongside the no-tier variant. A fold
changes array contents, never compiled shapes; ``cache_token`` does not
move either (a fold relocates rows without changing what is visible,
so cached results stay exact — deliberately NO epoch bump).

**Recovery** mirrors the build machinery (DESIGN.md §12): the
``compaction.fold_l1`` seam fires before any slab is touched, so an
injected fold failure leaves the sealed chain intact and queryable;
fold failures are recorded (never raised into a mutation batch), gated
by their own exponential backoff + ``build_retry_limit`` streak, and
surfaced by ``compact(wait=True)``. The ``compaction.promote`` seam
fires at the overflow decision, before the rebuild launches — an
injected promotion failure is recorded as a build failure and the
tier + chain keep serving.
"""

from __future__ import annotations

import time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import faults
from repro.core.engines import batch_bucket
from repro.core.layout import round_robin_shares
from repro.core.segments import DeltaSegment, SegmentedCatalogue

__all__ = ["ShardedLsmCatalogue", "DEFAULT_L1_CAPACITY_FACTOR"]

#: Default per-shard L1 run capacity, as a multiple of ``delta_capacity``.
#: 4 keeps the tier one power-of-two bucket (so ONE warmed tail shape)
#: while giving an S-shard ladder ~4·S folds per full rebuild.
DEFAULT_L1_CAPACITY_FACTOR = 4


class ShardedLsmCatalogue(SegmentedCatalogue):
    """Per-shard LSM compaction ladder over the segmented catalogue.

    Everything the base class guarantees (exactness at any mutation
    rate, compile-free mutation, crash-safe build recovery, the
    ``(version, epoch)`` cache token) holds unchanged; this subclass
    only changes WHAT a compaction trigger does: fold the sealed L0
    chain into the per-shard L1 tier when it fits, promote the tier
    into a full base rebuild when it does not.

    Args:
      targets: initial ``[M, R]`` catalogue (global ids ``0..M-1``).
      n_shards: L1 shard-run count. Align with the device mesh when the
        base is served by ``norm_sharded`` (the slabs then mirror the
        engine's shard layout), but any value >= 1 is valid — the tier
        merge is mesh-free.
      l1_capacity: per-shard run capacity in rows (rounded up to a
        power of two). ``None`` uses
        ``DEFAULT_L1_CAPACITY_FACTOR * delta_capacity``.
      **kwargs: forwarded to :class:`SegmentedCatalogue`.
    """

    def __init__(self, targets, *, n_shards: int = 8,
                 l1_capacity: Optional[int] = None, **kwargs):
        super().__init__(targets, **kwargs)
        self._n_shards = max(int(n_shards), 1)
        if l1_capacity is None:
            l1_capacity = DEFAULT_L1_CAPACITY_FACTOR * self.delta_capacity
        self._l1_run_capacity = batch_bucket(max(int(l1_capacity), 1))
        with self._lock:
            self._l1: List[DeltaSegment] = [
                DeltaSegment(self._l1_run_capacity, self.rank)
                for _ in range(self._n_shards)]
            self._l1_cursor = 0               # round-robin deal position
            self._l1_dev = None               # cached stacked device view
            # L1 runs parked in the frozen chain by an in-flight
            # promotion (excluded from chain-cap pressure; see
            # _chain_pressure_locked)
            self._promoted_runs: List[DeltaSegment] = []
            # fold-failure recovery state, mirroring the build machinery
            self._consec_fold_failures = 0
            self._fold_not_before = 0.0       # monotonic deadline
            self._last_fold_backoff_s = 0.0
            self._promoting = False           # re-entry guard
            self.last_fold_error: Optional[BaseException] = None

    # -- introspection -------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return self._n_shards

    @property
    def l1_run_capacity(self) -> int:
        return self._l1_run_capacity

    @property
    def l1_rows(self) -> int:
        with self._lock:
            return self._l1_live_locked()

    @property
    def consecutive_fold_failures(self) -> int:
        with self._lock:
            return self._consec_fold_failures

    @property
    def fold_backoff_s(self) -> float:
        with self._lock:
            return (self._last_fold_backoff_s
                    if self._consec_fold_failures else 0.0)

    @property
    def n_tombstones(self) -> int:
        with self._lock:
            return self._snapshot.n_dead + sum(
                int(np.sum(seg.dead[:seg.count]))
                for seg in (*self._l1, *self._segments()))

    @property
    def num_live(self) -> int:
        with self._lock:
            return (self._snapshot.num_rows - self._snapshot.n_dead
                    + sum(seg.n_live
                          for seg in (*self._l1, *self._segments())))

    @property
    def pristine(self) -> bool:
        with self._lock:                      # RLock: nested read is fine
            return (all(run.count == 0 for run in self._l1)
                    and SegmentedCatalogue.pristine.fget(self))

    def as_dense(self):
        with self._lock:
            # ladder age order: base, L1 (older), frozen L0, active delta
            return self._live_concat_locked(
                self._snapshot, [*self._l1, *self._segments()])

    def _chain_pressure_locked(self) -> int:
        self._promoted_runs = [r for r in self._promoted_runs
                               if r in self._frozen]
        return len(self._frozen) - len(self._promoted_runs)

    # -- locate/kill across the extra tier -----------------------------------

    def _locate(self, gid: int):
        if gid in self._delta._pos:
            return "delta", self._delta
        for frozen in self._frozen:
            if gid in frozen._pos:
                return "frozen", frozen
        for run in self._l1:
            if gid in run._pos:
                return "l1", run
        row = self._snapshot.gid_to_row.get(gid)
        if row is not None and not self._snapshot.dead_np[row]:
            return "base", row
        raise KeyError(f"gid {gid} is not a live catalogue item")

    def _kill_located(self, located) -> None:
        # "l1" kills ride the base else-branch (seg.kill); runs in the
        # tier are never captured by an in-flight build (promotion moves
        # them into the frozen chain first, where kills take the
        # pending-dead path), so no extra bookkeeping — just drop the
        # cached stacked view.
        super()._kill_located(located)
        if any(where == "l1" for _, where, _ in located):
            self._l1_dev = None

    # -- L1 tier presentation (the hooks the base query/warm paths call) -----

    def _l1_live_locked(self) -> int:
        return sum(run.n_live for run in self._l1)

    def _l1_stack_locked(self):
        if all(run.count == 0 for run in self._l1):
            return None
        if self._l1_dev is None:
            cap = self._l1_run_capacity
            live = np.zeros((self._n_shards, cap), bool)
            for s, run in enumerate(self._l1):
                live[s, :run.count] = ~run.dead[:run.count]
            # RAW storage arrays at full capacity — not device_view():
            # one (n_shards, capacity) operand shape for the whole tier,
            # whatever the occupancy, so folds never add tail compiles
            self._l1_dev = (
                jnp.asarray(np.stack([run.rows for run in self._l1])),
                jnp.asarray(np.stack([run.gids for run in self._l1]
                                     ).astype(np.int32)),
                jnp.asarray(live))
        return self._l1_dev

    def _warm_l1_variants(self):
        cap = self._l1_run_capacity
        s = self._n_shards
        dummy = (jnp.zeros((s, cap, self.rank), jnp.float32),
                 jnp.full((s, cap), -1, jnp.int32),
                 jnp.zeros((s, cap), bool))
        return (((), None), ((s, cap), dummy))

    # -- the ladder decision -------------------------------------------------

    def _compact_locked(self, force: bool = False,
                        force_sync: bool = False) -> None:
        if self._promoting:
            # re-entry guard: while the promotion path below is driving
            # the inherited builder, any nested virtual dispatch must
            # mean BASE semantics, not a second ladder decision
            return super()._compact_locked(force, force_sync)
        snap = self._snapshot
        if (self._delta.count == 0 and not self._frozen
                and snap.n_dead == 0):
            return                            # nothing to fold
        # seal the active delta into the L0 chain (same clause as base)
        if self._delta.count > 0 or not self._frozen:
            sealed = self._delta
            sealed.seal()
            self._frozen.append(sealed)
            self._delta = DeltaSegment(self.delta_capacity, self.rank)
            self.stats.max_l0_chain = max(self.stats.max_l0_chain,
                                          len(self._frozen))
        if self._build_thread is not None:
            return                            # in-flight build; chain waits
        # the ladder decision: fold when the tier can absorb the chain,
        # promote when it cannot (or base tombstones crossed the
        # compaction threshold — only a rebuild reclaims those)
        thresh = min(float(self.max_tombstones),
                     self.tombstone_compact_fraction
                     * max(snap.num_rows, 1))
        n_fold = sum(s.n_live for s in self._frozen)
        shares = round_robin_shares(n_fold, self._n_shards,
                                    self._l1_cursor)
        fits = all(int(shares[s]) <= run.capacity - run.count
                   for s, run in enumerate(self._l1))
        if (snap.n_dead and snap.n_dead >= thresh) or not fits:
            self._promote_locked(force_sync)
        else:
            self._fold_locked(force)

    def _fold_locked(self, force: bool) -> None:
        """Deal the sealed chain's live rows into the per-shard L1 runs.

        Synchronous under the lock — the fold is a few thousand host row
        copies, ~1000x cheaper than the rebuild it replaces. NEVER
        raises: a failure (the ``compaction.fold_l1`` seam, which fires
        before any slab is touched) is recorded exactly like a build
        failure — the chain stays sealed + queryable, retries are gated
        by an exponential backoff and the ``build_retry_limit`` streak,
        and ``compact(wait=True)`` surfaces the recorded error. The
        cache token does NOT move: a fold relocates rows without
        changing what queries see, so cached results remain exact.
        """
        if not force and self._consec_fold_failures:
            if (self._consec_fold_failures > self.build_retry_limit
                    or (self._consec_fold_failures >= 2
                        and time.monotonic() < self._fold_not_before)):
                return
        folding = list(self._frozen)
        if self._consec_fold_failures:
            self.stats.n_l1_fold_retries += 1
        t0 = time.perf_counter()
        try:
            faults.fire(faults.FAULT_FOLD_L1)
            cur, moved = self._l1_cursor, 0
            for seg in folding:
                if not seg.count:
                    continue
                rows, gids = seg.live_rows()
                for row, gid in zip(rows, gids):
                    run = self._l1[(cur + moved) % self._n_shards]
                    run.append(row, int(gid))
                    moved += 1
            self._l1_cursor = (cur + moved) % self._n_shards
            self._frozen = [s for s in self._frozen if s not in folding]
            self._l1_dev = None
            dt = time.perf_counter() - t0
            self.stats.n_l1_folds += 1
            self.stats.l1_fold_s_total += dt
            self.last_fold_error = None
            self._consec_fold_failures = 0
            self._fold_not_before = 0.0
            self._last_fold_backoff_s = 0.0
            # same join keys as compaction.success (version, epoch): the
            # journal can join a traced request's device span to the
            # exact per-shard state it scanned across the fold
            obs.on_compaction(
                "fold_l1", version=self._snapshot.version,
                epoch=self._epoch, chain_len=len(folding),
                rows_folded=int(moved),
                l1_rows=int(self._l1_live_locked()), duration_s=dt)
        except Exception as exc:
            self.last_fold_error = exc
            self.stats.n_failed_l1_folds += 1
            self._consec_fold_failures += 1
            backoff = min(
                self.build_backoff_s
                * (2 ** (self._consec_fold_failures - 1)),
                self.build_backoff_max_s)
            self._last_fold_backoff_s = backoff
            self._fold_not_before = time.monotonic() + backoff
            obs.on_compaction(
                "fold_fail", version=self._snapshot.version,
                epoch=self._epoch, error=repr(exc),
                consecutive_failures=self._consec_fold_failures,
                backoff_s=backoff)

    def _promote_locked(self, force_sync: bool) -> None:
        """Seal the L1 tier into the chain and run ONE full base rebuild.

        The inherited builder does all the heavy lifting (readiness
        warm, pending-dead replay, failure backoff, async recovery);
        this method only decides and stages. The ``compaction.promote``
        seam fires BEFORE anything moves — an injected failure is
        recorded as a build failure and the tier keeps serving as is.
        """
        # the build-failure gate, checked BEFORE disturbing the tier so
        # a gated promote leaves the runs in place (no churn through the
        # frozen chain); the super() call below then forces past its own
        # identical gate — the decision is already made here
        if self._consec_build_failures:
            if (self._consec_build_failures > self.build_retry_limit
                    or (self._consec_build_failures >= 2
                        and time.monotonic() < self._retry_not_before)):
                return
        try:
            faults.fire(faults.FAULT_PROMOTE)
        except Exception as exc:
            self.last_build_error = exc
            self.stats.n_failed_compactions += 1
            self._consec_build_failures += 1
            backoff = min(
                self.build_backoff_s
                * (2 ** (self._consec_build_failures - 1)),
                self.build_backoff_max_s)
            self._last_backoff_s = backoff
            self._retry_not_before = time.monotonic() + backoff
            obs.on_compaction(
                "fail", version_attempted=self._snapshot.version + 1,
                epoch=self._epoch, error=repr(exc),
                consecutive_failures=self._consec_build_failures,
                backoff_s=backoff)
            return
        promoted = []
        for run in self._l1:
            if run.count:
                run.seal()                    # full-capacity device view
                self._frozen.append(run)
                promoted.append(run)
        self._promoted_runs = promoted
        self._l1 = [DeltaSegment(self._l1_run_capacity, self.rank)
                    for _ in range(self._n_shards)]
        self._l1_cursor = 0
        self._l1_dev = None
        obs.on_compaction(
            "promote", version=self._snapshot.version, epoch=self._epoch,
            chain_len=len(self._frozen),
            rows_promoted=sum(r.n_live for r in promoted))
        self._promoting = True
        try:
            # force=True: the gate was already checked above, and the
            # runs are staged in the chain — the build MUST launch (a
            # bail here would leave them to churn back through a fold)
            super()._compact_locked(force=True, force_sync=force_sync)
        finally:
            self._promoting = False

    def promote(self, wait: bool = True) -> None:
        """Force a full promotion now: flatten L1 + L0 + delta into a
        fresh base snapshot (the ladder's equivalent of the base
        class's unconditional ``compact``). ``wait=True`` surfaces a
        recorded build failure as an exception."""
        self.flush()                          # let an in-flight build land
        with self._lock:
            # stage EVERYTHING: seal the active delta if it has rows...
            if self._delta.count > 0:
                sealed = self._delta
                sealed.seal()
                self._frozen.append(sealed)
                self._delta = DeltaSegment(self.delta_capacity, self.rank)
                self.stats.max_l0_chain = max(self.stats.max_l0_chain,
                                              len(self._frozen))
            if self._build_thread is None:
                fails_before = self.stats.n_failed_compactions
                self._promote_locked(force_sync=False)
            else:
                fails_before = None           # ride the in-flight build
        if not wait:
            return
        self.flush()
        with self._lock:
            if (fails_before is not None
                    and self.stats.n_failed_compactions > fails_before):
                raise RuntimeError(
                    "promotion build failed; the L1 tier and sealed "
                    "chain remain queryable"
                ) from self.last_build_error
