"""Block Threshold Algorithm (BTA) — the TPU-native adaptation of TA.

The paper's TA pops ONE item per list per round: pointer chasing, hash-set
dedup, heap update — shapes a TPU cannot execute efficiently (DESIGN.md §4).
BTA restructures the same exact algorithm around the MXU:

* one round pops a **depth block** of ``B`` entries from all R lists at once
  (``R*B`` candidate ids),
* the candidates are scored as a single gather + matvec/matmul,
* the running top-K is merged block-locally and folded into the carry with
  an O(K) sorted merge (:func:`repro.core.driver.merge_topk_sorted`),
* the stopping bound is evaluated at the block's LAST depth — still a valid
  upper bound for every unseen item because the lists are monotone (Eq. 3
  holds at any depth), so **exactness is preserved**; at most one extra
  block of items is scored compared to item-at-a-time TA.

``chunked_ta_topk`` keeps the paper's item-at-a-time *accounting* while
executing block-shaped work: a chunk of ``chunk`` rounds is gathered and
scored at once, then the driver's per-candidate prefix masking replays the
rounds sequentially so ``n_scored``/``depth`` equal the sequential
algorithm's exactly (the `ta` registry engine runs on this path).

Also here: ``norm_pruned_topk`` — a beyond-paper exact pruner that walks the
catalogue in decreasing ``||t(y)||`` order and bounds whole *contiguous*
blocks with Cauchy-Schwarz ``s(x,y) <= ||u|| * max_norm(block)`` (LEMP-style
screening, but block-synchronous for the MXU; gathers are contiguous, which
the Pallas kernel exploits).

All are thin wrappers: the loop itself is
:func:`repro.core.driver.pruned_block_scan` running
:func:`repro.core.strategies.blocked_lists_strategy` /
:func:`repro.core.strategies.norm_block_strategy`. ``block_size=1``
recovers paper-faithful TA rounds; ``max_blocks`` is the uniform halted
variant across every strategy.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.driver import (ScanState, batched_pruned_scan,
                               merge_block_into_carry_batched,
                               pruned_block_scan)
from repro.core.index import TopKIndex
from repro.core.naive import TopKResult
from repro.core.strategies import (
    batched_list_prefix_strategy,
    blocked_lists_strategy,
    list_prefix_strategy,
    norm_block_strategy,
)

Array = jnp.ndarray


def _pallas_tail_scorer(targets, u):
    """``ids -> scores`` via the gather-fused Pallas kernel (TPU tails).

    One fused DMA-per-row kernel instead of an XLA gather + matvec; only
    worth compiling on real TPU backends (``tail_pallas=True`` there), so
    the interpret-mode CPU path never pays the per-row interpreter cost.
    """
    from repro.kernels.topk_mips import gather_scores_pallas

    def score_fn(ids):
        return gather_scores_pallas(targets, ids, u)

    return score_fn


def _two_phase_list_scan(targets, order_desc, t_sorted_desc, u, k,
                         block_size, max_blocks, max_rounds, layout,
                         ta_rounds, tail_score_fn=None, m_real=None):
    """Contiguous prefix phase chained into a gather-side tail phase.

    Phase 1 runs :func:`repro.core.strategies.list_prefix_strategy` over
    the layout's contiguous prefix; its final :class:`ScanState` seeds a
    :func:`repro.core.strategies.blocked_lists_strategy` tail whose
    freshness comes from per-block ``rank_by_item`` gathers — so the tail
    needs neither the O(M) visited bitmap nor the O(R*M) key precompute,
    and a query that certifies inside the prefix (virtually all of them)
    never executes a tail iteration (DESIGN.md §7). Results and
    ``n_scored``/``depth`` are identical to the single-phase gather scan.
    ``m_real`` (traced) flows into both phases when the index arrays are
    M-bucket padded (DESIGN.md §10).
    """
    prefix = list_prefix_strategy(layout, t_sorted_desc, u, block_size,
                                  ta_rounds=ta_rounds, m_real=m_real)
    _, state = pruned_block_scan(
        targets, u, prefix, k, max_steps=max_blocks, max_rounds=max_rounds,
        return_state=True)
    tail = blocked_lists_strategy(order_desc, t_sorted_desc, u, block_size,
                                  rank_by_item=layout.rank_by_item,
                                  ta_rounds=ta_rounds,
                                  score_fn=tail_score_fn, m_real=m_real)
    return pruned_block_scan(targets, u, tail, k, max_steps=max_blocks,
                             max_rounds=max_rounds, init_state=state)


def _batched_two_phase_list_scan(targets, order_desc, t_sorted_desc, U, k,
                                 block_size, max_blocks, max_rounds, layout,
                                 ta_rounds, sign, dense, tail_pallas=False,
                                 m_real=None):
    """Batch-native prefix phase chained into a vmapped gather tail.

    Phase 1 is :func:`repro.core.driver.batched_pruned_scan` over
    :func:`repro.core.strategies.batched_list_prefix_strategy` — ONE
    shared tile enumeration per step for the whole batch, per-query
    liveness/freshness keeping every counter sequential-faithful
    (DESIGN.md §11). The final :class:`BatchedScanState` is split into
    per-lane :class:`ScanState` s (each lane's ABSOLUTE block cursor is
    its gated ``steps`` counter) seeding the same vmapped gather-side
    tail the per-query path uses; a batch whose every query certified
    inside the prefix — virtually all of them — executes ZERO tail
    iterations, and a prefix-overflowing lane resumes exactly where its
    sequential scan would.
    """
    prefix = batched_list_prefix_strategy(
        layout, t_sorted_desc, U, block_size, sign=sign, dense=dense,
        ta_rounds=ta_rounds, m_real=m_real)
    _, bstate = batched_pruned_scan(
        U, prefix, k, targets.dtype, max_steps=max_blocks,
        max_rounds=max_rounds, return_state=True)
    B = U.shape[0]
    states = ScanState(
        step=bstate.steps,                       # [B] absolute block cursor
        top_vals=bstate.top_vals, top_ids=bstate.top_ids,
        visited=jnp.zeros((B, 1), bool),         # tail is fresh_mask-based
        n_scored=bstate.n_scored, rounds=bstate.rounds,
        lower=bstate.lower, upper=bstate.upper)

    def tail_one(u, st):
        tail = blocked_lists_strategy(
            order_desc, t_sorted_desc, u, block_size,
            rank_by_item=layout.rank_by_item, ta_rounds=ta_rounds,
            score_fn=_pallas_tail_scorer(targets, u) if tail_pallas
            else None, m_real=m_real)
        return pruned_block_scan(targets, u, tail, k, max_steps=max_blocks,
                                 max_rounds=max_rounds, init_state=st)

    return jax.vmap(tail_one)(U, states)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_size", "max_blocks", "sign",
                                    "dense", "tail_pallas"))
def blocked_topk_batched_native(
    targets: Array,
    order_desc: Array,
    t_sorted_desc: Array,
    U: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
    layout=None,
    sign: int = 0,
    dense: bool = False,
    tail_pallas: bool = False,
    m_real=None,
) -> TopKResult:
    """Batch-native BTA over the list-prefix layout (DESIGN.md §11).

    The batched counterpart of ``vmap(blocked_topk)``: one shared prefix
    tile per step for the whole batch, a single batch-level while_loop
    whose step count is the max live query's depth, per-query
    freshness/liveness so results AND ``n_scored``/``depth`` equal the
    per-query scan's. ``sign``/``dense`` are the batch's STATIC sign
    bucket (:func:`repro.core.strategies.sign_bucket`); the caller
    guarantees they match ``U`` and that ``layout`` has the needed
    side(s). Requires a layout whose prefix covers at least one block.
    """
    if layout is None or layout.prefix_steps(block_size) < 1:
        raise ValueError("blocked_topk_batched_native requires a "
                         "ListMajorLayout with >= 1 prefix block")
    if not layout.serves_sign(sign):
        raise ValueError(
            f"layout with sides {layout.sides!r} cannot serve sign "
            f"bucket {sign} (mixed batches need both directions)")
    k = min(k, targets.shape[0])
    res = _batched_two_phase_list_scan(
        targets, order_desc, t_sorted_desc, U, k, block_size, max_blocks,
        -1, layout, ta_rounds=False, sign=sign, dense=dense,
        tail_pallas=tail_pallas, m_real=m_real)
    return res._replace(depth=res.depth * block_size)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_size", "max_blocks",
                                    "tail_pallas"))
def blocked_topk(
    targets: Array,
    order_desc: Array,
    t_sorted_desc: Array,
    u: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
    rank_desc: Optional[Array] = None,
    layout=None,
    tail_pallas: bool = False,
    m_real=None,
) -> TopKResult:
    """Exact top-K via the Block Threshold Algorithm (single query).

    Args:
      targets: ``[M, R]`` catalogue factors.
      order_desc / t_sorted_desc: the query-independent index
        (:class:`repro.core.index.TopKIndex` fields).
      u: ``[R]`` query.
      k: top-K size (static).
      block_size: list depth consumed per round (static). ``block_size=1``
        degenerates to the paper's TA round structure.
      max_blocks: optional round budget — the halted variant.
      rank_desc: optional inverse permutations
        (:attr:`repro.core.index.TopKIndex.rank_desc`); when given, dedup
        runs on cursor arithmetic and the O(M) visited bitmap disappears
        from the scan carry (identical results and counts, much cheaper
        per step).
      layout: optional :class:`repro.core.layout.ListMajorLayout`. Blocks
        inside the layout's prefix are then scored from contiguous
        ``[R, B, R]`` tiles (no row gathers) and the scan only falls back
        to gathers past the prefix — identical results and counts
        (DESIGN.md §7).
      m_real: optional TRACED real catalogue size when the index arrays
        (and ``layout.rank_by_item``) are padded to an M-bucket
        (DESIGN.md §10) — pad entries are never walked, scored, or
        counted, so results equal the unpadded scan bit for bit.
    """
    if layout is not None and layout.prefix_steps(block_size) > 0:
        res = _two_phase_list_scan(targets, order_desc, t_sorted_desc, u,
                                   k, block_size, max_blocks, -1, layout,
                                   ta_rounds=False,
                                   tail_score_fn=_pallas_tail_scorer(
                                       targets, u) if tail_pallas else None,
                                   m_real=m_real)
    else:
        strategy = blocked_lists_strategy(order_desc, t_sorted_desc, u,
                                          block_size, rank_desc=rank_desc,
                                          m_real=m_real)
        res = pruned_block_scan(targets, u, strategy, k,
                                max_steps=max_blocks)
    # public depth unit is list depth, not blocks
    return res._replace(depth=res.depth * block_size)


def blocked_topk_batched(
    targets: Array,
    index: TopKIndex,
    U: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
) -> TopKResult:
    """vmap of :func:`blocked_topk` over a query batch ``U: [B, R]``.

    Each query carries its own bound state; the vmapped while_loop runs
    until the slowest query terminates (lockstep on TPU), which is the
    batched-serving semantics discussed in DESIGN.md §4. The driver's
    per-query liveness gating keeps ``n_scored``/``depth`` faithful to the
    sequential algorithm even for queries that certified early.
    """
    def one(u):
        return blocked_topk(targets, index.order_desc, index.t_sorted_desc,
                            u, k, block_size, max_blocks,
                            rank_desc=index.rank_desc)

    return jax.vmap(one)(U)


# ---------------------------------------------------------------------------
# Chunked TA: block-shaped execution, item-at-a-time accounting
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("k", "chunk", "max_rounds",
                                    "tail_pallas"))
def chunked_ta_topk(
    targets: Array,
    order_desc: Array,
    t_sorted_desc: Array,
    rank_desc: Array,
    u: Array,
    k: int,
    chunk: int = 32,
    max_rounds: int = -1,
    layout=None,
    tail_pallas: bool = False,
    m_real=None,
) -> TopKResult:
    """Exact TA whose rounds are processed ``chunk`` at a time.

    One driver step gathers and scores ``R * chunk`` candidates (one
    MXU-shaped pass), then replays the chunk as ``chunk`` sequential paper
    rounds with per-candidate prefix masking — so the returned
    ``n_scored``/``depth`` are identical to the ``chunk=1`` sequential
    algorithm (and to :func:`repro.core.threshold.threshold_topk_np`),
    while the wall-clock cost per round drops by ~``chunk``.

    ``max_rounds`` is the paper's halted-TA budget, enforced at ROUND
    granularity even mid-chunk. ``depth`` is returned in rounds
    (= list depth), the same unit as ``blocked_topk`` at ``block_size=1``.

    ``layout`` (a :class:`repro.core.layout.ListMajorLayout`) makes the
    rounds inside the layout prefix gather-free — contiguous tile slices
    and a per-query O(R*P) freshness scatter instead of row gathers and
    the O(R*M) key precompute — chaining into a gather-side tail only for
    scans that outlive the prefix. Counts stay sequential-faithful on
    both phases (DESIGN.md §7).

    ``m_real`` (traced) is the real catalogue size when the index arrays
    are M-bucket padded (DESIGN.md §10); rounds past it never execute.
    """
    if (layout is not None and chunk > 1
            and layout.prefix_steps(chunk) > 0):
        return _two_phase_list_scan(targets, order_desc, t_sorted_desc, u,
                                    k, chunk, -1, max_rounds, layout,
                                    ta_rounds=True,
                                    tail_score_fn=_pallas_tail_scorer(
                                        targets, u) if tail_pallas else None,
                                    m_real=m_real)
    strategy = blocked_lists_strategy(order_desc, t_sorted_desc, u, chunk,
                                      rank_desc=rank_desc, ta_rounds=True,
                                      m_real=m_real)
    # at chunk=1 the strategy degenerates to the plain blocked scan, whose
    # halting budget is counted in (single-round) steps
    return pruned_block_scan(targets, u, strategy, k,
                             max_steps=max_rounds if chunk == 1 else -1,
                             max_rounds=max_rounds)


def chunked_ta_topk_batched(
    targets: Array,
    index: TopKIndex,
    U: Array,
    k: int,
    chunk: int = 32,
    max_rounds: int = -1,
) -> TopKResult:
    """vmap of :func:`chunked_ta_topk` over a query batch ``U: [B, R]``."""
    def one(u):
        return chunked_ta_topk(targets, index.order_desc,
                               index.t_sorted_desc, index.rank_desc, u, k,
                               chunk=chunk, max_rounds=max_rounds)

    return jax.vmap(one)(U)


@functools.partial(jax.jit,
                   static_argnames=("k", "chunk", "max_rounds", "sign",
                                    "dense", "tail_pallas"))
def chunked_ta_topk_batched_native(
    targets: Array,
    order_desc: Array,
    t_sorted_desc: Array,
    U: Array,
    k: int,
    chunk: int = 32,
    max_rounds: int = -1,
    layout=None,
    sign: int = 0,
    dense: bool = False,
    tail_pallas: bool = False,
    m_real=None,
) -> TopKResult:
    """Batch-native chunked TA over the list-prefix layout (DESIGN.md §11).

    The batched counterpart of ``vmap(chunked_ta_topk)``: the shared
    prefix tiles feed the driver's closed-form sequential-round
    recovery per lane, so each query's ``n_scored``/``depth`` equal the
    item-at-a-time paper algorithm's (and
    :func:`repro.core.threshold.threshold_topk_np`'s) exactly, while the
    whole batch shares one enumeration loop. ``sign``/``dense`` are the
    batch's static sign bucket, as in
    :func:`blocked_topk_batched_native`.
    """
    if layout is None or layout.prefix_steps(chunk) < 1:
        raise ValueError("chunked_ta_topk_batched_native requires a "
                         "ListMajorLayout with >= 1 prefix block")
    if not layout.serves_sign(sign):
        raise ValueError(
            f"layout with sides {layout.sides!r} cannot serve sign "
            f"bucket {sign} (mixed batches need both directions)")
    k = min(k, targets.shape[0])
    # chunk=1 degenerates to plain blocked steps (depth unit = rounds
    # either way); the halted budget then caps steps, as in the
    # per-query wrapper
    return _batched_two_phase_list_scan(
        targets, order_desc, t_sorted_desc, U, k, chunk,
        max_rounds if chunk == 1 else -1,
        max_rounds, layout, ta_rounds=chunk > 1, sign=sign, dense=dense,
        tail_pallas=tail_pallas, m_real=m_real)


# ---------------------------------------------------------------------------
# Norm-ordered Cauchy-Schwarz block pruning (beyond paper; exact)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "block_size", "max_blocks"))
def norm_pruned_topk_batched(
    targets_by_norm: Array,
    norm_order: Array,
    norms_sorted: Array,
    U: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
    m_real=None,
) -> TopKResult:
    """Batched-native norm scan: ONE shared tile per step for the batch.

    Unlike the list-based engines, the norm scan enumerates the SAME
    catalogue prefix in the same order for every query — so a lockstep
    batch never needs per-query gathers. Each step slices one contiguous
    ``[block, R]`` tile of the norm-ordered catalogue and scores the whole
    batch with a single ``[B, R] @ [R, block]`` matmul (the Pallas
    kernel's execution shape, in pure XLA; DESIGN.md §6). Per-query
    liveness gates every state update, so each query's
    ``n_scored``/``depth`` equal its own sequential scan's; the loop runs
    until the slowest live query certifies.

    ``m_real`` (traced) is the real catalogue size when the norm arrays
    are M-bucket padded (pad rows zero, norm 0 — sorted last;
    DESIGN.md §10): the tail block slides back against the real end, pad
    rows are masked from the merge and the counters, and the runtime
    step cap stops the loop exactly where the unpadded scan stops.

    Returns catalogue ids (rows are remapped through ``norm_order`` once,
    after the loop).
    """
    M, R = targets_by_norm.shape
    m = M if m_real is None else m_real
    B = U.shape[0]
    k = min(k, M)
    n_steps = -(-M // block_size)
    cap = n_steps if max_blocks < 0 else min(max_blocks, n_steps)
    cap_eff = cap if m_real is None else jnp.minimum(
        cap, -(-m_real // block_size))
    next_starts = jnp.minimum(
        (jnp.arange(n_steps, dtype=jnp.int32) + 1) * block_size, m - 1)
    bound_norms = norms_sorted[next_starts]              # [n_steps]
    u_norms = jnp.linalg.norm(U, axis=1)                 # [B]
    offs = jnp.arange(block_size, dtype=jnp.int32)
    neg_inf = jnp.asarray(float("-inf"), targets_by_norm.dtype)

    def cond(s):
        step, _, _, _, _, lower, upper = s
        return jnp.logical_and(step < cap_eff, jnp.any(lower < upper))

    def body(s):
        step, top_vals, top_ids, n_scored, depth, lower, upper = s
        live = lower < upper                             # [B]
        d0 = step * block_size
        start = jnp.maximum(0, jnp.minimum(d0, m - block_size))
        tile = jax.lax.dynamic_slice_in_dim(targets_by_norm, start,
                                            block_size)  # [block, R]
        scores = U @ tile.T                              # [B, block]
        rows = start + offs
        # tail block slides back (mask re-reads); pad rows masked too
        valid = jnp.logical_and(rows >= d0, rows < m)
        masked = jnp.where(valid[None, :], scores, neg_inf)
        new_vals, new_ids = merge_block_into_carry_batched(
            top_vals, top_ids, masked, rows, k)
        fresh = jnp.sum(valid).astype(jnp.int32)
        gate = live[:, None]
        return (step + 1,
                jnp.where(gate, new_vals, top_vals),
                jnp.where(gate, new_ids, top_ids),
                jnp.where(live, n_scored + fresh, n_scored),
                jnp.where(live, depth + 1, depth),
                jnp.where(live, new_vals[:, k - 1], lower),
                jnp.where(live, u_norms * bound_norms[step], upper))

    init = (jnp.int32(0),
            jnp.full((B, k), float("-inf"), targets_by_norm.dtype),
            jnp.full((B, k), -1, jnp.int32),
            jnp.zeros((B,), jnp.int32),
            jnp.zeros((B,), jnp.int32),
            jnp.full((B,), float("-inf"), targets_by_norm.dtype),
            jnp.full((B,), jnp.inf, targets_by_norm.dtype))
    if cap >= 1:
        init = body(init)       # block 0 is unconditionally live: unroll
    _, top_vals, top_ids, n_scored, depth, _, upper = jax.lax.while_loop(
        cond, body, init)
    ids = jnp.where(top_ids >= 0,
                    norm_order[jnp.clip(top_ids, 0, M - 1)], -1)
    # certificate tightening (as in the shared driver): a lane that
    # consumed every REAL block has nothing un-enumerated — vacuous -inf
    # bound; only a budget halt keeps the live block bound
    full_steps = -(-m // block_size)
    upper = jnp.where(depth >= full_steps, neg_inf, upper)
    return TopKResult(top_vals, ids, n_scored, depth * block_size,
                      upper=upper)


@functools.partial(jax.jit, static_argnames=("k", "block_size", "max_blocks"))
def norm_pruned_topk(
    targets: Array,
    norm_order: Array,
    norms_sorted: Array,
    u: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
    targets_by_norm: Optional[Array] = None,
    m_real=None,
) -> TopKResult:
    """Exact top-K scanning blocks in decreasing-norm order.

    Block ``b`` covers items ``norm_order[b*B:(b+1)*B]`` (a *contiguous*
    gather). Every unseen score is bounded by ``||u|| * norms_sorted[b*B]``;
    once the running K-th best exceeds that, no later block can contribute.
    Best when the catalogue norm spectrum decays (CF popularity, PLS factor
    scales); degenerates to a full scan for constant-norm catalogues
    (e.g. cosine-normalised items), where BTA should be used instead.

    ``max_blocks`` is the uniform halted variant (same contract as
    :func:`blocked_topk`). ``targets_by_norm``
    (:attr:`repro.core.index.TopKIndex.targets_by_norm`) turns the per-
    block row gather into a contiguous slice + matvec — same results,
    Pallas-layout memory traffic. ``m_real`` (traced) is the real
    catalogue size when the norm arrays are M-bucket padded
    (DESIGN.md §10).
    """
    strategy = norm_block_strategy(norm_order, norms_sorted, u, block_size,
                                   targets_by_norm=targets_by_norm,
                                   m_real=m_real)
    res = pruned_block_scan(targets, u, strategy, k, max_steps=max_blocks)
    if targets_by_norm is not None and targets.shape[0] >= block_size:
        # the slice path scans over norm-ordered ROW numbers (no id gather
        # inside the loop); map the k winners back to catalogue ids once
        m = targets.shape[0]
        ids = jnp.where(res.indices >= 0,
                        norm_order[jnp.clip(res.indices, 0, m - 1)], -1)
        res = res._replace(indices=ids)
    return res._replace(depth=res.depth * block_size)
