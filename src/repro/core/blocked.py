"""Block Threshold Algorithm (BTA) — the TPU-native adaptation of TA.

The paper's TA pops ONE item per list per round: pointer chasing, hash-set
dedup, heap update — shapes a TPU cannot execute efficiently (DESIGN.md §4).
BTA restructures the same exact algorithm around the MXU:

* one round pops a **depth block** of ``B`` entries from all R lists at once
  (``R*B`` candidate ids),
* the candidates are scored as a single gather + matvec/matmul,
* the running top-K is merged with one ``lax.top_k`` over ``K + R*B``,
* the stopping bound is evaluated at the block's LAST depth — still a valid
  upper bound for every unseen item because the lists are monotone (Eq. 3
  holds at any depth), so **exactness is preserved**; at most one extra
  block of items is scored compared to item-at-a-time TA.

Also here: ``norm_pruned_topk`` — a beyond-paper exact pruner that walks the
catalogue in decreasing ``||t(y)||`` order and bounds whole *contiguous*
blocks with Cauchy-Schwarz ``s(x,y) <= ||u|| * max_norm(block)`` (LEMP-style
screening, but block-synchronous for the MXU; gathers are contiguous, which
the Pallas kernel exploits).

Negative query weights are handled without materialising per-query flipped
lists: depth ``d`` in list ``r`` reads position ``M-1-d`` when ``u_r < 0``
(a gather-side index transform, not a data transform).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.index import TopKIndex
from repro.core.naive import TopKResult
from repro.core.threshold import _dedup_first_occurrence

Array = jnp.ndarray
NEG_INF = float("-inf")


class _BTAState(NamedTuple):
    b: Array            # current block
    top_vals: Array     # [K]
    top_ids: Array      # [K]
    visited: Array      # [M] bool
    n_scored: Array
    lower: Array
    upper: Array


@functools.partial(jax.jit, static_argnames=("k", "block_size", "max_blocks"))
def blocked_topk(
    targets: Array,
    order_desc: Array,
    t_sorted_desc: Array,
    u: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
) -> TopKResult:
    """Exact top-K via the Block Threshold Algorithm (single query).

    Args:
      targets: ``[M, R]`` catalogue factors.
      order_desc / t_sorted_desc: the query-independent index
        (:class:`repro.core.index.TopKIndex` fields).
      u: ``[R]`` query.
      k: top-K size (static).
      block_size: list depth consumed per round (static). ``block_size=1``
        degenerates to the paper's TA round structure.
      max_blocks: optional round budget — the halted variant.
    """
    M, R = targets.shape
    k = min(k, M)
    n_blocks = -(-M // block_size)
    block_cap = n_blocks if max_blocks < 0 else min(max_blocks, n_blocks)
    neg = u < 0  # [R] walk ascending when the weight is negative

    def cond(s: _BTAState):
        return jnp.logical_and(s.b < block_cap, s.lower < s.upper)

    active = u != 0  # sparse queries: zero-weight lists are never walked

    def body(s: _BTAState):
        d0 = s.b * block_size
        cols = jnp.minimum(d0 + jnp.arange(block_size, dtype=jnp.int32), M - 1)
        # per-list effective positions (sign flip = read from the far end)
        cols_eff = jnp.where(neg[:, None], M - 1 - cols[None, :], cols[None, :])
        ids = jnp.take_along_axis(order_desc, cols_eff, axis=1).reshape(-1)  # [R*B]
        active_rep = jnp.repeat(active, block_size,
                                total_repeat_length=R * block_size)
        # sentinel id M for inactive lists: never shadows active dedup
        ids_eff = jnp.where(active_rep, ids, M)
        fresh = jnp.logical_and(
            _dedup_first_occurrence(ids_eff, M + 1),
            jnp.logical_and(active_rep, ~s.visited[ids]))
        scores = targets[ids] @ u
        masked = jnp.where(fresh, scores, NEG_INF)
        cand_vals = jnp.concatenate([s.top_vals, masked])
        cand_ids = jnp.concatenate([s.top_ids, ids])
        top_vals, pos = jax.lax.top_k(cand_vals, k)
        top_ids = cand_ids[pos]
        # bound at the block's last processed depth
        end = jnp.minimum(d0 + block_size - 1, M - 1)
        end_eff = jnp.where(neg, M - 1 - end, end)
        t_end = t_sorted_desc[jnp.arange(R), end_eff]
        return _BTAState(
            b=s.b + 1,
            top_vals=top_vals,
            top_ids=top_ids,
            visited=s.visited.at[ids].max(active_rep),
            n_scored=s.n_scored + jnp.sum(fresh).astype(jnp.int32),
            lower=top_vals[k - 1],
            upper=jnp.sum(u * t_end),
        )

    init = _BTAState(
        b=jnp.int32(0),
        top_vals=jnp.full((k,), NEG_INF, dtype=targets.dtype),
        top_ids=jnp.full((k,), -1, dtype=jnp.int32),
        visited=jnp.zeros((M,), dtype=bool),
        n_scored=jnp.int32(0),
        lower=jnp.asarray(NEG_INF, dtype=targets.dtype),
        upper=jnp.asarray(jnp.inf, dtype=targets.dtype),
    )
    final = jax.lax.while_loop(cond, body, init)
    return TopKResult(final.top_vals, final.top_ids, final.n_scored,
                      final.b * block_size)


def blocked_topk_batched(
    targets: Array,
    index: TopKIndex,
    U: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
) -> TopKResult:
    """vmap of :func:`blocked_topk` over a query batch ``U: [B, R]``.

    Each query carries its own bound state; the vmapped while_loop runs
    until the slowest query terminates (lockstep on TPU), which is the
    batched-serving semantics discussed in DESIGN.md §4.
    """
    fn = functools.partial(
        blocked_topk, k=k, block_size=block_size, max_blocks=max_blocks
    )
    return jax.vmap(fn, in_axes=(None, None, None, 0))(
        targets, index.order_desc, index.t_sorted_desc, U
    )


# ---------------------------------------------------------------------------
# Norm-ordered Cauchy-Schwarz block pruning (beyond paper; exact)
# ---------------------------------------------------------------------------


class _NormState(NamedTuple):
    b: Array
    top_vals: Array
    top_ids: Array
    n_scored: Array
    lower: Array
    upper: Array


@functools.partial(jax.jit, static_argnames=("k", "block_size"))
def norm_pruned_topk(
    targets: Array,
    norm_order: Array,
    norms_sorted: Array,
    u: Array,
    k: int,
    block_size: int = 256,
) -> TopKResult:
    """Exact top-K scanning blocks in decreasing-norm order.

    Block ``b`` covers items ``norm_order[b*B:(b+1)*B]`` (a *contiguous*
    gather). Every unseen score is bounded by ``||u|| * norms_sorted[b*B]``;
    once the running K-th best exceeds that, no later block can contribute.
    Best when the catalogue norm spectrum decays (CF popularity, PLS factor
    scales); degenerates to a full scan for constant-norm catalogues
    (e.g. cosine-normalised items), where BTA should be used instead.
    """
    M = targets.shape[0]
    k = min(k, M)
    n_blocks = -(-M // block_size)
    u_norm = jnp.linalg.norm(u)

    # pad ids by clamping (duplicates only re-score already-kept items and
    # cannot enter the top-K twice because values tie and top_k is stable
    # on the concatenated layout: kept entries come first).
    def cond(s: _NormState):
        return jnp.logical_and(s.b < n_blocks, s.lower < s.upper)

    def body(s: _NormState):
        d0 = s.b * block_size
        rows = jnp.minimum(d0 + jnp.arange(block_size, dtype=jnp.int32), M - 1)
        valid = (d0 + jnp.arange(block_size, dtype=jnp.int32)) < M
        ids = norm_order[rows]
        scores = jnp.where(valid, targets[ids] @ u, NEG_INF)
        cand_vals = jnp.concatenate([s.top_vals, scores])
        cand_ids = jnp.concatenate([s.top_ids, ids])
        top_vals, pos = jax.lax.top_k(cand_vals, k)
        next_start = jnp.minimum((s.b + 1) * block_size, M - 1)
        return _NormState(
            b=s.b + 1,
            top_vals=top_vals,
            top_ids=cand_ids[pos],
            n_scored=s.n_scored + jnp.sum(valid).astype(jnp.int32),
            lower=top_vals[k - 1],
            upper=u_norm * norms_sorted[next_start],
        )

    init = _NormState(
        b=jnp.int32(0),
        top_vals=jnp.full((k,), NEG_INF, dtype=targets.dtype),
        top_ids=jnp.full((k,), -1, dtype=jnp.int32),
        n_scored=jnp.int32(0),
        lower=jnp.asarray(NEG_INF, dtype=targets.dtype),
        upper=jnp.asarray(jnp.inf, dtype=targets.dtype),
    )
    final = jax.lax.while_loop(cond, body, init)
    return TopKResult(final.top_vals, final.top_ids, final.n_scored,
                      final.b * block_size)
