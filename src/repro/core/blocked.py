"""Block Threshold Algorithm (BTA) — the TPU-native adaptation of TA.

The paper's TA pops ONE item per list per round: pointer chasing, hash-set
dedup, heap update — shapes a TPU cannot execute efficiently (DESIGN.md §4).
BTA restructures the same exact algorithm around the MXU:

* one round pops a **depth block** of ``B`` entries from all R lists at once
  (``R*B`` candidate ids),
* the candidates are scored as a single gather + matvec/matmul,
* the running top-K is merged with one ``lax.top_k`` over ``K + R*B``,
* the stopping bound is evaluated at the block's LAST depth — still a valid
  upper bound for every unseen item because the lists are monotone (Eq. 3
  holds at any depth), so **exactness is preserved**; at most one extra
  block of items is scored compared to item-at-a-time TA.

Also here: ``norm_pruned_topk`` — a beyond-paper exact pruner that walks the
catalogue in decreasing ``||t(y)||`` order and bounds whole *contiguous*
blocks with Cauchy-Schwarz ``s(x,y) <= ||u|| * max_norm(block)`` (LEMP-style
screening, but block-synchronous for the MXU; gathers are contiguous, which
the Pallas kernel exploits).

Both are thin wrappers: the loop itself is
:func:`repro.core.driver.pruned_block_scan` running
:func:`repro.core.strategies.blocked_lists_strategy` /
:func:`repro.core.strategies.norm_block_strategy`. ``block_size=1``
recovers paper-faithful TA rounds; ``max_blocks`` is the uniform halted
variant across every strategy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.driver import pruned_block_scan
from repro.core.index import TopKIndex
from repro.core.naive import TopKResult
from repro.core.strategies import blocked_lists_strategy, norm_block_strategy

Array = jnp.ndarray


@functools.partial(jax.jit, static_argnames=("k", "block_size", "max_blocks"))
def blocked_topk(
    targets: Array,
    order_desc: Array,
    t_sorted_desc: Array,
    u: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
) -> TopKResult:
    """Exact top-K via the Block Threshold Algorithm (single query).

    Args:
      targets: ``[M, R]`` catalogue factors.
      order_desc / t_sorted_desc: the query-independent index
        (:class:`repro.core.index.TopKIndex` fields).
      u: ``[R]`` query.
      k: top-K size (static).
      block_size: list depth consumed per round (static). ``block_size=1``
        degenerates to the paper's TA round structure.
      max_blocks: optional round budget — the halted variant.
    """
    strategy = blocked_lists_strategy(order_desc, t_sorted_desc, u,
                                      block_size)
    res = pruned_block_scan(targets, u, strategy, k, max_steps=max_blocks)
    # public depth unit is list depth, not blocks
    return res._replace(depth=res.depth * block_size)


def blocked_topk_batched(
    targets: Array,
    index: TopKIndex,
    U: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
) -> TopKResult:
    """vmap of :func:`blocked_topk` over a query batch ``U: [B, R]``.

    Each query carries its own bound state; the vmapped while_loop runs
    until the slowest query terminates (lockstep on TPU), which is the
    batched-serving semantics discussed in DESIGN.md §4. The driver's
    per-query liveness gating keeps ``n_scored``/``depth`` faithful to the
    sequential algorithm even for queries that certified early.
    """
    fn = functools.partial(
        blocked_topk, k=k, block_size=block_size, max_blocks=max_blocks
    )
    return jax.vmap(fn, in_axes=(None, None, None, 0))(
        targets, index.order_desc, index.t_sorted_desc, U
    )


# ---------------------------------------------------------------------------
# Norm-ordered Cauchy-Schwarz block pruning (beyond paper; exact)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "block_size", "max_blocks"))
def norm_pruned_topk(
    targets: Array,
    norm_order: Array,
    norms_sorted: Array,
    u: Array,
    k: int,
    block_size: int = 256,
    max_blocks: int = -1,
) -> TopKResult:
    """Exact top-K scanning blocks in decreasing-norm order.

    Block ``b`` covers items ``norm_order[b*B:(b+1)*B]`` (a *contiguous*
    gather). Every unseen score is bounded by ``||u|| * norms_sorted[b*B]``;
    once the running K-th best exceeds that, no later block can contribute.
    Best when the catalogue norm spectrum decays (CF popularity, PLS factor
    scales); degenerates to a full scan for constant-norm catalogues
    (e.g. cosine-normalised items), where BTA should be used instead.

    ``max_blocks`` is the uniform halted variant (same contract as
    :func:`blocked_topk`).
    """
    strategy = norm_block_strategy(norm_order, norms_sorted, u, block_size)
    res = pruned_block_scan(targets, u, strategy, k, max_steps=max_blocks)
    return res._replace(depth=res.depth * block_size)
