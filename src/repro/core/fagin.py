"""Fagin's algorithm (paper Algorithm 1). Faithful numpy implementation.

Included for completeness / didactic interest, exactly as the paper does:
the experiments section of the paper drops it because its candidate buffer
grows too fast in higher dimensions (and Theorem 3 shows it is not
instance-optimal). We implement it to (a) reproduce the toy example of
Table 1, (b) verify Theorem 4 (TA never scores more items) property-style.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np

from repro.core.threshold import _query_order_np


class FaginStats(NamedTuple):
    n_scored: int   # items scored in the sorted-access phase
    depth: int      # random-access depth at which K items were seen in all lists
    buffer_size: int  # peak |targetsToCheck| — the memory pathology


def fagin_topk_np(
    T: np.ndarray,
    order_desc: np.ndarray,
    u: np.ndarray,
    k: int,
) -> Tuple[np.ndarray, np.ndarray, FaginStats]:
    """Faithful Fagin. Returns (values[k], indices[k], stats)."""
    M, R = T.shape
    k = min(k, M)
    order = _query_order_np(order_desc, u)

    seen_count = np.zeros(M, dtype=np.int64)     # bookkeeping[y]
    targets_to_check: list[int] = []
    in_buffer = np.zeros(M, dtype=bool)
    n_in_all_lists = 0

    d = 0
    while n_in_all_lists < k and d < M:
        for r in range(R):
            y = order[r, d]
            if not in_buffer[y]:
                in_buffer[y] = True
                targets_to_check.append(y)
            seen_count[y] += 1
            if seen_count[y] == R:
                n_in_all_lists += 1
        d += 1

    ids = np.asarray(targets_to_check, dtype=np.int64)
    scores = T[ids] @ u
    top = np.argsort(-scores, kind="stable")[:k]
    stats = FaginStats(n_scored=len(ids), depth=d, buffer_size=len(ids))
    return scores[top], ids[top], stats
