"""Partial Threshold Algorithm (paper Algorithm 3 + Eq. 4).

Identical item set to TA; within one item's score the accumulation starts
from the round's upper bound and swaps in true contributions dimension by
dimension, aborting as soon as the partially-corrected score can no longer
beat the lower bound:

    s~ = upperBound(d);  for l = 1..R:  s~ += u_l t_l(y) - u_l t_l(y_{L_l(d)})
    abort when s~ < lowerBound

The oracle records the *fraction of a score* computed per item (the paper's
Fig. 2 metric). The TPU adaptation of this idea (R-chunked with residual
norm bounds) lives in :mod:`repro.core.blocked`; the paper itself concedes
scalar-granular early exit cannot beat dense matmul hardware — we quantify
that in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np

from repro.core.threshold import _query_order_np

NEG_INF = float("-inf")


class PartialTAStats(NamedTuple):
    n_items_touched: int       # == TA's n_scored (same item set, Thm 4 logic)
    n_full_scores: int         # items whose score was fully evaluated
    avg_score_fraction: float  # mean fraction of the R terms evaluated
    total_mults: int           # total multiply-adds spent on scoring
    depth: int


def partial_threshold_topk_np(
    T: np.ndarray,
    order_desc: np.ndarray,
    u: np.ndarray,
    k: int,
) -> Tuple[np.ndarray, np.ndarray, PartialTAStats]:
    M, R = T.shape
    k = min(k, M)
    order = _query_order_np(order_desc, u)
    active = np.nonzero(u)[0]   # sparse queries: same walk as TA

    calculated = np.zeros(M, dtype=bool)
    top_vals = np.full(k, NEG_INF)
    top_ids = np.full(k, -1, dtype=np.int64)
    n_items = 0
    n_full = 0
    total_terms = 0
    lower, upper = NEG_INF, np.inf

    d = 0
    while lower < upper and d < M:
        heads = order[:, d]                       # y_{L_r(d)} for each r
        head_terms = u * T[heads, np.arange(R)]   # u_r * t_r(y_{L_r(d)})
        upper = float(head_terms.sum())
        for r in active:
            y = order[r, d]
            if calculated[y]:
                continue
            calculated[y] = True
            n_items += 1
            # Algorithm 3: start from the upper bound, swap in true terms.
            s_tilde = upper
            completed = True
            terms = 0
            for l in range(R):
                s_tilde += u[l] * T[y, l] - head_terms[l]
                terms += 1
                if s_tilde < lower:
                    completed = False
                    break
            total_terms += terms
            if completed:
                n_full += 1
                score = s_tilde  # == full score after all R corrections
                if score > top_vals[-1]:
                    pos = np.searchsorted(-top_vals, -score)
                    top_vals = np.insert(top_vals, pos, score)[:k]
                    top_ids = np.insert(top_ids, pos, y)[:k]
        lower = top_vals[-1]
        d += 1

    stats = PartialTAStats(
        n_items_touched=n_items,
        n_full_scores=n_full,
        avg_score_fraction=(total_terms / (n_items * R)) if n_items else 0.0,
        total_mults=total_terms,
        depth=d,
    )
    return top_vals, top_ids, stats
