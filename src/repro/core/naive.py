"""Naive exact top-K: score every target, keep the best K.

The paper's baseline (``O((R + log K) M)``). On TPU this is a single
MXU matmul followed by ``lax.top_k`` — the strongest possible wall-clock
baseline, which is why EXPERIMENTS.md reports both score counts (the paper's
metric) and roofline terms (the hardware metric).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class TopKResult(NamedTuple):
    values: Array   # [K] (or [B, K]) scores, descending
    indices: Array  # [K] (or [B, K]) item ids
    n_scored: Array  # scalar (or [B]) int32 — number of s(x,y) evaluations
    depth: Array     # scalar (or [B]) int32 — list depth reached (0 for naive)


@functools.partial(jax.jit, static_argnames=("k",))
def naive_topk(targets: Array, u: Array, k: int) -> TopKResult:
    """Exact top-K by full scoring. ``targets: [M, R]``, ``u: [R] or [B, R]``."""
    scores = jnp.einsum("...r,mr->...m", u, targets)
    values, indices = jax.lax.top_k(scores, k)
    m = targets.shape[0]
    batch_shape = scores.shape[:-1]
    n_scored = jnp.full(batch_shape, m, dtype=jnp.int32)
    depth = jnp.zeros(batch_shape, dtype=jnp.int32)
    return TopKResult(values, indices, n_scored, depth)
