"""Naive exact top-K: score every target, keep the best K.

The paper's baseline (``O((R + log K) M)``). On TPU this is a single
MXU matmul followed by ``lax.top_k`` — the strongest possible wall-clock
baseline, which is why EXPERIMENTS.md reports both score counts (the paper's
metric) and roofline terms (the hardware metric).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class TopKResult(NamedTuple):
    values: Array   # [K] (or [B, K]) scores, descending
    indices: Array  # [K] (or [B, K]) item ids
    n_scored: Array  # scalar (or [B]) int32 — number of s(x,y) evaluations
    depth: Array     # scalar (or [B]) int32 — list depth reached (0 for naive)
    # Scalar (or [B]) upper bound on the score of every item the scan did
    # NOT enumerate when it stopped (-inf when the scan provably saw every
    # candidate).  None for legacy paths that don't track a bound.
    upper: Optional[Array] = None


def certificate_gaps(res: TopKResult) -> Array:
    """Per-slot certificate gap ``upper - value`` for a (possibly halted) scan.

    ``gap <= 0`` certifies the slot: its score is at least the bound on every
    unenumerated item, and since the scan's running top-K already dominates all
    enumerated items, the slot provably belongs to the true top-K.  Values are
    sorted descending, so gaps are ascending and the certified slots always
    form a prefix.  Pad slots (``indices < 0``) get ``+inf`` (never certified;
    also avoids ``-inf - -inf = nan`` when the bound itself is ``-inf``).
    """
    if res.upper is None:
        raise ValueError(
            "result carries no upper bound; run a budget-capable engine "
            "(naive/ta/bta/norm) to obtain certificates")
    gap = jnp.asarray(res.upper)[..., None] - res.values
    return jnp.where(res.indices >= 0, gap, jnp.inf)


def certified_counts(res: TopKResult) -> Array:
    """Number of certified-exact prefix slots per query ([B] or scalar int32)."""
    gaps = certificate_gaps(res)
    return jnp.sum(gaps <= 0, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def naive_topk(targets: Array, u: Array, k: int) -> TopKResult:
    """Exact top-K by full scoring. ``targets: [M, R]``, ``u: [R] or [B, R]``."""
    scores = jnp.einsum("...r,mr->...m", u, targets)
    values, indices = jax.lax.top_k(scores, k)
    m = targets.shape[0]
    batch_shape = scores.shape[:-1]
    n_scored = jnp.full(batch_shape, m, dtype=jnp.int32)
    depth = jnp.zeros(batch_shape, dtype=jnp.int32)
    upper = jnp.full(batch_shape, -jnp.inf, dtype=values.dtype)
    return TopKResult(values, indices, n_scored, depth, upper=upper)
