"""SEP-LR model container and adapters.

A separable linear relational model (paper Eq. 1) scores a (query, target)
couple as

    s(x, y) = u(x)^T t(y) = sum_r u_r(x) t_r(y)

The target side is a finite catalogue of M items held as a dense factor
matrix ``T`` of shape ``[M, R]``; the query side is an R-vector (or a batch
``[B, R]``).  Every model family in the paper's Section 3 reduces to this
container:

* memory-based CF (cosine):        u = x / ||x||,  T = Y / ||Y||_rows
* model-based CF (matrix factor.): u = U[i],       T = item factors
* multi-label / multivariate reg.: u = psi(x),     T = W (per-label weights)
* pairwise / Kronecker models:     u = W^T psi(x), T = phi(Y)   (folded)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SepLRModel:
    """A trained SEP-LR model over a finite catalogue.

    Attributes:
      targets: ``[M, R]`` dense target factors t(y) (one row per item).
      name: human-readable tag used in benchmark output.
    """

    targets: Array
    name: str = "seplr"

    @property
    def num_targets(self) -> int:
        return int(self.targets.shape[0])

    @property
    def rank(self) -> int:
        return int(self.targets.shape[1])

    def score_all(self, u: Array) -> Array:
        """Naive scoring of every target: ``[R] -> [M]`` or ``[B,R] -> [B,M]``."""
        return jnp.einsum("...r,mr->...m", u, self.targets)

    def score(self, u: Array, ids: Array) -> Array:
        """Score a subset of targets. ``u: [R]``, ``ids: [n]`` -> ``[n]``."""
        return self.targets[ids] @ u


# ---------------------------------------------------------------------------
# Adapters (paper Section 3)
# ---------------------------------------------------------------------------


def from_cosine_similarity(item_matrix: Array, name: str = "memory_cf") -> SepLRModel:
    """Memory-based CF: rows are items, cosine similarity as the score.

    Normalising each row to unit L2 norm makes the dot product equal to the
    cosine similarity (paper Eq. 5/6). Queries must be normalised with
    :func:`normalize_query`.
    """
    norms = jnp.linalg.norm(item_matrix, axis=1, keepdims=True)
    norms = jnp.where(norms == 0, 1.0, norms)
    return SepLRModel(targets=item_matrix / norms, name=name)


def normalize_query(x: Array) -> Array:
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.where(n == 0, 1.0, n)


def from_matrix_factorization(item_factors: Array, name: str = "mf") -> SepLRModel:
    """Model-based CF: ``C ~= U T``; queries are rows of U."""
    return SepLRModel(targets=item_factors, name=name)


def from_linear_multilabel(label_weights: Array, name: str = "multilabel") -> SepLRModel:
    """Binary-relevance style linear models: ``s(x, y) = w_y^T psi(x)``.

    ``label_weights``: ``[M_labels, R_features]`` — one weight vector per label.
    """
    return SepLRModel(targets=label_weights, name=name)


def from_pairwise_kronecker(W: Array, phi_targets: Array, name: str = "kronecker") -> SepLRModel:
    """Pairwise model ``s(x,y) = psi(x)^T W phi(y)``.

    Folds ``W`` into the query side: ``u(x) = W^T psi(x)``, ``t(y) = phi(y)``.
    Returns the target-side container; use :func:`kronecker_query` for u(x).
    """
    del W  # folded at query time
    return SepLRModel(targets=phi_targets, name=name)


def kronecker_query(W: Array, psi_x: Array) -> Array:
    return psi_x @ W


# ---------------------------------------------------------------------------
# Synthetic model generators used by tests and benchmarks
# ---------------------------------------------------------------------------


def random_model(
    rng: np.random.Generator,
    num_targets: int,
    rank: int,
    distribution: str = "normal",
    sparsity: float = 0.0,
    name: Optional[str] = None,
) -> SepLRModel:
    """Random SEP-LR model with controllable factor distribution.

    ``distribution``:
      * ``normal`` — iid N(0, 1): the hardest case for TA (independent lists).
      * ``lognormal`` — heavy-tailed positive factors (implicit-feedback CF).
      * ``lowrank_spectrum`` — factors scaled by a decaying spectrum, mimicking
        PCA / PLS factors where early dimensions dominate (TA's best case).
    """
    T = rng.standard_normal((num_targets, rank)).astype(np.float32)
    if distribution == "lognormal":
        T = np.abs(rng.lognormal(0.0, 1.0, (num_targets, rank))).astype(np.float32)
    elif distribution == "lowrank_spectrum":
        spectrum = (1.0 / np.sqrt(1.0 + np.arange(rank))).astype(np.float32)
        T = T * spectrum[None, :]
    if sparsity > 0.0:
        mask = rng.random((num_targets, rank)) >= sparsity
        T = T * mask
    return SepLRModel(
        targets=jnp.asarray(T),
        name=name or f"random_{distribution}_M{num_targets}_R{rank}",
    )
