"""Streaming catalogue: segmented (base + delta) exact top-K (DESIGN.md §9).

The paper's TA/BTA/norm pruning assumes a *static* catalogue: the sorted
lists, the norm order, and every layout in :mod:`repro.core.layout` are
built offline. A production retrieval tier must absorb item inserts,
updates, and deletions without a full index rebuild per mutation and
WITHOUT giving up the paper's exactness guarantee. This module is the
LSM-style answer:

* **Base segment** — an immutable snapshot of the catalogue: a normal
  :class:`repro.core.engines.EngineContext` (index, layouts, compile
  cache) plus the row -> global-id map. Queries run ANY registry engine
  over it, so every pruned scan in the repo is streaming-capable without
  touching the engines themselves.
* **Delta segment** — a fixed-capacity append buffer of inserted target
  rows. It is never indexed: every query scores the live delta slots
  densely with ONE ``[B, R] @ [R, D]`` matmul (exact trivially). The
  device view of the buffer is padded to a power-of-four occupancy
  bucket, so an insert changes array *contents*, never compiled
  *shapes* — zero retraces per insert once the buckets are warm
  (:meth:`warm`).
* **Tombstones** — deletes (and the delete half of updates) mark the
  victim row dead wherever it lives: a ``[M_base]`` mask over the base
  snapshot, a per-slot mask over the delta. The base fetch is
  TOMBSTONE-ADAPTIVE: plain ``k`` while the snapshot has no dead rows
  (the common warmed compile key — inserts never retrace), and the
  OVER-FETCHED ``k + reserve`` rung (also pre-warmed) the moment
  tombstones exist, so a dead row in the top-``k`` costs nothing. The
  merge tail counts the tombstoned rows that landed in the fetched
  slice; only when some query's dropped count exceeds its over-fetch
  margin (``dropped > k_base - k`` — more than ``reserve`` dead rows
  inside ONE query's top slice) does the fetch climb an escalation
  ladder (x4 per rung). A rung is exact as soon as the margin holds:
  at least ``k`` live base candidates survived the drop and every live
  row outside the fetched slice scores below all of them — one line
  per rung, and a full-base fetch is unconditionally exact
  (DESIGN.md §9).
* **Merge** — the dropped-and-resorted base list and each delta
  segment's dense scores fold through the SAME two-stage merge helpers
  every engine already uses (:func:`repro.core.driver.merge_topk_sorted`
  via :func:`repro.core.driver.merge_block_into_carry_batched`), so the
  result is exact by construction at any mutation rate.
* **Compaction** — when the delta fills (or tombstones cross a
  fraction of the base) the live rows of base + delta are folded into a
  FRESH snapshot (new index, new layouts, readied before the swap)
  under a monotonically increasing ``version``. The build can run on a
  background thread (``compact_async=True``): queries keep serving the
  old snapshot + a frozen delta + a fresh active delta until the swap,
  and deletes that land during the build are re-applied to the new
  snapshot at swap time (``pending dead``), so no mutation is ever
  lost. Compaction is COMPILE-FREE under the argument-passing engine
  contract (DESIGN.md §10): engines take the snapshot state — layout
  pytrees, index arrays, the catalogue itself, padded to a power-of-two
  M-bucket — as runtime ARGUMENTS of module-level executors whose
  compile keys carry no snapshot identity, so the new snapshot
  re-dispatches every existing trace (``stats.engine_compiles_total``
  records the traces a build into a never-warmed bucket pays, off the
  query path). In-flight calls hold references to the old snapshot's
  pytrees, which stay valid until released; the one closure-compiled
  engine left (``pallas``) still keys its per-context cache by
  ``EngineContext.version``, so even there an executable traced against
  snapshot v can never be fed snapshot v+1's arrays.

Per-query accounting extends the paper's cost metric to the delta:
``n_scored`` adds the number of LIVE delta slots scored (the dense
matmul's useful work; dead and padding lanes are masked, not candidates)
and ``depth`` stays the base engine's depth.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import faults
from repro.core.driver import NEG_INF, merge_block_into_carry_batched
from repro.core.engines import (Engine, EngineContext, batch_bucket,
                                pad_to_bucket)
from repro.core.naive import TopKResult
from repro.core.sharded import shard_fold_topk

Array = jnp.ndarray

# Named fault points (DESIGN.md §12): no-ops until a test arms them via
# repro.core.faults. The seams cover exactly the failure modes the
# recovery logic below exists for.
FAULT_BUILD = faults.register_point(
    "compaction.build",
    "raise inside the compaction builder before the snapshot swap")
FAULT_STALL = faults.register_point(
    "compaction.stall",
    "sleep inside the compaction builder (slow/stuck build)")
FAULT_WARM = faults.register_point(
    "compaction.warm",
    "raise during the post-build readiness warmup")
FAULT_DELTA_OVERFLOW = faults.register_point(
    "delta.overflow",
    "report the active delta as full on an append (mutation burst)")

#: Default delta-buffer capacity (rows). Power of two; a full delta
#: triggers compaction. 256 keeps warmup to 9 tail buckets while giving
#: the hot path hundreds of mutations between rebuilds.
DEFAULT_DELTA_CAPACITY = 256

#: Compact when dead base rows exceed this fraction of the base — more
#: tombstones mean more escalated (over-fetched) reruns, and past this
#: point re-packing is cheaper than dragging dead rows through every scan.
DEFAULT_TOMBSTONE_COMPACT_FRACTION = 0.25

#: Absolute tombstone count that triggers compaction regardless of the
#: base size. Bounds the escalated over-fetch (and therefore the number
#: of distinct escalated compile shapes) on delete-heavy streams against
#: large catalogues, where the fraction threshold alone would let the
#: over-fetch grow into the thousands. ``None`` couples it to
#: ``2 * delta_capacity`` — tombstone pressure compacts on the same scale
#: as append pressure.
DEFAULT_MAX_TOMBSTONES = None

#: First rung of the escalation ladder: a tombstone hit in the base
#: top-``k`` reruns at ``k + reserve`` (pre-warmed — the common retry is
#: retrace-free), then climbs x4 per rung only while some query's dropped
#: count exceeds the over-fetch margin (the per-rung exactness check).
DEFAULT_OVERFETCH_RESERVE = 32

#: Ladder growth factor between escalation rungs.
ESCALATION_STEP = 4


def delta_bucket(n: int) -> int:
    """Power-of-FOUR device-view bucket for ``n`` delta rows (min 1).

    Coarser than the batch buckets on purpose: each bucket is one tail
    compile at warmup time and the wasted lanes cost only a slice of the
    tiny ``[B, D]`` delta matmul, so x4 steps halve the number of compiled
    shapes for the same capacity.
    """
    b = 1
    while b < n:
        b <<= 2
    return b


@dataclasses.dataclass(frozen=True)
class QueryInfo:
    """Side-channel accounting for one segmented query batch.

    Attributes:
      delta_scored: live delta slots dense-scored per query (added into
        the returned ``TopKResult.n_scored``).
      overfetch_k: the ``k`` the AUTHORITATIVE base engine run used —
        plain ``k`` with no tombstones, ``k + reserve`` while any base
        row is dead, higher (x4 per climb) only when a query had more
        than ``reserve`` dead rows inside its fetched slice.
      n_segments: delta segments scored (0 pristine, 1 steady state,
        2 while a background compaction has a frozen delta in flight).
      version: snapshot version the batch was served from.
      retried: True when the first fetch was discarded and the batch
        re-ran up the escalation ladder (dropped count exceeded the
        over-fetch margin).
    """

    delta_scored: int
    overfetch_k: int
    n_segments: int
    version: int
    retried: bool = False


@dataclasses.dataclass
class SegmentStats:
    """Cumulative mutation/compaction counters (monotonic).

    ``engine_compiles_total`` counts the ENGINE traces a compaction
    build needed to make its new snapshot serveable at the warmed
    shapes (attributed from the new context's own ``trace_counts`` —
    traces a concurrent serving thread causes are never charged here).
    Under the argument-passing contract (DESIGN.md §10) a compaction
    into a warmed M-bucket contributes 0 — the acceptance criterion the
    streaming bench asserts; a build into a bucket nobody warmed pays
    its compiles here, on the build (background in ``compact_async``
    mode), never on the query hot path. ``headroom_compiles_total``
    separately counts the traces each build invests in the NEXT
    M-bucket (renewing the server's boot headroom so the guarantee is
    standing) — future capacity, not a cost of serving this snapshot.
    ``compaction_s_total``/``last_compaction_s`` time the whole build
    (live-row fold + index + layouts + readiness + swap).
    """

    n_inserts: int = 0
    n_deletes: int = 0
    n_updates: int = 0
    n_compactions: int = 0
    n_failed_compactions: int = 0
    max_delta_occupancy: int = 0
    engine_compiles_total: int = 0
    headroom_compiles_total: int = 0
    compaction_s_total: float = 0.0
    last_compaction_s: float = 0.0
    # recovery counters (DESIGN.md §12): build attempts launched while
    # recovering from a failure, sync compactions forced by the L0 chain
    # cap, watchdog detections of a stuck build thread, and the longest
    # sealed-segment chain ever observed
    n_build_retries: int = 0
    n_forced_sync_compactions: int = 0
    n_stuck_builds: int = 0
    max_l0_chain: int = 0
    # LSM ladder counters (DESIGN.md §15): zero on the single-level
    # catalogue. Folds are the cheap L0 -> per-shard-L1 moves that
    # REPLACE most full base rebuilds; their failures have their own
    # retry/backoff stream (mirroring the build machinery) so the
    # mutation_stats schema covers both recovery paths.
    n_l1_folds: int = 0
    n_failed_l1_folds: int = 0
    n_l1_fold_retries: int = 0
    l1_fold_s_total: float = 0.0


class Snapshot:
    """One immutable base segment: an EngineContext + the row/gid maps.

    The target ROWS never change after construction (engines, layouts,
    and the jit cache all hold them); only the tombstone mask mutates,
    and it mutates FUNCTIONALLY on the device side (``.at[].set`` builds
    a new array), so an in-flight jitted call that captured the previous
    mask keeps a valid pytree.
    """

    def __init__(self, targets_np: np.ndarray, gids_np: np.ndarray,
                 version: int, ctx: EngineContext):
        self.targets_np = targets_np          # [Mb, R] float32 (host copy)
        self.gids_np = gids_np.astype(np.int64)
        self.version = int(version)
        self.ctx = ctx
        mb = targets_np.shape[0]
        self.gids_dev = jnp.asarray(gids_np.astype(np.int32))
        self.dead_np = np.zeros((mb,), bool)
        self.dead_dev = jnp.zeros((mb,), bool)
        self.n_dead = 0
        self.gid_to_row = {int(g): i for i, g in enumerate(self.gids_np)}
        # identity snapshots (gid i lives at row i) can serve the
        # never-mutated fast path with raw engine indices
        self.identity = bool(
            mb == 0 or np.array_equal(self.gids_np, np.arange(mb)))

    @property
    def num_rows(self) -> int:
        return int(self.targets_np.shape[0])

    def kill_rows(self, rows: Sequence[int]) -> None:
        rows = np.asarray(list(rows), np.int32)
        fresh = ~self.dead_np[rows]
        self.dead_np[rows] = True
        self.dead_dev = self.dead_dev.at[rows].set(True)
        self.n_dead += int(np.sum(fresh))


class DeltaSegment:
    """Fixed-capacity append buffer of (row, gid) pairs with a dead mask.

    The device view is padded to the power-of-four bucket covering the
    current occupancy (:func:`delta_bucket`), so appends within a bucket
    re-upload contents but never change compiled shapes. ``seal()``
    freezes the segment for a background compaction — further appends
    are a bug (asserted).
    """

    def __init__(self, capacity: int, rank: int):
        cap = batch_bucket(capacity)          # power-of-two storage
        self.capacity = cap
        self.rows = np.zeros((cap, rank), np.float32)
        self.gids = np.full((cap,), -1, np.int64)
        self.dead = np.zeros((cap,), bool)
        self.count = 0
        self.sealed = False
        self._pos: Dict[int, int] = {}        # live gid -> slot
        self._dev: Optional[Tuple[Array, Array, Array]] = None

    @property
    def n_live(self) -> int:
        return self.count - int(np.sum(self.dead[:self.count]))

    @property
    def full(self) -> bool:
        return self.count >= self.capacity

    def append(self, row: np.ndarray, gid: int) -> int:
        assert not self.sealed, "appending to a sealed (compacting) delta"
        assert self.count < self.capacity
        slot = self.count
        self.rows[slot] = row
        self.gids[slot] = gid
        self._pos[gid] = slot
        self.count += 1
        self._dev = None
        return slot

    def kill(self, gid: int) -> None:
        slot = self._pos.pop(gid)
        self.dead[slot] = True
        self._dev = None

    def seal(self) -> None:
        self.sealed = True
        self._dev = None          # rebuild the view at the capacity bucket

    def live_rows(self) -> Tuple[np.ndarray, np.ndarray]:
        live = ~self.dead[:self.count]
        return (self.rows[:self.count][live].copy(),
                self.gids[:self.count][live].copy())

    def device_view(self) -> Tuple[Array, Array, Array]:
        """``(rows [D, R], gids [D], live [D])`` padded to the pow4 bucket.

        A SEALED segment always presents the full-capacity bucket: the
        two-segment tail shapes warmed ahead of time are
        ``(capacity, active_bucket)``, so mid-build queries stay on
        compiled executables even when a tombstone-threshold compaction
        froze a partially full delta (the extra lanes cost one slice of
        the tiny delta matmul, not a compile).
        """
        if self._dev is None:
            d = (self.capacity if self.sealed
                 else min(delta_bucket(max(self.count, 1)), self.capacity))
            live = np.zeros((d,), bool)
            live[:self.count] = ~self.dead[:self.count]
            self._dev = (jnp.asarray(self.rows[:d]),
                         jnp.asarray(self.gids[:d].astype(np.int32)),
                         jnp.asarray(live))
        return self._dev


def _segmented_tail(base_vals, tomb, base_gids, U, segs, l1=None, *, k, kb):
    """Drop tombstones from the base top-``kb``, fold in the delta segments.

    Pure function of device arrays (jitted per shape by the catalogue's
    tail cache). ``base_vals [B, kb]`` is the base engine's exact
    top-``kb`` (descending), ``tomb [B, kb]`` flags the tombstoned
    entries, ``base_gids [B, kb]`` carries the global ids (``-1`` for
    engine padding). The caller resolves both from the snapshot's
    ``[M_base]`` mask/gid arrays EAGERLY — two primitive gathers — so
    nothing in this program depends on the base size and every compiled
    tail is reused across snapshot versions (a compaction adds ZERO tail
    compiles). Masking dead rows to ``-inf`` breaks the sort, so the
    survivors are re-topped to ``k`` lanes (``kb`` is at most
    ``k + bucket(n_dead)`` — a few dozen lanes, nowhere near the
    ``K + C`` concat pattern the driver bans). Each delta segment then
    merges through the shared two-stage helper: block-local
    ``top_k(D -> K)`` + the O(K) sorted merge.

    Returns ``(values, gids, n_dropped)`` — ``n_dropped [B]`` counts the
    TOMBSTONED base rows that sat inside this top-``kb`` (engine ``-1``
    padding is not a drop). The optimistic query path (``kb == k``)
    reads it to decide whether the over-fetched escalation is needed at
    all: 0 dropped means nothing was lost and the result is exact as is.

    ``l1`` is the LSM catalogue's per-shard L1 tier (DESIGN.md §15):
    ``None`` for the single-level catalogue, else a shard-major stack
    ``(rows [S, C, R], gids [S, C], live [S, C])`` padded to the FIXED
    per-shard slab capacity, so the whole tier is one compile shape
    regardless of occupancy. It folds in through the two-level
    :func:`repro.core.sharded.shard_fold_topk` merge — each shard's
    dense block is cut to K locally, then K candidates per shard cross
    the O(K) sorted merge — before the (newer) L0/delta segments, so the
    scan-loop merge order mirrors the ladder's age order.
    """
    drop = jnp.logical_or(base_gids < 0, tomb)
    n_dropped = jnp.sum(tomb, axis=1, dtype=jnp.int32)
    v = jnp.where(drop, NEG_INF, base_vals)
    gi = jnp.where(drop, -1, base_gids)
    v, pos = jax.lax.top_k(v, min(k, kb))
    gi = jnp.take_along_axis(gi, pos, axis=1)
    if kb < k:                                # base smaller than k: pad
        b = v.shape[0]
        v = jnp.concatenate(
            [v, jnp.full((b, k - kb), NEG_INF, v.dtype)], axis=1)
        gi = jnp.concatenate(
            [gi, jnp.full((b, k - kb), -1, gi.dtype)], axis=1)
    if l1 is not None:
        l1_rows, l1_gids, l1_live = l1
        # one [B, R] x [S, C, R] einsum scores every shard's slab densely
        l1_scores = jnp.einsum("br,scr->sbc", U, l1_rows)
        l1_scores = jnp.where(l1_live[:, None, :], l1_scores, NEG_INF)
        v, gi = shard_fold_topk(v, gi, l1_scores, l1_gids, k)
    for rows, gid, live in segs:
        scores = U @ rows.T                   # [B, D] — one dense matmul
        scores = jnp.where(live[None, :], scores, NEG_INF)
        v, gi = merge_block_into_carry_batched(v, gi, scores, gid, k)
    return v, gi, n_dropped


class SegmentedCatalogue:
    """Base snapshot + delta buffer + tombstones: exact streaming top-K.

    Thread-safe for one writer + concurrent readers (a single lock
    guards the mutable maps; queries copy references out under it and
    compute outside it). All mutation entry points may trigger
    compaction; queries never do.

    Args:
      targets: initial ``[M, R]`` catalogue (global ids ``0..M-1``).
      delta_capacity: delta-buffer rows (rounded up to a power of two).
      tombstone_compact_fraction: compact once dead base rows exceed
        this fraction of the base.
      max_tombstones: absolute dead-row count that triggers compaction
        (bounds the escalated over-fetch on delete-heavy streams).
        ``None`` (default) uses ``2 * delta_capacity``.
      overfetch_reserve: first escalation rung — a tombstone hit in the
        base top-``k`` reruns at ``k + reserve`` (pre-warmed), climbing
        x4 per rung only while the per-query dropped count exceeds the
        over-fetch margin.
      compact_async: build replacement snapshots on a background thread
        (queries keep serving base + frozen delta + active delta until
        the swap). Synchronous by default — deterministic for tests.
      max_l0_segments: cap on the sealed-segment (L0) chain. Mutations
        that would grow the chain past it force a SYNCHRONOUS compaction
        (blocking that mutation call) instead of letting query latency
        degrade unboundedly under sustained build failure
        (DESIGN.md §12).
      build_retry_limit: consecutive failed builds after which automatic
        retries stop (an explicit :meth:`compact` or the chain cap still
        force attempts).
      build_backoff_s: initial retry backoff after a failed build,
        doubling per consecutive failure up to ``build_backoff_max_s``.
      build_watchdog_s: a background build older than this is flagged as
        STUCK (``SegmentStats.n_stuck_builds``) by the watchdog check
        that runs on query/mutation entry. Detection only — the build
        thread is never killed (it may still finish and swap in).
      ctx_kwargs: forwarded to every :class:`EngineContext` this
        catalogue builds (``block_size``, ``prefix_depth``, ...).
    """

    def __init__(self, targets, *, delta_capacity: int = DEFAULT_DELTA_CAPACITY,
                 tombstone_compact_fraction: float =
                 DEFAULT_TOMBSTONE_COMPACT_FRACTION,
                 max_tombstones: Optional[int] = DEFAULT_MAX_TOMBSTONES,
                 overfetch_reserve: int = DEFAULT_OVERFETCH_RESERVE,
                 compact_async: bool = False,
                 max_l0_segments: int = 4,
                 build_retry_limit: int = 3,
                 build_backoff_s: float = 0.05,
                 build_backoff_max_s: float = 2.0,
                 build_watchdog_s: float = 30.0,
                 auto_retry: bool = False, **ctx_kwargs):
        T = np.ascontiguousarray(np.asarray(targets, np.float32))
        self.rank = int(T.shape[1])
        self.delta_capacity = batch_bucket(max(int(delta_capacity), 1))
        self.tombstone_compact_fraction = float(tombstone_compact_fraction)
        if max_tombstones is None:
            max_tombstones = 2 * self.delta_capacity
        self.max_tombstones = int(max_tombstones)
        self.overfetch_reserve = batch_bucket(max(int(overfetch_reserve), 1))
        self.compact_async = bool(compact_async)
        self.max_l0_segments = max(int(max_l0_segments), 1)
        self.build_retry_limit = max(int(build_retry_limit), 0)
        self.build_backoff_s = float(build_backoff_s)
        self.build_backoff_max_s = float(build_backoff_max_s)
        self.build_watchdog_s = float(build_watchdog_s)
        # auto_retry=True makes a FAILED async build schedule its own
        # timed retry (backoff-spaced, bounded by build_retry_limit), so
        # a quiet catalogue heals without waiting for the next mutation.
        # Off by default: retries then ride the next compaction trigger,
        # preserving the legacy "flush() after a failure is passive"
        # semantics tests rely on.
        self.auto_retry = bool(auto_retry)
        self._ctx_kwargs = dict(ctx_kwargs)
        self._lock = threading.RLock()
        self._snapshot = Snapshot(
            T, np.arange(T.shape[0], dtype=np.int64), 0,
            EngineContext(T, version=0, **self._ctx_kwargs))
        self._delta = DeltaSegment(self.delta_capacity, self.rank)
        # sealed segments awaiting compaction (an L0 chain: normally one,
        # more only if a background build failed — nothing is ever lost,
        # sealed segments stay queryable and fold on the next compaction)
        self._frozen: List[DeltaSegment] = []
        self._next_gid = int(T.shape[0])
        self._pending_dead: set = set()       # deletes landed mid-build
        self._build_thread: Optional[threading.Thread] = None
        self._tail_cache: Dict[tuple, Callable] = {}
        self.trace_counts: Dict[str, int] = {}
        self.stats = SegmentStats()
        self.last_build_error: Optional[BaseException] = None
        # build-failure recovery state machine (DESIGN.md §12)
        self._consec_build_failures = 0
        self._retry_not_before = 0.0          # monotonic deadline (backoff)
        self._last_backoff_s = 0.0
        self._retry_timer: Optional[threading.Timer] = None
        self._build_started_at: Optional[float] = None
        self._watchdog_flagged = False
        self._warm_spec: Optional[tuple] = None
        # highest M-bucket any warmup has traced (DESIGN.md §10): the
        # headroom-renewal memo, so the pre-pay happens once per doubling
        self._headroom_bucket = 0
        # mutation epoch: bumped under the lock by EVERY visible mutation
        # (append/tombstone/update AND the compaction swap, which applies
        # pending deletes). (version, epoch) is the result-cache token —
        # version alone is NOT enough, deltas mutate visibility without
        # bumping it (DESIGN.md §13).
        self._epoch = 0
        self._invalidation_listeners: List[Callable[[], None]] = []

    # -- introspection -------------------------------------------------------

    @property
    def snapshot(self) -> Snapshot:
        return self._snapshot

    @property
    def version(self) -> int:
        return self._snapshot.version

    def _segments(self) -> List[DeltaSegment]:
        """Sealed segments (oldest first) + the active delta. Lock held."""
        return [*self._frozen, self._delta]

    def cache_token(self) -> Tuple[int, int]:
        """``(snapshot version, mutation epoch)`` — the identity of the
        CURRENTLY VISIBLE catalogue contents. Any visible mutation
        changes the token, so a result cached under a token captured
        BEFORE its scan dispatched can never serve contents older than
        that token. Compare tokens only for equality: a swap bumps
        version while epoch keeps counting."""
        with self._lock:
            return (self._snapshot.version, self._epoch)

    def add_invalidation_listener(self, fn: Callable[[], None]) -> None:
        """Register ``fn`` to run after every visible mutation (append /
        tombstone / update / compaction swap — including a swap that
        applied mid-build deletes). Listeners may fire while a mutating
        caller still holds the catalogue lock (the synchronous
        compaction path), so they MUST NOT call back into the catalogue;
        bumping a flag or clearing a cache's own structures is the
        intended use."""
        with self._lock:
            self._invalidation_listeners.append(fn)

    def _bump_epoch_locked(self, kind: str) -> None:
        self._epoch += 1
        # journal the new (version, epoch) identity under the catalogue
        # lock — obs emission takes only its own lock, never calls back
        # (the same constraint invalidation listeners live under)
        obs.on_epoch_bump(kind, self._snapshot.version, self._epoch)

    def _notify_invalidation(self) -> None:
        with self._lock:
            listeners = list(self._invalidation_listeners)
        for fn in listeners:
            fn()

    @property
    def delta_occupancy(self) -> int:
        with self._lock:
            return sum(seg.count for seg in self._segments())

    @property
    def n_tombstones(self) -> int:
        with self._lock:
            return self._snapshot.n_dead + sum(
                int(np.sum(seg.dead[:seg.count]))
                for seg in self._segments())

    @property
    def num_live(self) -> int:
        with self._lock:
            return (self._snapshot.num_rows - self._snapshot.n_dead
                    + sum(seg.n_live for seg in self._segments()))

    @property
    def pristine(self) -> bool:
        """No mutation is visible: raw engine results need no rewriting."""
        with self._lock:
            return (self._snapshot.identity and self._snapshot.n_dead == 0
                    and not self._frozen and self._delta.count == 0)

    @property
    def l0_chain_len(self) -> int:
        """Sealed segments currently awaiting compaction."""
        with self._lock:
            return len(self._frozen)

    # -- L1-tier hooks (no-ops here; the LSM ladder overrides them) ----------
    #
    # The single-level catalogue has no L1 tier: these hooks keep the
    # query/warm/stats plumbing shared with
    # :class:`repro.core.lsm.ShardedLsmCatalogue` (DESIGN.md §15)
    # instead of forking the query path.

    def _l1_stack_locked(self):
        """Stacked per-shard L1 device views, or ``None``. Lock held."""
        return None

    def _l1_live_locked(self) -> int:
        """Live rows resident in the L1 tier. Lock held."""
        return 0

    def _warm_l1_variants(self):
        """L1 operands :meth:`warm` compiles tails for: ``(spec, dummy)``
        pairs, where the single-level catalogue has only the no-tier
        variant."""
        return (((), None),)

    @property
    def n_shards(self) -> int:
        """L1 shard count (0: single-level, no L1 tier)."""
        return 0

    @property
    def l1_rows(self) -> int:
        """Live rows currently resident in the per-shard L1 tier."""
        return 0

    @property
    def consecutive_fold_failures(self) -> int:
        """Current L0->L1 fold failure streak (0 on a healthy ladder)."""
        return 0

    @property
    def fold_backoff_s(self) -> float:
        """Backoff the next ordinary fold retry is waiting out."""
        return 0.0

    def _chain_pressure_locked(self) -> int:
        """Sealed segments counted against ``max_l0_segments``. The LSM
        ladder overrides this to EXCLUDE L1 runs parked in the chain by
        an in-flight promotion: back-pressure exists to bound the extra
        per-query dense scans a FAILING build accumulates, and a
        promotion scans the same rows queries were already scoring
        through the stacked L1 path — no new pressure. Lock held."""
        return len(self._frozen)

    @property
    def consecutive_build_failures(self) -> int:
        with self._lock:
            return self._consec_build_failures

    @property
    def current_backoff_s(self) -> float:
        """The backoff the NEXT automatic retry is waiting out (0 when
        the last build succeeded)."""
        with self._lock:
            return self._last_backoff_s if self._consec_build_failures \
                else 0.0

    @property
    def retry_pending(self) -> bool:
        """True while an automatic post-failure retry is scheduled."""
        with self._lock:
            return self._retry_timer is not None

    def check_watchdog(self) -> bool:
        """Flag (once per build) an in-flight build exceeding the
        watchdog threshold. Returns True while the build is overdue.

        Detection only: the thread is never killed — a stalled build may
        still finish and swap in; the counter tells the operator that
        queries are meanwhile dragging an L0 chain.
        """
        with self._lock:
            started = self._build_started_at
            if self._build_thread is None or started is None:
                return False
            if time.monotonic() - started <= self.build_watchdog_s:
                return False
            if not self._watchdog_flagged:
                self._watchdog_flagged = True
                self.stats.n_stuck_builds += 1
                obs.on_compaction(
                    "stuck", version=self._snapshot.version,
                    overdue_s=time.monotonic() - started)
            return True

    def _live_concat_locked(self, snap: Snapshot, segs
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Base live rows + each segment's live rows, concatenated.

        THE liveness fold — shared by :meth:`as_dense` (the oracle view)
        and compaction (the rows the new snapshot indexes), so the two
        can never disagree about what is alive. Lock held.
        """
        parts_r: List[np.ndarray] = [snap.targets_np[~snap.dead_np]]
        parts_g: List[np.ndarray] = [snap.gids_np[~snap.dead_np]]
        for seg in segs:
            if seg.count:
                r, g = seg.live_rows()
                parts_r.append(r)
                parts_g.append(g)
        return (np.concatenate(parts_r, axis=0),
                np.concatenate(parts_g, axis=0))

    def as_dense(self) -> Tuple[np.ndarray, np.ndarray]:
        """A consistent ``(rows [N, R], gids [N])`` view of every LIVE item.

        What a from-scratch rebuild would index — the oracle the
        exactness tests and the streaming benchmark compare against.
        """
        with self._lock:
            return self._live_concat_locked(self._snapshot,
                                            self._segments())

    # -- mutations -----------------------------------------------------------

    def _locate(self, gid: int):
        """(where, segment-or-row) for a LIVE gid; KeyError if not live."""
        if gid in self._delta._pos:
            return "delta", self._delta
        for frozen in self._frozen:
            if gid in frozen._pos:
                return "frozen", frozen
        row = self._snapshot.gid_to_row.get(gid)
        if row is not None and not self._snapshot.dead_np[row]:
            return "base", row
        raise KeyError(f"gid {gid} is not a live catalogue item")

    def _kill_located(self, located) -> None:
        """Apply a validated batch of (gid, where, seg-or-row) kills.

        Base kills are BATCHED into one ``kill_rows`` call (one device
        mask update per mutation call, not per item). Lock held.
        """
        base_rows: List[int] = []
        for gid, where, seg in located:
            if where == "base":
                base_rows.append(seg)
                if self._build_thread is not None:
                    self._pending_dead.add(gid)
            else:
                seg.kill(gid)
                if where == "frozen":
                    self._pending_dead.add(gid)
        if base_rows:
            self._snapshot.kill_rows(base_rows)

    def _note_delta_peak(self) -> None:
        self.stats.max_delta_occupancy = max(
            self.stats.max_delta_occupancy, self._delta.count)

    def _validate_rows(self, rows, what: str) -> np.ndarray:
        """Shared mutation-input validation: shape, rank, finiteness.

        A NaN/Inf row would poison every score it participates in (NaN
        propagates through the matmul and breaks the sort), so it is
        rejected up front with a clear error instead of producing silent
        garbage downstream.
        """
        R = np.atleast_2d(np.asarray(rows, np.float32))
        if R.ndim != 2:
            raise ValueError(
                f"{what} must be [R] or [N, R], got shape {R.shape}")
        if R.shape[1] != self.rank:
            raise ValueError(f"rank mismatch: {R.shape[1]} != {self.rank}")
        if not np.all(np.isfinite(R)):
            bad = int(np.flatnonzero(~np.all(np.isfinite(R), axis=1))[0])
            raise ValueError(
                f"{what} contain non-finite values (first bad row: {bad}); "
                "NaN/Inf rows would corrupt every top-K they score in")
        return R

    def add_targets(self, rows) -> np.ndarray:
        """Append rows; returns their freshly assigned global ids."""
        R = self._validate_rows(rows, "inserted rows")
        out = np.empty((R.shape[0],), np.int64)
        with self._lock:
            for i, row in enumerate(R):
                if self._delta.full or faults.fire(FAULT_DELTA_OVERFLOW):
                    self._compact_locked()
                gid = self._next_gid
                self._next_gid += 1
                self._delta.append(row, gid)
                self._note_delta_peak()
                out[i] = gid
            self.stats.n_inserts += R.shape[0]
            self._bump_epoch_locked("insert")
        self._after_mutation()
        return out

    def delete_targets(self, gids) -> None:
        """Tombstone live items (base rows stay resident until compaction).

        Validate-then-apply: every gid is located while nothing has been
        mutated, so a KeyError (unknown/dead/duplicate gid) leaves the
        catalogue untouched and the batch is safely retryable.
        """
        gids = [int(g) for g in np.atleast_1d(np.asarray(gids))]
        with self._lock:
            if len(set(gids)) != len(gids):
                raise KeyError(f"duplicate gids in delete batch: {gids}")
            located = [(gid, *self._locate(gid)) for gid in gids]
            self._kill_located(located)
            self.stats.n_deletes += len(gids)
            self._bump_epoch_locked("delete")
            self._maybe_compact_locked()
        self._after_mutation()

    def update_targets(self, gids, rows) -> None:
        """Replace live items in place: tombstone the old row, append the
        new one to the delta UNDER THE SAME GID (queries see exactly one
        copy at all times). Validate-then-apply like :meth:`delete_targets`
        (a repeated gid is allowed: the LAST row wins).
        """
        gids = [int(g) for g in np.atleast_1d(np.asarray(gids))]
        R = self._validate_rows(rows, "updated rows")
        if len(gids) != R.shape[0]:
            raise ValueError("one row per gid required")
        with self._lock:
            seen: set = set()
            located = []
            for gid in gids:
                if gid not in seen:            # later copies shadow below
                    seen.add(gid)
                    located.append((gid, *self._locate(gid)))
            self._kill_located(located)
            for gid, row in zip(gids, R):
                try:
                    loc = self._locate(gid)
                except KeyError:
                    pass                       # first append for this gid
                else:
                    # same gid earlier in THIS batch — its copy may since
                    # have been frozen (or even folded into a new base) by
                    # a mid-batch compaction; the last row wins everywhere
                    self._kill_located([(gid, *loc)])
                if self._delta.full or faults.fire(FAULT_DELTA_OVERFLOW):
                    self._compact_locked()
                self._delta.append(row, gid)
                self._note_delta_peak()
            self.stats.n_updates += len(gids)
            self._bump_epoch_locked("update")
            self._maybe_compact_locked()
        self._after_mutation()

    # -- compaction ----------------------------------------------------------

    def _maybe_compact_locked(self) -> None:
        snap = self._snapshot
        thresh = min(float(self.max_tombstones),
                     self.tombstone_compact_fraction * max(snap.num_rows, 1))
        if self._delta.full or (snap.n_dead and snap.n_dead >= thresh):
            self._compact_locked()

    def _after_mutation(self) -> None:
        """Post-mutation hooks that must run OFF the catalogue lock.

        The chain cap may JOIN an in-flight build thread — and the build
        acquires the lock to swap, so joining under it would deadlock.
        Every mutation entry point calls this after releasing the lock.
        """
        self._notify_invalidation()
        self.check_watchdog()
        self._enforce_chain_cap()

    def _enforce_chain_cap(self) -> None:
        """Force the L0 chain back under ``max_l0_segments``.

        Sustained mutation pressure against failing (or merely slow)
        builds grows the sealed chain; every extra segment is one more
        dense matmul per query, so an unbounded chain degrades latency
        unboundedly. Past the cap this BLOCKS the mutating caller: joins
        the in-flight build if there is one, otherwise runs a forced
        SYNCHRONOUS build inline (bypassing the failure backoff — the
        cap outranks it). Bounded: after ``build_retry_limit + 1``
        consecutive inline failures it gives up and returns (the chain
        stays queryable; nothing is lost).
        """
        attempts = 0
        while True:
            with self._lock:
                if self._chain_pressure_locked() <= self.max_l0_segments:
                    return
                t = self._build_thread
                if t is None:
                    if attempts > self.build_retry_limit:
                        return
                    attempts += 1
                    self.stats.n_forced_sync_compactions += 1
                    obs.on_compaction("forced_sync",
                                      chain_len=len(self._frozen),
                                      attempt=attempts)
                    self._compact_locked(force=True, force_sync=True)
                    continue
            t.join()        # off-lock: the build takes the lock to swap

    def _retry_build(self) -> None:
        """Timer target: the automatic post-failure retry (async mode)."""
        with self._lock:
            self._retry_timer = None
            if self._build_thread is not None:
                return
            if (self._frozen or self._delta.count
                    or self._snapshot.n_dead):
                # force=True: the elapsed timer IS the backoff
                self._compact_locked(force=True)

    def _compact_locked(self, force: bool = False,
                        force_sync: bool = False) -> None:
        """Freeze the active delta and rebuild (inline or on a thread).

        NEVER blocks and never releases the lock: if a background build
        is already in flight, the freshly sealed delta simply joins the
        frozen chain and this call returns — the chain keeps serving
        queries and folds wholesale at the next compaction trigger (the
        L0 behaviour of an LSM under sustained write pressure; chain
        length is bounded by ``max_l0_segments`` via the chain cap). A
        build folds the ENTIRE chain as of its freeze point; a build
        exception leaves the sealed segments in place (still queryable,
        refolded later — a failed build never loses rows) and clears the
        thread slot (``try/finally``).

        After a failed build, new attempts are GATED: they wait out an
        exponential backoff and stop entirely after
        ``build_retry_limit`` consecutive failures. ``force=True``
        (explicit :meth:`compact`, the chain cap, the retry timer)
        bypasses the gate; ``force_sync=True`` additionally runs the
        build inline even in ``compact_async`` mode (the chain-cap
        back-pressure path).
        """
        if (self._delta.count == 0 and not self._frozen
                and self._snapshot.n_dead == 0):
            return                            # nothing to fold: cheap no-op
        if self._delta.count > 0 or not self._frozen:
            sealed = self._delta
            sealed.seal()
            self._frozen.append(sealed)
            self._delta = DeltaSegment(self.delta_capacity, self.rank)
            self.stats.max_l0_chain = max(self.stats.max_l0_chain,
                                          len(self._frozen))
        if self._build_thread is not None:
            return                            # in-flight build; chain waits
        if not force and self._consec_build_failures:
            # recovering from failure: stop auto-retrying entirely past
            # the limit, and from the SECOND consecutive failure on wait
            # out the exponential backoff (the first failure retries at
            # the very next trigger — transient blips heal immediately).
            # Explicit compact() and the chain cap still force attempts.
            if (self._consec_build_failures > self.build_retry_limit
                    or (self._consec_build_failures >= 2
                        and time.monotonic() < self._retry_not_before)):
                return
        snap = self._snapshot
        folding = list(self._frozen)
        # pending_dead means "kill this gid in the snapshot CURRENTLY
        # being built, whose capture predates the kill". The capture
        # below (no build is in flight here) reflects every kill so far,
        # so entries recorded against an EARLIER (failed) build are
        # stale — and a stale entry is not merely redundant: if the gid
        # was re-appended under an update since the kill, the live new
        # copy lands in this capture and the stale entry would wrongly
        # kill it at swap. Only kills landing AFTER this point belong in
        # the set.
        self._pending_dead.clear()
        new_rows, new_gids = self._live_concat_locked(snap, folding)
        new_rows = np.ascontiguousarray(new_rows)
        if new_rows.shape[0] == 0:
            # an empty catalogue cannot be indexed: keep one dead guard
            # row so engines always have M >= 1; queries see only -inf
            new_rows = np.zeros((1, self.rank), np.float32)
            new_gids = np.full((1,), -1, np.int64)
        version = snap.version + 1

        def build():
            ok = False
            t_build = time.perf_counter()
            own_compiles = 0
            headroom_compiles = 0
            try:
                faults.fire(FAULT_STALL)      # test seam: slow/stuck build
                faults.fire(FAULT_BUILD)      # test seam: failing build
                ctx = EngineContext(new_rows, version=version,
                                    **self._ctx_kwargs)
                ctx.index                     # offline index build, off-lock
                new_snap = Snapshot(new_rows, new_gids, version, ctx)
                if new_gids[0] < 0:
                    new_snap.kill_rows([0])   # the guard row is dead
                faults.fire(FAULT_WARM)       # test seam: readiness failure
                if self._warm_spec is not None:
                    # Readiness pass over the new snapshot BEFORE the swap
                    # (at the serving k and the escalated shape): builds +
                    # uploads the padded engine args and runs each warmed
                    # engine once, so the post-swap first query touches
                    # only device-resident state. Under the argument-
                    # passing contract (DESIGN.md §10) this COMPILES
                    # nothing for a same-bucket compaction — the shared
                    # executors' traces are bucket-keyed, version-free —
                    # and only a bucket-crossing build into a never-warmed
                    # bucket traces (counted in
                    # ``stats.engine_compiles_total``, off the query hot
                    # path). The segmented tails need no re-warm either:
                    # their compiles are batch-shaped, already cached.
                    # Traces are counted from the NEW context's own
                    # attributed ``trace_counts`` — a trace a concurrent
                    # serving thread causes on the OLD snapshot during
                    # this window is its own, not this build's.
                    k, sizes, engines, headroom, budgets = self._warm_spec
                    ctx.warmup(k, batch_sizes=sizes, engines=engines,
                               budgets=budgets)
                    kb_esc = min(new_snap.num_rows,
                                 int(k) + self.overfetch_reserve)
                    if engines and kb_esc > min(new_snap.num_rows, int(k)):
                        ctx.warmup(kb_esc, batch_sizes=sizes,
                                   engines=engines, budgets=budgets)
                    own_compiles = sum(ctx.trace_counts.values())
                    nxt = 2 * ctx.m_bucket
                    if (headroom
                            and 4 * new_snap.num_rows > 3 * ctx.m_bucket
                            and nxt > self._headroom_bucket):
                        # The snapshot fills ≥75% of its bucket and the
                        # next bucket was never warmed: renew the
                        # one-doubling headroom the server's boot warmup
                        # established, so the guarantee is STANDING —
                        # the crossing this growth is heading for finds
                        # its traces waiting. Renewing here (not at
                        # bucket ENTRY) defers the pre-pay until the
                        # boundary actually threatens, and the
                        # ``_headroom_bucket`` memo makes it once per
                        # doubling — steady-state builds never rebuild
                        # oversized args. Accounted separately: an
                        # investment for the next crossing, not a cost
                        # of serving this snapshot. (If delta_capacity
                        # exceeds a quarter-bucket, one compaction can
                        # leap the 75% band and the crossing build pays
                        # its own compiles — recorded, off the query
                        # path.)
                        ctx.warmup(k, batch_sizes=sizes, engines=engines,
                                   m_buckets=(nxt,), budgets=budgets)
                        if engines and kb_esc > min(new_snap.num_rows,
                                                    int(k)):
                            ctx.warmup(kb_esc, batch_sizes=sizes,
                                       engines=engines, m_buckets=(nxt,),
                                       budgets=budgets)
                        headroom_compiles = (
                            sum(ctx.trace_counts.values()) - own_compiles)
                        with self._lock:
                            self._headroom_bucket = max(
                                self._headroom_bucket, nxt)
                with self._lock:
                    pend = [new_snap.gid_to_row[g]
                            for g in self._pending_dead
                            if g in new_snap.gid_to_row]
                    if pend:
                        new_snap.kill_rows(pend)
                    self._pending_dead.clear()
                    self._snapshot = new_snap
                    self._frozen = [s for s in self._frozen
                                    if s not in folding]
                    # the swap changes visible identity (new version,
                    # pending deletes applied): old cache tokens die here
                    self._bump_epoch_locked("swap")
                    self.stats.n_compactions += 1
                    dt = time.perf_counter() - t_build
                    self.stats.last_compaction_s = dt
                    self.stats.compaction_s_total += dt
                    self.stats.engine_compiles_total += own_compiles
                    self.stats.headroom_compiles_total += headroom_compiles
                    # recovery: a successful swap clears ALL stale failure
                    # state — the error belongs to a chain that no longer
                    # exists, and keeping it would gate future builds
                    self.last_build_error = None
                    self._consec_build_failures = 0
                    self._retry_not_before = 0.0
                    self._last_backoff_s = 0.0
                    obs.on_compaction(
                        "success", version=version, epoch=self._epoch,
                        duration_s=dt, engine_compiles=own_compiles,
                        headroom_compiles=headroom_compiles,
                        num_live=int(new_snap.num_rows - new_snap.n_dead))
                self._notify_invalidation()
            except Exception as exc:
                # the sealed segments stay in self._frozen: still
                # queryable, re-folded by the next compaction — a failed
                # build loses nothing. Failures are RECORDED, never
                # raised from here: a synchronous build runs inline in
                # the middle of a mutation batch, and raising there
                # would abort the batch after its kills but before its
                # appends (losing updated rows). ``compact(wait=True)``
                # surfaces the recorded failure to callers. Recovery: an
                # exponential backoff gates ordinary retriggers, and in
                # async mode a daemon timer schedules the retry itself so
                # a quiet catalogue (no further mutations) still heals.
                with self._lock:
                    self.last_build_error = exc
                    self.stats.n_failed_compactions += 1
                    self._consec_build_failures += 1
                    backoff = min(
                        self.build_backoff_s
                        * (2 ** (self._consec_build_failures - 1)),
                        self.build_backoff_max_s)
                    self._last_backoff_s = backoff
                    self._retry_not_before = time.monotonic() + backoff
                    obs.on_compaction(
                        "fail", version_attempted=version,
                        epoch=self._epoch, error=repr(exc),
                        consecutive_failures=self._consec_build_failures,
                        backoff_s=backoff)
                    if (self.auto_retry and self.compact_async
                            and self._consec_build_failures
                            <= self.build_retry_limit
                            and self._retry_timer is None):
                        tmr = threading.Timer(backoff, self._retry_build)
                        tmr.daemon = True
                        self._retry_timer = tmr
                        tmr.start()
                        obs.on_compaction(
                            "retry_scheduled", version_attempted=version,
                            backoff_s=backoff)
            else:
                ok = True
            finally:
                with self._lock:
                    self._build_started_at = None
                    self._watchdog_flagged = False
                    if self._build_thread is threading.current_thread():
                        self._build_thread = None
                    if ok and self.compact_async and self._frozen:
                        # segments sealed while this build ran are still
                        # waiting: fold them now (a fresh thread; this one
                        # exits). Spawned under the SAME lock hold that
                        # cleared the slot, so flush() can never observe
                        # an empty slot between build and refold.
                        self._compact_locked()

        if self._consec_build_failures:
            self.stats.n_build_retries += 1     # attempt after >=1 failure
            obs.on_compaction(
                "retry", version_from=snap.version, version_to=version,
                consecutive_failures=self._consec_build_failures)
        self._build_started_at = time.monotonic()
        self._watchdog_flagged = False
        obs.on_compaction(
            "start", version_from=snap.version, version_to=version,
            epoch=self._epoch, chain_len=len(folding),
            n_rows=int(new_rows.shape[0]),
            sync=bool(not self.compact_async or force_sync))
        if self.compact_async and not force_sync:
            t = threading.Thread(target=build, name="segcat-compact",
                                 daemon=True)
            self._build_thread = t
            t.start()
        else:
            # force_sync: chain-cap back-pressure — the mutating caller
            # pays for the fold it caused (runs under the RLock; build's
            # swap re-enters it, which an RLock permits inline)
            build()

    def compact(self, wait: bool = True) -> None:
        """Force a compaction now (folds the delta + frozen chain into
        the base). ``wait=True`` loops until the chain is fully folded —
        even when builds were already in flight — and surfaces an async
        build failure as an exception instead of spinning on it."""
        first = True
        while True:
            with self._lock:
                if not first and not self._frozen:
                    return
                # fold failures count too: on the LSM ladder a failed
                # L0->L1 fold leaves the chain in place exactly like a
                # failed build, and wait=True must surface it instead of
                # spinning against an armed fold fault
                fails_before = (self.stats.n_failed_compactions
                                + self.stats.n_failed_l1_folds)
                # force=True: an explicit compact() call outranks the
                # failure backoff gate (and wait=True would otherwise
                # spin forever against it)
                self._compact_locked(force=True)
                t = self._build_thread
                first = False
            if not wait:
                return
            if t is not None:
                t.join()
            with self._lock:
                if not self._frozen:
                    return
                if (self.stats.n_failed_compactions
                        + self.stats.n_failed_l1_folds) > fails_before:
                    raise RuntimeError(
                        "compaction build failed; sealed segments remain "
                        "queryable and will be refolded"
                    ) from self.last_build_error

    def flush(self) -> None:
        """Block until every in-flight background build (including any
        auto-refold a build kicked off for segments sealed during it)
        has swapped in.

        Deliberately PASSIVE about failures: a failed build leaves its
        sealed chain in place and flush returns with it intact (the
        recorded error in :attr:`last_build_error` is the signal) —
        :meth:`compact` ``(wait=True)`` is the "fold or raise" API."""
        while True:
            with self._lock:
                # under the lock: a finishing build clears the slot and
                # spawns its refold inside ONE lock hold, so a locked
                # read can never catch the in-between state
                t = self._build_thread
            if t is None:
                return
            t.join()

    # -- query ---------------------------------------------------------------

    def _compiled_tail(self, k: int, kb: int, bucket: int,
                       seg_buckets: Tuple[int, ...],
                       l1_spec: Tuple[int, ...] = ()):
        # no snapshot version in the key: the tail's inputs are all
        # batch-shaped, so one compile serves every snapshot. The
        # check-then-insert and the trace counter run under the lock so
        # concurrent readers neither double-compile a shape nor lose
        # counter increments (the 0-retrace warmup assertions read them).
        # ``l1_spec`` is the stacked L1 tier's (n_shards, slab-capacity)
        # — a FIXED pair per LSM catalogue, so the ladder adds exactly
        # one extra tail shape per (k, kb, bucket, segs) combination.
        key = (int(k), int(kb), int(bucket), seg_buckets, tuple(l1_spec))
        with self._lock:
            fn = self._tail_cache.get(key)
            if fn is None:
                def traced(bv, tomb, bg, U, segs, l1,
                           _k=int(k), _kb=int(kb)):
                    with self._lock:
                        self.trace_counts["segmented_tail"] = (
                            self.trace_counts.get("segmented_tail", 0) + 1)
                    return _segmented_tail(bv, tomb, bg, U, segs, l1,
                                           k=_k, kb=_kb)

                fn = jax.jit(traced)
                self._tail_cache[key] = fn
        return fn

    def query(self, engine: Engine, U, k: int,
              budget: Optional[int] = None
              ) -> Tuple[TopKResult, QueryInfo]:
        """Exact top-``k`` over every LIVE item, through ``engine``.

        Returns ``(result, info)`` — ``result.indices`` are GLOBAL ids
        (stable across compactions), ``result.n_scored`` includes the
        live delta slots scored (the authoritative run's count; a
        discarded optimistic run shows up in wall-clock, not in the
        paper's score metric), and ``info`` carries the segmented
        accounting (:class:`QueryInfo`).

        ``budget`` caps the BASE engine's scan depth (list rows; see
        ``Engine.run``). The returned ``result.upper`` then bounds every
        un-enumerated base item, so :func:`certificate_gaps` stays valid
        over the live catalogue: the delta segments are always fully
        dense-scored (never budgeted), and the tombstone escalation
        ladder is budget-independent — a certified slot is provably in
        the true live top-``k`` even when the base scan halted early.

        The whole batch is computed against ONE consistent state
        captured under the lock (snapshot + dead mask + delta views) —
        mutations landing mid-query are simply not visible to it.
        """
        with self._lock:
            snap = self._snapshot
            segs = [s for s in self._segments() if s.count > 0]
            views = tuple(s.device_view() for s in segs)
            l1 = self._l1_stack_locked()      # None: no L1 tier / empty
            n_delta_live = (sum(s.n_live for s in segs)
                            + self._l1_live_locked())
            n_dead = snap.n_dead
            dead_dev, gids_dev = snap.dead_dev, snap.gids_dev
        if not views and l1 is None and n_dead == 0 and snap.identity:
            # never-mutated fast path: byte-identical to the static server
            res = engine.run(snap.ctx, U, k, budget=budget)
            return res, QueryInfo(0, min(int(k), snap.num_rows), 0,
                                  snap.version)
        # no np.asarray: a device-resident U must not round-trip the host
        U_dev = jnp.atleast_2d(jnp.asarray(U, dtype=jnp.float32))
        b = U_dev.shape[0]
        bucket = batch_bucket(b)
        U_dev = pad_to_bucket(U_dev)          # same rule as the engine cache
        seg_buckets = tuple(int(v[0].shape[0]) for v in views)
        l1_spec = () if l1 is None else tuple(int(d) for d in
                                              l1[0].shape[:2])

        mb = snap.num_rows

        def run_at(kb):
            res = engine.run(snap.ctx, U_dev, kb, budget=budget)
            # resolve mask/gids EAGERLY (two primitive gathers): the jitted
            # tail then never sees an [M_base]-shaped array, so its compile
            # key is snapshot-version-free
            safe = jnp.clip(res.indices, 0, max(mb - 1, 0))
            tomb = jnp.logical_and(res.indices >= 0, dead_dev[safe])
            bg = jnp.where(res.indices >= 0, gids_dev[safe], -1)
            fn = self._compiled_tail(k, kb, bucket, seg_buckets, l1_spec)
            vals, gids, dropped = fn(res.values, tomb, bg, U_dev, views,
                                     l1)
            return res, vals, gids, dropped

        # Tombstone-adaptive base fetch: plain k while the snapshot has no
        # dead rows (the common, warmed key — inserts never retrace), and
        # the k + reserve rung (ALSO pre-warmed) the moment tombstones
        # exist — one engine run with enough margin that a dead row in
        # the top-k costs nothing, instead of an optimistic run that
        # would be discarded and re-run on every tombstone hit.
        k = int(k)
        kb = min(mb, k if n_dead == 0 else k + self.overfetch_reserve)
        res, vals, gids, dropped = run_at(kb)
        retried = False
        # Escalation ladder. A rung's result is exact for every query
        # whose dropped count fits the over-fetch margin (dropped <=
        # kb - k: at least k live base rows survived the drop, and any
        # live row outside the top-kb scores below all of them); a full
        # base fetch (kb == M_base) is unconditionally exact, so the
        # ladder terminates. Climbing x4 is only reachable when more
        # than `reserve` dead rows sit inside ONE query's top slice —
        # those rungs compile lazily.
        while (n_dead and kb < mb
               and bool(np.any(np.asarray(dropped) > kb - k))):
            step = max(kb - k, self.overfetch_reserve // ESCALATION_STEP, 1)
            kb = min(mb, k + ESCALATION_STEP * step)
            res, vals, gids, dropped = run_at(kb)
            retried = True
        n_scored = res.n_scored + jnp.int32(n_delta_live)
        # the base engine's upper bound covers every un-enumerated base
        # item, and the delta is fully scored — so it is ALSO a valid
        # certificate bound for the merged live result
        upper = None if res.upper is None else res.upper[:b]
        out = TopKResult(vals[:b], gids[:b], n_scored[:b], res.depth[:b],
                         upper=upper)
        return out, QueryInfo(int(n_delta_live), kb, len(views),
                              snap.version, retried)

    # -- warmup --------------------------------------------------------------

    def delta_buckets(self) -> List[int]:
        """The power-of-four delta occupancy buckets up to capacity."""
        out, d = [], 1
        while d < self.delta_capacity:
            out.append(d)
            d <<= 2
        out.append(self.delta_capacity)
        return out

    def warm(self, k: int, batch_sizes=(1, 64),
             snap: Optional[Snapshot] = None,
             engines=None, m_buckets=None,
             budgets=None) -> "SegmentedCatalogue":
        """Compile the segmented tail for every delta-capacity bucket.

        Tails are warmed at BOTH base-fetch shapes — plain ``k`` (the
        no-tombstone path) and ``k + overfetch_reserve`` (what any
        tombstoned snapshot fetches) — including the two-segment shapes
        a background build exposes. After this, the first query after
        ANY insert (delta occupancy 1..capacity) dispatches a cached
        executable — 0 new traces (asserted in tests via
        :attr:`trace_counts`); deletes are likewise retrace-free when
        ``engines`` is given, which additionally pre-compiles those
        engines at the over-fetched shape — over every M-bucket in
        ``m_buckets`` (DESIGN.md §10), so a compaction that crosses into
        a warmed bucket stays compile-free on the tombstoned path too.
        ``snap`` warms a not-yet-swapped-in snapshot (the background
        compaction readiness path). Tail compiles are snapshot-free
        twice over (batch-shaped inputs AND, since the argument-passing
        refactor, version-free engine executors), so a compaction
        re-runs only the readiness pass for the new snapshot — the
        tails compiled here serve every future snapshot as is.
        """
        snap = self._snapshot if snap is None else snap
        kb = min(snap.num_rows, int(k))
        kb_esc = min(snap.num_rows, int(k) + self.overfetch_reserve)
        r = self.rank
        kbs = [kb] if kb_esc == kb else [kb, kb_esc]

        def dummy_seg(d):
            return (jnp.zeros((d, r), jnp.float32),
                    jnp.full((d,), -1, jnp.int32),
                    jnp.zeros((d,), bool))

        for bsz in batch_sizes:
            bucket = batch_bucket(bsz)
            U = jnp.ones((bucket, r), jnp.float32)
            for kb_w in kbs:
                bv = jnp.zeros((bucket, kb_w), jnp.float32)
                tomb = jnp.zeros((bucket, kb_w), bool)
                bg = jnp.zeros((bucket, kb_w), jnp.int32)
                # every tail shape is warmed with AND without the L1
                # tier operand (one extra variant on the LSM ladder —
                # the stacked tier is a single fixed shape, so folds
                # never add tail compiles)
                for l1_spec, l1_dummy in self._warm_l1_variants():
                    # post-compaction pristine-but-nonidentity tail
                    # (no segs)
                    fn = self._compiled_tail(k, kb_w, bucket, (), l1_spec)
                    jax.block_until_ready(fn(bv, tomb, bg, U, (),
                                             l1_dummy))
                    for d in self.delta_buckets():
                        fn = self._compiled_tail(k, kb_w, bucket, (d,),
                                                 l1_spec)
                        jax.block_until_ready(
                            fn(bv, tomb, bg, U, (dummy_seg(d),),
                               l1_dummy))
                    # while a background compaction is in flight queries
                    # see TWO segments: the frozen delta (sealed views
                    # present the capacity bucket) plus the active delta
                    # at any bucket
                    frozen = dummy_seg(self.delta_capacity)
                    for d in self.delta_buckets():
                        fn = self._compiled_tail(
                            k, kb_w, bucket, (self.delta_capacity, d),
                            l1_spec)
                        jax.block_until_ready(
                            fn(bv, tomb, bg, U, (frozen, dummy_seg(d)),
                               l1_dummy))
        if engines and kb_esc > kb:
            snap.ctx.warmup(kb_esc, batch_sizes=batch_sizes,
                            engines=engines, m_buckets=m_buckets,
                            budgets=budgets)
        if m_buckets:
            with self._lock:
                self._headroom_bucket = max(
                    self._headroom_bucket,
                    *(int(b) for b in m_buckets))
        return self

    def set_warm_spec(self, k: int, batch_sizes, engines=None,
                      headroom: bool = True, budgets=None) -> None:
        """Remember what to ready on each compacted snapshot, so the
        post-swap first query hits compiled executables (the rebuild cost
        stays off the query hot path, including compiles).

        ``headroom=True`` additionally has a build whose snapshot fills
        ≥75% of its M-bucket pre-trace the NEXT bucket, once per
        doubling (DESIGN.md §10) — renewing the boot warmup's
        one-doubling headroom just before growth needs it, so that
        EVERY future bucket crossing, not just the first, compacts
        compile-free; the investment is counted in
        ``SegmentStats.headroom_compiles_total``, never in
        ``engine_compiles_total``.
        """
        self._warm_spec = (int(k), tuple(batch_sizes), engines,
                           bool(headroom),
                           None if budgets is None
                           else tuple(int(b) for b in budgets))
