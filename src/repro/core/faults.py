"""Deterministic fault injection for the streaming/serving stack.

A process-wide registry of **named fault points** (DESIGN.md §12).
Production code declares a point once at import time
(:func:`register_point`) and calls :func:`fire` at the seam; the call is
a dictionary lookup and costs nothing unless a test has **armed** the
point (:func:`arm`) with a trigger spec — an exception to raise, a delay
to sleep, or both, gated by a deterministic seeded coin so multi-fault
schedules replay bit-identically across runs.

This exists because the recovery paths it exercises — failed background
compaction builds, stuck build threads, delta overflow under mutation
bursts (:mod:`repro.core.segments`) — are exactly the code that nothing
exercises in the happy path. The registry is thread-safe (faults fire
from background build threads) and test-scoped via the
:func:`injected` context manager, which always disarms on exit.

Typical test usage::

    from repro.core import faults

    with faults.injected("compaction.build", error=RuntimeError,
                         times=3):
        ...   # the next 3 compaction builds raise inside the builder

    faults.arm("compaction.stall", delay_s=0.5)   # one slow build
    faults.arm("delta.overflow", p=0.5, times=8, seed=7)  # burst coin

Fault points registered by the core (see the call sites for exact
semantics):

================== ========================================================
``compaction.build``   raises inside the compaction builder, before the
                       snapshot swap — the build fails, the L0 chain stays
``compaction.stall``   sleeps inside the builder — a slow/stuck build for
                       the watchdog to detect
``compaction.warm``    raises during the post-build readiness warmup
``delta.overflow``     trigger-style (no error): reports the delta as full
                       on an append, forcing an early seal + compaction
``compaction.fold_l1`` raises inside an L0 -> L1 per-shard fold, before any
                       slab is touched — the chain stays queryable
``compaction.promote`` raises at the L1-overflow promotion decision, before
                       the full base rebuild launches
================== ========================================================
"""

from __future__ import annotations

import contextlib
import random
import threading
import time
from typing import Dict, Optional, Type

from repro import obs

__all__ = [
    "FaultInjected", "register_point", "list_points", "arm", "disarm",
    "disarm_all", "fire", "counters", "injected",
]


class FaultInjected(RuntimeError):
    """Default error raised by an armed fault point."""


class _Armed:
    """Trigger spec + mutable counters for one armed point."""

    def __init__(self, error, delay_s: float, times: Optional[int],
                 after: int, p: float, seed: int):
        self.error = error
        self.delay_s = float(delay_s)
        self.times = None if times is None else int(times)
        self.after = int(after)
        self.p = float(p)
        self.rng = random.Random(seed)
        self.hits = 0      # fire() calls observed while armed
        self.fired = 0     # times the trigger actually went off


_LOCK = threading.Lock()
_POINTS: Dict[str, str] = {}
_ARMED: Dict[str, _Armed] = {}
#: cumulative per-point counters, surviving disarm (tests read them after
#: the context manager exits)
_TOTALS: Dict[str, Dict[str, int]] = {}


def register_point(name: str, description: str) -> str:
    """Declare a fault point. Idempotent; returns ``name`` for reuse."""
    with _LOCK:
        _POINTS[name] = description
        _TOTALS.setdefault(name, {"hits": 0, "fired": 0})
    return name


def list_points() -> Dict[str, str]:
    """All registered fault points, name -> description."""
    with _LOCK:
        return dict(_POINTS)


def arm(point: str, *, error: Optional[Type[BaseException]] = None,
        delay_s: float = 0.0, times: Optional[int] = 1, after: int = 0,
        p: float = 1.0, seed: int = 0) -> None:
    """Arm ``point`` to trigger on upcoming :func:`fire` calls.

    Args:
      error: exception TYPE to raise when the trigger goes off (called
        with a descriptive message). ``None`` makes the point
        trigger-style: :func:`fire` sleeps/returns ``True`` but raises
        nothing — for seams that branch on the return value.
      delay_s: sleep this long when triggered (before raising, if both).
      times: trigger at most this many times, then auto-disarm
        (``None`` = until :func:`disarm`).
      after: skip this many :func:`fire` calls before becoming eligible.
      p: per-call trigger probability, drawn from a ``random.Random(seed)``
        private to this arming — deterministic across runs and immune to
        global-RNG reseeding.
      seed: seed for that coin.
    """
    if point not in _POINTS:
        raise ValueError(
            f"unknown fault point {point!r}; registered: "
            f"{sorted(_POINTS)}")
    if not 0.0 < p <= 1.0:
        raise ValueError(f"p must be in (0, 1], got {p}")
    with _LOCK:
        _ARMED[point] = _Armed(error, delay_s, times, after, p, seed)


def disarm(point: str) -> None:
    """Disarm one point (no-op if it is not armed)."""
    with _LOCK:
        _ARMED.pop(point, None)


def disarm_all() -> None:
    """Disarm every point (test teardown safety net)."""
    with _LOCK:
        _ARMED.clear()


def fire(point: str) -> bool:
    """Production-side seam: trigger the point if a test armed it.

    Returns ``True`` when the trigger went off (after sleeping
    ``delay_s`` and raising ``error`` if one was armed), ``False``
    otherwise — including always-``False`` for the un-armed fast path,
    which is a single locked dict lookup.
    """
    with _LOCK:
        spec = _ARMED.get(point)
        if spec is None:
            return False
        totals = _TOTALS[point]
        spec.hits += 1
        totals["hits"] += 1
        if spec.hits <= spec.after:
            return False
        if spec.times is not None and spec.fired >= spec.times:
            _ARMED.pop(point, None)
            return False
        if spec.p < 1.0 and spec.rng.random() >= spec.p:
            return False
        spec.fired += 1
        totals["fired"] += 1
        if spec.times is not None and spec.fired >= spec.times:
            _ARMED.pop(point, None)
        error, delay = spec.error, spec.delay_s
    # observability: a trigger going off is exactly the kind of rare
    # state transition the event journal exists for — emitted OFF the
    # registry lock (journal takes only its own lock)
    obs.on_fault_fired(point)
    # sleep/raise OUTSIDE the lock: a stalled build must not block other
    # threads' (un-armed) fire() calls
    if delay > 0.0:
        time.sleep(delay)
    if error is not None:
        raise error(f"injected fault at {point!r}")
    return True


def counters() -> Dict[str, Dict[str, int]]:
    """Cumulative ``{point: {"hits": n, "fired": n}}`` since import."""
    with _LOCK:
        return {k: dict(v) for k, v in _TOTALS.items()}


@contextlib.contextmanager
def injected(point: str, **kw):
    """Arm ``point`` for the duration of a ``with`` block, then disarm."""
    arm(point, **kw)
    try:
        yield
    finally:
        disarm(point)


# LSM-ladder seams (DESIGN.md §15), registered here so tests can arm them
# before :mod:`repro.core.lsm` is imported. The fold seam fires before any
# L1 slab is touched, so an injected failure can never half-apply a fold;
# the promote seam fires at the overflow decision, before the full base
# rebuild launches.
FAULT_FOLD_L1 = register_point(
    "compaction.fold_l1",
    "raise inside an L0 -> L1 per-shard fold, before any slab is touched")
FAULT_PROMOTE = register_point(
    "compaction.promote",
    "raise at the L1-overflow promotion decision, before the full base "
    "rebuild launches")
