"""Unified pruned-block-scan driver (DESIGN.md §2).

Every exact engine in this repo — the paper's Threshold Algorithm, the
TPU-native Block Threshold Algorithm, and the norm-ordered Cauchy-Schwarz
scan — is the SAME state machine:

    while lower_bound < upper_bound and blocks remain:
        ids    <- enumerate the next block of candidates
        scores <- score the fresh candidates against the query
        top-K  <- merge
        bounds <- tighten (lower = running K-th best; upper = strategy bound)

:func:`pruned_block_scan` is that state machine, written once as a
``jax.lax.while_loop``, parameterised by a :class:`ScanStrategy` that
answers three questions — *which* candidates a block holds
(``candidates``), *how* to score them (``score``, defaulting to the dense
gather + matvec every current engine uses), and what *upper bound* holds
for every item not yet enumerated after the block (``bound``).

Two properties the copy-pasted per-engine loops did not have:

* **Uniform halting** — ``max_steps`` caps any strategy, so the paper's
  halted TA (§4.3) is a driver argument, not a per-engine reimplementation.
* **Faithful batched statistics** — every state update is gated on the
  per-query ``live`` predicate, so under ``jax.vmap`` a query that has
  already certified its top-K stops accumulating ``n_scored``/``depth``
  even though the lockstep loop keeps running for slower queries in the
  batch. Counts therefore match the sequential oracle exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.naive import TopKResult

Array = jnp.ndarray

NEG_INF = float("-inf")


def _dedup_first_occurrence(ids: Array, m: int) -> Array:
    """Boolean mask: True where ids[i] is the first occurrence of that id.

    Scatter-min of positions — O(|ids|) work, O(M) memory, jit-friendly.
    """
    n = ids.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    first_pos = jnp.full((m,), n, dtype=jnp.int32).at[ids].min(pos)
    return first_pos[ids] == pos


@dataclasses.dataclass(frozen=True)
class ScanStrategy:
    """What a pruned-scan engine must answer; everything else is the driver.

    Attributes:
      candidates: ``step -> (ids [C], active [C])`` — the candidate item ids
        enumerated by block ``step`` plus a mask of which slots are real
        (inactive lists, tail padding). ``C`` is static.
      bound: ``step -> scalar`` — an upper bound on the score of every item
        NOT yet enumerated once block ``step`` has been consumed. This is
        the exactness certificate: the scan may stop as soon as the running
        K-th best reaches it.
      num_steps: static number of blocks needed to enumerate the whole
        catalogue (the exact engine's worst case).
      track_visited: list-based strategies enumerate the same item from
        several lists and need the driver's visited-set + dedup pass;
        partition-based strategies (norm blocks) never repeat an item and
        skip that O(M) state entirely.
      score: optional ``(ids, active) -> scores [C]`` override; ``None``
        uses the dense gather + matvec ``targets[ids] @ u``.
    """

    candidates: Callable[[Array], Tuple[Array, Array]]
    bound: Callable[[Array], Array]
    num_steps: int
    track_visited: bool = True
    score: Optional[Callable[[Array, Array], Array]] = None


class ScanState(NamedTuple):
    step: Array         # blocks consumed
    top_vals: Array     # [K] running top scores, descending
    top_ids: Array      # [K] their item ids
    visited: Array      # [M] bool ([1] dummy when the strategy never repeats)
    n_scored: Array     # score evaluations (the paper's cost metric)
    lower: Array        # running K-th best
    upper: Array        # strategy bound on every unseen item


def pruned_block_scan(
    targets: Array,
    u: Array,
    strategy: ScanStrategy,
    k: int,
    max_steps: int = -1,
) -> TopKResult:
    """Run ``strategy`` to exactness (or to the ``max_steps`` halt budget).

    Returns a :class:`TopKResult` whose ``depth`` field is the number of
    *blocks* consumed; engines convert to their public depth unit
    (TA rounds, list depth = blocks * block_size, ...).
    """
    M = targets.shape[0]
    k = min(k, M)
    cap = strategy.num_steps if max_steps < 0 else min(max_steps,
                                                       strategy.num_steps)
    score = strategy.score or (lambda ids, active: targets[ids] @ u)

    def cond(s: ScanState):
        return jnp.logical_and(s.step < cap, s.lower < s.upper)

    def body(s: ScanState):
        # per-query liveness: under vmap the lockstep loop keeps running for
        # the slowest query; frozen lanes must not mutate state (else the
        # paper's score-count metric is inflated for fast queries).
        live = jnp.logical_and(s.step < cap, s.lower < s.upper)
        ids, active = strategy.candidates(s.step)
        if strategy.track_visited:
            # sentinel id M for inactive slots: never shadows an active
            # occurrence of the same item in the dedup pass
            ids_eff = jnp.where(active, ids, M)
            fresh = jnp.logical_and(
                _dedup_first_occurrence(ids_eff, M + 1),
                jnp.logical_and(active, ~s.visited[ids]))
            visited = s.visited.at[ids].max(active)
        else:
            fresh = active
            visited = s.visited
        scores = score(ids, active)
        masked = jnp.where(fresh, scores, NEG_INF)
        cand_vals = jnp.concatenate([s.top_vals, masked])
        cand_ids = jnp.concatenate([s.top_ids, ids])
        top_vals, pos = jax.lax.top_k(cand_vals, k)
        nxt = ScanState(
            step=s.step + 1,
            top_vals=top_vals,
            top_ids=cand_ids[pos],
            visited=visited,
            n_scored=s.n_scored + jnp.sum(fresh).astype(jnp.int32),
            lower=top_vals[k - 1],
            upper=strategy.bound(s.step),
        )
        return jax.tree_util.tree_map(
            lambda new, old: jnp.where(live, new, old), nxt, s)

    visited0 = jnp.zeros((M if strategy.track_visited else 1,), dtype=bool)
    init = ScanState(
        step=jnp.int32(0),
        top_vals=jnp.full((k,), NEG_INF, dtype=targets.dtype),
        top_ids=jnp.full((k,), -1, dtype=jnp.int32),
        visited=visited0,
        n_scored=jnp.int32(0),
        lower=jnp.asarray(NEG_INF, dtype=targets.dtype),
        upper=jnp.asarray(jnp.inf, dtype=targets.dtype),
    )
    final = jax.lax.while_loop(cond, body, init)
    return TopKResult(final.top_vals, final.top_ids, final.n_scored,
                      final.step)
