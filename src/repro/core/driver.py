"""Unified pruned-block-scan driver (DESIGN.md §2).

Every exact engine in this repo — the paper's Threshold Algorithm, the
TPU-native Block Threshold Algorithm, and the norm-ordered Cauchy-Schwarz
scan — is the SAME state machine:

    while lower_bound < upper_bound and blocks remain:
        ids    <- enumerate the next block of candidates
        scores <- score the fresh candidates against the query
        top-K  <- merge
        bounds <- tighten (lower = running K-th best; upper = strategy bound)

:func:`pruned_block_scan` is that state machine, written once as a
``jax.lax.while_loop``, parameterised by a :class:`ScanStrategy` that
answers three questions — *which* candidates a block holds
(``candidates``), *how* to score them (``score``, defaulting to the dense
gather + matvec every current engine uses), and what *upper bound* holds
for every item not yet enumerated after the block (``bound``).

Three properties the copy-pasted per-engine loops did not have:

* **Uniform halting** — ``max_steps`` caps any strategy, so the paper's
  halted TA (§4.3) is a driver argument, not a per-engine reimplementation.
* **Faithful batched statistics** — every state update is gated on the
  per-query ``live`` predicate, so under ``jax.vmap`` a query that has
  already certified its top-K stops accumulating ``n_scored``/``depth``
  even though the lockstep loop keeps running for slower queries in the
  batch. Counts therefore match the sequential oracle exactly.
* **Cheap merging** (DESIGN.md §6) — the per-block merge is a block-local
  ``lax.top_k`` followed by an O(K)-output sorted merge of two
  descending-sorted lists (:func:`merge_topk_sorted`), never a
  ``lax.top_k`` over ``K + C`` lanes, and strategies that can answer
  freshness by cursor arithmetic (``fresh_mask``) drop the O(M) visited
  bitmap from the loop carry entirely — the carried state is O(K), so the
  per-step ``live`` select stops costing O(M).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.naive import TopKResult

Array = jnp.ndarray

NEG_INF = float("-inf")


def _dedup_first_occurrence(ids: Array, m: int) -> Array:
    """Boolean mask: True where ids[i] is the first occurrence of that id.

    Scatter-min of positions — O(|ids|) work, O(M) memory, jit-friendly.
    """
    n = ids.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    first_pos = jnp.full((m,), n, dtype=jnp.int32).at[ids].min(pos)
    return first_pos[ids] == pos


def merge_topk_sorted(a_vals: Array, a_ids: Array,
                      b_vals: Array, b_ids: Array, k: int):
    """Top-``k`` of two DESCENDING-sorted (vals, ids) lists (DESIGN.md §6).

    Invariant both inputs must satisfy: sorted descending; ties rank the
    ``a`` side first, so the running top-K's ids win ties against fresh
    candidates (the same preference ``lax.top_k`` gives earlier operands).
    Two lowerings with identical semantics, picked at trace time:

    * off-CPU: a rank-arithmetic merge NETWORK — each element's merged
      rank is its own index plus a comparison-count against the other
      list (a dense ``[K, K]`` compare), and placement is a one-hot
      combine. O(K^2) VPU-friendly lanes, no ``lax.top_k``, no scatter —
      the shape TPUs want.
    * CPU: ``lax.top_k`` over the 2K-lane concatenation — XLA:CPU's
      ``top_k`` over 2K lanes is faster than scatter/one-hot placement at
      serving sizes, and for two sorted inputs it IS the O(K)-output
      sorted merge.

    Either way the driver never runs ``lax.top_k`` over ``K + C`` lanes:
    blocks are reduced block-locally first (:func:`_block_topk`), so the
    merge cost no longer scales with the block width.
    """
    ka = a_vals.shape[0]
    if jax.default_backend() == "cpu":
        cand_vals = jnp.concatenate([a_vals, b_vals])
        cand_ids = jnp.concatenate([a_ids, b_ids])
        top, pos = jax.lax.top_k(cand_vals, k)
        return top, cand_ids[pos]
    out_pos = jnp.arange(ka, dtype=jnp.int32)
    ra = out_pos + jnp.sum(b_vals[None, :] > a_vals[:, None], axis=1,
                           dtype=jnp.int32)
    rb = (jnp.arange(b_vals.shape[0], dtype=jnp.int32)
          + jnp.sum(a_vals[:, None] >= b_vals[None, :], axis=0,
                    dtype=jnp.int32))
    # one-hot placement via where (never multiply: values can be -inf, and
    # -inf * 0 would poison the sum with NaN). Merged ranks are distinct
    # and cover [0, ka+kb), so every output slot < k is filled exactly once.
    oh_a = ra[:, None] == out_pos[None, :]          # [ka, k] one-hot place
    oh_b = rb[:, None] == out_pos[None, :]
    zero = jnp.zeros((), a_vals.dtype)
    out_vals = (jnp.sum(jnp.where(oh_a, a_vals[:, None], zero), axis=0)
                + jnp.sum(jnp.where(oh_b, b_vals[:, None], zero), axis=0))
    out_ids = (jnp.sum(jnp.where(oh_a, a_ids[:, None], 0), axis=0)
               + jnp.sum(jnp.where(oh_b, b_ids[:, None], 0), axis=0))
    return out_vals[:k], out_ids[:k]


def _block_topk(masked_scores: Array, ids: Array, k: int):
    """Block-local top-k (sorted descending), padded to k slots."""
    c = masked_scores.shape[0]
    kk = min(k, c)
    vals, pos = jax.lax.top_k(masked_scores, kk)
    bids = ids[pos]
    if kk < k:
        vals = jnp.concatenate(
            [vals, jnp.full((k - kk,), NEG_INF, vals.dtype)])
        bids = jnp.concatenate(
            [bids, jnp.full((k - kk,), -1, bids.dtype)])
    return vals, bids


def _merge_block_into_carry(top_vals, top_ids, masked_scores, ids, k):
    """carry (sorted desc) + one block of masked scores -> new carry.

    Always two-stage: block-local ``top_k(C -> K)`` then the O(K) sorted
    merge. Never ``lax.top_k`` over the ``K + C`` concatenation — beyond
    the asymptotics, XLA:CPU's top_k degrades sharply once the lane count
    slips off the raw block width (measured ~6x on a C=8192 block: the
    K+C concatenation defeats the fast path the bare scores array hits).
    """
    bv, bi = _block_topk(masked_scores, ids, k)
    return merge_topk_sorted(top_vals, top_ids, bv, bi, k)


def merge_block_into_carry_batched(top_vals, top_ids, masked_scores,
                                   rows, k):
    """Batched :func:`_merge_block_into_carry`: a shared tile's scores.

    One block of ``[B, C]`` masked scores over an id vector ``rows`` that
    is either SHARED across the batch (``[C]`` — the lockstep batched
    scans where every query reads the same contiguous tile: the norm
    scan, the single-sign list prefix) or per-query (``[B, C]`` — the
    mixed-sign batched list scan, whose head/tail direction select gives
    each query its own candidate ids), merged into every query's
    ``[B, K]`` carry. Same two-stage invariant as the per-query helper:
    block-local ``top_k(C -> K)`` over the bare scores, pad to K lanes,
    then the O(K) sorted merge — never ``top_k`` over a ``K + C``
    concatenation.
    """
    B, c = masked_scores.shape
    kk = min(k, c)
    bv, bpos = jax.lax.top_k(masked_scores, kk)          # [B, kk]
    bi = rows[bpos] if rows.ndim == 1 \
        else jnp.take_along_axis(rows, bpos, axis=1)
    if kk < k:
        bv = jnp.concatenate(
            [bv, jnp.full((B, k - kk), NEG_INF, bv.dtype)], axis=1)
        bi = jnp.concatenate(
            [bi, jnp.full((B, k - kk), -1, bi.dtype)], axis=1)
    return jax.vmap(
        lambda tv, ti, v, i: merge_topk_sorted(tv, ti, v, i, k)
    )(top_vals, top_ids, bv, bi)


@dataclasses.dataclass(frozen=True)
class ScanStrategy:
    """What a pruned-scan engine must answer; everything else is the driver.

    Attributes:
      candidates: ``step -> (ids [C], active [C])`` — the candidate item ids
        enumerated by block ``step`` plus a mask of which slots are real
        (inactive lists, tail padding). ``C`` is static.
      bound: ``step -> scalar`` — an upper bound on the score of every item
        NOT yet enumerated once block ``step`` has been consumed. This is
        the exactness certificate: the scan may stop as soon as the running
        K-th best reaches it. When ``rounds_per_step > 1`` it returns a
        ``[rounds_per_step]`` vector — one Eq. 3 bound per sub-round.
      num_steps: static number of blocks needed to enumerate the whole
        catalogue (the exact engine's worst case).
      track_visited: list-based strategies enumerate the same item from
        several lists and need the driver's visited-set + dedup pass;
        partition-based strategies (norm blocks) never repeat an item and
        skip that O(M) state entirely. Ignored when ``fresh_mask`` is set.
      score: optional ``(step, ids, active) -> scores [C]`` override;
        ``None`` uses the dense gather + matvec ``targets[ids] @ u``.
        Strategies whose blocks are contiguous in some materialised layout
        use the ``step`` to slice instead of gather.
      fresh_mask: optional ``(step, ids, active) -> [C] bool`` answering
        "is this slot the FIRST enumeration of its item?" by cursor
        arithmetic (inverse-permutation positions) instead of the visited
        bitmap. Setting it removes the O(M) visited array from the loop
        carry — the per-step ``live`` select becomes O(K).
      rounds_per_step: >1 turns a step into ``rounds_per_step`` sequential
        paper rounds processed from one gather+matvec (chunked TA). The
        candidate layout must then be ``[R, rounds_per_step]`` flattened
        row-major (slot ``r * rounds_per_step + j`` holds list ``r``'s
        round-``j`` candidate), and ``fresh_mask`` is required so prefix
        masking can keep ``n_scored``/``depth`` count-faithful to the
        sequential algorithm.
      num_rounds: total sub-rounds in the exact scan (chunked mode only;
        e.g. M for TA).
      num_steps_dynamic: optional TRACED tighter step cap (DESIGN.md §10).
        Strategies over catalogue arrays padded to an M-bucket keep
        ``num_steps`` static at the padded worst case (the while_loop
        shape contract) and report the number of steps the REAL catalogue
        needs here, as a runtime scalar derived from the ``m_real``
        argument. The driver caps the loop at
        ``min(num_steps, num_steps_dynamic)``, so pad rows beyond the
        real catalogue are never enumerated and every counter stays
        sequential-faithful to the unpadded scan.
      num_rounds_dynamic: the same runtime cap in sub-rounds (chunked
        mode): typically ``m_real`` for TA. Caps the per-chunk
        ``cap_local`` masking, so a chunk straddling the real catalogue
        end scores and counts only real rounds.
    """

    candidates: Callable[[Array], Tuple[Array, Array]]
    bound: Callable[[Array], Array]
    num_steps: int
    track_visited: bool = True
    score: Optional[Callable[[Array, Array, Array], Array]] = None
    fresh_mask: Optional[Callable[[Array, Array, Array], Array]] = None
    rounds_per_step: int = 1
    num_rounds: Optional[int] = None
    num_steps_dynamic: Optional[Array] = None
    num_rounds_dynamic: Optional[Array] = None


class ScanState(NamedTuple):
    step: Array         # blocks consumed
    top_vals: Array     # [K] running top scores, descending
    top_ids: Array      # [K] their item ids
    visited: Array      # [M] bool ([1] dummy when the strategy never repeats)
    n_scored: Array     # score evaluations (the paper's cost metric)
    rounds: Array       # sub-rounds consumed (chunked strategies only)
    lower: Array        # running K-th best
    upper: Array        # strategy bound on every unseen item


def pruned_block_scan(
    targets: Array,
    u: Array,
    strategy: ScanStrategy,
    k: int,
    max_steps: int = -1,
    max_rounds: int = -1,
    init_state: Optional[ScanState] = None,
    return_state: bool = False,
):
    """Run ``strategy`` to exactness (or to the ``max_steps`` halt budget).

    Returns a :class:`TopKResult` whose ``depth`` field is the number of
    *blocks* consumed (engines convert to their public depth unit), except
    for chunked strategies (``rounds_per_step > 1``) where it is the exact
    number of sequential rounds processed — count-faithful to the
    item-at-a-time algorithm. ``max_rounds`` is the halted budget in
    rounds for chunked strategies (``max_steps`` still caps outer steps).

    **Phase chaining** (DESIGN.md §7): ``return_state=True`` additionally
    returns the final :class:`ScanState`; passing it as another scan's
    ``init_state`` resumes with the carried top-K, bounds, and counters
    intact. The step counter is ABSOLUTE across phases — the second
    strategy's ``candidates``/``bound`` must interpret ``step`` on the
    same global block axis, and ``num_steps``/``max_steps`` cap that
    global counter. A query already certified at the phase boundary
    (``lower >= upper``) never executes a body iteration of the second
    phase. Both phases must agree on the visited representation (the
    list-layout phases both use ``fresh_mask``, so the O(M) bitmap never
    appears).
    """
    M = targets.shape[0]
    k = min(k, M)
    chunk = strategy.rounds_per_step
    cap = strategy.num_steps if max_steps < 0 else min(max_steps,
                                                       strategy.num_steps)
    if chunk > 1:
        if strategy.fresh_mask is None:
            raise ValueError("chunked strategies require fresh_mask")
        total_rounds = (strategy.num_rounds if strategy.num_rounds is not None
                        else strategy.num_steps * chunk)
        round_cap = (total_rounds if max_rounds < 0
                     else min(max_rounds, total_rounds))
        cap = min(cap, -(-round_cap // chunk))
    else:
        round_cap = cap
    # Pad-aware halting (DESIGN.md §10): `cap`/`round_cap` above are STATIC
    # (the padded worst case — while_loop shapes must not depend on the
    # real catalogue size); strategies over M-bucket-padded arrays supply
    # the real catalogue's step/round budget as traced scalars, and the
    # loop condition uses the minimum. Pad rows therefore never execute a
    # step, and `n_scored`/`depth` match the unpadded sequential scan.
    cap_eff = cap
    round_cap_eff = round_cap
    if chunk > 1 and strategy.num_rounds_dynamic is not None:
        round_cap_eff = jnp.minimum(round_cap,
                                    strategy.num_rounds_dynamic)
        cap_eff = jnp.minimum(cap_eff,
                              (round_cap_eff + chunk - 1) // chunk)
    if strategy.num_steps_dynamic is not None:
        cap_eff = jnp.minimum(cap_eff, strategy.num_steps_dynamic)
    score = strategy.score or (lambda step, ids, active: targets[ids] @ u)
    use_visited = strategy.track_visited and strategy.fresh_mask is None

    def cond(s: ScanState):
        return jnp.logical_and(s.step < cap_eff, s.lower < s.upper)

    def chunked_body(s: ScanState, ids, active, fresh, scores):
        """rounds_per_step sequential paper rounds from one gather+matvec.

        The sequential semantics are recovered in closed form, not by an
        inner loop: the stopping test ``lower_j >= ub_j`` (the K-th best
        after merging rounds ``<= j`` reaching round j's Eq. 3 bound) is
        equivalent to "at least K candidates of rounds ``<= j`` (or the
        carry) score ``>= ub_j``" — a pure counting reduction over a
        ``[chunk, K + C]`` broadcast, no per-round sort. Candidates of
        rounds after the stop are masked out of the merge and the
        counters, so ``n_scored``/``depth`` equal the item-at-a-time
        algorithm's even though the whole chunk was gathered and scored in
        one MXU-shaped pass.
        """
        ubs = strategy.bound(s.step)              # [chunk] per-round bounds
        base_round = s.step * chunk
        # rounds allowed by the halted budget (and the real, unpadded
        # catalogue size), local to this chunk
        cap_local = jnp.clip(round_cap_eff - base_round, 0, chunk)
        tags = jnp.tile(jnp.arange(chunk, dtype=jnp.int32),
                        scores.shape[0] // chunk)   # slot -> round (r-major)
        eligible = jnp.logical_and(fresh, tags < cap_local)
        cand = jnp.where(eligible, scores, NEG_INF)
        # row j counts the carry (tag -1) + candidates of rounds <= j that
        # reach round j's bound; lower_j >= ub_j  <=>  count >= k
        all_vals = jnp.concatenate([s.top_vals, cand])
        all_tags = jnp.concatenate(
            [jnp.full((k,), -1, jnp.int32), tags])
        js = jnp.arange(chunk, dtype=jnp.int32)[:, None]
        reach = jnp.logical_and(all_tags[None, :] <= js,
                                all_vals[None, :] >= ubs[:, None])
        stop = jnp.logical_and(jnp.sum(reach, axis=1) >= k,
                               js[:, 0] < cap_local)
        j_stop = jnp.argmax(stop)                   # first True (or 0)
        processed = jnp.where(jnp.any(stop), j_stop + 1, cap_local)
        done = jnp.logical_and(fresh, tags < processed)
        masked = jnp.where(done, scores, NEG_INF)
        top_vals, top_ids = _merge_block_into_carry(
            s.top_vals, s.top_ids, masked, ids, k)
        upper = jnp.where(processed > 0, ubs[jnp.maximum(processed - 1, 0)],
                          s.upper)
        return ScanState(
            step=s.step + 1, top_vals=top_vals, top_ids=top_ids,
            visited=s.visited,
            n_scored=s.n_scored + jnp.sum(done).astype(jnp.int32),
            rounds=s.rounds + processed.astype(jnp.int32),
            lower=top_vals[k - 1], upper=upper)

    def body(s: ScanState):
        # per-query liveness: under vmap the lockstep loop keeps running for
        # the slowest query; frozen lanes must not mutate state (else the
        # paper's score-count metric is inflated for fast queries).
        live = jnp.logical_and(s.step < cap_eff, s.lower < s.upper)
        ids, active = strategy.candidates(s.step)
        if strategy.fresh_mask is not None:
            fresh = strategy.fresh_mask(s.step, ids, active)
            visited = s.visited
        elif use_visited:
            # sentinel id M for inactive slots: never shadows an active
            # occurrence of the same item in the dedup pass
            ids_eff = jnp.where(active, ids, M)
            fresh = jnp.logical_and(
                _dedup_first_occurrence(ids_eff, M + 1),
                jnp.logical_and(active, ~s.visited[ids]))
            visited = s.visited.at[ids].max(active)
        else:
            fresh = active
            visited = s.visited
        scores = score(s.step, ids, active)
        if chunk > 1:
            nxt = chunked_body(s, ids, active, fresh, scores)
            nxt = nxt._replace(visited=visited)
        else:
            masked = jnp.where(fresh, scores, NEG_INF)
            top_vals, top_ids = _merge_block_into_carry(
                s.top_vals, s.top_ids, masked, ids, k)
            nxt = ScanState(
                step=s.step + 1,
                top_vals=top_vals,
                top_ids=top_ids,
                visited=visited,
                n_scored=s.n_scored + jnp.sum(fresh).astype(jnp.int32),
                rounds=s.rounds,      # identity: depth is step-counted here
                lower=top_vals[k - 1],
                upper=strategy.bound(s.step),
            )
        # identity leaves (dummy visited, rounds outside chunked mode)
        # skip their select entirely — fewer ops per loop iteration
        return jax.tree_util.tree_map(
            lambda new, old: old if new is old else jnp.where(live, new, old),
            nxt, s)

    if init_state is not None:
        init = init_state
    else:
        visited0 = jnp.zeros((M if use_visited else 1,), dtype=bool)
        init = ScanState(
            step=jnp.int32(0),
            top_vals=jnp.full((k,), NEG_INF, dtype=targets.dtype),
            top_ids=jnp.full((k,), -1, dtype=jnp.int32),
            visited=visited0,
            n_scored=jnp.int32(0),
            rounds=jnp.int32(0),
            lower=jnp.asarray(NEG_INF, dtype=targets.dtype),
            upper=jnp.asarray(jnp.inf, dtype=targets.dtype),
        )
        if cap >= 1:
            # the first block is unconditionally live (lower = -inf < upper
            # = +inf), so unroll it: XLA folds the literal init state into
            # the block-0 computation and the loop runs one iteration
            # fewer. (Chained phases skip this: their first block is NOT
            # unconditionally live — the prior phase may have certified.)
            init = body(init)
    final = jax.lax.while_loop(cond, body, init)
    depth = final.rounds if chunk > 1 else final.step
    # Certificate tightening: when the scan consumed every REAL block
    # (not a budget halt — the full, pad-aware step/round count), no item
    # is left un-enumerated and the vacuous bound -inf replaces the last
    # block bound, which only speaks for items BEYOND the blocks scanned.
    # Exact-but-unpruned scans (tiny M, k ~ M) then certify fully.
    if chunk > 1:
        full_rounds = (strategy.num_rounds_dynamic
                       if strategy.num_rounds_dynamic is not None
                       else total_rounds)
        exhausted = final.rounds >= full_rounds
    else:
        full_steps = (strategy.num_steps_dynamic
                      if strategy.num_steps_dynamic is not None
                      else strategy.num_steps)
        exhausted = final.step >= full_steps
    upper = jnp.where(exhausted,
                      jnp.asarray(NEG_INF, dtype=final.upper.dtype),
                      final.upper)
    res = TopKResult(final.top_vals, final.top_ids, final.n_scored, depth,
                     upper=upper)
    return (res, final) if return_state else res


@dataclasses.dataclass(frozen=True)
class BatchedScanStrategy:
    """A batch-NATIVE strategy: one shared enumeration for the whole batch.

    Where :class:`ScanStrategy` under ``jax.vmap`` replicates every slice,
    matvec, and bound lookup per query, a batched strategy answers each
    step ONCE for the batch — the tile slice and the score matmul are
    shared, and only the quantities that genuinely vary per query
    (scores, freshness, bounds) carry a leading ``B`` axis.

    Attributes:
      block: ``step -> (ids, scores, fresh)`` where ``ids`` is ``[C]``
        (shared candidate row — every query reads the same tile) or
        ``[B, C]`` (per-query ids, e.g. the mixed-sign list scan whose
        head/tail select differs per query), ``scores`` is ``[B, C]``,
        and ``fresh`` is ``[B, C]`` bool — True where the slot is the
        FIRST enumeration of its item for that query AND the slot is
        active. Inactive/pad slots must be False.
      bound: ``step -> [B]`` upper bound per query on every item not yet
        enumerated after the block (``[B, rounds_per_step]`` per-round
        Eq. 3 bounds in chunked mode).
      num_steps / rounds_per_step / num_rounds / num_steps_dynamic /
      num_rounds_dynamic: as in :class:`ScanStrategy` (the dynamic caps
        are shared scalars — the enumeration axis is query-independent).
    """

    block: Callable[[Array], Tuple[Array, Array, Array]]
    bound: Callable[[Array], Array]
    num_steps: int
    rounds_per_step: int = 1
    num_rounds: Optional[int] = None
    num_steps_dynamic: Optional[Array] = None
    num_rounds_dynamic: Optional[Array] = None


class BatchedScanState(NamedTuple):
    step: Array         # scalar: blocks consumed by the batch-level loop
    steps: Array        # [B] blocks each query consumed while live
    top_vals: Array     # [B, K] running top scores, descending
    top_ids: Array      # [B, K] their item ids
    n_scored: Array     # [B] per-query score evaluations
    rounds: Array       # [B] per-query sub-rounds (chunked mode)
    lower: Array        # [B] running K-th best
    upper: Array        # [B] bound on every unseen item


def batched_pruned_scan(
    U: Array,
    strategy: BatchedScanStrategy,
    k: int,
    dtype,
    max_steps: int = -1,
    max_rounds: int = -1,
    return_state: bool = False,
):
    """The batch-level pruned scan: ONE ``while_loop`` for the whole batch.

    Replaces ``vmap(pruned_block_scan)`` for strategies that can share
    their enumeration across queries (the list prefix, the norm order):
    the loop runs until every query has certified (``cond`` is an
    ``any``), so its step count is the MAX live query's depth, and every
    per-query state update is gated on that query's own ``live``
    predicate — a lane whose ``lower >= upper`` is frozen, exactly as a
    certified query under the vmapped driver stops accumulating. Counts
    (``n_scored``, per-query ``steps``/``rounds``) therefore equal the
    sequential per-query oracle's even though slower queries keep the
    shared loop running (DESIGN.md §11).

    ``depth`` in the returned :class:`~repro.core.naive.TopKResult` is
    per-query blocks consumed (``rounds`` in chunked mode), matching
    ``vmap(pruned_block_scan)`` field-for-field. ``return_state=True``
    additionally returns the final :class:`BatchedScanState`; its
    per-lane ``steps`` is the ABSOLUTE per-query block cursor a chained
    per-query tail phase resumes from (DESIGN.md §7).
    """
    B = U.shape[0]
    chunk = strategy.rounds_per_step
    cap = strategy.num_steps if max_steps < 0 else min(max_steps,
                                                       strategy.num_steps)
    if chunk > 1:
        total_rounds = (strategy.num_rounds if strategy.num_rounds is not None
                        else strategy.num_steps * chunk)
        round_cap = (total_rounds if max_rounds < 0
                     else min(max_rounds, total_rounds))
        cap = min(cap, -(-round_cap // chunk))
    else:
        round_cap = cap
    cap_eff = cap
    round_cap_eff = round_cap
    if chunk > 1 and strategy.num_rounds_dynamic is not None:
        round_cap_eff = jnp.minimum(round_cap, strategy.num_rounds_dynamic)
        cap_eff = jnp.minimum(cap_eff, (round_cap_eff + chunk - 1) // chunk)
    if strategy.num_steps_dynamic is not None:
        cap_eff = jnp.minimum(cap_eff, strategy.num_steps_dynamic)

    def cond(s: BatchedScanState):
        return jnp.logical_and(s.step < cap_eff,
                               jnp.any(s.lower < s.upper))

    def body(s: BatchedScanState):
        live = s.lower < s.upper                              # [B]
        ids, scores, fresh = strategy.block(s.step)
        C = scores.shape[1]
        if chunk > 1:
            # the closed-form sequential-round recovery of `chunked_body`,
            # vectorised over the batch: each lane stops at ITS sequential
            # round, candidates past it are masked from merge and counts
            ubs = strategy.bound(s.step)                      # [B, chunk]
            base_round = s.step * chunk
            cap_local = jnp.clip(round_cap_eff - base_round, 0, chunk)
            tags = jnp.tile(jnp.arange(chunk, dtype=jnp.int32), C // chunk)
            eligible = jnp.logical_and(fresh, tags[None, :] < cap_local)
            cand = jnp.where(eligible, scores, NEG_INF)
            all_vals = jnp.concatenate([s.top_vals, cand], axis=1)
            all_tags = jnp.concatenate(
                [jnp.full((k,), -1, jnp.int32), tags])        # [k + C]
            js = jnp.arange(chunk, dtype=jnp.int32)
            reach = jnp.logical_and(
                all_tags[None, None, :] <= js[None, :, None],
                all_vals[:, None, :] >= ubs[:, :, None])      # [B, chunk, k+C]
            stop = jnp.logical_and(
                jnp.sum(reach, axis=2) >= k,
                js[None, :] < cap_local)                      # [B, chunk]
            j_stop = jnp.argmax(stop, axis=1)                 # [B]
            processed = jnp.where(jnp.any(stop, axis=1), j_stop + 1,
                                  cap_local)                  # [B]
            done = jnp.logical_and(fresh, tags[None, :] < processed[:, None])
            masked = jnp.where(done, scores, NEG_INF)
            new_vals, new_ids = merge_block_into_carry_batched(
                s.top_vals, s.top_ids, masked, ids, k)
            upper_new = jnp.where(
                processed > 0,
                jnp.take_along_axis(
                    ubs, jnp.maximum(processed - 1, 0)[:, None],
                    axis=1)[:, 0],
                s.upper)
            n_inc = jnp.sum(done, axis=1).astype(jnp.int32)
            r_inc = processed.astype(jnp.int32)
        else:
            masked = jnp.where(fresh, scores, NEG_INF)
            new_vals, new_ids = merge_block_into_carry_batched(
                s.top_vals, s.top_ids, masked, ids, k)
            upper_new = strategy.bound(s.step)                # [B]
            n_inc = jnp.sum(fresh, axis=1).astype(jnp.int32)
            r_inc = jnp.zeros((B,), jnp.int32)
        gate = live[:, None]
        return BatchedScanState(
            step=s.step + 1,
            steps=jnp.where(live, s.steps + 1, s.steps),
            top_vals=jnp.where(gate, new_vals, s.top_vals),
            top_ids=jnp.where(gate, new_ids, s.top_ids),
            n_scored=jnp.where(live, s.n_scored + n_inc, s.n_scored),
            rounds=jnp.where(live, s.rounds + r_inc, s.rounds),
            lower=jnp.where(live, new_vals[:, k - 1], s.lower),
            upper=jnp.where(live, upper_new, s.upper),
        )

    init = BatchedScanState(
        step=jnp.int32(0),
        steps=jnp.zeros((B,), jnp.int32),
        top_vals=jnp.full((B, k), NEG_INF, dtype=dtype),
        top_ids=jnp.full((B, k), -1, dtype=jnp.int32),
        n_scored=jnp.zeros((B,), jnp.int32),
        rounds=jnp.zeros((B,), jnp.int32),
        lower=jnp.full((B,), NEG_INF, dtype=dtype),
        upper=jnp.full((B,), jnp.inf, dtype=dtype),
    )
    if cap >= 1:
        # first block is unconditionally live for every lane — unroll it
        # (same literal-folding win as the per-query driver)
        init = body(init)
    final = jax.lax.while_loop(cond, body, init)
    depth = final.rounds if chunk > 1 else final.steps
    # Same certificate tightening as the per-query driver: a lane whose
    # scan consumed every REAL block/round has nothing un-enumerated —
    # its upper drops to the vacuous -inf (a budget halt keeps the live
    # block bound; per-lane because frozen lanes stop at their own depth)
    if chunk > 1:
        full_rounds = (strategy.num_rounds_dynamic
                       if strategy.num_rounds_dynamic is not None
                       else total_rounds)
        exhausted = final.rounds >= full_rounds
    else:
        full_steps = (strategy.num_steps_dynamic
                      if strategy.num_steps_dynamic is not None
                      else strategy.num_steps)
        exhausted = final.steps >= full_steps
    upper = jnp.where(exhausted,
                      jnp.asarray(NEG_INF, dtype=final.upper.dtype),
                      final.upper)
    res = TopKResult(final.top_vals, final.top_ids, final.n_scored, depth,
                     upper=upper)
    return (res, final) if return_state else res
