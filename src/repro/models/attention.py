"""Attention: RoPE + GQA/MQA, memory-bounded blocked softmax, decode path.

Training/prefill uses a flash-attention-style online-softmax scan over KV
blocks so the [S, S] score matrix is never materialised (the pure-JAX
analogue of the IO-aware kernel; the Pallas decode kernel lives in
``repro.kernels``). Decode attends one query token against a long KV cache —
linear in context length, which is why the long_500k cells run as decode
(DESIGN.md §3: attention itself is not separable; only bilinear
retrieval heads are SEP-LR catalogues).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [..., S, D/2]
    sin = jnp.sin(angles)[..., None, :]                          # [..., S, 1, D/2]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention for training / prefill
# ---------------------------------------------------------------------------


def _expand_kv(k: Array, n_q_heads: int) -> Array:
    """GQA: repeat KV heads to match query heads. k: [B, S, Hkv, D]."""
    n_kv = k.shape[2]
    if n_kv == n_q_heads:
        return k
    return jnp.repeat(k, n_q_heads // n_kv, axis=2)


def blocked_attention(
    q: Array,
    k: Array,
    v: Array,
    causal: bool = True,
    kv_block: int = 512,
    q_positions: Optional[Array] = None,
    kv_positions: Optional[Array] = None,
    scale: Optional[float] = None,
    unroll: bool = False,
) -> Array:
    """Online-softmax attention. q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D].

    Scans over KV blocks carrying (acc, running max, running sum); peak
    intermediate is [B, H, Sq, kv_block] instead of [B, H, Sq, Skv].
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)

    n_blocks = -(-Skv // kv_block)
    pad = n_blocks * kv_block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)

    qt = (q * scale).transpose(0, 2, 1, 3)                    # [B, H, Sq, D]
    kt = k.transpose(0, 2, 1, 3).reshape(B, H, n_blocks, kv_block, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B, H, n_blocks, kv_block, D)
    kv_pos_blocks = kv_positions.reshape(n_blocks, kv_block)

    def step(carry, blk):
        acc, m, l = carry
        kb, vb, posb = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", qt, kb)             # [B,H,Sq,blk]
        mask = posb[None, None, None, :] >= 0
        if causal:
            mask = jnp.logical_and(
                mask, posb[None, None, None, :] <= q_positions[None, None, :, None])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vb.dtype), vb)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (acc_new, m_new, l_new), None

    # fp32 accumulator (flash-attention numerics)
    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    blocks = (kt.transpose(2, 0, 1, 3, 4), vt.transpose(2, 0, 1, 3, 4),
              kv_pos_blocks)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), blocks,
                                  unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)          # [B, Sq, H, D]


# ---------------------------------------------------------------------------
# Decode attention (one new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    cache_len: Optional[Array] = None,
    scale: Optional[float] = None,
    seq_shard: Optional[object] = None,
) -> Array:
    """q: [B, 1, H, D]; caches: [B, S, Hkv, D]. Linear in S.

    Flash-decoding layout (§Perf-B): GQA is computed as a GROUPED einsum
    (q reshaped [B, Hkv, G, D]) so the KV heads are never repeated, and
    the score tensor is explicitly constrained to stay sequence-sharded —
    without the constraint GSPMD chose to all-gather the whole KV cache
    (2 x 2.1 GB f32 PER LAYER on deepseek long_500k). The softmax max/sum
    over the sharded seq axis lower to tiny [B, Hkv, G] psums.

    ``seq_shard``: optional callable mapping the score tensor to its
    sharding-constrained version (models.common.shard partial).
    """
    B, _, H, D = q.shape
    S = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    q5 = (q * scale).reshape(B, Hkv, G, D)
    # grouped scores, f32 accumulation without materialising f32 inputs
    s = jnp.einsum("bkgd,bskd->bkgs", q5, k_cache,
                   preferred_element_type=jnp.float32)
    if seq_shard is not None:
        s = seq_shard(s)
    if cache_len is not None:
        mask = jnp.arange(S)[None, None, None, :] < cache_len[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)            # psum-max over shards
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / l[..., 0][..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)
