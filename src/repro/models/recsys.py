"""Recsys architectures: FM, DeepFM, DCN-v2, DLRM (assigned archs).

All four share the sparse-embedding substrate (``repro.models.embedding``)
and a common batch layout:

  batch = {"dense": [B, n_dense] float, "sparse": [B, n_sparse] int32,
           "label": [B] float}

The FM interaction uses Rendle's O(nk) sum-square identity
  sum_{i<j} <v_i, v_j> x_i x_j = 1/2 * sum_k [(sum_i v_ik x_i)^2 - sum_i v_ik^2 x_i^2]
(kernels/fm_interaction.py holds the fused Pallas version).

Retrieval (`retrieval_cand` cells) goes through the SEP-LR top-K core:
the query tower output is u(x), the candidate item table is T — exactly
the paper's model class (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshRules, dense_init, mlp_apply, mlp_params, shard
from repro.models.embedding import embedding_lookup

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    arch: str                      # fm | deepfm | dcn_v2 | dlrm
    n_dense: int
    n_sparse: int
    embed_dim: int
    vocab_per_field: int
    mlp_dims: Tuple[int, ...] = ()           # deep tower (deepfm / dcn)
    bot_mlp: Tuple[int, ...] = ()            # dlrm bottom
    top_mlp: Tuple[int, ...] = ()            # dlrm top
    n_cross_layers: int = 0                  # dcn_v2
    compute_dtype: object = jnp.float32

    @property
    def total_vocab(self) -> int:
        return self.n_sparse * self.vocab_per_field

    @property
    def interaction_input(self) -> int:
        if self.arch == "dcn_v2":
            return self.n_dense + self.n_sparse * self.embed_dim
        if self.arch == "dlrm":
            n = self.n_sparse + 1
            return self.bot_mlp[-1] + n * (n - 1) // 2
        return 0

    def param_count(self) -> int:
        import numpy as np
        c = self.total_vocab * self.embed_dim
        if self.arch in ("fm", "deepfm"):
            c += self.total_vocab + 1          # linear weights + bias
        if self.arch == "deepfm":
            dims = (self.n_sparse * self.embed_dim,) + self.mlp_dims + (1,)
            c += sum(dims[i] * dims[i+1] + dims[i+1] for i in range(len(dims)-1))
        if self.arch == "dcn_v2":
            d0 = self.interaction_input
            c += self.n_cross_layers * (d0 * d0 + d0)
            dims = (d0,) + self.mlp_dims + (1,)
            c += sum(dims[i] * dims[i+1] + dims[i+1] for i in range(len(dims)-1))
        if self.arch == "dlrm":
            dims = (self.n_dense,) + self.bot_mlp
            c += sum(dims[i] * dims[i+1] + dims[i+1] for i in range(len(dims)-1))
            dims = (self.interaction_input,) + self.top_mlp
            c += sum(dims[i] * dims[i+1] + dims[i+1] for i in range(len(dims)-1))
        return c


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(config: RecsysConfig, key) -> Dict:
    keys = jax.random.split(key, 8)
    scale = 1.0 / jnp.sqrt(jnp.float32(config.embed_dim))
    params: Dict = {
        # one logical table: field f owns rows [f*V, (f+1)*V) — keeps a single
        # shardable array instead of n_sparse small ones.
        "embed": jax.random.normal(keys[0], (config.total_vocab, config.embed_dim),
                                   jnp.float32) * scale,
    }
    if config.arch in ("fm", "deepfm"):
        params["linear"] = jax.random.normal(keys[1], (config.total_vocab,),
                                             jnp.float32) * 0.01
        params["bias"] = jnp.zeros((), jnp.float32)
    if config.arch == "deepfm":
        dims = (config.n_sparse * config.embed_dim,) + config.mlp_dims + (1,)
        params["deep"] = mlp_params(keys[2], dims)
    if config.arch == "dcn_v2":
        d0 = config.interaction_input
        params["cross_w"] = dense_init(keys[3], (config.n_cross_layers, d0, d0))
        params["cross_b"] = jnp.zeros((config.n_cross_layers, d0), jnp.float32)
        dims = (d0,) + config.mlp_dims + (1,)
        params["deep"] = mlp_params(keys[4], dims)
    if config.arch == "dlrm":
        params["bot"] = mlp_params(keys[5], (config.n_dense,) + config.bot_mlp)
        params["top"] = mlp_params(keys[6], (config.interaction_input,) + config.top_mlp)
    return params


def param_specs(config: RecsysConfig, rules: MeshRules,
                mode: str = "train") -> Dict:
    """Embedding rows over tp (DLRM row-parallel); MLPs replicated (tiny)."""
    tp = rules.tp
    specs: Dict = {"embed": P(tp, None)}
    if config.arch in ("fm", "deepfm"):
        specs["linear"] = P(tp)
        specs["bias"] = P()
    if config.arch == "deepfm":
        specs["deep"] = [{"w": P(None, None), "b": P(None)}
                         for _ in range(len(config.mlp_dims) + 1)]
    if config.arch == "dcn_v2":
        specs["cross_w"] = P(None, None, None)
        specs["cross_b"] = P(None, None)
        specs["deep"] = [{"w": P(None, None), "b": P(None)}
                         for _ in range(len(config.mlp_dims) + 1)]
    if config.arch == "dlrm":
        specs["bot"] = [{"w": P(None, None), "b": P(None)}
                        for _ in range(len(config.bot_mlp))]
        specs["top"] = [{"w": P(None, None), "b": P(None)}
                        for _ in range(len(config.top_mlp))]
    return specs


def _field_offsets(config: RecsysConfig) -> Array:
    return (jnp.arange(config.n_sparse, dtype=jnp.int32)
            * config.vocab_per_field)


def _gather_fields(params: Dict, sparse: Array, config: RecsysConfig,
                   rules: MeshRules) -> Array:
    """sparse: [B, F] per-field ids -> [B, F, d] embeddings."""
    ids = sparse + _field_offsets(config)[None, :]
    emb = embedding_lookup(params["embed"], ids)
    return shard(emb, rules, "dp", None, None)


# ---------------------------------------------------------------------------
# Interactions
# ---------------------------------------------------------------------------


def fm_interaction(emb: Array) -> Array:
    """Rendle sum-square trick. emb: [B, F, d] -> [B] second-order term."""
    s = jnp.sum(emb, axis=1)                 # [B, d]
    sq = jnp.sum(emb * emb, axis=1)          # [B, d]
    return 0.5 * jnp.sum(s * s - sq, axis=-1)


def dot_interaction(vectors: Array) -> Array:
    """DLRM pairwise dots. vectors: [B, n, d] -> [B, n(n-1)/2]."""
    B, n, d = vectors.shape
    gram = jnp.einsum("bnd,bmd->bnm", vectors, vectors)
    iu, ju = jnp.triu_indices(n, k=1)
    return gram[:, iu, ju]


def cross_layer(x0: Array, x: Array, w: Array, b: Array) -> Array:
    """DCN-v2 full-matrix cross: x' = x0 * (W x + b) + x."""
    return x0 * (x @ w + b) + x


# ---------------------------------------------------------------------------
# Forward / loss per architecture
# ---------------------------------------------------------------------------


def forward(params: Dict, batch: Dict, config: RecsysConfig,
            rules: MeshRules = MeshRules()) -> Array:
    """Returns logits [B]."""
    emb = _gather_fields(params, batch["sparse"], config, rules)   # [B, F, d]
    B = emb.shape[0]
    if config.arch == "fm":
        lin_ids = batch["sparse"] + _field_offsets(config)[None, :]
        first = jnp.sum(jnp.take(params["linear"], lin_ids), axis=1)
        return params["bias"] + first + fm_interaction(emb)
    if config.arch == "deepfm":
        lin_ids = batch["sparse"] + _field_offsets(config)[None, :]
        first = jnp.sum(jnp.take(params["linear"], lin_ids), axis=1)
        fm = params["bias"] + first + fm_interaction(emb)
        deep = mlp_apply(params["deep"], emb.reshape(B, -1))[:, 0]
        return fm + deep
    if config.arch == "dcn_v2":
        x0 = jnp.concatenate([batch["dense"], emb.reshape(B, -1)], axis=-1)
        x0 = shard(x0, rules, "dp", None)
        x = x0
        for l in range(config.n_cross_layers):
            x = cross_layer(x0, x, params["cross_w"][l], params["cross_b"][l])
        return mlp_apply(params["deep"], x)[:, 0]
    if config.arch == "dlrm":
        bot = mlp_apply(params["bot"], batch["dense"], final_act=True)  # [B, d]
        vectors = jnp.concatenate([bot[:, None, :], emb], axis=1)       # [B, 27, d]
        inter = dot_interaction(vectors)
        z = jnp.concatenate([bot, inter], axis=-1)
        return mlp_apply(params["top"], z)[:, 0]
    raise ValueError(config.arch)


def loss_fn(params: Dict, batch: Dict, config: RecsysConfig,
            rules: MeshRules = MeshRules()) -> Tuple[Array, Dict]:
    logits = forward(params, batch, config, rules)
    y = batch["label"].astype(jnp.float32)
    # numerically-stable BCE-with-logits
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean(((logits > 0) == (y > 0.5)).astype(jnp.float32))
    return loss, {"bce": loss, "acc": acc}


# ---------------------------------------------------------------------------
# Retrieval head (the paper's technique in-system)
# ---------------------------------------------------------------------------


def query_tower(params: Dict, batch: Dict, config: RecsysConfig,
                rules: MeshRules = MeshRules()) -> Array:
    """User/query embedding u(x) for SEP-LR retrieval. [B, d]."""
    emb = _gather_fields(params, batch["sparse"], config, rules)
    if config.arch == "dlrm" and config.n_dense:
        bot = mlp_apply(params["bot"], batch["dense"], final_act=True)
        return bot + jnp.mean(emb, axis=1)
    return jnp.mean(emb, axis=1)


def retrieval_scores(params: Dict, batch: Dict, candidates: Array,
                     config: RecsysConfig,
                     rules: MeshRules = MeshRules()) -> Array:
    """Naive scoring of all candidates: [B, n_candidates]. The exact
    top-K path goes through repro.core / repro.serving instead."""
    u = query_tower(params, batch, config, rules)
    return jnp.einsum("bd,md->bm", u, candidates)
