"""Mixture-of-Experts FFN with sort-based token dispatch (EP-shardable).

Dispatch uses the argsort formulation (MegaBlocks-style, DESIGN.md §5):
flatten (token, expert) assignments, sort by expert, compute each
assignment's position within its expert group, scatter into a fixed
[E, capacity, D] buffer, run one batched expert GEMM, and combine with
gate-weighted segment-sum. Everything is static-shaped: tokens beyond an
expert's capacity are dropped (classic Switch behaviour) and counted in
aux stats. Sharding: tokens over "dp", experts over "tp" — the scatter
between those two layouts is the MoE all-to-all.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (ACTIVATIONS, MeshRules,
                                 current_abstract_mesh, dense_init, shard)

Array = jnp.ndarray


class MoEParams(NamedTuple):
    router: Array   # [D, E]
    w_gate: Array   # [E, D, F]
    w_up: Array     # [E, D, F]
    w_down: Array   # [E, F, D]


def init_moe(key, d_model: int, d_ff: int, n_experts: int) -> MoEParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return MoEParams(
        router=dense_init(k1, (d_model, n_experts)),
        w_gate=dense_init(k2, (n_experts, d_model, d_ff), in_axis=-2),
        w_up=dense_init(k3, (n_experts, d_model, d_ff), in_axis=-2),
        w_down=dense_init(k4, (n_experts, d_ff, d_model), in_axis=-2),
    )


def moe_ffn(
    params: MoEParams,
    x: Array,                     # [T, D] flattened tokens
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    rules: MeshRules = MeshRules(),
) -> Tuple[Array, dict]:
    """Returns (output [T, D], aux dict with load-balance loss + drop rate)."""
    T, D = x.shape
    E = params.router.shape[1]
    fn = ACTIVATIONS[act]
    capacity = max(int(T * top_k * capacity_factor / E), 1)
    # round capacity to a lane-friendly multiple
    capacity = -(-capacity // 8) * 8

    logits = x.astype(jnp.float32) @ params.router                # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)           # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- aux load-balance loss (Switch eq. 4) -----------------------------
    me = jnp.mean(probs, axis=0)                                  # [E]
    one_hot = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux_loss = E * jnp.sum(me * ce)

    # --- sort-based dispatch ----------------------------------------------
    flat_e = expert_ids.reshape(-1)                               # [T*k]
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    flat_g = gate_vals.reshape(-1)
    sort_idx = jnp.argsort(flat_e, stable=True)
    se = flat_e[sort_idx]
    st = flat_t[sort_idx]
    sg = flat_g[sort_idx]
    group_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - group_start[se]
    keep = pos < capacity
    dst = jnp.where(keep, se * capacity + pos, E * capacity)      # drop slot

    buf = jnp.zeros((E * capacity + 1, D), x.dtype)
    buf = buf.at[dst].set(x[st] * keep[:, None].astype(x.dtype))
    buf = buf[: E * capacity].reshape(E, capacity, D)
    buf = shard(buf, rules, "tp", None, None)

    # --- batched expert GEMMs ----------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params.w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params.w_up.astype(buf.dtype))
    h = fn(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params.w_down.astype(buf.dtype))
    y = shard(y, rules, "tp", None, None)

    # --- combine ------------------------------------------------------------
    y_flat = y.reshape(E * capacity, D)
    contrib = jnp.where(keep[:, None], y_flat[jnp.minimum(dst, E * capacity - 1)],
                        0.0) * sg[:, None].astype(y_flat.dtype)
    out = jax.ops.segment_sum(contrib, st, num_segments=T)
    drop_rate = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out.astype(x.dtype), {"aux_loss": aux_loss, "drop_rate": drop_rate}


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map) — §Perf hillclimb A
# ---------------------------------------------------------------------------


def ep_available(n_experts: int, rules: MeshRules) -> bool:
    mesh = current_abstract_mesh()
    if mesh is None or mesh.empty or rules.tp not in mesh.axis_names:
        return False
    return n_experts % dict(mesh.shape)[rules.tp] == 0


def moe_ffn_ep(
    params: MoEParams,
    h: Array,                     # [B, S, D] residual-layout activations
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    rules: MeshRules = MeshRules(),
) -> Tuple[Array, dict]:
    """Expert-parallel MoE via shard_map (DESIGN/EXPERIMENTS §Perf-A).

    The pjit global dispatch (argsort over ALL tokens + scatter into a
    tp-sharded buffer) makes XLA reshard token payloads repeatedly —
    measured 2.0e15 collective bytes/step on olmoe train_4k. Here instead:

    * activations enter replicated over tp within each dp row
      (in_spec P(dp, -, -); one [T_loc, D] all-gather per layer),
    * every device routes its dp-row's tokens LOCALLY and builds the
      capacity buffer only for ITS E/tp experts (no token exchange),
    * local expert GEMMs,
    * combine = one bf16 psum over tp (each token's top-k experts live on
      disjoint shards).

    Capacity is per (dp-row, expert) — GShard-style local capacity.
    """
    mesh = current_abstract_mesh()
    tp = rules.tp
    sizes = dict(mesh.shape)
    tp_size = sizes[tp]
    dp = tuple(a for a in (rules.dp if isinstance(rules.dp, tuple)
                           else (rules.dp,)) if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    if h.shape[0] % max(dp_size, 1) != 0:
        dp = ()   # tiny decode batches: tokens replicated, experts still EP
    E = params.router.shape[1]
    E_local = E // tp_size
    fn = ACTIVATIONS[act]

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(None, None), P(tp, None, None), P(tp, None, None),
                  P(tp, None, None), P(dp if dp else None, None, None)),
        out_specs=(P(dp if dp else None, None, None), P(), P()),
        check_vma=False,
    )
    def _local(router, w_gate, w_up, w_down, h_l):
        Bl, S, D = h_l.shape
        T_l = Bl * S
        x = h_l.reshape(T_l, D)
        capacity = max(int(T_l * top_k * capacity_factor / E), 1)
        capacity = -(-capacity // 8) * 8

        # route in the compute dtype: upcasting x to f32 here makes XLA
        # hoist the convert BEFORE the boundary all-gather, doubling every
        # activation collective (§Perf-A iter 3). The [T_l, E] logits are
        # tiny — upcast those instead.
        logits = (x @ router.astype(x.dtype)).astype(jnp.float32)  # [T_l, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32),
                      axis=0)
        aux = E * jnp.sum(me * ce)

        e_first = jax.lax.axis_index(tp) * E_local
        flat_e = expert_ids.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T_l, dtype=jnp.int32), top_k)
        flat_g = gate_vals.reshape(-1)
        local_e = flat_e - e_first
        is_local = jnp.logical_and(local_e >= 0, local_e < E_local)
        le = jnp.where(is_local, local_e, E_local)            # dump bucket
        sort_idx = jnp.argsort(le, stable=True)
        se = le[sort_idx]
        st_tok = flat_t[sort_idx]
        sg = flat_g[sort_idx]
        group_start = jnp.searchsorted(se, jnp.arange(E_local, dtype=se.dtype))
        pos = jnp.arange(T_l * top_k, dtype=jnp.int32) - group_start[se]
        keep = jnp.logical_and(se < E_local, pos < capacity)
        dst = jnp.where(keep, se * capacity + pos, E_local * capacity)

        # §Perf-A iter 2: scatter token INDICES (4 bytes/slot) and gate
        # values into the capacity layout, then gather only the
        # E_local*capacity rows actually computed — never materialising
        # the [T_l*top_k, D] token payload the naive formulation reads.
        n_slots = E_local * capacity
        tok_buf = jnp.full((n_slots + 1,), T_l, jnp.int32).at[dst].set(st_tok)
        gate_buf = jnp.zeros((n_slots + 1,), jnp.float32).at[dst].set(
            sg * keep.astype(jnp.float32))
        tok_buf = tok_buf[:n_slots]
        gate_buf = gate_buf[:n_slots]
        valid = (tok_buf < T_l).astype(x.dtype)[:, None]
        buf = (x[jnp.minimum(tok_buf, T_l - 1)] * valid
               ).reshape(E_local, capacity, D)

        g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
        y = jnp.einsum("ecf,efd->ecd", fn(g) * u, w_down.astype(buf.dtype))

        y_flat = y.reshape(n_slots, D) * gate_buf[:, None].astype(x.dtype)
        partial = jax.ops.segment_sum(y_flat * valid, tok_buf,
                                      num_segments=T_l + 1)[:T_l]
        out = jax.lax.psum(partial.astype(h_l.dtype), tp)     # combine experts

        denom = 1.0
        for a in dp:
            aux = jax.lax.psum(aux, a)
            denom *= jax.lax.axis_size(a)
        # each tp shard keeps a disjoint subset of the T_l*top_k assignments
        kept = jax.lax.psum(jnp.mean(keep.astype(jnp.float32)), tp)
        drop = 1.0 - kept
        for a in dp:
            drop = jax.lax.psum(drop, a)
        return out.reshape(Bl, S, D), aux / denom, drop / denom

    out, aux_loss, drop_rate = _local(params.router, params.w_gate,
                                      params.w_up, params.w_down, h)
    return out, {"aux_loss": aux_loss, "drop_rate": drop_rate}
