"""Embedding tables for recsys: lookup + EmbeddingBag, row-shardable.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — per the brief this
layer IS part of the system: multi-hot bags are implemented as
``jnp.take`` + ``jax.ops.segment_sum`` (taxonomy §B.6 / §B.11). Tables are
row-sharded over the "tp" mesh axis (classic DLRM row-wise model
parallelism); the gather across shards lowers to the expected all-to-all
style collectives under pjit.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def embedding_lookup(table: Array, ids: Array) -> Array:
    """One-hot field lookup. table: [V, d]; ids: [...] -> [..., d]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: Array,
    flat_ids: Array,
    segment_ids: Array,
    num_segments: int,
    mode: str = "sum",
    weights: Optional[Array] = None,
) -> Array:
    """Multi-hot bag reduce: gather rows then segment-reduce per bag.

    Args:
      table: ``[V, d]``.
      flat_ids: ``[N]`` row indices (ragged bags flattened).
      segment_ids: ``[N]`` bag index per entry (sorted not required).
      num_segments: number of bags (static).
      mode: ``sum`` | ``mean`` | ``max``.
      weights: optional ``[N]`` per-entry weights (sum/mean only).
    """
    rows = jnp.take(table, flat_ids, axis=0)                   # [N, d]
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
        c = jax.ops.segment_sum(jnp.ones_like(flat_ids, rows.dtype),
                                segment_ids, num_segments=num_segments)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_segments)
    raise ValueError(f"unknown mode {mode}")


def hashed_lookup(table: Array, raw_ids: Array, num_hashes: int = 2) -> Array:
    """Hash-trick lookup for unbounded vocabularies (QR-style compromise):
    sum of ``num_hashes`` universal-hash probes into one physical table."""
    V = table.shape[0]
    out = 0
    for i in range(num_hashes):
        # Knuth multiplicative hashing with distinct odd constants
        h = (raw_ids.astype(jnp.uint32) * jnp.uint32(2654435761 + 2 * i + 1)) % V
        out = out + jnp.take(table, h.astype(jnp.int32), axis=0)
    return out / num_hashes
