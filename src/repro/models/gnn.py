"""PNA — Principal Neighbourhood Aggregation (assigned GNN arch).

Message passing via ``jax.ops.segment_sum`` / ``segment_max`` over an
edge-index scatter (JAX is BCOO-only; per the brief the segment-op
formulation IS the system). PNA combines 4 aggregators (mean, max, min,
std) x 3 degree scalers (identity, amplification, attenuation)
[arXiv:2004.05718].

Graph batch layout (static shapes, padded):
  nodes:    [N, F] float
  edge_src: [E] int32     (messages flow src -> dst)
  edge_dst: [E] int32
  edge_mask:[E] bool      (padding)
  node_mask:[N] bool
  labels:   [N] int32 (node classification) or [G] (graph tasks)
  graph_ids:[N] int32     (for batched small graphs / readout)

Sharding: edges over "dp" (the only axis with enough parallelism for
message passing), node states replicated per device — segment-sums over a
sharded edge axis lower to psum. The paper's top-K technique does not
apply to the message-passing forward (DESIGN.md §3: only the bilinear
retrieval head is a SEP-LR catalogue);
the optional link-prediction head ``link_scores`` is SEP-LR and routes
through repro.core.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import MeshRules, dense_init, shard

Array = jnp.ndarray

AGGREGATORS = ("mean", "max", "min", "std")
SCALERS = ("identity", "amplification", "attenuation")


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 1433
    n_classes: int = 7
    delta: float = 2.5          # mean log-degree of the training graphs
    task: str = "node"          # node | graph
    compute_dtype: object = jnp.float32

    def param_count(self) -> int:
        d = self.d_hidden
        c = self.d_in * d + d                      # encoder
        per_layer = (2 * d) * d + d                # message MLP
        per_layer += (len(AGGREGATORS) * len(SCALERS) * d) * d + d  # update
        c += self.n_layers * per_layer
        c += d * self.n_classes + self.n_classes   # decoder
        return c


def init_params(config: PNAConfig, key) -> Dict:
    keys = jax.random.split(key, 4)
    d = config.d_hidden
    L = config.n_layers
    n_cat = len(AGGREGATORS) * len(SCALERS) * d
    return {
        "enc_w": dense_init(keys[0], (config.d_in, d)),
        "enc_b": jnp.zeros((d,), jnp.float32),
        "layers": {
            "msg_w": dense_init(keys[1], (L, 2 * d, d)),
            "msg_b": jnp.zeros((L, d), jnp.float32),
            "upd_w": dense_init(keys[2], (L, n_cat, d)),
            "upd_b": jnp.zeros((L, d), jnp.float32),
        },
        "dec_w": dense_init(keys[3], (d, config.n_classes)),
        "dec_b": jnp.zeros((config.n_classes,), jnp.float32),
    }


def param_specs(config: PNAConfig, rules: MeshRules, mode: str = "train"):
    from jax.sharding import PartitionSpec as P
    rep2, rep1 = P(None, None), P(None)
    return {
        "enc_w": rep2, "enc_b": rep1,
        "layers": {"msg_w": P(None, None, None), "msg_b": rep2,
                   "upd_w": P(None, None, None), "upd_b": rep2},
        "dec_w": rep2, "dec_b": rep1,
    }


def _pna_aggregate(messages: Array, edge_dst: Array, edge_mask: Array,
                   num_nodes: int, degrees: Array, delta: float) -> Array:
    """messages: [E, d] -> [N, 12d] (4 aggregators x 3 scalers)."""
    w = edge_mask.astype(messages.dtype)[:, None]
    m = messages * w
    seg_sum = jax.ops.segment_sum(m, edge_dst, num_segments=num_nodes)
    count = jnp.maximum(degrees, 1.0)[:, None].astype(messages.dtype)
    mean = seg_sum / count
    big_neg = jnp.asarray(-1e30, messages.dtype)
    mx = jax.ops.segment_max(jnp.where(edge_mask[:, None], messages, big_neg),
                             edge_dst, num_segments=num_nodes)
    mx = jnp.where(mx <= big_neg / 2, 0.0, mx)
    mn = -jax.ops.segment_max(jnp.where(edge_mask[:, None], -messages, big_neg),
                              edge_dst, num_segments=num_nodes)
    mn = jnp.where(mn >= -big_neg / 2, 0.0, mn)
    sq = jax.ops.segment_sum(m * m, edge_dst, num_segments=num_nodes)
    var = jnp.maximum(sq / count - mean * mean, 0.0)
    std = jnp.sqrt(var + 1e-5)
    agg = jnp.concatenate([mean, mx, mn, std], axis=-1)          # [N, 4d]
    logd = jnp.log1p(degrees)[:, None].astype(messages.dtype)
    amp = logd / delta
    att = delta / jnp.maximum(logd, 1e-5)
    return jnp.concatenate([agg, agg * amp, agg * att], axis=-1)  # [N, 12d]


def forward(params: Dict, graph: Dict, config: PNAConfig,
            rules: MeshRules = MeshRules()) -> Array:
    """Returns node logits [N, n_classes] (or graph logits for task=graph)."""
    dt = config.compute_dtype
    h = graph["nodes"].astype(dt) @ params["enc_w"].astype(dt) + params["enc_b"].astype(dt)
    src = graph["edge_src"]
    dst = graph["edge_dst"]
    emask = graph["edge_mask"]
    N = h.shape[0]
    degrees = jax.ops.segment_sum(emask.astype(jnp.float32), dst,
                                  num_segments=N)

    def body(h, lp):
        hs = jnp.take(h, src, axis=0)
        hd = jnp.take(h, dst, axis=0)
        msg_in = jnp.concatenate([hs, hd], axis=-1)
        msg_in = shard(msg_in, rules, "dp", None)
        m = jax.nn.relu(msg_in @ lp["msg_w"].astype(dt) + lp["msg_b"].astype(dt))
        agg = _pna_aggregate(m, dst, emask, N, degrees, config.delta)
        upd = agg @ lp["upd_w"].astype(dt) + lp["upd_b"].astype(dt)
        return h + jax.nn.relu(upd), None        # residual

    # few layers -> always unroll so dry-run cost_analysis is exact
    h, _ = jax.lax.scan(body, h, params["layers"], unroll=True)
    if config.task == "graph":
        gids = graph["graph_ids"]
        G = int(graph["n_graphs"]) if "n_graphs" in graph else int(jnp.max(gids)) + 1
        pooled = jax.ops.segment_sum(
            h * graph["node_mask"][:, None].astype(dt), gids, num_segments=G)
        return pooled @ params["dec_w"].astype(dt) + params["dec_b"].astype(dt)
    return h @ params["dec_w"].astype(dt) + params["dec_b"].astype(dt)


def loss_fn(params: Dict, graph: Dict, config: PNAConfig,
            rules: MeshRules = MeshRules()) -> Tuple[Array, Dict]:
    logits = forward(params, graph, config, rules).astype(jnp.float32)
    labels = graph["labels"]
    if config.task == "graph":
        mask = jnp.ones(labels.shape, jnp.float32)
    else:
        mask = graph["node_mask"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    xent = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    pred = jnp.argmax(logits, axis=-1)
    acc = jnp.sum((pred == labels) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return xent, {"xent": xent, "acc": acc}


def link_scores(params: Dict, h: Array, query_nodes: Array) -> Array:
    """SEP-LR link-prediction head: u = h[q], T = h — exact top-K neighbour
    retrieval goes through repro.core (DESIGN.md §3)."""
    return jnp.take(h, query_nodes, axis=0) @ h.T


# ---------------------------------------------------------------------------
# Neighbour sampler (host-side, numpy) — minibatch_lg cells
# ---------------------------------------------------------------------------


class NeighborSampler:
    """Uniform fanout sampler over a CSR adjacency (GraphSAGE-style)."""

    def __init__(self, edge_src: np.ndarray, edge_dst: np.ndarray,
                 num_nodes: int, seed: int = 0):
        order = np.argsort(edge_dst, kind="stable")
        self.src_sorted = edge_src[order].astype(np.int32)
        self.indptr = np.zeros(num_nodes + 1, np.int64)
        counts = np.bincount(edge_dst, minlength=num_nodes)
        self.indptr[1:] = np.cumsum(counts)
        self.num_nodes = num_nodes
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray, fanouts=(15, 10)) -> Dict[str, np.ndarray]:
        """Returns a padded subgraph: layered sampling seeds<-hop1<-hop2."""
        nodes = [np.unique(seeds.astype(np.int32))]
        edges_src, edges_dst = [], []
        frontier = nodes[0]
        for f in fanouts:
            srcs, dsts = [], []
            for v in frontier:
                lo, hi = self.indptr[v], self.indptr[v + 1]
                nbrs = self.src_sorted[lo:hi]
                if len(nbrs) == 0:
                    continue
                take = nbrs if len(nbrs) <= f else self.rng.choice(nbrs, f, replace=False)
                srcs.append(take)
                dsts.append(np.full(len(take), v, np.int32))
            if srcs:
                srcs = np.concatenate(srcs)
                dsts = np.concatenate(dsts)
            else:
                srcs = np.zeros(0, np.int32)
                dsts = np.zeros(0, np.int32)
            edges_src.append(srcs)
            edges_dst.append(dsts)
            frontier = np.unique(srcs)
            nodes.append(frontier)
        all_nodes = np.unique(np.concatenate(nodes))
        remap = np.full(self.num_nodes, -1, np.int32)
        remap[all_nodes] = np.arange(len(all_nodes), dtype=np.int32)
        es = remap[np.concatenate(edges_src)] if edges_src else np.zeros(0, np.int32)
        ed = remap[np.concatenate(edges_dst)] if edges_dst else np.zeros(0, np.int32)
        return {
            "node_ids": all_nodes,
            "edge_src": es,
            "edge_dst": ed,
            "seed_local": remap[np.unique(seeds.astype(np.int32))],
        }


def pad_subgraph(sub: Dict[str, np.ndarray], feats: np.ndarray,
                 labels: np.ndarray, max_nodes: int, max_edges: int) -> Dict:
    """Pad a sampled subgraph to static shapes for jit."""
    n = min(len(sub["node_ids"]), max_nodes)
    e = min(len(sub["edge_src"]), max_edges)
    nodes = np.zeros((max_nodes, feats.shape[1]), feats.dtype)
    nodes[:n] = feats[sub["node_ids"][:n]]
    lab = np.zeros((max_nodes,), np.int32)
    lab[:n] = labels[sub["node_ids"][:n]]
    node_mask = np.zeros((max_nodes,), bool)
    # supervise only the seed nodes
    seeds = sub["seed_local"][sub["seed_local"] < n]
    node_mask[seeds] = True
    es = np.zeros((max_edges,), np.int32)
    ed = np.zeros((max_edges,), np.int32)
    emask = np.zeros((max_edges,), bool)
    keep = (sub["edge_src"][:e] < n) & (sub["edge_dst"][:e] < n)
    es[:e] = np.where(keep, sub["edge_src"][:e], 0)
    ed[:e] = np.where(keep, sub["edge_dst"][:e], 0)
    emask[:e] = keep
    return {"nodes": nodes, "labels": lab, "node_mask": node_mask,
            "edge_src": es, "edge_dst": ed, "edge_mask": emask}
