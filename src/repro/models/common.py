"""Shared model plumbing: sharding constraints, norms, init, activations.

Sharding is expressed through *logical axis names* resolved against the
ambient mesh. When no mesh is active (single-device tests) every constraint
is a no-op, so the same model code runs in smoke tests and in the 512-chip
dry-run unchanged.

Logical axes (DESIGN.md §5):
  "dp"     — batch / data parallel (mesh: ("pod", "data") when multi-pod)
  "tp"     — tensor parallel / expert parallel / vocab shard (mesh: "model")
  "fsdp"   — parameter FSDP shard (mesh: "data")
  "sp"     — sequence parallel for the residual stream (mesh: "model")
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jnp.ndarray
PyTree = Any


def current_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` across jax versions.

    Public on newer jax. On 0.4.x the internal getter returns the raw
    context-manager stack (a tuple; ``()`` when no mesh is active), so
    anything without mesh attributes is normalised to None — callers
    already treat None like an empty mesh.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is None:
        from jax._src import mesh as _mesh
        getter = getattr(_mesh, "get_abstract_mesh", None)
    if getter is None:
        return None
    try:
        mesh = getter()
    except Exception:
        return None
    return mesh if hasattr(mesh, "axis_names") else None


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Maps logical axis names to mesh axis names (or None = replicate)."""

    dp: Union[str, Tuple[str, ...], None] = ("pod", "data")
    tp: Optional[str] = "model"
    fsdp: Optional[str] = "data"
    sp: Optional[str] = "model"

    def resolve(self, *logical: Optional[str]) -> P:
        """Translate logical names into a PartitionSpec for the ambient mesh."""
        mesh = current_abstract_mesh()
        if mesh is None or mesh.empty:
            return P()
        names = set(mesh.axis_names)

        def r(ax):
            if ax is None:
                return None
            got = getattr(self, ax)
            if got is None:
                return None
            if isinstance(got, tuple):
                sub = tuple(g for g in got if g in names)
                return sub if sub else None
            return got if got in names else None

        return P(*(r(ax) for ax in logical))


# Single-pod rules drop the "pod" axis automatically via resolve().
DEFAULT_RULES = MeshRules()


def shard(x: Array, rules: MeshRules, *logical: Optional[str]) -> Array:
    """with_sharding_constraint against logical axes; no-op without a mesh."""
    mesh = current_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(x, rules.resolve(*logical))


# ---------------------------------------------------------------------------
# Initialisers / numerics
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = -2) -> Array:
    """LeCun-normal (fan-in) init in fp32."""
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            / jnp.sqrt(jnp.asarray(fan_in, jnp.float32)))


def embed_init(key, shape, scale: float = 1.0) -> Array:
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
}


def count_params(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))


def cast_tree(params: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)


def mlp_params(key, dims: Sequence[int], bias: bool = True):
    """Plain MLP parameter stack for recsys/GNN towers."""
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, k in enumerate(keys):
        p = {"w": dense_init(k, (dims[i], dims[i + 1]))}
        if bias:
            p["b"] = jnp.zeros((dims[i + 1],), jnp.float32)
        layers.append(p)
    return layers


def mlp_apply(layers, x: Array, act: str = "relu", final_act: bool = False) -> Array:
    fn = ACTIVATIONS[act]
    n = len(layers)
    for i, p in enumerate(layers):
        x = x @ p["w"].astype(x.dtype)
        if "b" in p:
            x = x + p["b"].astype(x.dtype)
        if i + 1 < n or final_act:
            x = fn(x)
    return x
