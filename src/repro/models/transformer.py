"""Decoder-only LM (dense + MoE) with pod-scale sharding annotations.

Implementation notes (DESIGN.md §5):
* ``lax.scan`` over stacked layer params — HLO size is O(1) in depth
  (deepseek-67b has 95 layers; unrolled HLO would not compile in reasonable
  time at mesh 512).
* Megatron-style TP + sequence parallelism: the residual stream lives
  sequence-sharded P(dp, sp, -); attention/FFN inner activations live
  head-/ff-sharded P(dp, -, tp). XLA inserts the all-gather /
  reduce-scatter pairs at the constraint boundaries.
* Per-layer remat (``jax.checkpoint``) — only layer-boundary residuals are
  stored; internals recompute in backward.
* Chunked cross-entropy: logits are never materialised at [B, S, V];
  a scan over sequence chunks bounds peak memory at [B, chunk, V].
* Decode: KV caches stacked [L, B, S, Hkv, hd], sequence-shardable for
  long contexts (long_500k runs as decode; linear in context).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import apply_rope, blocked_attention, decode_attention
from repro.models.common import (
    ACTIVATIONS,
    MeshRules,
    current_abstract_mesh,
    dense_init,
    embed_init,
    rms_norm,
    shard,
)
from repro.models.moe import MoEParams, ep_available, init_moe, moe_ffn, moe_ffn_ep

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    act: str = "silu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # MoE
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    moe_ep: bool = True   # shard_map expert-parallel dispatch (§Perf-A)
    # numerics / memory
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    logit_chunk: int = 512
    kv_block: int = 512
    # roofline-calibration mode: unroll every scan so cost_analysis counts
    # loop bodies exactly (XLA counts a while body ONCE; see DESIGN.md §8)
    unroll: bool = False

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers + unembed)."""
        d, l = self.d_model, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.moe:
            ffn = d * self.n_experts + 3 * self.n_experts * d * self.moe_d_ff
        else:
            ffn = 3 * d * self.d_ff
        norms = 2 * d
        return (self.vocab_size * d                      # embed
                + l * (attn + ffn + norms)
                + d                                       # final norm
                + d * self.vocab_size)                    # unembed

    def active_param_count(self) -> int:
        """Active-per-token params (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = d * self.n_experts + 3 * self.moe_top_k * d * self.moe_d_ff
        return (self.vocab_size * d + l * (attn + ffn + 2 * d)
                + d + d * self.vocab_size)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(config: TransformerConfig, key) -> Dict:
    keys = jax.random.split(key, 12)
    L, D = config.n_layers, config.d_model
    layers = {
        "ln1": jnp.zeros((L, D), jnp.float32),
        "ln2": jnp.zeros((L, D), jnp.float32),
        "wq": dense_init(keys[0], (L, D, config.q_dim)),
        "wk": dense_init(keys[1], (L, D, config.kv_dim)),
        "wv": dense_init(keys[2], (L, D, config.kv_dim)),
        "wo": dense_init(keys[3], (L, config.q_dim, D)),
    }
    if config.moe:
        layers["router"] = dense_init(keys[4], (L, D, config.n_experts))
        layers["moe_gate"] = dense_init(keys[5], (L, config.n_experts, D, config.moe_d_ff))
        layers["moe_up"] = dense_init(keys[6], (L, config.n_experts, D, config.moe_d_ff))
        layers["moe_down"] = dense_init(keys[7], (L, config.n_experts, config.moe_d_ff, D))
    else:
        layers["w_gate"] = dense_init(keys[4], (L, D, config.d_ff))
        layers["w_up"] = dense_init(keys[5], (L, D, config.d_ff))
        layers["w_down"] = dense_init(keys[6], (L, config.d_ff, D))
    return {
        "embed": embed_init(keys[8], (config.vocab_size, D)),
        "layers": layers,
        "final_norm": jnp.zeros((D,), jnp.float32),
        "unembed": dense_init(keys[9], (D, config.vocab_size)),
    }


def _div(n: int, mesh_axis: Optional[str]) -> bool:
    """True if dim n is divisible by the ambient mesh axis size."""
    if mesh_axis is None:
        return False
    mesh = current_abstract_mesh()
    if mesh is None or mesh.empty or mesh_axis not in mesh.axis_names:
        return False
    return n % dict(mesh.shape)[mesh_axis] == 0


def param_specs(config: TransformerConfig, rules: MeshRules,
                mode: str = "train") -> Dict:
    """PartitionSpec tree matching init_params. mode 'serve' drops FSDP
    (batch owns the data axis exclusively at inference)."""
    tp = rules.tp
    fsdp = rules.fsdp if mode == "train" else None
    layers = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, fsdp, tp),
        "wk": P(None, fsdp, tp),
        "wv": P(None, fsdp, tp),
        "wo": P(None, tp, fsdp),
    }
    if config.moe:
        layers["router"] = P(None, fsdp, None)
        layers["moe_gate"] = P(None, tp, fsdp, None)
        layers["moe_up"] = P(None, tp, fsdp, None)
        layers["moe_down"] = P(None, tp, None, fsdp)
    else:
        layers["w_gate"] = P(None, fsdp, tp)
        layers["w_up"] = P(None, fsdp, tp)
        layers["w_down"] = P(None, tp, fsdp)
    return {
        "embed": P(tp, fsdp),
        "layers": layers,
        "final_norm": P(None),
        "unembed": P(fsdp, tp),
    }


# ---------------------------------------------------------------------------
# Layer
# ---------------------------------------------------------------------------


def _attention_block(lp: Dict, x: Array, config: TransformerConfig,
                     rules: MeshRules, positions: Array,
                     kv_cache: Optional[Tuple[Array, Array]] = None,
                     cache_len: Optional[Array] = None):
    """x: [B, S, D] (residual layout). Returns (out [B,S,D], new_kv)."""
    B, S, D = x.shape
    dt = config.compute_dtype
    h = rms_norm(x, lp["ln1"], config.norm_eps)
    # qkv projections — inner layout: heads sharded, sequence gathered
    q = (h @ lp["wq"].astype(dt)).reshape(B, S, config.n_heads, config.head_dim)
    k = (h @ lp["wk"].astype(dt)).reshape(B, S, config.n_kv_heads, config.head_dim)
    v = (h @ lp["wv"].astype(dt)).reshape(B, S, config.n_kv_heads, config.head_dim)
    if _div(config.n_heads, rules.tp):
        q = shard(q, rules, "dp", None, "tp", None)
    if _div(config.n_kv_heads, rules.tp):
        k = shard(k, rules, "dp", None, "tp", None)
        v = shard(v, rules, "dp", None, "tp", None)
    q = apply_rope(q, positions, config.rope_theta)
    k = apply_rope(k, positions, config.rope_theta)

    new_kv = None
    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        # append at cache_len (batch-uniform position); S == 1 in decode
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
        new_kv = (k_cache, v_cache)
        valid = jnp.full((B,), cache_len + S, jnp.int32)
        # scores stay sharded exactly like the cache's seq axis
        cache_spec = kv_cache_specs(config, rules, B, k_cache.shape[1])["k"]
        score_spec = P(cache_spec[1], None, None, cache_spec[2])
        mesh = current_abstract_mesh()

        def seq_shard(s):
            if mesh is None or mesh.empty:
                return s
            return jax.lax.with_sharding_constraint(s, score_spec)

        attn = decode_attention(
            q, k_cache.astype(dt), v_cache.astype(dt), cache_len=valid,
            seq_shard=seq_shard)
    else:
        attn = blocked_attention(q, k, v, causal=True, kv_block=config.kv_block,
                                 q_positions=positions, kv_positions=positions,
                                 unroll=config.unroll)
    attn = attn.reshape(B, S, config.q_dim)
    out = attn @ lp["wo"].astype(dt)
    return out, new_kv


def _ffn_block(lp: Dict, x: Array, config: TransformerConfig, rules: MeshRules):
    """Returns (out [B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    dt = config.compute_dtype
    h = rms_norm(x, lp["ln2"], config.norm_eps)
    if config.moe:
        params = MoEParams(router=lp["router"], w_gate=lp["moe_gate"],
                           w_up=lp["moe_up"], w_down=lp["moe_down"])
        if config.moe_ep and ep_available(config.n_experts, rules):
            out, aux = moe_ffn_ep(params, h, config.moe_top_k,
                                  config.capacity_factor, config.act, rules)
            return out, aux["aux_loss"]
        flat = h.reshape(B * S, D)
        out, aux = moe_ffn(params, flat, config.moe_top_k,
                           config.capacity_factor, config.act, rules)
        return out.reshape(B, S, D), aux["aux_loss"]
    act = ACTIVATIONS[config.act]
    g = h @ lp["w_gate"].astype(dt)
    u = h @ lp["w_up"].astype(dt)
    g = shard(g, rules, "dp", None, "tp")
    out = (act(g) * u) @ lp["w_down"].astype(dt)
    return out, jnp.float32(0.0)


def _layer(lp: Dict, x: Array, config: TransformerConfig, rules: MeshRules,
           positions: Array, kv_cache=None, cache_len=None):
    residual_spec = ("dp", "sp", None) if x.shape[1] > 1 else ("dp", None, None)
    attn_out, new_kv = _attention_block(lp, x, config, rules, positions,
                                        kv_cache, cache_len)
    x = shard(x + attn_out, rules, *residual_spec)
    ffn_out, aux = _ffn_block(lp, x, config, rules)
    x = shard(x + ffn_out, rules, *residual_spec)
    return x, new_kv, aux


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def forward(params: Dict, tokens: Array, config: TransformerConfig,
            rules: MeshRules = MeshRules()) -> Tuple[Array, Array]:
    """Training/prefill forward. tokens: [B, S] -> (hidden [B,S,D], aux)."""
    B, S = tokens.shape
    dt = config.compute_dtype
    x = params["embed"].astype(dt)[tokens]
    x = shard(x, rules, "dp", "sp", None)
    positions = jnp.arange(S)

    def body(carry, lp):
        x, aux = carry
        y, _, a = _layer(lp, x, config, rules, positions)
        return (y, aux + a), None

    body_fn = jax.checkpoint(body) if config.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["layers"],
                               unroll=True if config.unroll else 1)
    x = rms_norm(x, params["final_norm"], config.norm_eps)
    return x, aux


def prefill(params: Dict, tokens: Array, config: TransformerConfig,
            rules: MeshRules = MeshRules(), cache_dtype=jnp.bfloat16):
    """Prompt ingestion: forward pass that also emits the stacked KV cache
    ({k, v}: [L, B, S, Hkv, hd]) plus last-position hidden states."""
    B, S = tokens.shape
    dt = config.compute_dtype
    x = params["embed"].astype(dt)[tokens]
    x = shard(x, rules, "dp", "sp", None)
    positions = jnp.arange(S)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], config.norm_eps)
        q = (h @ lp["wq"].astype(dt)).reshape(B, S, config.n_heads, config.head_dim)
        k = (h @ lp["wk"].astype(dt)).reshape(B, S, config.n_kv_heads, config.head_dim)
        v = (h @ lp["wv"].astype(dt)).reshape(B, S, config.n_kv_heads, config.head_dim)
        q = apply_rope(q, positions, config.rope_theta)
        k = apply_rope(k, positions, config.rope_theta)
        attn = blocked_attention(q, k, v, causal=True, kv_block=config.kv_block,
                                 q_positions=positions, kv_positions=positions,
                                 unroll=config.unroll)
        x = x + attn.reshape(B, S, config.q_dim) @ lp["wo"].astype(dt)
        ffn_out, _ = _ffn_block(lp, x, config, rules)
        x = shard(x + ffn_out, rules, "dp", "sp", None)
        return x, (k.astype(cache_dtype), v.astype(cache_dtype))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"],
                               unroll=True if config.unroll else 1)
    x = rms_norm(x, params["final_norm"], config.norm_eps)
    return x[:, -1, :], {"k": ks, "v": vs}


def logits_from_hidden(params: Dict, hidden: Array,
                       config: TransformerConfig) -> Array:
    return hidden @ params["unembed"].astype(hidden.dtype)


def chunked_xent(params: Dict, hidden: Array, labels: Array,
                 config: TransformerConfig, rules: MeshRules) -> Array:
    """Cross-entropy without materialising [B, S, V] logits.

    Scans sequence chunks; each chunk computes its own logits + logsumexp
    and is rematted, so peak memory is [B, chunk, V / tp].
    """
    B, S, D = hidden.shape
    chunk = min(config.logit_chunk, S)
    n_chunks = S // chunk if S % chunk == 0 else 1
    if S % chunk != 0:
        chunk = S
    hc = hidden.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    w = params["unembed"]

    V = w.shape[1]

    @jax.checkpoint
    def one_chunk(carry, xs):
        h, y = xs
        logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
        logits = shard(logits, rules, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot contraction: partitions over the sharded
        # vocab axis as a local partial + psum; take_along_axis would
        # all-gather the [B, chunk, V] logits (67 GB/step at gemma scale —
        # found via the collective-bytes audit, see EXPERIMENTS.md §Perf).
        onehot = jax.nn.one_hot(y, V, dtype=logits.dtype)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(one_chunk, jnp.float32(0.0), (hc, lc),
                            unroll=True if config.unroll else 1)
    return total / (B * S)


def loss_fn(params: Dict, batch: Dict, config: TransformerConfig,
            rules: MeshRules = MeshRules()) -> Tuple[Array, Dict]:
    hidden, aux = forward(params, batch["tokens"], config, rules)
    xent = chunked_xent(params, hidden, batch["labels"], config, rules)
    loss = xent + config.aux_loss_weight * aux / max(config.n_layers, 1)
    return loss, {"xent": xent, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_kv_cache(config: TransformerConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict:
    shape = (config.n_layers, batch, max_len, config.n_kv_heads, config.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(config: TransformerConfig, rules: MeshRules,
                   batch: int, seq_len: int) -> Dict:
    """Shard cache over batch (dp) and sequence (sp) where divisible.

    §Perf-B iter 3: when the batch cannot occupy the data axis (e.g.
    long_500k's batch=1), the SEQUENCE takes it instead — 256-way context
    parallelism (data x model) instead of 16-way, cutting both the
    per-device cache slice and the per-token attention reads 16x.
    """
    mesh = current_abstract_mesh()
    dp = None
    sp = None
    if mesh is not None and not mesh.empty:
        sizes = dict(mesh.shape)
        dp_axes = rules.dp if isinstance(rules.dp, tuple) else (rules.dp,)
        dp_axes = tuple(a for a in dp_axes if a in sizes)
        dp_size = 1
        for a in dp_axes:
            dp_size *= sizes[a]
        dp = dp_axes if (dp_axes and batch % dp_size == 0) else None
        seq_axes = tuple(a for a in ((rules.sp,) if rules.sp in sizes else ())
                         if a in sizes)
        if dp is None and dp_axes:
            seq_axes = dp_axes + tuple(a for a in seq_axes if a not in dp_axes)
        seq_size = 1
        for a in seq_axes:
            seq_size *= sizes[a]
        sp = seq_axes if (seq_axes and seq_len % seq_size == 0) else None
    spec = P(None, dp, sp, None, None)
    return {"k": spec, "v": spec}


def serve_step(params: Dict, cache: Dict, tokens: Array, cache_len,
               config: TransformerConfig, rules: MeshRules = MeshRules(),
               top_k: int = 0):
    """One decode step. tokens: [B, 1]. Returns (logits-or-topk, new cache).

    ``top_k > 0`` routes the logit head through the sharded exact top-K
    merge (the paper's technique as the LM sampling head).
    """
    B, S = tokens.shape
    dt = config.compute_dtype
    x = params["embed"].astype(dt)[tokens]
    x = shard(x, rules, "dp", None, None)
    positions = cache_len + jnp.arange(S)

    def body(carry, xs):
        x = carry
        lp, kc, vc = xs
        y, new_kv, _ = _layer(lp, x, config, rules, positions,
                              kv_cache=(kc, vc), cache_len=cache_len)
        return y, new_kv

    x, new_kv = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                             unroll=True if config.unroll else 1)
    x = rms_norm(x, params["final_norm"], config.norm_eps)
    hidden = x[:, -1, :]                                   # [B, D]
    new_cache = {"k": new_kv[0], "v": new_kv[1]}
    if top_k <= 0:
        logits = hidden @ params["unembed"].astype(dt)
        return logits, new_cache
    vals, idx = topk_logits(hidden, params["unembed"], top_k, rules)
    return (vals, idx), new_cache


def topk_logits(hidden: Array, unembed: Array, k: int,
                rules: MeshRules = MeshRules()):
    """Exact top-K over the vocab — the SEP-LR head (DESIGN.md §3).

    With the vocab tp-sharded this is the distributed merge of
    ``repro.core.sharded``: local matmul + local top-K, all-gather only
    ``K`` candidates per shard. Without a mesh it degrades to naive.
    """
    mesh = current_abstract_mesh()
    tp = rules.tp
    if mesh is None or mesh.empty or tp not in mesh.axis_names \
            or unembed.shape[1] % dict(mesh.shape)[tp] != 0:
        logits = hidden.astype(jnp.float32) @ unembed.astype(jnp.float32)
        return jax.lax.top_k(logits, k)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(), P(None, tp)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    def _local(h, w_local):
        v_local = w_local.shape[1]
        logits = h.astype(jnp.float32) @ w_local.astype(jnp.float32)
        vals, idx = jax.lax.top_k(logits, min(k, v_local))
        idx = idx + jax.lax.axis_index(tp) * v_local
        vals = jax.lax.all_gather(vals, tp, axis=1, tiled=True)
        idx = jax.lax.all_gather(idx, tp, axis=1, tiled=True)
        fvals, fpos = jax.lax.top_k(vals, k)
        return fvals, jnp.take_along_axis(idx, fpos, axis=1)

    return _local(hidden, unembed)
