"""Pallas kernel: fused FM second-order interaction (Rendle sum-square).

    out[b] = 0.5 * sum_d [ (sum_f v[b,f,d])^2 - sum_f v[b,f,d]^2 ]

One VMEM tile of field embeddings per grid step; both reductions fuse into
a single pass so the [B, d] partial sums never round-trip to HBM (the jnp
reference materialises two). Pure VPU work — no MXU needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(emb_ref, out_ref):
    v = emb_ref[...].astype(jnp.float32)       # [block_b, F, d]
    s = v.sum(axis=1)                          # [block_b, d]
    sq = (v * v).sum(axis=1)
    out_ref[...] = (0.5 * (s * s - sq).sum(axis=-1)).astype(out_ref.dtype)


def fm_interaction_pallas(emb, block_b: int = 64, interpret: bool = True):
    """emb: [B, F, d] (B % block_b == 0) -> [B]."""
    B, F, d = emb.shape
    assert B % block_b == 0, (B, block_b)
    return pl.pallas_call(
        _kernel,
        grid=(B // block_b,),
        in_specs=[pl.BlockSpec((block_b, F, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), emb.dtype),
        interpret=interpret,
    )(emb)
