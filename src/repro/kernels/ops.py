"""jit'd public wrappers around the Pallas kernels.

``topk_mips`` handles the catalogue preparation (norm ordering, padding,
per-block Cauchy-Schwarz bounds) and maps kernel-local indices back to
catalogue ids; kernels themselves stay shape-strict.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas
from repro.kernels.topk_mips import (HAS_SCALAR_PREFETCH, NEG_INF,
                                     topk_mips_pallas,
                                     topk_mips_pallas_batched,
                                     topk_mips_pallas_batched_prefetch,
                                     topk_mips_pallas_prefetch)

Array = jnp.ndarray


class MIPSCatalog:
    """Norm-ordered, block-padded catalogue for the topk_mips kernels.

    Owns the TWO-LEVEL bound hierarchy (DESIGN.md §6): per-tile
    Cauchy-Schwarz bounds for the in-kernel runtime test, plus a
    superblock-granular pre-screen derived from an a-priori lower bound
    lb0 — the K-th best score of the first (largest-norm) superblock,
    computed with one cheap XLA matmul before the kernel launches. Blocks
    whose bound is already below lb0 are delivered to the kernel as
    scalar-prefetch skip instructions, so their HBM->VMEM DMA never
    happens. The pre-screen can only drop blocks the runtime test would
    drop anyway (lb0 is a true lower bound on the final K-th best), so
    results AND statistics match the single-level kernels exactly.

    ``interpret=None`` (the default on both query paths) autodetects the
    Pallas execution mode: interpreter off-TPU, compiled on TPU. When the
    installed jax lacks ``PrefetchScalarGridSpec`` both query paths fall
    back to the single-level kernels.

    Args:
      T: ``[M, R]`` catalogue.
      block_m: tile rows (the runtime bound-test granularity).
      superblock: tiles per superblock — the pre-screen/DMA granularity
        and the batched kernel's multi-tile grid-step size (clamped to the
        tile count of small catalogues).
    """

    def __init__(self, T, block_m: int = 256, superblock: int = 8):
        T = np.asarray(T, np.float32)
        M, R = T.shape
        norms = np.linalg.norm(T, axis=1)
        order = np.argsort(-norms, kind="stable")
        self.superblock = int(max(1, min(superblock, -(-M // block_m))))
        span = block_m * self.superblock
        M_pad = -(-M // span) * span
        T_sorted = np.zeros((M_pad, R), np.float32)
        T_sorted[:M] = T[order]
        self.block_m = block_m
        self.num_real = M
        self.n_blocks = M_pad // block_m
        self.n_super = M_pad // span
        self.order = jnp.asarray(order.astype(np.int32))
        self.T_sorted = jnp.asarray(T_sorted)
        # max norm per block/superblock = norm of its first row (sorted)
        norms_pad = np.pad(norms[order], (0, M_pad - M))
        self.block_max_norm = jnp.asarray(norms_pad[::block_m].copy())
        self.super_max_norm = jnp.asarray(norms_pad[::span].copy())
        # head slab (the first superblock) that seeds lb0
        self.head_rows = min(span, M_pad)
        self._head = self.T_sorted[:self.head_rows]
        self._head_valid = jnp.arange(self.head_rows) < self.num_real

    def _to_catalogue_ids(self, local_idx: Array) -> Array:
        return jnp.where(
            local_idx >= 0,
            self.order[jnp.clip(local_idx, 0, self.num_real - 1)],
            -1)

    def _lower_bound0(self, U: Array, k: int) -> Array:
        """A-priori per-query lower bound on the final K-th best score.

        The K-th best of the head superblock's REAL rows — fully scored,
        so a certificate, not an estimate. Returns -inf (prescreen off,
        still exact) when the head holds fewer than k real rows.
        """
        hs = jnp.where(self._head_valid[None, :], U @ self._head.T, NEG_INF)
        kk = min(k, self.head_rows)
        lb0 = jax.lax.top_k(hs, kk)[0][:, kk - 1]
        if kk < k or self.num_real < k:
            lb0 = jnp.full_like(lb0, NEG_INF)
        return lb0

    def query(self, u: Array, k: int, interpret=None):
        """Exact top-K. Returns (values, catalogue ids, stats [3])."""
        u = jnp.asarray(u, jnp.float32)
        bounds = jnp.linalg.norm(u) * self.block_max_norm
        if not HAS_SCALAR_PREFETCH:
            vals, local_idx, stats = topk_mips_pallas(
                self.T_sorted, bounds, u, k, self.block_m,
                interpret=interpret, num_real=self.num_real)
            return vals, self._to_catalogue_ids(local_idx), stats
        lb0 = self._lower_bound0(u[None, :], k)[0]
        steps = jnp.arange(self.n_blocks, dtype=jnp.int32)
        # head tiles stay live: lb0's witnesses must reach the merge
        live = jnp.logical_or(bounds > lb0, steps < self.superblock)
        n_live = jnp.sum(live.astype(jnp.int32))      # live is a prefix
        tile_idx = jnp.minimum(steps, n_live - 1)
        vals, local_idx, stats = topk_mips_pallas_prefetch(
            self.T_sorted, bounds, tile_idx, live.astype(jnp.int32), u, k,
            self.block_m, interpret=interpret, num_real=self.num_real)
        return vals, self._to_catalogue_ids(local_idx), stats

    def query_batch(self, U: Array, k: int, interpret=None):
        """Exact top-K for a query batch ``U: [B, R]`` in ONE kernel launch.

        Returns (values [B, k], catalogue ids [B, k], stats [B, 3]).
        """
        U = jnp.atleast_2d(jnp.asarray(U, jnp.float32))
        u_norm = jnp.linalg.norm(U, axis=1)
        bounds = u_norm[:, None] * self.block_max_norm[None, :]
        if not HAS_SCALAR_PREFETCH:
            vals, local_idx, stats = topk_mips_pallas_batched(
                self.T_sorted, bounds, U, k, self.block_m,
                interpret=interpret, num_real=self.num_real)
            return vals, self._to_catalogue_ids(local_idx), stats
        lb0 = self._lower_bound0(U, k)
        super_bounds = u_norm[:, None] * self.super_max_norm[None, :]
        live = (super_bounds > lb0[:, None]).at[:, 0].set(True)
        n_live = jnp.sum(live.astype(jnp.int32), axis=1)  # prefix length
        steps = jnp.arange(self.n_super, dtype=jnp.int32)[None, :]
        sb_idx = jnp.minimum(steps, n_live[:, None] - 1)
        tile_bounds = bounds.reshape(U.shape[0], self.n_super,
                                     self.superblock)
        vals, local_idx, stats = topk_mips_pallas_batched_prefetch(
            self.T_sorted, tile_bounds, sb_idx,
            (steps < n_live[:, None]).astype(jnp.int32), U, k,
            block_m=self.block_m, tiles_per_step=self.superblock,
            interpret=interpret, num_real=self.num_real)
        return vals, self._to_catalogue_ids(local_idx), stats


def embedding_bag(table: Array, ids: Array, mode: str = "sum",
                  block_b: int = 8, interpret: bool = True) -> Array:
    """Fused EmbeddingBag. table: [V, d]; ids: [B, F] -> [B, d]."""
    B = ids.shape[0]
    pad = (-B) % block_b
    if pad:
        ids = jnp.pad(ids, ((0, pad), (0, 0)))
    out = embedding_bag_pallas(table, ids, mode, block_b, interpret)
    return out[:B]


def fm_interaction(emb: Array, block_b: int = 64,
                   interpret: bool = True) -> Array:
    """Fused FM sum-square interaction. emb: [B, F, d] -> [B]."""
    B = emb.shape[0]
    pad = (-B) % block_b
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    out = fm_interaction_pallas(emb, block_b, interpret)
    return out[:B]
