"""jit'd public wrappers around the Pallas kernels.

``topk_mips`` handles the catalogue preparation (norm ordering, padding,
per-block Cauchy-Schwarz bounds) and maps kernel-local indices back to
catalogue ids; kernels themselves stay shape-strict.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas
from repro.kernels.topk_mips import (topk_mips_pallas,
                                     topk_mips_pallas_batched)

Array = jnp.ndarray


class MIPSCatalog:
    """Norm-ordered, block-padded catalogue for the topk_mips kernel.

    ``interpret=None`` (the default on both query paths) autodetects the
    Pallas execution mode: interpreter off-TPU, compiled on TPU.
    """

    def __init__(self, T, block_m: int = 256):
        T = np.asarray(T, np.float32)
        M, R = T.shape
        norms = np.linalg.norm(T, axis=1)
        order = np.argsort(-norms, kind="stable")
        M_pad = -(-M // block_m) * block_m
        T_sorted = np.zeros((M_pad, R), np.float32)
        T_sorted[:M] = T[order]
        self.block_m = block_m
        self.num_real = M
        self.order = jnp.asarray(order.astype(np.int32))
        self.T_sorted = jnp.asarray(T_sorted)
        # max norm per block = norm of its first row (sorted order)
        self.block_max_norm = jnp.asarray(
            np.pad(norms[order], (0, M_pad - M))[::block_m].copy())

    def _to_catalogue_ids(self, local_idx: Array) -> Array:
        return jnp.where(
            local_idx >= 0,
            self.order[jnp.clip(local_idx, 0, self.num_real - 1)],
            -1)

    def query(self, u: Array, k: int, interpret=None):
        """Exact top-K. Returns (values, catalogue ids, stats)."""
        u = jnp.asarray(u, jnp.float32)
        bounds = jnp.linalg.norm(u) * self.block_max_norm
        vals, local_idx, stats = topk_mips_pallas(
            self.T_sorted, bounds, u, k, self.block_m, interpret=interpret,
            num_real=self.num_real)
        return vals, self._to_catalogue_ids(local_idx), stats

    def query_batch(self, U: Array, k: int, interpret=None):
        """Exact top-K for a query batch ``U: [B, R]`` in ONE kernel launch.

        Returns (values [B, k], catalogue ids [B, k], stats [B, 2]).
        """
        U = jnp.atleast_2d(jnp.asarray(U, jnp.float32))
        bounds = (jnp.linalg.norm(U, axis=1)[:, None]
                  * self.block_max_norm[None, :])
        vals, local_idx, stats = topk_mips_pallas_batched(
            self.T_sorted, bounds, U, k, self.block_m, interpret=interpret,
            num_real=self.num_real)
        return vals, self._to_catalogue_ids(local_idx), stats


def embedding_bag(table: Array, ids: Array, mode: str = "sum",
                  block_b: int = 8, interpret: bool = True) -> Array:
    """Fused EmbeddingBag. table: [V, d]; ids: [B, F] -> [B, d]."""
    B = ids.shape[0]
    pad = (-B) % block_b
    if pad:
        ids = jnp.pad(ids, ((0, pad), (0, 0)))
    out = embedding_bag_pallas(table, ids, mode, block_b, interpret)
    return out[:B]


def fm_interaction(emb: Array, block_b: int = 64,
                   interpret: bool = True) -> Array:
    """Fused FM sum-square interaction. emb: [B, F, d] -> [B]."""
    B = emb.shape[0]
    pad = (-B) % block_b
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    out = fm_interaction_pallas(emb, block_b, interpret)
    return out[:B]
