"""Pallas kernel: EmbeddingBag (fixed-arity multi-hot gather + reduce).

The recsys hot path (taxonomy §B.6): JAX has no native EmbeddingBag, so
this kernel fuses the row gather with the bag reduction — rows stream from
the HBM-resident table one DMA per (bag, field) and accumulate in a VMEM
tile, never materialising the [B, F, d] gathered tensor that the jnp
reference allocates.

TPU notes: the table stays in ANY/HBM memory space (it is far larger than
VMEM); ids prefetch to SMEM via PrefetchScalarGridSpec so the row addresses
are known before the body runs. Interpret mode validates the semantics on
CPU; on hardware the per-row loads become async DMAs double-buffered
against the accumulate (as in FBGEMM's TBE).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, table_ref, out_ref, *, block_b: int, n_fields: int,
            mode: str):
    i = pl.program_id(0)

    def body(b, _):
        def inner(f, acc):
            row_id = ids_ref[i * block_b + b, f]
            row = pl.load(table_ref, (pl.dslice(row_id, 1), slice(None)))
            return acc + row[0].astype(jnp.float32)

        acc0 = jnp.zeros((table_ref.shape[1],), jnp.float32)
        acc = jax.lax.fori_loop(0, n_fields, inner, acc0)
        if mode == "mean":
            acc = acc / n_fields
        pl.store(out_ref, (pl.dslice(b, 1), slice(None)),
                 acc[None].astype(out_ref.dtype))
        return _

    jax.lax.fori_loop(0, block_b, body, 0)


def embedding_bag_pallas(table, ids, mode: str = "sum", block_b: int = 8,
                         interpret: bool = True):
    """table: [V, d]; ids: [B, F] (B % block_b == 0) -> [B, d]."""
    B, F = ids.shape
    V, d = table.shape
    assert B % block_b == 0, (B, block_b)
    kernel = functools.partial(_kernel, block_b=block_b, n_fields=F,
                               mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # ids (small, scalar use)
            pl.BlockSpec(memory_space=pltpu.ANY),    # table stays in HBM
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), table.dtype),
        interpret=interpret,
    )(ids, table)
