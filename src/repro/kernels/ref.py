"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def topk_mips_ref(T_sorted: Array, u: Array, k: int):
    """Exact top-K over the norm-ordered catalogue (ids are positions in
    T_sorted; ops.py maps them back through the permutation)."""
    scores = T_sorted @ u
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx


def embedding_bag_ref(table: Array, ids: Array, weights: Array | None = None,
                      mode: str = "sum"):
    """ids: [B, F] fixed-size bags -> [B, d]."""
    rows = jnp.take(table, ids, axis=0)            # [B, F, d]
    if weights is not None:
        rows = rows * weights[..., None]
    if mode == "sum":
        return rows.sum(axis=1)
    if mode == "mean":
        return rows.mean(axis=1)
    raise ValueError(mode)


def fm_interaction_ref(emb: Array):
    """emb: [B, F, d] -> [B] Rendle sum-square second-order term."""
    s = emb.sum(axis=1)
    sq = (emb * emb).sum(axis=1)
    return 0.5 * (s * s - sq).sum(axis=-1)
