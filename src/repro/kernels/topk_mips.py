"""Pallas TPU kernel: threshold-pruned blocked MIPS top-K.

The hardware form of the paper's pruning idea (DESIGN.md §4, §6): the
catalogue is stored in DECREASING-NORM order so that a whole VMEM tile of
candidates can be skipped with one Cauchy-Schwarz bound test

    max possible score in block b  <=  ||u|| * max_norm(block b)  <=  lowerBound

TPU mapping:
  * grid = (n_blocks,); TPU grid steps run sequentially on a core, so the
    running top-K lives in VMEM scratch and carries across blocks,
  * the tile load (block_m x R) is a contiguous HBM->VMEM DMA declared by
    BlockSpec (the norm ordering is what makes it contiguous — the paper's
    per-dimension lists would gather scattered rows),
  * scoring is one (block_m x R) @ (R x 1) MXU matvec per tile,
  * the merge is lax.top_k over K + block_m lanes,
  * the bound test is @pl.when on a scalar — a skipped block costs only
    its (prefetched) DMA, no MXU work.

**Two-level bound hierarchy** (the ``*_prefetch`` kernels): the runtime
``@pl.when`` test above can only skip MXU work — by the time the bound is
known false, the BlockSpec pipeline has already issued the tile's
HBM->VMEM DMA. The prefetch kernels add a second, coarser level: the
caller derives an a-priori lower bound lb0 (top-K of the first,
largest-norm superblock, one cheap XLA matmul) and pre-screens blocks
whose Cauchy-Schwarz bound is already below lb0. The surviving scan
prefix is delivered via SCALAR PREFETCH — ``tile_idx[i]`` names the tile
grid step ``i`` should map, and pre-pruned steps repeat the last live
tile, so the pipeline sees an unchanged block index and issues NO DMA at
all. Because the catalogue is norm-sorted, pre-pruned blocks form a
suffix, and every pre-pruned block would also have been runtime-pruned
(its bound <= lb0 <= the running lower bound), so ``n_scored`` /
``blocks_visited`` statistics are identical to the single-level kernels.

The batched variant adds the query dimension to the grid —
``grid = (B, n_steps)`` with steps innermost, so each query's scan is
still sequential (the scratch top-K resets at step 0 of every query) and
the whole batch is one kernel launch. Its grid steps are MULTI-TILE: one
step DMAs a whole superblock (``tiles_per_step * block_m`` rows) and the
kernel body walks the resident tiles with per-tile runtime bound tests,
keeping statistics tile-granular while amortising grid and DMA overhead.

Exactness: identical guarantee as core.blocked.norm_pruned_topk (blocks
are visited in decreasing max-norm order; once the K-th best exceeds the
bound no later block can contribute; lb0 is a true lower bound because it
is the K-th best of real, fully scored rows). Rows past ``num_real`` are
zero padding added by the catalogue wrapper; their scores are masked to
-inf so a pad row can never displace a real (possibly negative) score
from the top-K.

Stats layout (all kernels): ``(rows_scored, blocks_visited, blocks_dma)``
— the third column is what the two-level hierarchy saves; on the
single-level kernels it simply counts every grid step.

``interpret=None`` autodetects: interpret mode off TPU (CPU CI runs the
kernel bodies in the Pallas interpreter), compiled on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

HAS_SCALAR_PREFETCH = hasattr(pltpu, "PrefetchScalarGridSpec")


def resolve_interpret(interpret):
    """None -> interpret everywhere except on real TPU backends."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _merge_block(scores, block_start, scratch_vals, scratch_idx,
                 *, k: int, block_m: int, num_real: int):
    ids = block_start + jax.lax.iota(jnp.int32, block_m)
    scores = jnp.where(ids < num_real, scores, NEG_INF)  # mask zero padding
    # two-stage (DESIGN.md §6): top_k over the BARE block, then a 2K-lane
    # fold with the carry — top_k over the K+C concatenation falls off
    # the fast path on CPU (interpret mode) and wastes lanes on TPU
    kk = min(k, block_m)
    bv, bpos = jax.lax.top_k(scores, kk)
    bi = jnp.take(ids, bpos)
    if kk < k:
        bv = jnp.concatenate([bv, jnp.full((k - kk,), NEG_INF, bv.dtype)])
        bi = jnp.concatenate([bi, jnp.full((k - kk,), -1, bi.dtype)])
    cand_vals = jnp.concatenate([scratch_vals[...], bv])
    cand_idx = jnp.concatenate([scratch_idx[...], bi])
    top, pos = jax.lax.top_k(cand_vals, k)
    scratch_vals[...] = top
    scratch_idx[...] = jnp.take(cand_idx, pos)


# ---------------------------------------------------------------------------
# Single-level kernels (fallback when scalar prefetch is unavailable)
# ---------------------------------------------------------------------------


def _kernel(bound_ref, t_ref, u_ref, vals_ref, idx_ref, stats_ref,
            scratch_vals, scratch_idx, *, k: int, block_m: int,
            num_real: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        scratch_vals[...] = jnp.full_like(scratch_vals, NEG_INF)
        scratch_idx[...] = jnp.full_like(scratch_idx, -1)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    lb = scratch_vals[k - 1]
    bound = bound_ref[0]

    @pl.when(bound > lb)
    def _score():
        tile = t_ref[...]                                  # [block_m, R]
        u = u_ref[...]                                     # [R, 1]
        scores = jnp.dot(tile, u,
                         preferred_element_type=jnp.float32)[:, 0]
        _merge_block(scores, i * block_m, scratch_vals, scratch_idx,
                     k=k, block_m=block_m, num_real=num_real)
        stats_ref[0] += block_m                            # scored
        stats_ref[1] += 1                                  # blocks visited

    stats_ref[2] += 1            # single-level: every grid step is a DMA
    vals_ref[...] = scratch_vals[...]
    idx_ref[...] = scratch_idx[...]


def topk_mips_pallas(T_sorted, block_bounds, u, k: int,
                     block_m: int = 256, interpret=None,
                     num_real: int = -1):
    """T_sorted: [M, R] decreasing-norm order (M % block_m == 0);
    block_bounds: [n_blocks] = ||u|| * max norm per block; u: [R].

    Returns (values [k], local indices [k], stats [3] = (n_scored,
    blocks_visited, blocks_dma)). ``num_real`` marks the tail of
    zero-padded rows (default: no padding). Validated in interpret mode on
    CPU; compiled path targets TPU VMEM tiling via the BlockSpecs below.
    """
    M, R = T_sorted.shape
    assert M % block_m == 0, (M, block_m)
    n_blocks = M // block_m
    num_real = M if num_real < 0 else num_real
    kernel = functools.partial(_kernel, k=k, block_m=block_m,
                               num_real=num_real)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),                    # bound
            pl.BlockSpec((block_m, R), lambda i: (i, 0)),          # T tile
            pl.BlockSpec((R, 1), lambda i: (0, 0)),                # u
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((3,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(block_bounds, T_sorted, u[:, None])


def _kernel_batched(bound_ref, t_ref, u_ref, vals_ref, idx_ref, stats_ref,
                    scratch_vals, scratch_idx, *, k: int, block_m: int,
                    num_real: int):
    j = pl.program_id(1)  # block index — innermost, sequential per query

    @pl.when(j == 0)
    def _init():
        # a new query's scan begins: reset the carried top-K
        scratch_vals[...] = jnp.full_like(scratch_vals, NEG_INF)
        scratch_idx[...] = jnp.full_like(scratch_idx, -1)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    lb = scratch_vals[k - 1]
    bound = bound_ref[0, 0]

    @pl.when(bound > lb)
    def _score():
        tile = t_ref[...]                                  # [block_m, R]
        u = u_ref[0]                                       # [R, 1]
        scores = jnp.dot(tile, u,
                         preferred_element_type=jnp.float32)[:, 0]
        _merge_block(scores, j * block_m, scratch_vals, scratch_idx,
                     k=k, block_m=block_m, num_real=num_real)
        stats_ref[0, 0] += block_m                         # scored
        stats_ref[0, 1] += 1                               # blocks visited

    stats_ref[0, 2] += 1
    vals_ref[0, :] = scratch_vals[...]
    idx_ref[0, :] = scratch_idx[...]


def topk_mips_pallas_batched(T_sorted, block_bounds, U, k: int,
                             block_m: int = 256, interpret=None,
                             num_real: int = -1):
    """Query-grid variant: one launch scans the catalogue for a whole batch.

    T_sorted: [M, R] decreasing-norm order (M % block_m == 0);
    block_bounds: [B, n_blocks] per-query Cauchy-Schwarz block bounds;
    U: [B, R] queries.

    Returns (values [B, k], local indices [B, k], stats [B, 3]). The grid
    is (B, n_blocks) with the block dimension innermost, so the VMEM
    scratch top-K carries across a query's blocks and resets when the grid
    advances to the next query. The catalogue tile DMA pattern is identical
    to the single-query kernel; only the tiny u / bound operands change per
    grid row.
    """
    M, R = T_sorted.shape
    B = U.shape[0]
    assert M % block_m == 0, (M, block_m)
    assert block_bounds.shape == (B, M // block_m), block_bounds.shape
    n_blocks = M // block_m
    num_real = M if num_real < 0 else num_real
    kernel = functools.partial(_kernel_batched, k=k, block_m=block_m,
                               num_real=num_real)
    return pl.pallas_call(
        kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, j)),             # bound
            pl.BlockSpec((block_m, R), lambda b, j: (j, 0)),       # T tile
            pl.BlockSpec((1, R, 1), lambda b, j: (b, 0, 0)),       # u
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, j: (b, 0)),
            pl.BlockSpec((1, k), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 3), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(block_bounds, T_sorted, U[:, :, None])


# ---------------------------------------------------------------------------
# Two-level kernels: scalar-prefetched pre-screen skips the DMA itself
# ---------------------------------------------------------------------------


def _kernel_prefetch(tile_idx_ref, live_ref, bound_ref, t_ref, u_ref,
                     vals_ref, idx_ref, stats_ref, scratch_vals, scratch_idx,
                     *, k: int, block_m: int, num_real: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        scratch_vals[...] = jnp.full_like(scratch_vals, NEG_INF)
        scratch_idx[...] = jnp.full_like(scratch_idx, -1)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    lb = scratch_vals[k - 1]
    bound = bound_ref[0]
    live = live_ref[i] > 0      # pre-screen survivor: its tile is resident

    @pl.when(jnp.logical_and(live, bound > lb))
    def _score():
        tile = t_ref[...]                                  # [block_m, R]
        u = u_ref[...]                                     # [R, 1]
        scores = jnp.dot(tile, u,
                         preferred_element_type=jnp.float32)[:, 0]
        # live steps map tile i (prefix property), so ids start at i*block_m
        _merge_block(scores, i * block_m, scratch_vals, scratch_idx,
                     k=k, block_m=block_m, num_real=num_real)
        stats_ref[0] += block_m
        stats_ref[1] += 1

    @pl.when(live)
    def _dma():
        stats_ref[2] += 1       # pre-pruned steps re-map the resident tile

    vals_ref[...] = scratch_vals[...]
    idx_ref[...] = scratch_idx[...]


def topk_mips_pallas_prefetch(T_sorted, block_bounds, tile_idx, live, u,
                              k: int, block_m: int = 256, interpret=None,
                              num_real: int = -1):
    """Two-level single-query kernel (DESIGN.md §6).

    tile_idx: [n_blocks] int32 — the tile grid step ``i`` maps; pre-pruned
    steps repeat the last live tile so the BlockSpec pipeline issues no
    DMA for them. live: [n_blocks] int32 — 1 where the pre-screen kept the
    step. Both are SCALAR-PREFETCH operands: they are resident before the
    pipeline starts, which is what lets the index map depend on them.
    Other arguments and returns as :func:`topk_mips_pallas`.
    """
    M, R = T_sorted.shape
    assert M % block_m == 0, (M, block_m)
    n_blocks = M // block_m
    num_real = M if num_real < 0 else num_real
    kernel = functools.partial(_kernel_prefetch, k=k, block_m=block_m,
                               num_real=num_real)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i, ti, lv: (i,)),            # bound
            pl.BlockSpec((block_m, R), lambda i, ti, lv: (ti[i], 0)),
            pl.BlockSpec((R, 1), lambda i, ti, lv: (0, 0)),        # u
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i, ti, lv: (0,)),
            pl.BlockSpec((k,), lambda i, ti, lv: (0,)),
            pl.BlockSpec((3,), lambda i, ti, lv: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((3,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(tile_idx, live, block_bounds, T_sorted, u[:, None])


def _kernel_batched_prefetch(sb_idx_ref, live_ref, bound_ref, t_ref, u_ref,
                             vals_ref, idx_ref, stats_ref, scratch_vals,
                             scratch_idx, *, k: int, block_m: int,
                             tiles: int, num_real: int):
    b = pl.program_id(0)
    s = pl.program_id(1)  # superblock step — innermost, sequential per query

    @pl.when(s == 0)
    def _init():
        scratch_vals[...] = jnp.full_like(scratch_vals, NEG_INF)
        scratch_idx[...] = jnp.full_like(scratch_idx, -1)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    @pl.when(live_ref[b, s] > 0)
    def _step():
        # live ⇒ the resident superblock IS s (prefix property); walk its
        # tiles with per-tile runtime bound tests so statistics stay
        # tile-granular even though the DMA was superblock-granular.
        u = u_ref[0]                                       # [R, 1]
        for t in range(tiles):
            lb = scratch_vals[k - 1]
            bnd = bound_ref[0, 0, t]

            @pl.when(bnd > lb)
            def _score(t=t):
                tile = t_ref[t * block_m:(t + 1) * block_m, :]
                scores = jnp.dot(tile, u,
                                 preferred_element_type=jnp.float32)[:, 0]
                _merge_block(scores, (s * tiles + t) * block_m,
                             scratch_vals, scratch_idx,
                             k=k, block_m=block_m, num_real=num_real)
                stats_ref[0, 0] += block_m
                stats_ref[0, 1] += 1

        stats_ref[0, 2] += tiles

    vals_ref[0, :] = scratch_vals[...]
    idx_ref[0, :] = scratch_idx[...]


def topk_mips_pallas_batched_prefetch(T_sorted, tile_bounds, sb_idx, live,
                                      U, k: int, block_m: int = 256,
                                      tiles_per_step: int = 8,
                                      interpret=None, num_real: int = -1):
    """Two-level batched kernel with multi-tile grid steps.

    T_sorted: [M, R] decreasing-norm order, M % (block_m * tiles_per_step)
    == 0; tile_bounds: [B, n_steps, tiles_per_step] per-tile
    Cauchy-Schwarz bounds; sb_idx / live: [B, n_steps] int32 scalar-
    prefetch operands — the superblock each step maps (pre-pruned steps
    repeat the last live superblock: no DMA) and the pre-screen survivor
    mask. U: [B, R].

    Returns (values [B, k], local indices [B, k], stats [B, 3]).
    """
    M, R = T_sorted.shape
    B = U.shape[0]
    span = block_m * tiles_per_step
    assert M % span == 0, (M, span)
    n_steps = M // span
    assert tile_bounds.shape == (B, n_steps, tiles_per_step), \
        tile_bounds.shape
    assert sb_idx.shape == (B, n_steps) and live.shape == (B, n_steps)
    num_real = M if num_real < 0 else num_real
    kernel = functools.partial(_kernel_batched_prefetch, k=k,
                               block_m=block_m, tiles=tiles_per_step,
                               num_real=num_real)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_steps),
        in_specs=[
            pl.BlockSpec((1, 1, tiles_per_step),
                         lambda b, s, si, lv: (b, s, 0)),          # bounds
            pl.BlockSpec((span, R),
                         lambda b, s, si, lv: (si[b, s], 0)),      # supertile
            pl.BlockSpec((1, R, 1), lambda b, s, si, lv: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, s, si, lv: (b, 0)),
            pl.BlockSpec((1, k), lambda b, s, si, lv: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, s, si, lv: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 3), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(sb_idx, live, tile_bounds, T_sorted, U[:, :, None])


# ---------------------------------------------------------------------------
# Gather-fused scoring: score scattered rows without materialising the gather
# ---------------------------------------------------------------------------


def _gather_score_kernel(ids_ref, t_row_ref, u_ref, out_ref):
    # the row DMA'd for this step IS ids[i] (index-map remap below)
    out_ref[0] = jnp.dot(t_row_ref[0, :], u_ref[:, 0],
                         preferred_element_type=jnp.float32)


def gather_scores_pallas(T, ids, u, interpret=None):
    """Score ``C`` scattered catalogue rows as one fused kernel.

    ``T: [M, R]``, ``ids: [C] int32`` (need not be distinct, must be in
    range), ``u: [R]``. Returns ``T[ids] @ u`` — but the gather never
    materialises ``[C, R]`` in HBM: ``ids`` is a SCALAR-PREFETCH operand
    and the BlockSpec index map sends grid step ``i`` straight to row
    ``ids[i]``, so the pipeline DMAs exactly the rows needed, one
    ``(1, R)`` tile per step, overlapped with the matvec of the previous
    row. This is the post-prefix TAIL scorer for the list_major layout
    (DESIGN.md §7): the rare blocks past the prefix are scored without a
    separate XLA gather kernel and without HBM round-tripping the
    gathered rows.

    Falls back to the XLA gather+matvec when the installed jax lacks
    scalar prefetch. Exposed to the strategies through the ``score_fn``
    hook of :func:`repro.core.strategies.blocked_lists_strategy`.
    """
    if not HAS_SCALAR_PREFETCH:
        return T[ids] @ u
    C = ids.shape[0]
    R = T.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, R), lambda i, ids_: (ids_[i], 0)),    # row
            pl.BlockSpec((R, 1), lambda i, ids_: (0, 0)),          # u
        ],
        out_specs=[pl.BlockSpec((1,), lambda i, ids_: (i,))],
    )
    (out,) = pl.pallas_call(
        _gather_score_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((C,), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(ids, T, u[:, None])
    return out
