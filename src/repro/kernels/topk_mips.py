"""Pallas TPU kernel: threshold-pruned blocked MIPS top-K.

The hardware form of the paper's pruning idea (DESIGN.md §4): the catalogue
is stored in DECREASING-NORM order so that a whole VMEM tile of candidates
can be skipped with one Cauchy-Schwarz bound test

    max possible score in block b  <=  ||u|| * max_norm(block b)  <=  lowerBound

TPU mapping:
  * grid = (n_blocks,); TPU grid steps run sequentially on a core, so the
    running top-K lives in VMEM scratch and carries across blocks,
  * the tile load (block_m x R) is a contiguous HBM->VMEM DMA declared by
    BlockSpec (the norm ordering is what makes it contiguous — the paper's
    per-dimension lists would gather scattered rows),
  * scoring is one (block_m x R) @ (R x 1) MXU matvec per tile,
  * the merge is lax.top_k over K + block_m lanes,
  * the bound test is @pl.when on a scalar — a skipped block costs only
    its (prefetched) DMA, no MXU work.

The batched variant adds the query dimension to the grid —
``grid = (B, n_blocks)`` with blocks innermost, so each query's scan is
still sequential (the scratch top-K resets at block 0 of every query) and
the whole batch is one kernel launch.

Exactness: identical guarantee as core.blocked.norm_pruned_topk (blocks are
visited in decreasing max-norm order; once the K-th best exceeds the bound
no later block can contribute). Rows past ``num_real`` are zero padding
added by the catalogue wrapper; their scores are masked to -inf so a pad
row can never displace a real (possibly negative) score from the top-K.

``interpret=None`` autodetects: interpret mode off TPU (CPU CI runs the
kernel bodies in the Pallas interpreter), compiled on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def resolve_interpret(interpret):
    """None -> interpret everywhere except on real TPU backends."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _merge_block(scores, block_start, scratch_vals, scratch_idx,
                 *, k: int, block_m: int, num_real: int):
    ids = block_start + jax.lax.iota(jnp.int32, block_m)
    scores = jnp.where(ids < num_real, scores, NEG_INF)  # mask zero padding
    cand_vals = jnp.concatenate([scratch_vals[...], scores])
    cand_idx = jnp.concatenate([scratch_idx[...], ids])
    top, pos = jax.lax.top_k(cand_vals, k)
    scratch_vals[...] = top
    scratch_idx[...] = jnp.take(cand_idx, pos)


def _kernel(bound_ref, t_ref, u_ref, vals_ref, idx_ref, stats_ref,
            scratch_vals, scratch_idx, *, k: int, block_m: int,
            num_real: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        scratch_vals[...] = jnp.full_like(scratch_vals, NEG_INF)
        scratch_idx[...] = jnp.full_like(scratch_idx, -1)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    lb = scratch_vals[k - 1]
    bound = bound_ref[0]

    @pl.when(bound > lb)
    def _score():
        tile = t_ref[...]                                  # [block_m, R]
        u = u_ref[...]                                     # [R, 1]
        scores = jnp.dot(tile, u,
                         preferred_element_type=jnp.float32)[:, 0]
        _merge_block(scores, i * block_m, scratch_vals, scratch_idx,
                     k=k, block_m=block_m, num_real=num_real)
        stats_ref[0] += block_m                            # scored
        stats_ref[1] += 1                                  # blocks visited

    vals_ref[...] = scratch_vals[...]
    idx_ref[...] = scratch_idx[...]


def topk_mips_pallas(T_sorted, block_bounds, u, k: int,
                     block_m: int = 256, interpret=None,
                     num_real: int = -1):
    """T_sorted: [M, R] decreasing-norm order (M % block_m == 0);
    block_bounds: [n_blocks] = ||u|| * max norm per block; u: [R].

    Returns (values [k], local indices [k], stats [2] = (n_scored,
    blocks_visited)). ``num_real`` marks the tail of zero-padded rows
    (default: no padding). Validated in interpret mode on CPU; compiled
    path targets TPU VMEM tiling via the BlockSpecs below.
    """
    M, R = T_sorted.shape
    assert M % block_m == 0, (M, block_m)
    n_blocks = M // block_m
    num_real = M if num_real < 0 else num_real
    kernel = functools.partial(_kernel, k=k, block_m=block_m,
                               num_real=num_real)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),                    # bound
            pl.BlockSpec((block_m, R), lambda i: (i, 0)),          # T tile
            pl.BlockSpec((R, 1), lambda i: (0, 0)),                # u
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(block_bounds, T_sorted, u[:, None])


def _kernel_batched(bound_ref, t_ref, u_ref, vals_ref, idx_ref, stats_ref,
                    scratch_vals, scratch_idx, *, k: int, block_m: int,
                    num_real: int):
    j = pl.program_id(1)  # block index — innermost, sequential per query

    @pl.when(j == 0)
    def _init():
        # a new query's scan begins: reset the carried top-K
        scratch_vals[...] = jnp.full_like(scratch_vals, NEG_INF)
        scratch_idx[...] = jnp.full_like(scratch_idx, -1)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    lb = scratch_vals[k - 1]
    bound = bound_ref[0, 0]

    @pl.when(bound > lb)
    def _score():
        tile = t_ref[...]                                  # [block_m, R]
        u = u_ref[0]                                       # [R, 1]
        scores = jnp.dot(tile, u,
                         preferred_element_type=jnp.float32)[:, 0]
        _merge_block(scores, j * block_m, scratch_vals, scratch_idx,
                     k=k, block_m=block_m, num_real=num_real)
        stats_ref[0, 0] += block_m                         # scored
        stats_ref[0, 1] += 1                               # blocks visited

    vals_ref[0, :] = scratch_vals[...]
    idx_ref[0, :] = scratch_idx[...]


def topk_mips_pallas_batched(T_sorted, block_bounds, U, k: int,
                             block_m: int = 256, interpret=None,
                             num_real: int = -1):
    """Query-grid variant: one launch scans the catalogue for a whole batch.

    T_sorted: [M, R] decreasing-norm order (M % block_m == 0);
    block_bounds: [B, n_blocks] per-query Cauchy-Schwarz block bounds;
    U: [B, R] queries.

    Returns (values [B, k], local indices [B, k], stats [B, 2]). The grid
    is (B, n_blocks) with the block dimension innermost, so the VMEM
    scratch top-K carries across a query's blocks and resets when the grid
    advances to the next query. The catalogue tile DMA pattern is identical
    to the single-query kernel; only the tiny u / bound operands change per
    grid row.
    """
    M, R = T_sorted.shape
    B = U.shape[0]
    assert M % block_m == 0, (M, block_m)
    assert block_bounds.shape == (B, M // block_m), block_bounds.shape
    n_blocks = M // block_m
    num_real = M if num_real < 0 else num_real
    kernel = functools.partial(_kernel_batched, k=k, block_m=block_m,
                               num_real=num_real)
    return pl.pallas_call(
        kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, j)),             # bound
            pl.BlockSpec((block_m, R), lambda b, j: (j, 0)),       # T tile
            pl.BlockSpec((1, R, 1), lambda b, j: (b, 0, 0)),       # u
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, j: (b, 0)),
            pl.BlockSpec((1, k), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 2), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(block_bounds, T_sorted, U[:, :, None])
