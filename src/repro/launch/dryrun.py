import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    os.environ.get("REPRO_DRYRUN_DEVICES", "512")

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and record memory/cost/collective analysis.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first initialisation (see the brief). Do not
import this module from tests/benchmarks (they want 1 device); run it as
``PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k``.

Outputs one JSON per cell under --out (default results/dryrun/).
"""

import argparse   # noqa: E402
import gzip       # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import REGISTRY, all_cells, get_arch   # noqa: E402
from repro.launch.cells import build_cell                 # noqa: E402
from repro.launch.mesh import MESHES                      # noqa: E402
from repro.roofline.analysis import Roofline, from_compiled  # noqa: E402


def _compile_cell(cell, donate: bool = True):
    donate_args = ()
    if donate and cell.kind.endswith("_train"):
        donate_args = (0, 1)
    elif donate and cell.kind == "lm_decode":
        donate_args = (1,)
    jfn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                  out_shardings=cell.out_shardings,
                  donate_argnums=donate_args)
    lowered = jfn.lower(*cell.args)
    return lowered, lowered.compile()


def calibrated_roofline(arch_id, shape_name, mesh, n_chips, model_flops):
    """LM cells: XLA counts scan (while) bodies once, so compile the cell
    at n_layers in {1, 2} fully UNROLLED and extrapolate linearly:
    Q(L) = Q(1) + (Q(2) - Q(1)) * (L - 1). Collectives/bytes/FLOPs are all
    per-layer-affine, embed/unembed/loss live in the L-independent part."""
    qs = {}
    for L in (1, 2):
        cell = build_cell(arch_id, shape_name, mesh,
                          override={"n_layers": L, "unroll": True})
        _, compiled = _compile_cell(cell)
        r = from_compiled(compiled, compiled.as_text(), n_chips, 0.0)
        qs[L] = r
    L_full = get_arch(arch_id).make_config().n_layers
    def extrap(f):
        q1, q2 = f(qs[1]), f(qs[2])
        return q1 + (q2 - q1) * (L_full - 1)
    return Roofline(
        flops=extrap(lambda r: r.flops),
        hbm_bytes=extrap(lambda r: r.hbm_bytes),
        collective_bytes=extrap(lambda r: r.collective_bytes),
        n_chips=n_chips, model_flops=model_flops)


def run_cell(arch_id: str, shape_name: str, mesh_name: str,
             out_dir: str, donate: bool = True) -> dict:
    mesh = MESHES[mesh_name]()
    n_chips = mesh.devices.size
    record = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "n_chips": int(n_chips), "status": "unknown",
    }
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            cell = build_cell(arch_id, shape_name, mesh)
            lowered, compiled = _compile_cell(cell, donate)
            t_lower = 0.0
            t_compile = time.time() - t0

            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            roof_raw = from_compiled(compiled, hlo, n_chips, cell.model_flops)
            # the roofline table is single-pod only (brief: the multi-pod
            # pass just proves the pod axis shards) -> calibrate single-pod
            if get_arch(arch_id).family == "lm" and mesh_name != "multi":
                # de-bias the while-body-once cost analysis (DESIGN.md §8)
                roof = calibrated_roofline(arch_id, shape_name, mesh,
                                           n_chips, cell.model_flops)
            else:
                roof = roof_raw
            if mesh_name != "multi":
                os.makedirs(out_dir, exist_ok=True)
                hpath = os.path.join(
                    out_dir, f"{arch_id}__{shape_name}__{mesh_name}.hlo.gz")
                with gzip.open(hpath, "wt") as hf:
                    hf.write(hlo)
            record.update(
                status="ok",
                lower_s=round(t_lower, 2),
                compile_s=round(t_compile, 2),
                memory={
                    "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                    "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                    "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                    "peak_bytes_per_device": int(
                        getattr(mem, "argument_size_in_bytes", 0)
                        + getattr(mem, "output_size_in_bytes", 0)
                        + getattr(mem, "temp_size_in_bytes", 0)),
                },
                roofline=roof.to_dict(),
                roofline_scan_raw=roof_raw.to_dict(),
                meta=cell.meta,
                hlo_lines=len(hlo.splitlines()),
            )
            # console proof (per the brief)
            print(f"== {arch_id} x {shape_name} x {mesh_name} "
                  f"({n_chips} chips) ==")
            print(f"memory_analysis: {record['memory']}")
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            print("cost_analysis: flops=%.3e bytes=%.3e" % (
                float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0))))
            print("roofline:", json.dumps(record["roofline"], indent=None))
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        print(f"== {arch_id} x {shape_name} x {mesh_name} FAILED: "
              f"{record['error']}")
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch_id}__{shape_name}__{mesh_name}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=2, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=list(MESHES) + ["both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    ok = err = 0
    for arch_id, shape_name in cells:
        if arch_id is None or shape_name is None:
            raise SystemExit("--arch/--shape required unless --all")
        for mesh_name in meshes:
            rec = run_cell(arch_id, shape_name, mesh_name, args.out)
            ok += rec["status"] == "ok"
            err += rec["status"] != "ok"
    print(f"\nDRYRUN DONE: {ok} ok, {err} failed")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
