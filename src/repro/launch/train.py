"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the arch's REDUCED (smoke) config end to
end with the full substrate — synthetic data pipeline, AdamW, fault-
tolerant checkpointing, resume. On a real pod the same entry point takes
``--full --mesh single|multi`` and pjit-shards the step exactly like the
dry-run cells (launch/cells.py is the shared source of shardings).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import lm_batches, molecule_batch, random_graph, recsys_batches
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tf_mod
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def build(arch_id: str, batch: int, seq_len: int, seed: int):
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(seed)
    if spec.family == "lm":
        params = tf_mod.init_params(cfg, key)
        loss = lambda p, b: tf_mod.loss_fn(p, b, cfg)  # noqa: E731
        data = lambda: lm_batches(seed, cfg.vocab_size, batch, seq_len)  # noqa: E731
    elif spec.family == "recsys":
        params = recsys_mod.init_params(cfg, key)
        loss = lambda p, b: recsys_mod.loss_fn(p, b, cfg)  # noqa: E731
        data = lambda: recsys_batches(  # noqa: E731
            seed, cfg.n_dense, cfg.n_sparse, cfg.vocab_per_field, batch)
    elif spec.family == "gnn":
        params = gnn_mod.init_params(cfg, key)
        loss = lambda p, b: gnn_mod.loss_fn(p, b, cfg)  # noqa: E731
        rng = np.random.default_rng(seed)

        def data():
            while True:
                yield random_graph(rng, 256, 1024, cfg.d_in, cfg.n_classes)
    else:
        raise ValueError(spec.family)
    return cfg, params, loss, data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, params, loss, data = build(args.arch, args.batch, args.seq_len,
                                    args.seed)
    opt = OptimizerConfig(kind="adamw", lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    tr = Trainer(loss, params, opt, PrefetchLoader(data),
                 TrainerConfig(total_steps=args.steps, log_every=10,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir))
    final = tr.run()
    first = tr.history[0]["loss"] if tr.history else float("nan")
    print(f"arch={args.arch} config={cfg.name} steps={tr.step} "
          f"loss {first:.4f} -> {final.get('loss', float('nan')):.4f}")


if __name__ == "__main__":
    main()
