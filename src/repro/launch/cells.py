"""Dry-run cell builders: (architecture x input shape x mesh) -> lowerable.

Each builder returns a ``Cell``:
  fn            — the step callable (train_step / prefill / serve_step /
                  retrieval),
  args          — ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
                  NO device allocation),
  in_shardings  — NamedSharding pytree matching args,
  model_flops   — the analytic "useful" FLOPs for §Roofline
                  (6·N_active·D train / 2·N_active·D forward, + attention).

Builders must run under ``jax.set_mesh(mesh)`` so the divisibility-aware
sharding rules resolve against the actual mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ShapeCell
from repro.core.sharded import sharded_naive_topk
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tf_mod
from repro.models.common import MeshRules
from repro.train.optimizer import OptimizerConfig, apply_updates, init_state

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    model_flops: float
    meta: Dict[str, Any]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _mesh_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _dp_axes(mesh) -> Tuple[str, ...]:
    sizes = _mesh_sizes(mesh)
    return tuple(a for a in ("pod", "data") if a in sizes)


def _dp_size(mesh) -> int:
    sizes = _mesh_sizes(mesh)
    out = 1
    for a in _dp_axes(mesh):
        out *= sizes[a]
    return out


def _batch_spec(mesh, batch: int) -> P:
    dp = _dp_axes(mesh)
    return P(dp) if dp and batch % _dp_size(mesh) == 0 else P(None)


def _ns(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _sds_like(tree):
    return jax.tree_util.tree_map(
        lambda x: SDS(x.shape, x.dtype), tree)


OPT_CFG = OptimizerConfig(kind="adamw", lr=3e-4, total_steps=100_000,
                          warmup_steps=2000)


def _train_step(loss_fn):
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_p, new_s, om = apply_updates(OPT_CFG, params, grads, opt_state)
        return new_p, new_s, {"loss": loss, **metrics, **om}
    return step


def _params_and_opt_sds(init_fn):
    params = jax.eval_shape(init_fn)
    opt = jax.eval_shape(lambda p: init_state(OPT_CFG, p), params)
    return params, opt


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_attn_flops(cfg, batch: int, seq: int, factor: float) -> float:
    # qk^T + pv per layer: 2 * 2 * B * H * S^2/2 (causal) * hd
    per_layer = 2.0 * batch * cfg.n_heads * seq * seq * cfg.head_dim
    return factor * cfg.n_layers * per_layer


def _build_lm(arch_id: str, cell: ShapeCell, mesh, rules: MeshRules,
              override: Optional[Dict] = None) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_config()
    if override:
        cfg = dataclasses.replace(cfg, **override)
    dims = cell.dims
    B, S = dims["global_batch"], dims["seq_len"]
    p_sds, opt_sds = _params_and_opt_sds(
        lambda: tf_mod.init_params(cfg, jax.random.PRNGKey(0)))
    if cell.kind in ("lm_prefill", "lm_decode"):
        # §Perf-B iter 2: serving weights are stored bf16 (halves the
        # per-token weight-read memory term and the argument footprint)
        p_sds = jax.tree_util.tree_map(
            lambda x: SDS(x.shape, jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, p_sds)

    if cell.kind == "lm_train":
        pspec = tf_mod.param_specs(cfg, rules, "train")
        opt_spec = type(opt_sds)(P(), pspec, pspec)
        batch_sds = {"tokens": SDS((B, S), jnp.int32),
                     "labels": SDS((B, S), jnp.int32)}
        bspec = {"tokens": P(_dp_axes(mesh), None),
                 "labels": P(_dp_axes(mesh), None)}
        fn = _train_step(lambda p, b: tf_mod.loss_fn(p, b, cfg, rules))
        args = (p_sds, opt_sds, batch_sds)
        in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, bspec))
        out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), None)
        flops = 6.0 * cfg.active_param_count() * B * S \
            + 3.0 * _lm_attn_flops(cfg, B, S, 0.5)
    elif cell.kind == "lm_prefill":
        pspec = tf_mod.param_specs(cfg, rules, "serve")
        batch_sds = SDS((B, S), jnp.int32)
        fn = functools.partial(tf_mod.prefill, config=cfg, rules=rules)
        args = (p_sds, batch_sds)
        in_sh = (_ns(mesh, pspec),
                 NamedSharding(mesh, P(_dp_axes(mesh) if B % _dp_size(mesh) == 0 else None, None)))
        out_sh = None
        flops = 2.0 * cfg.active_param_count() * B * S \
            + _lm_attn_flops(cfg, B, S, 0.5)
    elif cell.kind == "lm_decode":
        pspec = tf_mod.param_specs(cfg, rules, "serve")
        cache_sds = jax.eval_shape(
            lambda: tf_mod.init_kv_cache(cfg, B, S))
        cache_spec = tf_mod.kv_cache_specs(cfg, rules, B, S)
        tok_spec = P(_dp_axes(mesh) if B % _dp_size(mesh) == 0 else None, None)
        tok_sds = SDS((B, 1), jnp.int32)
        clen_sds = SDS((), jnp.int32)

        def fn(params, cache, tokens, cache_len):
            return tf_mod.serve_step(params, cache, tokens, cache_len, cfg,
                                     rules, top_k=8)

        args = (p_sds, cache_sds, tok_sds, clen_sds)
        in_sh = (_ns(mesh, pspec), _ns(mesh, cache_spec),
                 NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()))
        out_sh = None
        # one token per sequence + attention over the cache
        flops = 2.0 * cfg.active_param_count() * B \
            + 4.0 * cfg.n_layers * B * cfg.n_heads * S * cfg.head_dim
    else:
        raise ValueError(cell.kind)
    return Cell(arch_id, cell.name, cell.kind, fn, args, in_sh, out_sh,
                flops, {"config": cfg.name, "params": cfg.param_count(),
                        "active_params": cfg.active_param_count(),
                        "batch": B, "seq": S})


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _build_gnn(arch_id: str, cell: ShapeCell, mesh, rules: MeshRules) -> Cell:
    spec = get_arch(arch_id)
    dims = cell.dims
    task = dims.get("task", "node")
    cfg = spec.make_config(d_feat=dims["d_feat"],
                           n_classes=dims["n_classes"], task=task)
    dp = _dp_axes(mesh)
    dp_size = _dp_size(mesh)

    if cell.name == "minibatch_lg":
        N = _pad_to(dims["pad_nodes"], 512)
        E = _pad_to(dims["pad_edges"], 512)
    elif cell.name == "molecule":
        N = dims["batch"] * dims["n_nodes"]
        E = _pad_to(dims["batch"] * dims["n_edges"], 512)
    else:
        N = dims["n_nodes"]
        E = _pad_to(dims["n_edges"], 512)

    graph_sds = {
        "nodes": SDS((N, dims["d_feat"]), jnp.float32),
        "edge_src": SDS((E,), jnp.int32),
        "edge_dst": SDS((E,), jnp.int32),
        "edge_mask": SDS((E,), jnp.bool_),
        "node_mask": SDS((N,), jnp.bool_),
        "labels": SDS((dims["batch"],) if task == "graph" else (N,), jnp.int32),
    }
    espec = P(dp) if E % dp_size == 0 else P(None)
    gspec = {
        "nodes": P(None, None),
        "edge_src": espec, "edge_dst": espec, "edge_mask": espec,
        "node_mask": P(None), "labels": P(None),
    }
    if task == "graph":
        graph_sds["graph_ids"] = SDS((N,), jnp.int32)
        graph_sds["n_graphs"] = dims["batch"]
        gspec["graph_ids"] = P(None)
        gspec["n_graphs"] = None

    p_sds, opt_sds = _params_and_opt_sds(
        lambda: gnn_mod.init_params(cfg, jax.random.PRNGKey(0)))
    pspec = gnn_mod.param_specs(cfg, rules)
    opt_spec = type(opt_sds)(P(), pspec, pspec)

    static_ng = graph_sds.pop("n_graphs", None)
    gspec.pop("n_graphs", None)

    def loss(p, g):
        if static_ng is not None:
            g = dict(g, n_graphs=static_ng)
        return gnn_mod.loss_fn(p, g, cfg, rules)

    fn = _train_step(loss)
    args = (p_sds, opt_sds, graph_sds)
    in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, gspec))
    out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), None)
    d = cfg.d_hidden
    flops = 3.0 * cfg.n_layers * (2.0 * E * (2 * d) * d + 2.0 * N * (12 * d) * d) \
        + 6.0 * N * dims["d_feat"] * d
    return Cell(arch_id, cell.name, cell.kind, fn, args, in_sh, out_sh,
                flops, {"config": cfg.name, "params": cfg.param_count(),
                        "nodes": N, "edges": E})


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------


def _recsys_batch(cfg, B: int, mesh):
    sds = {
        "dense": SDS((B, cfg.n_dense), jnp.float32),
        "sparse": SDS((B, cfg.n_sparse), jnp.int32),
        "label": SDS((B,), jnp.float32),
    }
    bspec = _batch_spec(mesh, B)
    spec = {"dense": P(*bspec, None), "sparse": P(*bspec, None),
            "label": bspec}
    return sds, spec


def _recsys_mlp_flops(cfg) -> float:
    """per-example forward MACs x2 in the dense towers + interaction."""
    fl = 0.0
    if cfg.arch == "deepfm":
        dims = (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp_dims + (1,)
        fl += sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        fl += 4.0 * cfg.n_sparse * cfg.embed_dim
    if cfg.arch == "fm":
        fl += 4.0 * cfg.n_sparse * cfg.embed_dim
    if cfg.arch == "dcn_v2":
        d0 = cfg.interaction_input
        fl += cfg.n_cross_layers * 2.0 * d0 * d0
        dims = (d0,) + cfg.mlp_dims + (1,)
        fl += sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    if cfg.arch == "dlrm":
        dims = (cfg.n_dense,) + cfg.bot_mlp
        fl += sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        n = cfg.n_sparse + 1
        fl += 2.0 * n * n * cfg.embed_dim
        dims = (cfg.interaction_input,) + cfg.top_mlp
        fl += sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    return fl


def _build_recsys(arch_id: str, cell: ShapeCell, mesh, rules: MeshRules) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_config()
    dims = cell.dims
    p_sds, opt_sds = _params_and_opt_sds(
        lambda: recsys_mod.init_params(cfg, jax.random.PRNGKey(0)))
    pspec = recsys_mod.param_specs(cfg, rules)

    if cell.kind == "recsys_train":
        B = dims["batch"]
        batch_sds, bspec = _recsys_batch(cfg, B, mesh)
        opt_spec = type(opt_sds)(P(), pspec, pspec)
        fn = _train_step(lambda p, b: recsys_mod.loss_fn(p, b, cfg, rules))
        args = (p_sds, opt_sds, batch_sds)
        in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, bspec))
        out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), None)
        flops = 3.0 * B * _recsys_mlp_flops(cfg)
    elif cell.kind == "recsys_serve":
        B = dims["batch"]
        batch_sds, bspec = _recsys_batch(cfg, B, mesh)
        fn = functools.partial(recsys_mod.forward, config=cfg, rules=rules)

        def fn(p, b, _cfg=cfg, _r=rules):
            return recsys_mod.forward(p, b, _cfg, _r)

        args = (p_sds, batch_sds)
        in_sh = (_ns(mesh, pspec), _ns(mesh, bspec))
        out_sh = None
        flops = 1.0 * B * _recsys_mlp_flops(cfg)
    elif cell.kind == "recsys_retrieval":
        B = dims["batch"]
        M = _pad_to(dims["n_candidates"], 1 << 14)   # even sharding at 512
        axes = tuple(a for a in ("data", "model") if a in _mesh_sizes(mesh))
        topk_fn = sharded_naive_topk(mesh, P(axes, None), axes)
        batch_sds, bspec = _recsys_batch(cfg, B, mesh)
        batch_sds.pop("label"); bspec.pop("label")
        # §Perf-C: candidate catalogue served in bf16 (halves the scan read;
        # scores accumulate f32 inside the merge)
        cand_sds = SDS((M, cfg.embed_dim), jnp.bfloat16)

        def fn(params, batch, candidates, _cfg=cfg, _r=rules):
            u = recsys_mod.query_tower(params, batch, _cfg, _r)
            return topk_fn(candidates, u, 100)

        args = (p_sds, batch_sds, cand_sds)
        in_sh = (_ns(mesh, pspec), _ns(mesh, bspec),
                 NamedSharding(mesh, P(axes, None)))
        out_sh = None
        flops = 2.0 * B * M * cfg.embed_dim
    else:
        raise ValueError(cell.kind)
    return Cell(arch_id, cell.name, cell.kind, fn, args, in_sh, out_sh,
                flops, {"config": cfg.name, "params": cfg.param_count(),
                        "batch": dims.get("batch")})


# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh,
               rules: Optional[MeshRules] = None,
               override: Optional[Dict] = None) -> Cell:
    """Must be called under ``jax.set_mesh(mesh)``.

    ``override`` (LM only): dataclasses.replace kwargs on the config —
    used by the dry-run's roofline calibration compiles (n_layers 1/2,
    unroll=True) to de-bias XLA's while-body-counted-once cost analysis.
    """
    rules = rules or MeshRules()
    spec = get_arch(arch_id)
    cell = spec.shape(shape_name)
    if spec.family == "lm":
        return _build_lm(arch_id, cell, mesh, rules, override)
    if spec.family == "gnn":
        return _build_gnn(arch_id, cell, mesh, rules)
    if spec.family == "recsys":
        return _build_recsys(arch_id, cell, mesh, rules)
    raise ValueError(spec.family)


def lm_family(arch_id: str) -> bool:
    return get_arch(arch_id).family == "lm" 
