"""Production meshes. FUNCTIONS, never module-level constants — importing
this module must not touch jax device state (dryrun.py sets the fake device
count before any jax initialisation)."""

from __future__ import annotations

import jax


def _mk(shape, axes):
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False):
    """Scaled-down mesh for in-repo integration tests (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


MESHES = {
    "single": lambda: make_production_mesh(multi_pod=False),
    "multi": lambda: make_production_mesh(multi_pod=True),
    "tiny": lambda: make_tiny_mesh(multi_pod=False),
    "tiny-multi": lambda: make_tiny_mesh(multi_pod=True),
}
