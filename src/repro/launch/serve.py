"""Serving launcher: exact top-K query serving over a SEP-LR catalogue.

``python -m repro.launch.serve --targets 50000 --rank 50 --k 10 -n 200``
builds a catalogue, indexes it, and serves batched queries through the
selected engine, printing the paper's efficiency metric (scores/query)
next to wall time. ``--engine all`` sweeps every exact engine in the
registry (``repro.core.engines``); any registry name or alias is accepted.
"""

from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--targets", type=int, default=20000)
    ap.add_argument("--rank", type=int, default=50)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("-n", "--num-queries", type=int, default=100)
    ap.add_argument("--batch", type=int, default=25)
    ap.add_argument("--engine", default="bta",
                    help="registry engine name/alias, or 'all' to sweep "
                         "every exact engine")
    ap.add_argument("--distribution", default="lowrank_spectrum",
                    choices=["normal", "lognormal", "lowrank_spectrum"])
    ap.add_argument("--block-size", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.core import random_model
    from repro.core.engines import auto_candidates, get_engine, list_engines
    from repro.serving.server import TopKServer

    rng = np.random.default_rng(args.seed)
    model = random_model(rng, args.targets, args.rank, args.distribution)
    print(f"catalogue: M={args.targets} R={args.rank} "
          f"dist={args.distribution}; building index...")
    srv = TopKServer(model, max_batch=args.batch, block_size=args.block_size)
    spectrum = (1.0 / np.sqrt(1.0 + np.arange(args.rank))).astype(np.float32) \
        if args.distribution == "lowrank_spectrum" else 1.0
    U = jnp.asarray(rng.standard_normal(
        (args.num_queries, args.rank)).astype(np.float32) * spectrum)

    if args.engine == "all":
        # skip the host-only numpy oracles: item-at-a-time python loops
        # at serving sizes are minutes per batch (they stay reachable by
        # explicit --engine fagin / partial)
        engines = [e.name for e in list_engines(exact=True)
                   if e.name != "auto" and not e.host_only]
        # naive first: it is the ground-truth reference the others are
        # asserted against
        engines.sort(key=lambda n: n != "naive")
    else:
        engines = [get_engine(args.engine).name]
    # populate the compiled-executable cache so reported us/query is
    # steady-state serving latency, not trace+compile time (DESIGN.md §6).
    # Warm the buckets the actual chunk sequence will hit: full chunks of
    # --batch plus the remainder chunk, not just --batch.
    sizes = {min(args.batch, args.num_queries)}
    if args.num_queries % args.batch:
        sizes.add(args.num_queries % args.batch)
    # auto resolves per batch to a concrete engine — warm exactly the
    # candidates its policy can pick (host oracles have no compiled
    # executable; never warm them)
    warm = [e for e in engines
            if e != "auto" and not get_engine(e).host_only]
    if "auto" in engines:
        warm = sorted(set(warm) | set(auto_candidates()))
    if warm:
        srv.warmup(args.k, batch_sizes=sorted(sizes), engines=warm)
    ref = None
    for eng in engines:
        res = srv.query(U, args.k, method=eng)
        if ref is None:
            ref = np.sort(np.asarray(res.values), axis=1)
        else:
            assert np.allclose(np.sort(np.asarray(res.values), axis=1), ref,
                               atol=1e-4), f"{eng} mismatches naive!"
        # auto's traffic is accounted to the engine that actually ran
        # (DESIGN.md §3), so report every resolved engine it used
        resolved = sorted(srv.stats) if eng == "auto" else [eng]
        for name in resolved:
            st = srv.stats[name]
            label = f"auto->{name}" if eng == "auto" else name
            print(f"{label:>12s}: {st.scores_per_query:10.1f} scores/query "
                  f"({st.scores_per_query / args.targets:6.2%} of naive)  "
                  f"{st.us_per_query:10.1f} us/query")


if __name__ == "__main__":
    main()
