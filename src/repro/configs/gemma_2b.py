"""gemma-2b [arXiv:2403.08295; hf] — GeGLU, head_dim 256, MQA (kv=1)."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="gemma-2b", n_layers=18, d_model=2048, n_heads=8,
        n_kv_heads=1, head_dim=256, d_ff=16384, vocab_size=256000,
        act="gelu_tanh")


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="gemma-2b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=32, d_ff=128, vocab_size=512,
        act="gelu_tanh", logit_chunk=64, kv_block=32)


SPEC = ArchSpec("gemma-2b", "lm", "arXiv:2403.08295",
                make_config, make_smoke_config, LM_SHAPES)
