"""dcn-v2 [arXiv:2008.13535; paper] — 13 dense, 26 sparse, embed 16,
3 cross layers, MLP 1024-1024-512."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig


def make_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="dcn-v2", arch="dcn_v2", n_dense=13, n_sparse=26,
                        embed_dim=16, vocab_per_field=1_000_000,
                        mlp_dims=(1024, 1024, 512), n_cross_layers=3)


def make_smoke_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="dcn-v2-smoke", arch="dcn_v2", n_dense=4,
                        n_sparse=6, embed_dim=4, vocab_per_field=100,
                        mlp_dims=(16, 8), n_cross_layers=2)


SPEC = ArchSpec("dcn-v2", "recsys", "arXiv:2008.13535",
                make_config, make_smoke_config, RECSYS_SHAPES)
