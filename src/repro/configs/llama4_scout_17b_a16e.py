"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d5120 40H(kv8) d_ff 8192 vocab 202048, MoE 16e top-1."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, head_dim=128, d_ff=0, vocab_size=202048,
        moe=True, n_experts=16, moe_top_k=1, moe_d_ff=8192, act="silu")


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="llama4-scout-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, head_dim=8, d_ff=0, vocab_size=512,
        moe=True, n_experts=4, moe_top_k=1, moe_d_ff=64, act="silu",
        logit_chunk=64, kv_block=32)


SPEC = ArchSpec("llama4-scout-17b-a16e", "lm",
                "hf:meta-llama/Llama-4-Scout-17B-16E",
                make_config, make_smoke_config, LM_SHAPES)
