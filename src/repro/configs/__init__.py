"""Registry of the 10 assigned architectures (+ the paper's own configs)."""
from repro.configs import (
    dcn_v2,
    deepfm,
    deepseek_67b,
    dlrm_rm2,
    fm,
    gemma_2b,
    llama4_scout_17b_a16e,
    olmoe_1b_7b,
    pna,
    stablelm_3b,
)
from repro.configs.base import ArchSpec, ShapeCell

REGISTRY = {
    spec.arch_id: spec
    for spec in [
        olmoe_1b_7b.SPEC,
        llama4_scout_17b_a16e.SPEC,
        deepseek_67b.SPEC,
        gemma_2b.SPEC,
        stablelm_3b.SPEC,
        pna.SPEC,
        deepfm.SPEC,
        dcn_v2.SPEC,
        dlrm_rm2.SPEC,
        fm.SPEC,
    ]
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def all_cells():
    """Every (arch x shape) dry-run cell — 40 total."""
    for arch_id, spec in REGISTRY.items():
        for cell in spec.shapes:
            yield arch_id, cell.name
