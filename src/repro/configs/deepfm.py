"""deepfm [arXiv:1703.04247; paper] — 39 sparse, embed 10, MLP 400x3, FM."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig


def make_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="deepfm", arch="deepfm", n_dense=0, n_sparse=39,
                        embed_dim=10, vocab_per_field=1_000_000,
                        mlp_dims=(400, 400, 400))


def make_smoke_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="deepfm-smoke", arch="deepfm", n_dense=0,
                        n_sparse=8, embed_dim=4, vocab_per_field=100,
                        mlp_dims=(16, 16))


SPEC = ArchSpec("deepfm", "recsys", "arXiv:1703.04247",
                make_config, make_smoke_config, RECSYS_SHAPES)
