"""olmoe-1b-7b [arXiv:2409.02060; hf] — 16L d2048 16H(kv16) MoE 64e top-8."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=0, vocab_size=50304,
        moe=True, n_experts=64, moe_top_k=8, moe_d_ff=1024, act="silu")


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-1b-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=0, vocab_size=512,
        moe=True, n_experts=8, moe_top_k=2, moe_d_ff=32, act="silu",
        logit_chunk=64, kv_block=32)


SPEC = ArchSpec("olmoe-1b-7b", "lm", "arXiv:2409.02060",
                make_config, make_smoke_config, LM_SHAPES)
