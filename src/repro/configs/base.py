"""Architecture registry: every assigned arch is a selectable config.

An ArchSpec pairs the exact published configuration with its assigned
input-shape set (each family has its own shape vocabulary), plus a reduced
smoke configuration exercised by per-arch CPU tests. The FULL configs are
only ever lowered via ShapeDtypeStruct in the dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    name: str
    kind: str                  # lm_train | lm_prefill | lm_decode |
    #                            gnn_train | recsys_train | recsys_serve |
    #                            recsys_retrieval
    dims: Dict[str, int]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                        # lm | gnn | recsys
    source: str                        # citation per the assignment
    make_config: Callable[..., object]     # full config (may take shape kwargs)
    make_smoke_config: Callable[..., object]
    shapes: Tuple[ShapeCell, ...]

    def shape(self, name: str) -> ShapeCell:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}; "
                       f"available: {[s.name for s in self.shapes]}")


# ----- family shape sets (assignment block) ---------------------------------

LM_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "lm_train", {"seq_len": 4096, "global_batch": 256}),
    ShapeCell("prefill_32k", "lm_prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeCell("decode_32k", "lm_decode", {"seq_len": 32768, "global_batch": 128}),
    # long_500k is a DECODE shape (1 token against a 512k KV cache) —
    # linear in context, so full-attention archs run it (DESIGN.md §3).
    ShapeCell("long_500k", "lm_decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("full_graph_sm", "gnn_train",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
               "n_classes": 7}),
    ShapeCell("minibatch_lg", "gnn_train",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout0": 15, "fanout1": 10, "d_feat": 602, "n_classes": 41,
               # padded subgraph sizes for the sampled-training step:
               # seeds + 15*seeds + 10*15*seeds nodes; edges 15s + 150s
               "pad_nodes": 1024 * (1 + 15 + 150), "pad_edges": 1024 * (15 + 150)}),
    ShapeCell("ogb_products", "gnn_train",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
               "n_classes": 47}),
    ShapeCell("molecule", "gnn_train",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 14,
               "n_classes": 2, "task": "graph"}),
)

RECSYS_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_batch", "recsys_train", {"batch": 65536}),
    ShapeCell("serve_p99", "recsys_serve", {"batch": 512}),
    ShapeCell("serve_bulk", "recsys_serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "recsys_retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
)
