"""The paper's own model class: SEP-LR catalogues at the scales of its
experiments (§4.1 CF, §4.2 Uniprot, §4.4 LSHTC). Used by benchmarks."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SepLRBenchConfig:
    name: str
    num_targets: int
    rank: int
    distribution: str = "normal"
    sparsity: float = 0.0


# paper-scale stand-ins (offline container; see EXPERIMENTS.md)
CF_DATASETS = (
    SepLRBenchConfig("audioscrobbler-like", 47085, 50, "lognormal", 0.99),
    SepLRBenchConfig("bookcrossing-like", 105283, 50, "lognormal", 0.995),
    SepLRBenchConfig("movielens100k-like", 1682, 50, "normal", 0.94),
    SepLRBenchConfig("movielens1m-like", 3952, 50, "normal", 0.96),
    SepLRBenchConfig("recipes-like", 381, 50, "lognormal", 0.9),
)

UNIPROT_LIKE = SepLRBenchConfig("uniprot-like", 21274, 500, "lowrank_spectrum")
LSHTC_LIKE = SepLRBenchConfig("lshtc-like", 325056, 100, "lowrank_spectrum")
