"""fm [ICDM'10 (Rendle); paper] — 39 sparse, embed 10, pairwise via O(nk)
sum-square trick. Exactly the paper's SEP-LR model class."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig


def make_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="fm", arch="fm", n_dense=0, n_sparse=39,
                        embed_dim=10, vocab_per_field=1_000_000)


def make_smoke_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="fm-smoke", arch="fm", n_dense=0, n_sparse=8,
                        embed_dim=4, vocab_per_field=100)


SPEC = ArchSpec("fm", "recsys", "ICDM'10 Rendle",
                make_config, make_smoke_config, RECSYS_SHAPES)
