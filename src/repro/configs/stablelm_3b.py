"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b; unverified] — dense MHA."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-3b", n_layers=32, d_model=2560, n_heads=32,
        n_kv_heads=32, head_dim=80, d_ff=6912, vocab_size=50304, act="silu")


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, act="silu",
        logit_chunk=64, kv_block=32)


SPEC = ArchSpec("stablelm-3b", "lm", "hf:stabilityai/stablelm-2-1_6b",
                make_config, make_smoke_config, LM_SHAPES)
