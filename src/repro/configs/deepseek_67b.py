"""deepseek-67b [arXiv:2401.02954; hf] — dense llama-arch 95L d8192 64H(kv8)."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-67b", n_layers=95, d_model=8192, n_heads=64,
        n_kv_heads=8, head_dim=128, d_ff=22016, vocab_size=102400, act="silu")


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-67b-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, head_dim=8, d_ff=128, vocab_size=512, act="silu",
        logit_chunk=64, kv_block=32)


SPEC = ArchSpec("deepseek-67b", "lm", "arXiv:2401.02954",
                make_config, make_smoke_config, LM_SHAPES)
