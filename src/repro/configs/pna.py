"""pna [arXiv:2004.05718; paper] — 4L d75, mean/max/min/std x id/amp/atten."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import PNAConfig


def make_config(d_feat: int = 1433, n_classes: int = 7, task: str = "node",
                **kw) -> PNAConfig:
    return PNAConfig(name="pna", n_layers=4, d_hidden=75, d_in=d_feat,
                     n_classes=n_classes, task=task)


def make_smoke_config(**kw) -> PNAConfig:
    return PNAConfig(name="pna-smoke", n_layers=2, d_hidden=16, d_in=8,
                     n_classes=3)


SPEC = ArchSpec("pna", "gnn", "arXiv:2004.05718",
                make_config, make_smoke_config, GNN_SHAPES)
