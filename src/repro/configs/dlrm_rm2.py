"""dlrm-rm2 [arXiv:1906.00091; paper] — 13 dense, 26 sparse, embed 64,
bot 13-512-256-64, top 512-512-256-1, dot interaction."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig


def make_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="dlrm-rm2", arch="dlrm", n_dense=13, n_sparse=26,
                        embed_dim=64, vocab_per_field=1_000_000,
                        bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1))


def make_smoke_config(**kw) -> RecsysConfig:
    return RecsysConfig(name="dlrm-smoke", arch="dlrm", n_dense=4, n_sparse=6,
                        embed_dim=8, vocab_per_field=100,
                        bot_mlp=(16, 8), top_mlp=(16, 8, 1))


SPEC = ArchSpec("dlrm-rm2", "recsys", "arXiv:1906.00091",
                make_config, make_smoke_config, RECSYS_SHAPES)
