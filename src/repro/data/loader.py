"""Host data loader: sharding-aware, prefetching, deterministically resumable.

Each host pulls only its shard of the global batch (``shard``/``num_shards``
from the launcher); a background thread keeps ``prefetch`` batches ready.
``skip(n)`` fast-forwards after checkpoint restore so the token stream is
bitwise identical to an uninterrupted run (tested in test_train.py).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional


class PrefetchLoader:
    def __init__(self, make_iter: Callable[[], Iterator[Dict]],
                 prefetch: int = 2):
        self._make_iter = make_iter
        self._prefetch = prefetch
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._iter: Optional[Iterator[Dict]] = None
        self._stop = threading.Event()

    def skip(self, n: int) -> "PrefetchLoader":
        """Fast-forward n batches (resume-after-restore)."""
        it = self._make_iter()
        for _ in range(n):
            next(it)
        self._iter = it
        return self

    def _worker(self):
        it = self._iter if self._iter is not None else self._make_iter()
        try:
            for batch in it:
                if self._stop.is_set():
                    return
                self._queue.put(batch)
        finally:
            self._queue.put(None)

    def __iter__(self):
        self._queue = queue.Queue(maxsize=self._prefetch)
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        while True:
            batch = self._queue.get()
            if batch is None:
                return
            yield batch

    def close(self):
        self._stop.set()
        if self._queue is not None:
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
