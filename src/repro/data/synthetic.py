"""Synthetic corpora shaped like the paper's datasets + arch training data.

The offline container cannot download Movielens/BookCrossing/
Audioscrobbler/Uniprot/LSHTC; these generators reproduce their *shape
statistics* — size, sparsity, implicit/explicit feedback, factor spectra,
popularity power laws — which is what the paper's (purely algorithmic)
efficiency claims depend on (EXPERIMENTS.md).

Everything is deterministic in (seed, shard): restarted jobs regenerate
bitwise-identical batches (fault-tolerance invariant, tested).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Collaborative-filtering matrices (paper §4.1)
# ---------------------------------------------------------------------------


def cf_ratings(
    rng: np.random.Generator,
    n_users: int,
    n_items: int,
    density: float = 0.01,
    implicit: bool = False,
    rank: int = 20,
) -> np.ndarray:
    """Dense low-rank-plus-noise rating matrix with power-law item popularity.

    Mirrors the paper's CF set-up: explicit feedback (ratings 1..5) or
    implicit (log play counts, non-negative).
    """
    U = rng.standard_normal((n_users, rank)) / np.sqrt(rank)
    V = rng.standard_normal((n_items, rank)) / np.sqrt(rank)
    scores = U @ V.T
    popularity = rng.zipf(1.5, n_items).astype(np.float64)
    popularity = np.clip(popularity / popularity.max(), 1e-4, 1.0)
    mask = rng.random((n_users, n_items)) < density * popularity[None, :] \
        / popularity.mean()
    if implicit:
        M = np.where(mask, np.log1p(np.abs(scores) * 10), 0.0)
    else:
        M = np.where(mask, np.clip(np.round(3 + 2 * scores), 1, 5), 0.0)
    return M.astype(np.float32)


def probabilistic_pca(M: np.ndarray, rank: int, n_iters: int = 12,
                      seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """EM for probabilistic PCA (paper §4.1: Tipping & Bishop 1997) —
    returns (U [n, r], V [m, r]) with M ~= U V^T. Deterministic."""
    rng = np.random.default_rng(seed)
    n, m = M.shape
    W = rng.standard_normal((m, rank)).astype(np.float64) * 0.01
    X = M.astype(np.float64)
    for _ in range(n_iters):
        # E: latent posterior mean (sigma^2 -> 0 limit == alternating LS)
        Z = X @ W @ np.linalg.inv(W.T @ W + 1e-6 * np.eye(rank))
        W = X.T @ Z @ np.linalg.inv(Z.T @ Z + 1e-6 * np.eye(rank))
    Z = X @ W @ np.linalg.inv(W.T @ W + 1e-6 * np.eye(rank))
    return Z.astype(np.float32), W.astype(np.float32)


# ---------------------------------------------------------------------------
# Multi-label ridge / PLS style factors (paper §4.2, §4.4)
# ---------------------------------------------------------------------------


def multilabel_factors(
    rng: np.random.Generator,
    n_labels: int,
    n_features: int,
    kind: str = "ridge",
) -> np.ndarray:
    """Label-side weight matrix T: [n_labels, R].

    ``ridge``: anisotropic weights with decaying feature relevance (what a
    ridge model trained on correlated features looks like — TA-friendly).
    ``pls``: orthogonalised, near-isotropic factors (the paper observes PLS
    is TA-hostile because variance is spread evenly).
    """
    T = rng.standard_normal((n_labels, n_features)).astype(np.float32)
    if kind == "ridge":
        spectrum = 1.0 / np.sqrt(1.0 + np.arange(n_features, dtype=np.float32))
        T *= spectrum[None, :]
        # label popularity skew (GO term frequencies are power-law)
        pop = rng.zipf(1.8, n_labels).astype(np.float32)
        T *= np.log1p(pop[:, None]) / 3.0
    elif kind == "pls":
        q, _ = np.linalg.qr(T.T @ T + 1e-3 * np.eye(n_features))
        T = (T @ q).astype(np.float32)
    return T


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------


def lm_batches(seed: int, vocab: int, batch: int, seq_len: int,
               shard: int = 0, num_shards: int = 1) -> Iterator[Dict]:
    """Zipf-distributed token stream; labels = next token. Infinite."""
    local = batch // num_shards
    step = 0
    while True:
        # (seed, step, shard) -> independent, reproducible stream
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, shard]))
        toks = rng.zipf(1.2, (local, seq_len + 1)) % vocab
        toks = toks.astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        step += 1


# ---------------------------------------------------------------------------
# Recsys click logs
# ---------------------------------------------------------------------------


def recsys_batches(seed: int, n_dense: int, n_sparse: int, vocab_per_field: int,
                   batch: int, shard: int = 0, num_shards: int = 1,
                   embed_dim_for_labels: int = 8) -> Iterator[Dict]:
    """Criteo-shaped synthetic clicks: power-law ids, planted logistic CTR."""
    local = batch // num_shards
    ss = np.random.SeedSequence([seed, 7, shard])
    planted = np.random.default_rng(ss).standard_normal(
        (n_sparse, 8)).astype(np.float32)
    step = 0
    while True:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, shard]))
        dense = rng.standard_normal((local, n_dense)).astype(np.float32) \
            if n_dense else np.zeros((local, 0), np.float32)
        sparse = (rng.zipf(1.3, (local, n_sparse)) % vocab_per_field).astype(np.int32)
        # planted CTR signal so training can actually reduce the loss
        sig = np.tanh((sparse % 8) @ planted.sum(axis=1) / (4 * n_sparse))
        prob = 1.0 / (1.0 + np.exp(-2.0 * sig))
        label = (rng.random(local) < prob).astype(np.float32)
        yield {"dense": dense, "sparse": sparse, "label": label}
        step += 1


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------


def random_graph(rng: np.random.Generator, n_nodes: int, n_edges: int,
                 d_feat: int, n_classes: int = 7,
                 power_law: bool = True) -> Dict[str, np.ndarray]:
    """Power-law (preferential-attachment-ish) graph with planted community
    labels correlated with features (so GNN accuracy is learnable)."""
    if power_law:
        w = rng.zipf(1.6, n_nodes).astype(np.float64)
        p = w / w.sum()
        src = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
        dst = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
    else:
        src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
        dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    centers = rng.standard_normal((n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + 0.5 * rng.standard_normal(
        (n_nodes, d_feat)).astype(np.float32)
    return {
        "nodes": feats,
        "edge_src": src,
        "edge_dst": dst,
        "edge_mask": np.ones(n_edges, bool),
        "node_mask": np.ones(n_nodes, bool),
        "labels": labels,
    }


def molecule_batch(rng: np.random.Generator, n_graphs: int, nodes_per: int,
                   edges_per: int, d_feat: int, n_classes: int = 2) -> Dict:
    """Batched small graphs flattened with offsets (molecule cells)."""
    N = n_graphs * nodes_per
    E = n_graphs * edges_per
    offs = np.repeat(np.arange(n_graphs) * nodes_per, edges_per)
    src = (rng.integers(0, nodes_per, E) + offs).astype(np.int32)
    dst = (rng.integers(0, nodes_per, E) + offs).astype(np.int32)
    labels = rng.integers(0, n_classes, n_graphs).astype(np.int32)
    centers = rng.standard_normal((n_classes, d_feat)).astype(np.float32)
    feats = (np.repeat(centers[labels], nodes_per, axis=0)
             + 0.7 * rng.standard_normal((N, d_feat))).astype(np.float32)
    return {
        "nodes": feats,
        "edge_src": src,
        "edge_dst": dst,
        "edge_mask": np.ones(E, bool),
        "node_mask": np.ones(N, bool),
        "labels": labels,
        "graph_ids": np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per),
        "n_graphs": n_graphs,
    }
