"""Pod-scale streaming sweep: the sharded LSM ladder vs single-level
compaction (DESIGN.md §15).

Both sides replay the SAME pre-generated insert-heavy schedule at the
SAME delta capacity. The single-level :class:`SegmentedCatalogue` must
run a FULL base rebuild (index + layout over all M rows) on every delta
overflow; the :class:`ShardedLsmCatalogue` absorbs those overflows with
per-shard L0 -> L1 folds — a round-robin row deal that touches only the
shard slabs, never the base index — and pays a full rebuild only when
the L1 tier itself overflows (promotion). The headline measurement is
``rebuild_amortisation``: single-level full rebuilds divided by ladder
full rebuilds over an identical mutation stream — by construction
roughly ``1 + l1_capacity_total / delta_capacity`` (~4x the shard count
at the default :data:`~repro.core.DEFAULT_L1_CAPACITY_FACTOR` sizing).

Exactness is never traded for the amortisation: every query the ladder
side answers during the stream is stored and verified AFTER timing
against an incremental array-backed oracle (float64 dense scoring over
the live set — gids are array indices, so the oracle replays mutations
in O(1) and the check runs at M >= 1M without the dict-per-row cost of
:mod:`benchmarks.streaming`). ``exact_verified`` per row; the CI smoke
fails on any ``False``.

The §10 argument-passing contract is also gated here, on the ladder's
promotions: ``engine_compiles_per_compaction`` counts engine traces
charged to full-base builds and must be 0 — folds must not compile
anything (they change no shapes: the L1 stack is presented to the
scan-loop merge at full per-shard capacity regardless of occupancy),
and a warmed promotion reuses the same executors the single-level
catalogue does.

Reported per row: full-rebuild counts and the amortisation ratio,
fold counts and fold wall-clock vs build wall-clock, mutation+query
throughput for both sides over the identical stream, and the ladder's
final occupancy (L1 rows, chain length, live set).
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows

QUICK_SWEEP = (131072,)
FULL_SWEEP = (1 << 20,)

R, K, B = 32, 10, 8
N_SHARDS = 8
DELTA_CAPACITY = 256


def _catalogue(rng, m: int) -> np.ndarray:
    T = rng.standard_normal((m, R)).astype(np.float32)
    T *= (1.0 / np.sqrt(1.0 + np.arange(m, dtype=np.float32)))[:, None]
    return T


def make_schedule(rng, m0: int, rounds: int, ins: int, dels: int,
                  upds: int, q_per: int):
    """Insert-heavy op stream (both sides replay it verbatim); mutation
    victims are drawn against a simulated live set, so the timed loops
    never query the catalogue for liveness."""
    live = list(range(m0))
    next_gid = m0
    ops = []
    for _ in range(rounds):
        rows = rng.standard_normal((ins, R)).astype(np.float32)
        ops.append(("ins", rows))
        live.extend(range(next_gid, next_gid + ins))
        next_gid += ins
        victims = [live.pop(int(rng.integers(len(live))))
                   for _ in range(dels)]
        ops.append(("del", victims))
        upd_gids = [live[int(rng.integers(len(live)))] for _ in range(upds)]
        ops.append(("upd", upd_gids,
                    rng.standard_normal((upds, R)).astype(np.float32)))
        for _ in range(q_per):
            ops.append(("query",
                        rng.standard_normal((B, R)).astype(np.float32)))
    return ops


class _ArrayOracle:
    """Incremental fresh-rebuild oracle that scales to M >= 1M: rows live
    at index == gid (appends are sequential, updates reuse the gid), a
    boolean mask tracks liveness, and top-K is a dense float64 matmul
    over the populated prefix."""

    def __init__(self, T0):
        m0 = T0.shape[0]
        self._rows = np.empty((m0 + (m0 // 2) + 1024, R), np.float32)
        self._rows[:m0] = T0
        self._live = np.zeros(self._rows.shape[0], bool)
        self._live[:m0] = True
        self._n = m0

    def apply(self, op):
        if op[0] == "ins":
            n = op[1].shape[0]
            if self._n + n > self._rows.shape[0]:
                grow = max(self._rows.shape[0] // 2, n)
                self._rows = np.concatenate(
                    [self._rows, np.empty((grow, R), np.float32)])
                self._live = np.concatenate(
                    [self._live, np.zeros(grow, bool)])
            self._rows[self._n:self._n + n] = op[1]
            self._live[self._n:self._n + n] = True
            self._n += n
        elif op[0] == "del":
            self._live[np.asarray(op[1], np.int64)] = False
        elif op[0] == "upd":
            self._rows[np.asarray(op[1], np.int64)] = op[2]

    def topk(self, U, k):
        s = U.astype(np.float64) @ self._rows[:self._n].astype(np.float64).T
        s[:, ~self._live[:self._n]] = -np.inf
        order = np.argsort(-s, kind="stable", axis=1)[:, :k]
        return s[np.arange(U.shape[0])[:, None], order], order

    def is_live(self, gid):
        return bool(self._live[gid])

    def row(self, gid):
        return self._rows[gid]


def run_side(T0, ops, *, n_shards, method="norm", store_results=True):
    """Replay the schedule through a TopKServer over either catalogue
    (n_shards=0: single-level). Returns the server, stored query
    results, and the timed wall-clock (flush included, so in-flight
    builds are fully charged)."""
    import jax.numpy as jnp

    from repro.core import SepLRModel
    from repro.serving.server import TopKServer

    # an absolute tombstone cap sized to M, IDENTICAL for both sides:
    # the catalogue default (2 * delta_capacity = 512) would force a
    # full O(M)-rebuild to clear 0.05% dead rows at M = 1M, burying the
    # capacity-driven rebuild schedule this sweep measures under
    # tombstone-triggered ones (the §9 over-fetch the dead rows cost is
    # O(n_dead) per query — harmless at this fraction)
    srv = TopKServer(SepLRModel(jnp.asarray(T0)), max_batch=B,
                     block_size=256, delta_capacity=DELTA_CAPACITY,
                     compact_async=True, n_shards=n_shards,
                     max_tombstones=max(T0.shape[0] // 64,
                                        2 * DELTA_CAPACITY))
    srv.warmup(K, batch_sizes=(B,), engines=[method])
    results = []
    t0 = time.perf_counter()
    for op in ops:
        if op[0] == "ins":
            srv.add_targets(op[1])
        elif op[0] == "del":
            srv.delete_targets(op[1])
        elif op[0] == "upd":
            srv.update_targets(op[1], op[2])
        else:
            res = srv.query(op[1], K, method)
            if store_results:
                results.append((np.asarray(res.values),
                                np.asarray(res.indices)))
    srv.catalogue.flush()
    return srv, results, time.perf_counter() - t0


def verify(T0, ops, results, atol=1e-3):
    """Replay the schedule on the array oracle; check every stored query
    result: value vectors match the dense float64 top-K, every returned
    gid is live and scores the value next to it."""
    oracle = _ArrayOracle(T0)
    it = iter(results)
    for op in ops:
        if op[0] != "query":
            oracle.apply(op)
            continue
        vals, gids = next(it)
        ov, _ = oracle.topk(op[1], K)
        if not np.allclose(vals, ov, atol=atol):
            return False
        for b in range(vals.shape[0]):
            for j in range(K):
                g = int(gids[b, j])
                if not oracle.is_live(g):
                    return False
                if abs(float(op[1][b].astype(np.float64)
                             @ oracle.row(g)) - vals[b, j]) > atol:
                    return False
    return True


def run(quick: bool = True, rounds: int = None,
        save_as: str = "streaming_lsm", method: str = "norm"):
    rng = np.random.default_rng(29)
    # full mode streams past the L1 tier's total capacity
    # (n_shards * 4 * delta_capacity = 8192 rows) so at least one
    # promotion — the ladder's only full rebuild — lands inside the
    # measured window; quick stays within the tier (folds only)
    rounds = rounds if rounds is not None else (60 if quick else 320)
    ins, dels, upds, q_per = 24, 4, 4, 2         # insert-heavy by design
    rows_out = []
    for M in (QUICK_SWEEP if quick else FULL_SWEEP):
        T0 = _catalogue(rng, M)
        ops = make_schedule(rng, M, rounds, ins, dels, upds, q_per)
        n_ops = 3 * rounds + q_per * rounds
        lsm_srv, results, lsm_s = run_side(T0, ops, n_shards=N_SHARDS,
                                           method=method)
        exact = verify(T0, ops, results)
        # the baseline: identical stream, same delta capacity, but every
        # overflow is a full base rebuild
        flat_srv, _, flat_s = run_side(T0, ops, n_shards=0, method=method,
                                       store_results=False)
        lm, fm = lsm_srv.mutation_stats, flat_srv.mutation_stats
        rebuilds_lsm = lm["n_compactions"]       # promotions only
        rebuilds_flat = fm["n_compactions"]      # every overflow
        rows_out.append({
            "M": M, "R": R, "K": K, "batch": B, "method": method,
            "rounds": rounds, "n_shards": N_SHARDS,
            "delta_capacity": DELTA_CAPACITY,
            "l1_capacity_rows": N_SHARDS
            * lsm_srv.catalogue.l1_run_capacity,
            "mutation_calls": 3 * rounds,
            "mutated_items": rounds * (ins + dels + upds),
            "queries": q_per * rounds * B,
            "exact_verified": bool(exact),
            # the headline: full-base rebuilds over the identical stream
            "full_rebuilds_lsm": rebuilds_lsm,
            "full_rebuilds_single_level": rebuilds_flat,
            "rebuild_amortisation": rebuilds_flat / max(rebuilds_lsm, 1),
            "n_l1_folds": lm["n_l1_folds"],
            "l1_fold_s_total": lm["l1_fold_s_total"],
            "l1_fold_s_mean": (lm["l1_fold_s_total"]
                               / max(lm["n_l1_folds"], 1)),
            "compaction_s_total_lsm": lm["compaction_s_total"],
            "compaction_s_total_single_level": fm["compaction_s_total"],
            "compaction_s_mean_single_level": (
                fm["compaction_s_total"] / max(rebuilds_flat, 1)),
            # throughput over the identical stream
            "wall_s_lsm": lsm_s,
            "wall_s_single_level": flat_s,
            "ops_per_s_lsm": n_ops / lsm_s,
            "ops_per_s_single_level": n_ops / flat_s,
            "speedup_vs_single_level": flat_s / lsm_s,
            # §10 contract on the ladder's promotions: folds compile
            # nothing, a warmed promotion retraces nothing
            "engine_compiles_total": lm["engine_compiles_total"],
            "engine_compiles_per_compaction":
                lm["engine_compiles_per_compaction"],
            # final ladder occupancy
            "l1_rows_final": lm["l1_rows"],
            "l0_chain_len_final": lm["l0_chain_len"],
            "n_tombstones_final": lm["n_tombstones"],
            "num_live_final": lm["num_live"],
            "n_failed_l1_folds": lm["n_failed_l1_folds"],
            "snapshot_version_lsm": lm["snapshot_version"],
        })
    save_rows(save_as, rows_out)
    return rows_out


def main(quick: bool = True):
    rows = run(quick)
    bad = [r["M"] for r in rows if not r["exact_verified"]]
    r0 = rows[0]
    derived = (f"amortisation={r0['rebuild_amortisation']:.1f}x,"
               f"rebuilds={r0['full_rebuilds_lsm']}"
               f"vs{r0['full_rebuilds_single_level']},"
               f"folds={r0['n_l1_folds']},"
               f"compiles_per_compaction="
               f"{r0['engine_compiles_per_compaction']:.0f},"
               f"exact_failures={bad or 'none'}")
    print(csv_line("streaming_lsm", 1e6 * r0["wall_s_lsm"]
                   / max(r0["queries"], 1), derived))
    assert not bad, f"ladder results diverged from the dense oracle: {bad}"
    # acceptance (DESIGN.md §10 extended to §15): neither folds nor
    # warmed promotions may retrace engines
    retraced = [r["M"] for r in rows
                if r["engine_compiles_per_compaction"] != 0]
    assert not retraced, \
        f"ladder compaction performed engine retraces at M={retraced}"
    # the amortisation the tier exists for. Quick mode stays inside the
    # L1 tier, so its gate is absolute: the ladder absorbed EVERY
    # overflow the single-level side paid a full rebuild for (a ratio
    # against zero ladder rebuilds would hinge on how many seals the
    # baseline's slow async builds coalesce — timing, not sizing). Full
    # mode streams past the tier; with >= 1 promotion in the window the
    # measured ratio must clear the 4x sizing floor.
    weak = [r["M"] for r in rows
            if (r["rebuild_amortisation"] < 4.0
                if r["full_rebuilds_lsm"] > 0 else
                not (r["full_rebuilds_single_level"] >= 1
                     and r["n_l1_folds"] >= 1))]
    assert not weak, f"rebuild amortisation below the floor at M={weak}"


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
