"""Beyond-paper — the TPU-native engines: BTA block-size trade-off,
norm-pruned scanning, and the Pallas topk_mips kernel.

The paper's cost metric (scores computed) meets the hardware's cost metric
(MXU-shaped block work). BTA with block size B preserves exactness while
cutting rounds by ~B; the scores it wastes inside the final block are the
price of vectorisation. The norm-pruned scan exploits catalogue norm decay
(CF popularity / PLS spectra) with contiguous DMA — the layout the Pallas
kernel consumes.
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows


def run(quick: bool = True):
    import jax.numpy as jnp

    from repro.core import (blocked_topk, naive_topk, norm_pruned_topk,
                            threshold_topk_from_index)
    from repro.core.index import build_index
    from repro.core.seplr import random_model
    from repro.kernels.ops import MIPSCatalog

    rng = np.random.default_rng(4)
    M = 20000 if quick else 100000
    R, K = 50, 10
    model = random_model(rng, M, R, "lowrank_spectrum")
    T = np.asarray(model.targets)
    idx = build_index(T)
    Tj = jnp.asarray(T)
    spectrum = 1.0 / np.sqrt(1.0 + np.arange(R, dtype=np.float32))
    Q = rng.standard_normal((5, R)).astype(np.float32) * spectrum
    rows = []

    # exact TA reference counts
    ta_scored = []
    for u in Q:
        r = threshold_topk_from_index(Tj, idx, jnp.asarray(u), K)
        ta_scored.append(int(r.n_scored))
    ta_mean = float(np.mean(ta_scored))

    for block in (64, 256, 1024):
        scored, times = [], []
        for u in Q:
            t0 = time.perf_counter()
            r = blocked_topk(Tj, idx.order_desc, idx.t_sorted_desc,
                             jnp.asarray(u), K, block_size=block)
            r.values.block_until_ready()
            times.append(time.perf_counter() - t0)
            scored.append(int(r.n_scored))
        rows.append({"engine": f"bta_b{block}", "M": M, "K": K,
                     "avg_scores": float(np.mean(scored)),
                     "vs_ta": float(np.mean(scored)) / max(ta_mean, 1),
                     "us_per_query": float(np.mean(times)) * 1e6})

    # norm-pruned scan
    scored, times = [], []
    for u in Q:
        t0 = time.perf_counter()
        r = norm_pruned_topk(Tj, idx.norm_order, idx.norms_sorted,
                             jnp.asarray(u), K, block_size=256)
        r.values.block_until_ready()
        times.append(time.perf_counter() - t0)
        scored.append(int(r.n_scored))
    rows.append({"engine": "norm_pruned", "M": M, "K": K,
                 "avg_scores": float(np.mean(scored)),
                 "vs_ta": float(np.mean(scored)) / max(ta_mean, 1),
                 "us_per_query": float(np.mean(times)) * 1e6})

    # Pallas kernel (interpret mode on CPU)
    cat = MIPSCatalog(T, block_m=256)
    scored, times = [], []
    for u in Q:
        t0 = time.perf_counter()
        vals, ids, stats = cat.query(jnp.asarray(u), K)
        vals.block_until_ready()
        times.append(time.perf_counter() - t0)
        scored.append(int(stats[0]))
    rows.append({"engine": "pallas_topk_mips(interpret)", "M": M, "K": K,
                 "avg_scores": float(np.mean(scored)),
                 "vs_ta": float(np.mean(scored)) / max(ta_mean, 1),
                 "us_per_query": float(np.mean(times)) * 1e6})

    # naive matmul baseline
    t0 = time.perf_counter()
    naive_topk(Tj, jnp.asarray(Q), K).values.block_until_ready()
    rows.append({"engine": "naive_matmul", "M": M, "K": K,
                 "avg_scores": M, "vs_ta": M / max(ta_mean, 1),
                 "us_per_query": (time.perf_counter() - t0) / len(Q) * 1e6})
    rows.append({"engine": "ta_reference", "M": M, "K": K,
                 "avg_scores": ta_mean, "vs_ta": 1.0, "us_per_query": None})
    save_rows("bta_tpu", rows)
    return rows


def main(quick: bool = True):
    rows = run(quick)
    by = {r["engine"]: r for r in rows}
    ta = by["ta_reference"]["avg_scores"]
    derived = ";".join(
        f"{r['engine']}={r['avg_scores']:.0f}sc" for r in rows
        if r["engine"] != "ta_reference") + f";ta={ta:.0f}sc"
    print(csv_line("bta_tpu", by["naive_matmul"]["us_per_query"], derived))


if __name__ == "__main__":
    main()
