"""Beyond-paper — the TPU-native engines: BTA block-size trade-off,
norm-pruned scanning, and the Pallas topk_mips kernel.

The paper's cost metric (scores computed) meets the hardware's cost metric
(MXU-shaped block work). BTA with block size B preserves exactness while
cutting rounds by ~B; the scores it wastes inside the final block are the
price of vectorisation. The norm-pruned scan exploits catalogue norm decay
(CF popularity / PLS spectra) with contiguous DMA — the layout the Pallas
kernel consumes.

Every engine here is invoked through the registry
(``repro.core.engines``) — the same dispatch path the serving layer uses —
with per-engine contexts carrying the block-size configuration.
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows


def _timed_engine(engine_name, ctx, U, k):
    from repro.core.engines import get_engine
    eng = get_engine(engine_name)
    res = eng.run(ctx, U, k)                 # warm the jit cache
    t0 = time.perf_counter()
    res = eng.run(ctx, U, k)
    np.asarray(res.values)
    dt = time.perf_counter() - t0
    return (float(np.mean(np.asarray(res.n_scored))),
            dt / U.shape[0] * 1e6)


def run(quick: bool = True):
    import jax.numpy as jnp

    from repro.core.engines import EngineContext
    from repro.core.seplr import random_model

    rng = np.random.default_rng(4)
    M = 20000 if quick else 100000
    R, K = 50, 10
    model = random_model(rng, M, R, "lowrank_spectrum")
    T = np.asarray(model.targets)
    spectrum = 1.0 / np.sqrt(1.0 + np.arange(R, dtype=np.float32))
    Q = jnp.asarray(rng.standard_normal((5, R)).astype(np.float32) * spectrum)
    rows = []

    ctx = EngineContext(T, block_size=256)

    # exact TA reference counts (registry "ta" = blocked strategy, B=1)
    ta_mean, _ = _timed_engine("ta", ctx, Q, K)

    for block in (64, 256, 1024):
        ctx_b = EngineContext(T, index=ctx.index, block_size=block)
        scored, us = _timed_engine("bta", ctx_b, Q, K)
        rows.append({"engine": f"bta_b{block}", "M": M, "K": K,
                     "avg_scores": scored,
                     "vs_ta": scored / max(ta_mean, 1),
                     "us_per_query": us})

    # norm-pruned scan
    scored, us = _timed_engine("norm", ctx, Q, K)
    rows.append({"engine": "norm_pruned", "M": M, "K": K,
                 "avg_scores": scored, "vs_ta": scored / max(ta_mean, 1),
                 "us_per_query": us})

    # Pallas kernel (interpret autodetect: interpreter on CPU, compiled on TPU)
    scored, us = _timed_engine("pallas", ctx, Q, K)
    rows.append({"engine": "pallas_topk_mips(interpret)", "M": M, "K": K,
                 "avg_scores": scored, "vs_ta": scored / max(ta_mean, 1),
                 "us_per_query": us})

    # naive matmul baseline
    _, us = _timed_engine("naive", ctx, Q, K)
    rows.append({"engine": "naive_matmul", "M": M, "K": K,
                 "avg_scores": M, "vs_ta": M / max(ta_mean, 1),
                 "us_per_query": us})
    rows.append({"engine": "ta_reference", "M": M, "K": K,
                 "avg_scores": ta_mean, "vs_ta": 1.0, "us_per_query": None})
    save_rows("bta_tpu", rows)
    return rows


def main(quick: bool = True):
    rows = run(quick)
    by = {r["engine"]: r for r in rows}
    ta = by["ta_reference"]["avg_scores"]
    derived = ";".join(
        f"{r['engine']}={r['avg_scores']:.0f}sc" for r in rows
        if r["engine"] != "ta_reference") + f";ta={ta:.0f}sc"
    print(csv_line("bta_tpu", by["naive_matmul"]["us_per_query"], derived))


if __name__ == "__main__":
    main()
