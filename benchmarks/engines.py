"""Registry sweep — every registered engine on one catalogue.

The benchmark equivalent of ``TopKServer.available_engines()``: whatever
is in ``repro.core.engines`` gets measured (wall time + the paper's
score-count metric) and, when it advertises ``exact``, checked against
the naive scan. A newly registered engine shows up here with zero harness
changes — the point of the registry layer (DESIGN.md §1).
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows


def run(quick: bool = True):
    import jax.numpy as jnp

    from repro.core import naive_topk
    from repro.core.engines import EngineContext, list_engines, select_engine

    rng = np.random.default_rng(7)
    M = 8000 if quick else 50000
    R, K, B = 32, 10, 8
    T = rng.standard_normal((M, R)).astype(np.float32)
    T *= (1.0 / np.sqrt(1.0 + np.arange(M, dtype=np.float32)))[:, None]
    ctx = EngineContext(T, block_size=256)
    U = jnp.asarray(rng.standard_normal((B, R)).astype(np.float32))
    ref = np.sort(np.asarray(naive_topk(ctx.targets, U, K).values), axis=1)

    rows = []
    for eng in list_engines():
        run_as = select_engine(ctx, U) if eng.name == "auto" else eng
        res = run_as.run(ctx, U, K)          # warm the jit cache
        t0 = time.perf_counter()
        res = run_as.run(ctx, U, K)
        np.asarray(res.values)
        dt = time.perf_counter() - t0
        exact_ok = bool(np.allclose(
            np.sort(np.asarray(res.values), axis=1), ref, atol=1e-3))
        rows.append({
            "engine": eng.name,
            "resolved": run_as.name,
            "backend": eng.backend,
            "exact": eng.exact,
            "exact_verified": exact_ok,
            "needs_index": eng.needs_index,
            "M": M, "R": R, "K": K, "batch": B,
            "avg_scores": float(np.mean(np.asarray(res.n_scored))),
            "us_per_query": dt / B * 1e6,
        })
    save_rows("engines", rows)
    return rows


def main(quick: bool = True):
    rows = run(quick)
    bad = [r["engine"] for r in rows if r["exact"] and not r["exact_verified"]]
    derived = ";".join(
        f"{r['engine']}={r['avg_scores']:.0f}sc" for r in rows)
    derived += f";exact_failures={bad or 'none'}"
    fastest = min(rows, key=lambda r: r["us_per_query"])
    print(csv_line("engines", fastest["us_per_query"], derived))
    assert not bad, f"exact engines diverged from naive: {bad}"


if __name__ == "__main__":
    main()
