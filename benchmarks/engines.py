"""Registry sweep — every registered engine over an M x B x sign grid.

The benchmark equivalent of ``TopKServer.available_engines()``: whatever
is in ``repro.core.engines`` gets measured (wall time + the paper's
score-count metric) and, when it advertises ``exact``, checked against
the naive scan. A newly registered engine shows up here with zero harness
changes — the point of the registry layer (DESIGN.md §1).

Measurement protocol (DESIGN.md §6): engines run through the registry's
compiled-executable cache (``EngineContext.warmup`` first — which also
warms the common SIGN buckets of the batched list scan, DESIGN.md §11 —
so the numbers are steady-state serving latency, not trace+compile
time), and ``us_per_query`` is the MINIMUM over ``iters`` timed batches —
the shared-host-noise-robust estimator; the median is recorded alongside.
Each row also records ``queries_per_s`` (batch throughput at this B),
``speedup_vs_naive`` (same M, same batch, same sign), and
``interpret_mode`` — Pallas rows measured off-TPU run in the Pallas
interpreter, which is orders of magnitude slower than both compiled TPU
execution and the XLA engines, and must never be read as a hardware
result (interpreter rows are measured only at the reference batch
``B = 8``; at B = 64 x 262k they are minutes per call and say nothing).

The sweep carries two axes beyond M:

* ``batch`` in {1, 8, 64} — the batched-native list scan shares ONE
  prefix-tile enumeration across the batch, so ta/bta per-QUERY latency
  must collapse as B grows (the PR-6 tentpole claim); B = 1 keeps the
  un-amortised floor visible.
* ``sign`` in {mixed, nonneg} — only for the list engines (plus naive,
  the baseline): a single-sign batch takes the sign-specialised variant
  reading ONE direction's prefix tiles with batch-SHARED freshness keys;
  mixed batches pay the per-query direction select. The other engines
  are sign-indifferent and are measured on the mixed batch only.

``sign_bucket`` records the bucket the dispatch actually specialised on
(``unbucketed`` = layout off, one unspecialised trace) and
``traces_by_sign`` snapshots the process-wide per-(engine, bucket)
compile counters (``repro.core.engines.trace_detail``) at row time — the
artifact's record that warmed buckets served without retraces.

Host-only reference oracles (``backend == "numpy"``: ``fagin``,
``partial``) are registered engines but are skipped here — item-at-a-time
python loops at M ≥ 8k are minutes-per-batch and say nothing about the
serving path.
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows

QUICK_SWEEP = (8000,)
FULL_SWEEP = (8000, 32768, 131072, 262144)
BATCH_SWEEP = (1, 8, 64)
#: quick mode forces the list layout ON below LIST_LAYOUT_MIN_TARGETS so
#: the CI smoke sweep exercises the batched+sign-specialised path at 8k
QUICK_PREFIX_DEPTH = 512


def _catalogue(rng, m: int, r: int) -> np.ndarray:
    T = rng.standard_normal((m, r)).astype(np.float32)
    T *= (1.0 / np.sqrt(1.0 + np.arange(m, dtype=np.float32)))[:, None]
    return T


def _timed(run, U, iters: int, budget_s: float = 2.0):
    import jax

    def once():
        t0 = time.perf_counter()
        res = run(U)
        jax.tree_util.tree_map(
            lambda a: a.block_until_ready()
            if hasattr(a, "block_until_ready") else a, res)
        return res, time.perf_counter() - t0

    run(U)                       # ensure compiled
    _, est = once()              # warm estimate sizes the loop: slow calls
    iters = max(3, min(iters, int(budget_s / max(est, 1e-9))))
    ts = []
    for _ in range(iters):
        res, dt = once()
        ts.append(dt)
    return res, float(np.min(ts)), float(np.median(ts))


def run(quick: bool = True, iters: int = 30, save_as: str = "engines"):
    import jax
    import jax.numpy as jnp

    from repro.core import naive_topk
    from repro.core.engines import (
        EngineContext,
        list_engines,
        select_engine,
        trace_detail,
    )
    from repro.core.strategies import sign_bucket_label
    from repro.kernels.topk_mips import resolve_interpret

    interpret = bool(resolve_interpret(None))
    rng = np.random.default_rng(7)
    R, K = 32, 10
    rows = []
    for M in (QUICK_SWEEP if quick else FULL_SWEEP):
        T = _catalogue(rng, M, R)
        ctx = EngineContext(T, block_size=256,
                            prefix_depth=QUICK_PREFIX_DEPTH if quick
                            else None)
        ctx.warmup(K, batch_sizes=BATCH_SWEEP)
        for B in BATCH_SWEEP:
            U_mixed = rng.standard_normal((B, R)).astype(np.float32)
            U_nonneg = (np.abs(U_mixed) + 1e-3).astype(np.float32)
            for sign_name, U_np in (("mixed", U_mixed),
                                    ("nonneg", U_nonneg)):
                U = jnp.asarray(U_np)
                ref = np.sort(
                    np.asarray(naive_topk(ctx.targets, U, K).values),
                    axis=1)
                naive_us = None
                for eng in list_engines():
                    if eng.backend == "numpy":
                        continue    # host-only oracles: not a serving path
                    if sign_name == "nonneg" and eng.name != "naive" \
                            and eng.layout != "list_major":
                        continue    # sign-indifferent engines: mixed only
                    if eng.backend == "pallas" and interpret and B != 8:
                        continue    # interpreter: reference batch only
                    run_as = (select_engine(ctx, U_np)
                              if eng.name == "auto" else eng)
                    res, t_min, t_med = _timed(
                        lambda q, e=run_as: e.run(ctx, q, K), U, iters)
                    exact_ok = bool(np.allclose(
                        np.sort(np.asarray(res.values), axis=1), ref,
                        atol=1e-3))
                    us = t_min / B * 1e6
                    if eng.name == "naive":
                        naive_us = us
                    traffic = (run_as.traffic(ctx, res) if run_as.traffic
                               else {"rows_gathered": None,
                                     "rows_contiguous": None,
                                     "est_bytes_moved": None,
                                     "gather_fraction": None})
                    bucket = (run_as.batch_config(ctx, U_np)
                              if run_as.batch_config is not None else ())
                    traces = {sign_bucket_label(bc): n
                              for (nm, bc), n in trace_detail().items()
                              if nm == run_as.name}
                    rows.append({
                        "engine": eng.name,
                        "resolved": run_as.name,
                        "backend": eng.backend,
                        "exact": eng.exact,
                        "exact_verified": exact_ok,
                        "needs_index": eng.needs_index,
                        "layout": run_as.layout,
                        # 0 = adaptive default left the list_major layout
                        # OFF at this M (plain gather path, unbucketed)
                        "prefix_depth": (
                            ctx.resolved_prefix_depth
                            if run_as.layout == "list_major" else None),
                        "interpret_mode": (
                            bool(resolve_interpret(ctx.interpret))
                            if run_as.backend == "pallas" else False),
                        "M": M, "R": R, "K": K, "batch": B,
                        "sign": sign_name,
                        "sign_bucket": sign_bucket_label(bucket),
                        "traces_by_sign": traces,
                        "avg_scores": float(
                            np.mean(np.asarray(res.n_scored))),
                        "us_per_query": us,
                        "us_per_query_median": t_med / B * 1e6,
                        "queries_per_s": B / t_min,
                        "speedup_vs_naive": None,   # filled below
                        **traffic,
                    })
                assert naive_us is not None
                for r_ in rows:
                    if (r_["M"] == M and r_["batch"] == B
                            and r_["sign"] == sign_name):
                        r_["speedup_vs_naive"] = naive_us / r_["us_per_query"]
    save_rows(save_as, rows)
    return rows


def main(quick: bool = True):
    rows = run(quick)
    bad = [r["engine"] for r in rows if r["exact"] and not r["exact_verified"]]
    m0, b0 = rows[0]["M"], 8
    derived = ";".join(
        f"{r['engine']}={r['avg_scores']:.0f}sc,{r['speedup_vs_naive']:.2f}x"
        for r in rows if r["M"] == m0 and r["batch"] == b0
        and r["sign"] == "mixed")
    derived += f";exact_failures={bad or 'none'}"
    fastest = min((r for r in rows
                   if r["M"] == m0 and r["batch"] == b0
                   and r["sign"] == "mixed"),
                  key=lambda r: r["us_per_query"])
    print(csv_line("engines", fastest["us_per_query"], derived))
    assert not bad, f"exact engines diverged from naive: {bad}"


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
