"""Dry-run sweep driver: one subprocess per cell (bounds compiler RSS),
resume-safe (skips cells whose JSON already reports status=ok)."""
import json
import os
import subprocess
import sys
import time

OUT = "results/dryrun"
LM_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
GNN_SHAPES = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]
RECSYS_SHAPES = ["train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]

CELLS = []
for a in ["olmoe-1b-7b", "llama4-scout-17b-a16e", "deepseek-67b",
          "gemma-2b", "stablelm-3b"]:
    CELLS += [(a, s) for s in LM_SHAPES]
CELLS += [("pna", s) for s in GNN_SHAPES]
for a in ["deepfm", "dcn-v2", "dlrm-rm2", "fm"]:
    CELLS += [(a, s) for s in RECSYS_SHAPES]
assert len(CELLS) == 40, len(CELLS)


def done(arch, shape, mesh):
    f = os.path.join(OUT, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(f):
        return False
    try:
        return json.load(open(f)).get("status") == "ok"
    except Exception:
        return False


def main():
    meshes = sys.argv[1:] or ["single", "multi"]
    t0 = time.time()
    for mesh in meshes:
        for arch, shape in CELLS:
            if done(arch, shape, mesh):
                print(f"skip {arch} x {shape} x {mesh}", flush=True)
                continue
            t = time.time()
            try:
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun", "--arch",
                     arch, "--shape", shape, "--mesh", mesh, "--out", OUT],
                    env={**os.environ, "PYTHONPATH": "src"},
                    capture_output=True, text=True, timeout=2400)
            except subprocess.TimeoutExpired:
                print(f"TIMEOUT {arch} x {shape} x {mesh}", flush=True)
                continue
            status = "ok" if done(arch, shape, mesh) else "FAIL"
            print(f"{status} {arch} x {shape} x {mesh} "
                  f"({time.time()-t:.0f}s)", flush=True)
            if status == "FAIL":
                print(r.stdout[-1200:], r.stderr[-1200:], flush=True)
    print(f"sweep wall: {(time.time()-t0)/60:.1f} min", flush=True)


if __name__ == "__main__":
    main()
