"""Closed-loop load test: offered QPS sweep against the async pipeline.

Measures the serving claim of DESIGN.md §13 end to end: a synchronous
call-per-request baseline (one engine dispatch per arriving query, the
pre-PR-8 serving shape) against :class:`AsyncTopKServer`'s deadline-
coalesced micro-batching, at the same catalogue and the same exactness
bar. For each offered rate the harness submits on an open-loop arrival
schedule, blocks until every request completes, verifies every result
against a float64 oracle, and reports completed QPS (goodput — every
row is exact, so goodput IS throughput), per-request p50/p95/p99, the
coalesced-batch-size histogram, and the cache hit rate. Two derived
numbers are the acceptance gates:

* ``speedup_at_saturation`` — completed QPS at the saturating offered
  rate over the sync baseline's QPS: coalescing must win >= 3x.
* ``low_qps_p99_ratio`` — async p99 at the LOW offered rate over the
  sync p99: the idle-pipeline immediate flush must keep it <= 2x (a
  lone request must not wait out ``flush_ms`` for company that is not
  coming).

A final streaming phase mutates the catalogue under query load (enough
appends to force compactions, plus deletes), asserting post-mutation
exactness (the result cache must never serve a pre-mutation answer)
and zero engine compiles per compaction through the async path.

``--quick`` shrinks M and the durations for the CI tier-2 smoke;
``--check`` exits non-zero when a SOUNDNESS gate fails (exactness,
cache staleness, compile-free compaction — CI runs both flags), while
``--check-perf`` additionally gates the two wall-clock criteria (for
artifact generation on a quiet machine; shared-runner clocks are too
noisy to gate CI on). The committed ``results/bench/loadtest.json`` is
the full-size artifact.
"""
from __future__ import annotations

import argparse
import queue
import sys
import threading
import time

import numpy as np

from benchmarks.common import save_rows


def _oracle_topk(T: np.ndarray, pool: np.ndarray, k: int) -> np.ndarray:
    out = np.empty((pool.shape[0], k), np.float64)
    Td = T.astype(np.float64).T
    for i in range(0, pool.shape[0], 2048):
        s = pool[i:i + 2048].astype(np.float64) @ Td
        out[i:i + 2048] = np.sort(s, axis=1)[:, ::-1][:, :k]
    return out


def _percentiles_ms(lat_s):
    a = 1e3 * np.asarray(lat_s, np.float64)
    return (float(np.percentile(a, 50)), float(np.percentile(a, 95)),
            float(np.percentile(a, 99)))


def run_sync(srv, pool, oracle, k, duration_s, method):
    """Call-per-request baseline: one blocking query() per arrival."""
    # burn-in (discarded): the first calls after warmup carry
    # allocator/dispatch stragglers the steady state never sees
    for i in range(32):
        srv.query(pool[i % pool.shape[0]], k, method=method)
    lat, n_bad, i = [], 0, 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < duration_s:
        q = pool[i % pool.shape[0]]
        t1 = time.perf_counter()
        res = srv.query(q, k, method=method)
        lat.append(time.perf_counter() - t1)
        if not np.allclose(np.asarray(res.values)[0],
                           oracle[i % pool.shape[0]], atol=1e-3):
            n_bad += 1
        i += 1
    wall = time.perf_counter() - t0
    p50, p95, p99 = _percentiles_ms(lat)
    return {"mode": "sync", "offered_qps": None, "n": i,
            "completed_qps": i / wall, "p50_ms": p50, "p95_ms": p95,
            "p99_ms": p99, "exact_verified": n_bad == 0,
            "mean_batch_size": 1.0, "cache_hit_rate": 0.0}


def run_async(srv, pool, oracle, k, qps, duration_s, method,
              n_waiters=None, tag="async", n=None):
    """Open-loop arrivals at ``qps`` for ``duration_s``; waits for every
    completion (the closed loop), verifying each against the oracle.

    The waiter pool scales with the offered rate: a fixed large pool
    would idle-spin thread wakeups through the GIL at a low-QPS trickle
    and inflate exactly the tail the low-load gate measures, while a
    tiny pool would serialise completions at saturation."""
    if n is None:
        n = max(int(qps * duration_s), 1)
    if n_waiters is None:
        n_waiters = max(2, min(16, int(qps) // 50 + 2))
    done_q: "queue.Queue" = queue.Queue()
    done, lock = [], threading.Lock()

    def waiter():
        # record completion time and the values row only — oracle
        # verification happens AFTER the timed window, so its cost
        # never pollutes the latency/throughput measurement
        while True:
            item = done_q.get()
            if item is None:
                return
            idx, t_submit, h = item
            res = h.result()
            t_done = time.perf_counter()
            with lock:
                done.append((idx, t_done - t_submit,
                             np.asarray(res.values)[0]))

    waiters = [threading.Thread(target=waiter, daemon=True)
               for _ in range(n_waiters)]
    for w in waiters:
        w.start()
    hits0, miss0 = srv.cache.hits, srv.cache.misses
    batches0 = srv.pipeline_stats.n_batches
    reqs0 = srv.pipeline_stats.n_requests
    t0 = time.perf_counter()
    for i in range(n):
        target = t0 + i / qps
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        idx = i % pool.shape[0]
        t_submit = time.perf_counter()
        done_q.put((idx, t_submit, srv.submit(pool[idx], k,
                                              method=method)))
    for _ in waiters:
        done_q.put(None)
    for w in waiters:
        w.join()
    wall = time.perf_counter() - t0
    lat = [d[1] for d in done]
    bad = [d[0] for d in done
           if not np.allclose(d[2], oracle[d[0]], atol=1e-3)]
    p50, p95, p99 = _percentiles_ms(lat)
    hits = srv.cache.hits - hits0
    misses = srv.cache.misses - miss0
    n_batches = srv.pipeline_stats.n_batches - batches0
    n_reqs = srv.pipeline_stats.n_requests - reqs0
    return {"mode": tag, "offered_qps": qps, "n": n,
            "completed_qps": n / wall, "p50_ms": p50, "p95_ms": p95,
            "p99_ms": p99, "exact_verified": not bad,
            "mean_batch_size": (n_reqs - hits) / max(n_batches, 1),
            "cache_hit_rate": hits / max(hits + misses, 1)}


def run_streaming_phase(srv, T, k, method, n_adds=96):
    """Mutations under the async path: appended rows must surface in
    the very next query (no stale cache), compactions must stay
    compile-free, deletes must vanish exactly."""
    rng = np.random.default_rng(7)
    rank = T.shape[1]
    stale = 0
    for i in range(n_adds):
        u = rng.standard_normal(rank).astype(np.float32)
        big = (10.0 + i) * u / max(float(np.linalg.norm(u)), 1e-9)
        # prime the cache with this query, then mutate, then re-query:
        # the add must be visible immediately
        srv.query(u, k, method=method)
        gid = int(srv.add_targets(big[None])[0])
        res = srv.query(u, k, method=method)
        if int(np.asarray(res.indices)[0, 0]) != gid:
            stale += 1
        srv.delete_targets([gid])
        res2 = srv.query(u, k, method=method)
        if gid in set(np.asarray(res2.indices)[0].tolist()):
            stale += 1
    ms = srv.mutation_stats
    return {"mode": "streaming", "n": n_adds,
            "n_compactions": ms["n_compactions"],
            "engine_compiles_per_compaction":
                ms["engine_compiles_per_compaction"],
            "exact_verified": stale == 0,
            "cache_hit_rate": srv.cache.hits
                / max(srv.cache.hits + srv.cache.misses, 1)}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small M / short durations (CI tier-2 smoke)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if a SOUNDNESS gate fails (exactness, "
                         "cache staleness, compile-free compaction) — "
                         "what CI runs; wall-clock gates stay off "
                         "because shared-runner clocks are noise")
    ap.add_argument("--check-perf", action="store_true",
                    help="additionally gate the throughput/latency "
                         "criteria (>=3x saturated speedup, low-QPS "
                         "p99 <= 2x sync) — for artifact generation "
                         "on a quiet machine")
    ap.add_argument("--method", default="auto")
    args = ap.parse_args(argv)

    from repro.core import SepLRModel
    from repro.serving.pipeline import AsyncTopKServer
    from repro.serving.server import TopKServer

    # full-size M puts the run in the regime the async tier is FOR:
    # the per-query scan cost dominates the host-side per-request
    # overhead (~0.6ms on this 1-core box), so coalescing's win is
    # structural rather than marginal
    M = 4096 if args.quick else 65536
    R, k, pool_n = 32, 10, 512
    dur = 1.0 if args.quick else 3.0
    max_batch = 64
    rng = np.random.default_rng(0)
    T = rng.standard_normal((M, R)).astype(np.float32)
    pool = rng.standard_normal((pool_n, R)).astype(np.float32)
    oracle = _oracle_topk(T, pool, k)
    meta = {"M": M, "R": R, "k": k, "method": args.method,
            "max_batch": max_batch}

    print(f"# loadtest M={M} k={k} method={args.method}", flush=True)
    sync_srv = TopKServer(SepLRModel(T), max_batch=max_batch,
                          delta_capacity=64)
    sync_srv.warmup(k)
    sync_row = dict(run_sync(sync_srv, pool, oracle, k, dur,
                             args.method), **meta)
    print(f"sync: {sync_row['completed_qps']:.0f} qps "
          f"p99={sync_row['p99_ms']:.2f}ms", flush=True)

    srv = AsyncTopKServer(SepLRModel(T), max_batch=max_batch,
                          delta_capacity=64, method=args.method)
    srv.warmup(k)
    rows = [sync_row]
    sync_qps = sync_row["completed_qps"]
    with srv:
        # burn-in (discarded): first-dispatch stragglers — thread
        # wake-up, allocator warmth — must not pollute the low-QPS p99
        burn = rng.standard_normal((64, R)).astype(np.float32)
        run_async(srv, burn, _oracle_topk(T, burn, k), k,
                  max(0.5 * sync_qps, 1.0), 0.5, args.method, n=64)
        # offered-rate sweep: fractions of the sync baseline up to a
        # saturating 8x (the open loop outruns the device there; the
        # closed-loop completion rate is the saturated throughput).
        # Every request in a sweep phase is a UNIQUE query — the cache
        # cannot contribute, so completed QPS measures coalescing alone
        for frac in (0.2, 1.0, 3.0, 8.0):
            qps = max(frac * sync_qps, 1.0)
            # the low-QPS phase runs twice as long: its p99 is a GATED
            # number and a 3s trickle yields too few samples for a
            # stable tail estimate
            phase_dur = 2 * dur if frac < 1.0 else dur
            n = min(max(int(qps * phase_dur), 200), 20000)
            qs = rng.standard_normal((n, R)).astype(np.float32)
            row = dict(run_async(srv, qs, _oracle_topk(T, qs, k), k,
                                 qps, phase_dur, args.method, n=n), **meta)
            rows.append(row)
            print(f"async offered={qps:.0f}: "
                  f"{row['completed_qps']:.0f} qps "
                  f"p99={row['p99_ms']:.2f}ms "
                  f"B={row['mean_batch_size']:.1f}", flush=True)
        # hot-set phase: 32 distinct queries cycled — steady-state cache
        # hit rate (the head-query cache earning its keep)
        hot = pool[:32]
        row = dict(run_async(srv, hot, oracle[:32], k,
                             max(sync_qps, 50.0), dur, args.method,
                             tag="async_hot"), **meta)
        rows.append(row)
        print(f"hot-set: hit_rate={row['cache_hit_rate']:.2f}", flush=True)
        stream_row = dict(run_streaming_phase(srv, T, k, args.method),
                          **meta)
        rows.append(stream_row)
        print(f"streaming: compactions={stream_row['n_compactions']} "
              f"compiles/compaction="
              f"{stream_row['engine_compiles_per_compaction']}",
              flush=True)

    low = next(r for r in rows if r["mode"] == "async"
               and r["offered_qps"] <= 0.3 * sync_qps)
    sat = max((r for r in rows if r["mode"] == "async"),
              key=lambda r: r["completed_qps"])
    summary = {
        "mode": "summary", **meta,
        "sync_qps": sync_qps,
        "saturated_qps": sat["completed_qps"],
        "speedup_at_saturation": sat["completed_qps"] / sync_qps,
        "low_qps_p99_ms": low["p99_ms"],
        "sync_p99_ms": sync_row["p99_ms"],
        "low_qps_p99_ratio": low["p99_ms"]
            / max(sync_row["p99_ms"], 1e-9),
        "exact_verified": all(r["exact_verified"] for r in rows),
        "engine_compiles_per_compaction":
            stream_row["engine_compiles_per_compaction"],
    }
    rows.append(summary)
    save_rows("loadtest", rows)
    print(f"speedup_at_saturation={summary['speedup_at_saturation']:.2f}x"
          f"  low_qps_p99_ratio={summary['low_qps_p99_ratio']:.2f}x",
          flush=True)

    failures = []
    if args.check or args.check_perf:
        if not summary["exact_verified"]:
            failures.append("a served result diverged from the oracle "
                            "(or a cached result went stale)")
        if summary["engine_compiles_per_compaction"] != 0:
            failures.append("compaction retraced engines on the async "
                            "path")
    if args.check_perf:
        if summary["speedup_at_saturation"] < 3.0:
            failures.append(
                f"saturated speedup {summary['speedup_at_saturation']:.2f}"
                "x < 3x")
        if summary["low_qps_p99_ratio"] > 2.0:
            failures.append(
                f"low-QPS p99 {summary['low_qps_p99_ratio']:.2f}x sync "
                "> 2x")
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
