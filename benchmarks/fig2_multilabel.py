"""Paper Fig. 2 — Uniprot-style multi-label querying: (left) score-count
improvement vs wall-time improvement; (right) partial-TA score fractions.

Ridge-like label weights (anisotropic, popularity-skewed — TA-friendly)
vs PLS-like (orthogonalised — TA-hostile), matching the paper's
observation that ridge improves much more than PLS. The partial TA
touches the SAME items but computes only a fraction of each score (Alg. 3).
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows


def run(quick: bool = True):
    import jax.numpy as jnp

    from repro.core import naive_topk, partial_threshold_topk_np
    from repro.core.engines import EngineContext, get_engine
    from repro.core.index import build_index
    from repro.data.synthetic import multilabel_factors

    rng = np.random.default_rng(1)
    n_labels = 4000 if quick else 21274
    n_feat = 100 if quick else 500
    ks = (1, 10) if quick else (1, 5, 10, 25, 50)
    n_queries = 5 if quick else 10
    rows = []
    for kind in ("ridge", "pls"):
        T = multilabel_factors(rng, n_labels, n_feat, kind)
        idx = build_index(T)
        Tj = jnp.asarray(T)
        # queries: feature vectors of held-out instances (same spectrum)
        Q = rng.standard_normal((n_queries, n_feat)).astype(np.float32)
        if kind == "ridge":
            Q *= (1.0 / np.sqrt(1.0 + np.arange(n_feat, dtype=np.float32)))
        ctx = EngineContext(Tj, index=idx)
        ta = get_engine("ta")
        Qj = jnp.asarray(Q)
        for k in ks:
            # wall time + counts: TA vs naive — both through registry dispatch
            t0 = time.perf_counter()
            res = ta.run(ctx, Qj, k)
            scored = np.asarray(res.n_scored)
            res.values.block_until_ready()
            t_ta = (time.perf_counter() - t0) / n_queries
            t0 = time.perf_counter()
            for u in Q:
                naive_topk(Tj, jnp.asarray(u), k).values.block_until_ready()
            t_naive = (time.perf_counter() - t0) / n_queries
            # partial TA fractions (numpy oracle, one query is enough to
            # characterise the fraction)
            _, _, ps = partial_threshold_topk_np(
                T, np.asarray(idx.order_desc), Q[0], k)
            rows.append({
                "kind": kind, "K": k, "M": n_labels, "R": n_feat,
                "scores_ta": float(np.mean(scored)),
                "score_ratio": float(np.mean(scored)) / n_labels,
                "time_ta_us": t_ta * 1e6, "time_naive_us": t_naive * 1e6,
                "time_ratio": t_ta / t_naive,
                "partial_avg_fraction": ps.avg_score_fraction,
                "partial_full_scores": ps.n_full_scores,
                "partial_items": ps.n_items_touched,
            })
    save_rows("fig2_multilabel", rows)
    return rows


def main(quick: bool = True):
    t0 = time.perf_counter()
    rows = run(quick)
    dt = time.perf_counter() - t0
    ridge = np.mean([r["score_ratio"] for r in rows if r["kind"] == "ridge"])
    pls = np.mean([r["score_ratio"] for r in rows if r["kind"] == "pls"])
    frac = np.mean([r["partial_avg_fraction"] for r in rows])
    derived = (f"ridge_ratio={ridge:.3f};pls_ratio={pls:.3f};"
               f"ridge_better={ridge < pls};partial_frac={frac:.2f}<1")
    print(csv_line("fig2_multilabel", dt / max(len(rows), 1) * 1e6, derived))


if __name__ == "__main__":
    main()
