"""Shared benchmark harness helpers."""
import json
import os
import time

import numpy as np


def timed(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / iters


def block_until_ready(x):
    import jax
    jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
        else a, x)
    return x


def save_rows(name, rows):
    os.makedirs("results/bench", exist_ok=True)
    with open(f"results/bench/{name}.json", "w") as f:
        json.dump(rows, f, indent=2, default=float)
    return rows


def csv_line(name, us_per_call, derived):
    return f"{name},{us_per_call:.1f},{derived}"
