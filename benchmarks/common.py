"""Shared benchmark harness helpers."""
import json
import os
import time

import numpy as np


def timed(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / iters


def block_until_ready(x):
    import jax
    jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
        else a, x)
    return x


def save_rows(name, rows):
    os.makedirs("results/bench", exist_ok=True)
    with open(f"results/bench/{name}.json", "w") as f:
        json.dump(rows, f, indent=2, default=float)
    return rows


def csv_line(name, us_per_call, derived):
    return f"{name},{us_per_call:.1f},{derived}"


def engine_counts(T, U, k, engine="ta", block_size=256, ctx=None):
    """Per-query-faithful (mean scores, mean depth) via a registry engine.

    The driver's liveness gating keeps batched counts identical to running
    the queries one at a time, so every figure benchmark reports the
    paper's cost metric through the same registry dispatch the server uses.
    Pass a prebuilt ``ctx`` to keep offline index construction out of any
    wall-clock window the caller is timing.
    """
    import jax.numpy as jnp

    from repro.core.engines import EngineContext, get_engine

    if ctx is None:
        ctx = EngineContext(T, block_size=block_size)
    U = jnp.atleast_2d(jnp.asarray(np.asarray(U, np.float32)))
    res = get_engine(engine).run(ctx, U, k)
    return (float(np.mean(np.asarray(res.n_scored))),
            float(np.mean(np.asarray(res.depth))))
