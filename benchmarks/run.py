"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) and
writes detailed rows to ``results/bench/*.json``. ``--full`` runs at
paper scale (slow on this 1-core container); default is the reduced
sweep.

**results/bench JSON schema.** Every artifact is a JSON LIST OF ROW
DICTS (one row per swept configuration), written by
:func:`benchmarks.common.save_rows`; numeric values serialise as floats.
Committed artifacts are measured on the full sweep on the development
box; CI's tier-2 job regenerates the quick sweep per commit (the gate is
exactness, wall-clock on shared runners is noise). Shared keys across
artifacts:

``M``/``R``/``K``/``batch``
    Sweep point: catalogue rows, rank, top-K size, query batch size.
``exact_verified`` (bool)
    The row's results matched the dense/oracle recomputation AFTER
    timing. CI fails on any ``false``; treat a row without it as
    unverified.
``us_per_query`` / ``us_per_query_median`` / ``us_per_query_mean``
    Wall-clock per query: min-over-iterations (noise-robust), the
    median alongside, or the lifetime mean (streaming).

``engines.json`` (``benchmarks/engines.py``) adds per engine row:
capability echoes (``engine``/``backend``/``layout``/``exact``/
``needs_index``/``resolved``/``interpret_mode`` — Pallas rows measured
off-TPU are interpreter time, never hardware results), the paper's cost
metric (``avg_scores``), ``speedup_vs_naive``, and the layout-traffic
estimators ``rows_gathered``/``rows_contiguous``/``est_bytes_moved``/
``gather_fraction`` plus ``prefix_depth`` (0 = adaptive default left the
list layout off).

``streaming.json`` (``benchmarks/streaming.py``) adds per row: the
schedule (``rounds``/``mutation_calls``/``mutated_items``/``queries``),
both sides' totals and throughput (``segmented_s``/``rebuild_s``/
``rebuild_lazy_s``/``ops_per_s_*``/``qps_segmented``/``n_rebuilds``),
``speedup_vs_rebuild[_lazy]``, latency percentiles ``p50_us``/
``p95_us``/``p99_us``, delta/compaction counters (``delta_capacity``/
``max_delta_occupancy``/``n_compactions``/``n_tombstones_final``/
``snapshot_version``/``num_live_final``/``delta_scored_per_query``),
and the compile-free-compaction acceptance fields (DESIGN.md §10):
``engine_compiles_total``/``engine_compiles_per_compaction`` (engine
traces during compaction builds; 0 = every build hit warmed M-buckets)
and ``compaction_s_total``/``compaction_s_mean`` (build wall-clock —
index/layout rebuild only, now that no engine recompiles ride along).

The figure/table artifacts (``table1_toy``/``fig1_cf``/
``fig2_multilabel``/``fig3_halted``/``table4_scaling``/``bta_tpu``)
mirror the paper's axes: per-(M, K, algorithm) rows of score counts,
depths, and per-query latency. Smoke runs write ``*_smoke.json`` names
so committed full-sweep artifacts are never clobbered by CI.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bta_tpu, engines, fig1_cf, fig2_multilabel,
                            fig3_halted, streaming, table1_toy,
                            table4_scaling)
    mods = {
        "table1_toy": table1_toy,
        "fig1_cf": fig1_cf,
        "fig2_multilabel": fig2_multilabel,
        "fig3_halted": fig3_halted,
        "table4_scaling": table4_scaling,
        "bta_tpu": bta_tpu,
        "engines": engines,   # sweeps every engine in the registry
        "streaming": streaming,   # interleaved mutations + queries (§9)
    }
    if args.only:
        mods = {k: v for k, v in mods.items() if k in args.only.split(",")}
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods.items():
        try:
            mod.main(quick=quick)
        except Exception:
            failures += 1
            print(f"{name},nan,FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
