"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) and
writes detailed rows to results/bench/*.json. ``--full`` runs at paper
scale (slow on this 1-core container); default is the reduced sweep.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bta_tpu, engines, fig1_cf, fig2_multilabel,
                            fig3_halted, streaming, table1_toy,
                            table4_scaling)
    mods = {
        "table1_toy": table1_toy,
        "fig1_cf": fig1_cf,
        "fig2_multilabel": fig2_multilabel,
        "fig3_halted": fig3_halted,
        "table4_scaling": table4_scaling,
        "bta_tpu": bta_tpu,
        "engines": engines,   # sweeps every engine in the registry
        "streaming": streaming,   # interleaved mutations + queries (§9)
    }
    if args.only:
        mods = {k: v for k, v in mods.items() if k in args.only.split(",")}
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods.items():
        try:
            mod.main(quick=quick)
        except Exception:
            failures += 1
            print(f"{name},nan,FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
