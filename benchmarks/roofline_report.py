"""Summarise results/dryrun/*.json into the §Roofline table (markdown +
console) and rank cells by roofline fraction / bottleneck."""
import glob
import json
import sys


def load(mesh="single"):
    rows = []
    for f in sorted(glob.glob(f"results/dryrun/*__{mesh}.json")):
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r.get("error", "error")})
            continue
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_compute": ro["t_compute_s"], "t_memory": ro["t_memory_s"],
            "t_collective": ro["t_collective_s"],
            "bottleneck": ro["bottleneck"],
            "useful": ro["useful_flops_ratio"],
            "frac": ro["roofline_fraction"],
            "peak_gb": r["memory"]["peak_bytes_per_device"] / 1e9,
            "compile_s": r.get("compile_s", 0),
        })
    return rows


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    rows = load(mesh)
    hdr = (f"{'arch':<24s}{'shape':<15s}{'t_comp':>9s}{'t_mem':>9s}"
           f"{'t_coll':>9s} {'bound':<11s}{'useful':>7s}{'roofl%':>8s}"
           f"{'GB/dev':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda x: -x.get("frac", 0)):
        if r["status"] != "ok":
            print(f"{r['arch']:<24s}{r['shape']:<15s} {r['status']}")
            continue
        print(f"{r['arch']:<24s}{r['shape']:<15s}"
              f"{r['t_compute']:>9.3g}{r['t_memory']:>9.3g}"
              f"{r['t_collective']:>9.3g} {r['bottleneck']:<11s}"
              f"{r['useful']:>7.3f}{100*r['frac']:>7.2f}%"
              f"{r['peak_gb']:>8.1f}")
    n_ok = sum(1 for r in rows if r["status"] == "ok")
    print(f"\n{n_ok}/{len(rows)} cells ok on mesh={mesh}")


if __name__ == "__main__":
    main()
