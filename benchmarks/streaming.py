"""Streaming catalogue sweep: interleaved mutations + queries (DESIGN.md §9).

Measures the segmented (base + delta + tombstone) serving path against the
only exact alternative a static-index tier has: REBUILD-PER-MUTATION —
after every mutation call the baseline rebuilds the offline sorted-list
index + engine context over the live set (the state the paper's pruned
serving requires), and serves queries through the SAME registry engine
the segmented server uses. Both sides follow the SAME readiness policy a
serving tier must: stay query-ready at all times. The segmented server
warms once at boot (excluded, like any steady-state measurement) and its
caches stay valid because snapshots are immutable; the baseline's every
mutation invalidates its context, so the primary baseline re-warms after
every rebuild (``rebuild_s``). A lazier variant that defers compilation
to the first query after each rebuild — trading p99 for throughput — is
measured alongside (``rebuild_lazy_s``) so the comparison is transparent
about how much of the gap is compile churn vs index churn. Either way,
the asymmetry is not unfairness: keeping caches valid under mutation is
the contribution being measured.

Both sides execute the SAME pre-generated schedule: per round, one
insert batch, one delete batch, one update batch (three mutation calls),
then ``q_per`` query batches. Exactness of every stored segmented result
is verified AFTER timing against an oracle replay of the schedule
(``exact_verified`` per row — the CI smoke fails on any ``False``).
The segmented side runs with BACKGROUND compaction (the subsystem's
deployment mode); the timed region ends with ``flush()`` so any build
still in flight is fully charged.

Reported per row: mutation+query throughput for both sides
(``ops_per_s_*``), the speedup (acceptance floor: >= 10x at M >= 32k),
per-batch latency percentiles from the server's bounded ring
(p50/p95/p99), the delta/compaction counters (max delta occupancy,
compactions, tombstones, final snapshot version), and the
argument-passing contract's acceptance fields (DESIGN.md §10):
``engine_compiles_per_compaction`` — engine traces observed per
compaction build, asserted 0 (warmed buckets make compaction
compile-free) — plus ``compaction_s_total``/``compaction_s_mean``, the
builds' wall-clock (before the refactor this carried ~0.5s/engine of
recompiles per snapshot; now it is the index/layout rebuild alone).
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows

QUICK_SWEEP = (8192,)
FULL_SWEEP = (32768, 131072)

R, K, B = 32, 10, 8


def _catalogue(rng, m: int) -> np.ndarray:
    T = rng.standard_normal((m, R)).astype(np.float32)
    T *= (1.0 / np.sqrt(1.0 + np.arange(m, dtype=np.float32)))[:, None]
    return T


def make_schedule(rng, m0: int, rounds: int, ins: int, dels: int,
                  upds: int, q_per: int):
    """Pre-generate the op stream (both sides replay it verbatim).

    Mutation targets are chosen against a simulated live set so the
    timed loops never have to ask the catalogue what is alive.
    """
    live = list(range(m0))
    next_gid = m0
    ops = []
    for _ in range(rounds):
        rows = rng.standard_normal((ins, R)).astype(np.float32)
        ops.append(("ins", rows))
        live.extend(range(next_gid, next_gid + ins))
        next_gid += ins
        victims = [live.pop(int(rng.integers(len(live))))
                   for _ in range(dels)]
        ops.append(("del", victims))
        upd_gids = [live[int(rng.integers(len(live)))] for _ in range(upds)]
        ops.append(("upd", upd_gids,
                    rng.standard_normal((upds, R)).astype(np.float32)))
        for _ in range(q_per):
            ops.append(("query",
                        rng.standard_normal((B, R)).astype(np.float32)))
    return ops


class _OracleCatalogue:
    """gid -> row dict; exact top-K by dense float64 scoring."""

    def __init__(self, T0):
        self.items = {i: T0[i] for i in range(T0.shape[0])}
        self.next_gid = T0.shape[0]

    def apply(self, op):
        if op[0] == "ins":
            for row in op[1]:
                self.items[self.next_gid] = row
                self.next_gid += 1
        elif op[0] == "del":
            for g in op[1]:
                del self.items[g]
        elif op[0] == "upd":
            for g, row in zip(op[1], op[2]):
                self.items[g] = row

    def topk(self, U, k):
        gids = np.fromiter(self.items.keys(), np.int64, len(self.items))
        rows = np.stack([self.items[g] for g in gids])
        s = U.astype(np.float64) @ rows.astype(np.float64).T
        order = np.argsort(-s, kind="stable", axis=1)[:, :k]
        return s[np.arange(U.shape[0])[:, None], order], gids[order]


def run_segmented(T0, ops, method="norm", delta_capacity=64,
                  warm=True):
    import jax.numpy as jnp

    from repro.core import SepLRModel
    from repro.serving.server import TopKServer

    srv = TopKServer(SepLRModel(jnp.asarray(T0)), max_batch=B,
                     block_size=256, delta_capacity=delta_capacity,
                     compact_async=True)
    if warm:
        srv.warmup(K, batch_sizes=(B,), engines=[method])
    results = []
    t0 = time.perf_counter()
    for op in ops:
        if op[0] == "ins":
            srv.add_targets(op[1])
        elif op[0] == "del":
            srv.delete_targets(op[1])
        elif op[0] == "upd":
            srv.update_targets(op[1], op[2])
        else:
            res = srv.query(op[1], K, method)
            results.append((np.asarray(res.values),
                            np.asarray(res.indices)))
    srv.catalogue.flush()                    # charge any in-flight build
    elapsed = time.perf_counter() - t0
    return srv, results, elapsed


def run_rebuild_baseline(T0, ops, method="norm", lazy=False):
    """Rebuild the serving state after EVERY mutation call: the offline
    sorted-list index + a fresh :class:`EngineContext` over the live set.
    Queries go through the same registry engine as the segmented side.

    ``lazy=False`` (the readiness-symmetric primary): each rebuild also
    re-warms the engine, keeping the tier query-ready at all times — the
    policy the segmented server follows. ``lazy=True`` defers
    compilation to the first query after each rebuild (fewer compiles
    when mutations arrive in bursts, at the cost of post-mutation
    latency spikes)."""
    from repro.core import EngineContext, get_engine

    eng = get_engine(method)
    oracle = _OracleCatalogue(T0)
    # boot (untimed, like the segmented server's warmup): a ready context
    # over the initial catalogue — the timed loop measures keeping it
    # ready under mutations, not standing it up
    ctx = EngineContext(T0, block_size=256)
    ctx.index
    if not lazy:
        ctx.warmup(K, batch_sizes=(B,), engines=[method])
    n_rebuilds = 0
    t0 = time.perf_counter()
    for op in ops:
        if op[0] == "query":
            res = eng.run(ctx, op[1], K)
            np.asarray(res.values)
        else:
            oracle.apply(op)
            gids = list(oracle.items.keys())
            rows = np.stack([oracle.items[g] for g in gids])
            ctx = EngineContext(rows, block_size=256)
            ctx.index                         # the O(R M log M) offline step
            if not lazy:
                ctx.warmup(K, batch_sizes=(B,), engines=[method])
            n_rebuilds += 1
    return time.perf_counter() - t0, n_rebuilds


def verify(T0, ops, results, atol=1e-3):
    """Replay the schedule on the oracle; check every stored query result:
    value vectors match, every returned gid is live and scores its value."""
    oracle = _OracleCatalogue(T0)
    it = iter(results)
    for op in ops:
        if op[0] != "query":
            oracle.apply(op)
            continue
        vals, gids = next(it)
        ov, _ = oracle.topk(op[1], K)
        if not np.allclose(vals, ov, atol=atol):
            return False
        for b in range(vals.shape[0]):
            for j in range(K):
                g = int(gids[b, j])
                if g not in oracle.items:
                    return False
                if abs(float(op[1][b] @ oracle.items[g]) - vals[b, j]) > atol:
                    return False
    return True


def run(quick: bool = True, rounds: int = None, save_as: str = "streaming",
        method: str = "norm"):
    rng = np.random.default_rng(13)
    rounds = rounds if rounds is not None else (6 if quick else 24)
    # delta sized so the stream overflows it at least once (compaction is
    # exercised) while the LSM amortization is visible: one fold per
    # hundreds of mutations vs the baseline's rebuild per mutation call
    delta_capacity = 64 if quick else 512
    ins, dels, upds, q_per = 16, 8, 8, 4     # mutation-heavy by design
    rows_out = []
    for M in (QUICK_SWEEP if quick else FULL_SWEEP):
        T0 = _catalogue(rng, M)
        ops = make_schedule(rng, M, rounds, ins, dels, upds, q_per)
        n_mut_calls = 3 * rounds
        n_queries = q_per * rounds * B
        n_ops = n_mut_calls + q_per * rounds
        srv, results, seg_s = run_segmented(T0, ops, method=method,
                                            delta_capacity=delta_capacity)
        exact = verify(T0, ops, results)
        reb_s, n_rebuilds = run_rebuild_baseline(T0, ops, method=method)
        reb_lazy_s, _ = run_rebuild_baseline(T0, ops, method=method,
                                             lazy=True)
        st = srv.stats[method]
        ms = srv.mutation_stats
        rows_out.append({
            "M": M, "R": R, "K": K, "batch": B, "method": method,
            "rounds": rounds, "mutation_calls": n_mut_calls,
            "mutated_items": rounds * (ins + dels + upds),
            "queries": n_queries,
            "exact_verified": bool(exact),
            "segmented_s": seg_s,
            "rebuild_s": reb_s,
            "rebuild_lazy_s": reb_lazy_s,
            "n_rebuilds": n_rebuilds,
            "ops_per_s_segmented": n_ops / seg_s,
            "ops_per_s_rebuild": n_ops / reb_s,
            "speedup_vs_rebuild": reb_s / seg_s,
            "speedup_vs_rebuild_lazy": reb_lazy_s / seg_s,
            "qps_segmented": n_queries / seg_s,
            "us_per_query_mean": st.us_per_query,
            "p50_us": st.p50_us, "p95_us": st.p95_us, "p99_us": st.p99_us,
            "delta_scored_per_query": st.delta_scored / max(st.n_queries, 1),
            "delta_capacity": srv.catalogue.delta_capacity,
            "max_delta_occupancy": ms["max_delta_occupancy"],
            "n_compactions": ms["n_compactions"],
            "n_tombstones_final": ms["n_tombstones"],
            "snapshot_version": ms["snapshot_version"],
            "num_live_final": ms["num_live"],
            # compile-free compaction (DESIGN.md §10): engine traces per
            # compaction build (0 = every build hit warmed buckets) and
            # the builds' wall-clock, now index/layout rebuild only
            "engine_compiles_total": ms["engine_compiles_total"],
            "engine_compiles_per_compaction":
                ms["engine_compiles_per_compaction"],
            "compaction_s_total": ms["compaction_s_total"],
            "compaction_s_mean": (ms["compaction_s_total"]
                                  / max(ms["n_compactions"], 1)),
        })
    save_rows(save_as, rows_out)
    return rows_out


def main(quick: bool = True):
    rows = run(quick)
    bad = [r["M"] for r in rows if not r["exact_verified"]]
    r0 = rows[0]
    derived = (f"speedup={r0['speedup_vs_rebuild']:.1f}x,"
               f"compactions={r0['n_compactions']},"
               f"compiles_per_compaction="
               f"{r0['engine_compiles_per_compaction']:.0f},"
               f"p99={r0['p99_us']:.0f}us,exact_failures={bad or 'none'}")
    print(csv_line("streaming", 1e6 / r0["qps_segmented"], derived))
    assert not bad, f"segmented results diverged from rebuild oracle: {bad}"
    slow = [r["M"] for r in rows
            if r["M"] >= 32768 and r["speedup_vs_rebuild"] < 10.0]
    assert not slow, f"segmented < 10x rebuild-per-mutation at M={slow}"
    # acceptance (DESIGN.md §10): warmed-bucket compactions retrace nothing
    retraced = [r["M"] for r in rows
                if r["n_compactions"] > 0
                and r["engine_compiles_per_compaction"] != 0]
    assert not retraced, \
        f"compaction performed engine retraces at M={retraced}"


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
