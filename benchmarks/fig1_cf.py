"""Paper Fig. 1 — collaborative filtering: TA vs naive score counts.

Sweeps (dataset x top-size x database-fraction) for memory-based
(cosine-normalised sparse items) and model-based (probabilistic-PCA
factors at R in {5,10,50,100,250}) CF, mirroring §4.1. Datasets are
synthetic stand-ins with the papers' shape statistics (offline container;
EXPERIMENTS.md). The paper's claims under test:
  C1 gain grows with database size M,
  C2 gain shrinks with top size K,
  C3 gain shrinks with rank R,
  C4 sparse memory-based >> dense model-based.
"""
import numpy as np

from benchmarks.common import csv_line, engine_counts, save_rows, timed


def _ta_counts(T, U, k):
    """Exact TA score counts via the registry "ta" engine (the driver's
    liveness gating keeps the batched counts per-query faithful)."""
    return engine_counts(T, U, k, engine="ta")


def run(quick: bool = True):
    from repro.data.synthetic import cf_ratings, probabilistic_pca

    rng = np.random.default_rng(0)
    rows = []
    n_users = 400 if quick else 2000
    sizes = {"movielens100k-like": 1682, "movielens1m-like": 3952,
             "audioscrobbler-like": 12000 if quick else 47085}
    ks = (1, 10, 100) if quick else (1, 5, 10, 50, 100)
    fracs = (0.1, 1.0) if quick else (0.1, 0.5, 1.0)
    ranks = (5, 10, 50) if quick else (5, 10, 50, 100, 250)
    n_queries = 5 if quick else 10

    for name, m_items in sizes.items():
        implicit = "scrobbler" in name
        M = cf_ratings(rng, n_users, m_items, density=0.02, implicit=implicit)
        # --- memory-based: items are sparse rating columns, cosine sim ----
        items = M.T.astype(np.float32)                      # [m_items, users]
        norms = np.linalg.norm(items, axis=1, keepdims=True)
        items_n = items / np.maximum(norms, 1e-9)
        queries = items_n[rng.choice(m_items, n_queries, replace=False)]
        for frac in fracs:
            keep = rng.choice(m_items, max(int(m_items * frac), 200),
                              replace=False)
            Tm = items_n[keep]
            for k in ks:
                n_ta, depth = _ta_counts(Tm, queries, k)
                rows.append({
                    "setting": "memory", "dataset": name, "M": len(keep),
                    "R": Tm.shape[1], "K": k, "frac": frac,
                    "scores_ta": n_ta, "scores_naive": len(keep),
                    "ratio": n_ta / len(keep)})
        # --- model-based: pPCA factors -------------------------------------
        for rank in ranks:
            Uf, Vf = probabilistic_pca(M, rank, n_iters=6)
            qs = Uf[rng.choice(n_users, n_queries, replace=False)]
            for k in ks:
                n_ta, depth = _ta_counts(Vf, qs, k)
                rows.append({
                    "setting": "model", "dataset": name, "M": m_items,
                    "R": rank, "K": k, "frac": 1.0,
                    "scores_ta": n_ta, "scores_naive": m_items,
                    "ratio": n_ta / m_items})
    save_rows("fig1_cf", rows)
    return rows


def main(quick: bool = True):
    import time
    t0 = time.perf_counter()
    rows = run(quick)
    dt = time.perf_counter() - t0
    mem = [r for r in rows if r["setting"] == "memory"]
    mod = [r for r in rows if r["setting"] == "model"]
    mem_ratio = float(np.mean([r["ratio"] for r in mem]))
    mod_ratio = float(np.mean([r["ratio"] for r in mod]))
    # C1: gain grows with database size — the paper's 10%/50%/100% withheld
    # fractions of the SAME dataset (Fig. 1 x-axis), averaged over datasets
    fr = sorted({r["frac"] for r in mem})
    big = np.mean([r["ratio"] for r in mem if r["frac"] == fr[-1]])
    small = np.mean([r["ratio"] for r in mem if r["frac"] == fr[0]])
    # C2: K monotonicity (model-based)
    k_lo = np.mean([r["ratio"] for r in mod if r["K"] == 1])
    k_hi = np.mean([r["ratio"] for r in mod if r["K"] == max(x["K"] for x in mod)])
    # C3: R monotonicity
    r_lo = np.mean([r["ratio"] for r in mod if r["R"] == 5])
    r_hi = np.mean([r["ratio"] for r in mod if r["R"] == max(x["R"] for x in mod)])
    derived = (f"mem_ratio={mem_ratio:.3f};model_ratio={mod_ratio:.3f};"
               f"C1_bigM<smallM={big < small};C2_K1<Kmax={k_lo < k_hi};"
               f"C3_R5<Rmax={r_lo < r_hi};C4_mem<model={mem_ratio < mod_ratio}")
    print(csv_line("fig1_cf", dt / max(len(rows), 1) * 1e6, derived))


if __name__ == "__main__":
    main()
