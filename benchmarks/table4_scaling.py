"""Paper Table 4 — LSHTC-style text classification: average number of
scores vs the number of latent features R (K=1 over a huge label space).

The paper reports 28.3 / 179.4 / 441.7 / 3581.3 / 8995.7 scored labels for
R = 10 / 50 / 100 / 500 / 1000 on 325,056 classes — i.e. even at R=1000
only 2.8% of classes are touched. We verify the same R-scaling shape on
PLS-like synthetic embeddings and report the scored fraction per R.
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows


def run(quick: bool = True):
    from benchmarks.common import engine_counts
    from repro.data.synthetic import multilabel_factors

    rng = np.random.default_rng(3)
    n_labels = 20000 if quick else 325056
    ranks = (10, 50, 100) if quick else (10, 50, 100, 500, 1000)
    n_queries = 5 if quick else 10
    rows = []
    from repro.core.engines import EngineContext

    for R in ranks:
        T = multilabel_factors(rng, n_labels, R, "ridge")
        spectrum = 1.0 / np.sqrt(1.0 + np.arange(R, dtype=np.float32))
        U = rng.standard_normal((n_queries, R)).astype(np.float32) * spectrum
        ctx = EngineContext(T)
        ctx.index  # build offline, outside the timed window
        # compile offline too (DESIGN.md §6): us_per_query is steady-state
        # serving latency, not the one-off trace+compile cost
        ctx.warmup(1, batch_sizes=(n_queries,), engines=["ta"])
        t0 = time.perf_counter()
        avg_scores, _ = engine_counts(T, U, 1, engine="ta", ctx=ctx)
        dt = (time.perf_counter() - t0) / n_queries
        rows.append({"R": R, "M": n_labels,
                     "avg_scores": avg_scores,
                     "fraction": avg_scores / n_labels,
                     "us_per_query": dt * 1e6})
    save_rows("table4_scaling", rows)
    return rows


def main(quick: bool = True):
    rows = run(quick)
    fr = {r["R"]: r["fraction"] for r in rows}
    rs = sorted(fr)
    monotone = all(fr[rs[i]] <= fr[rs[i + 1]] * 1.5 for i in range(len(rs) - 1))
    derived = ";".join(f"R{r}={fr[r]:.4f}" for r in rs) + \
        f";scores_grow_with_R={fr[rs[0]] < fr[rs[-1]]};all_small={max(fr.values()) < 0.5}"
    print(csv_line("table4_scaling", rows[-1]["us_per_query"], derived))


if __name__ == "__main__":
    main()
