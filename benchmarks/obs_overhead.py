"""Observability overhead gate: the PR-8 closed loop, instrumented.

DESIGN.md §14's overhead budget, measured end to end: the loadtest's
saturated closed-loop phase (open-loop arrivals at 8x the sync
baseline's rate, every result oracle-verified) runs against ONE shared
async server in interleaved A/B phases — observability DISABLED
(``repro.obs.set_enabled(False)``: every seam early-outs), then
EVERYTHING on (metrics registry recording, the event journal, and span
traces at ``sample_rate=1.0`` — a worse-than-production setting;
production samples), in interleaved repetitions that ALTERNATE which
mode runs first. Sharing the server, interleaving, and alternating the
order is what makes this a CONTROLLED comparison: both sides see
identical compiled executables, warm cost tables and allocator state,
and slow machine-wide drift lands on both sides instead of biasing
whichever mode ran second. Both modes are burned in at the saturated
rate before timing starts (first-phase one-time costs — label-series
creation, span-store allocator growth — are warmup, not overhead).
Every phase submits unique queries, so the result cache contributes to
neither side. The gate is the ratio of best-of-N saturated completed
QPS (per-rep paired ratios ride in the summary for honesty):

* ``obs_on_qps / obs_off_qps >= 0.9`` — full observability may cost at
  most 10% of saturated throughput. This is the ``--check-perf`` gate
  the committed full-size ``results/bench/obs_overhead.json`` must
  pass on a quiet machine.
* ``--check`` (what CI runs, with ``--quick``) gates SOUNDNESS only —
  every row oracle-exact, the metrics snapshot validates against the
  checked-in schema, the Prometheus rendering parses, and a full span
  tree was captured. The ratio is REPORTED but not gated in CI:
  shared-runner clocks jitter far more than the 10% budget itself
  (observed same-mode back-to-back runs varying 10x under co-tenant
  load), so the wall-clock criterion is an artifact-generation gate,
  not a CI gate — the same split ``benchmarks/loadtest.py`` settled on.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import save_rows
from benchmarks.loadtest import _oracle_topk, run_async, run_sync


def _saturated_phase(srv, rng, T, R, k, qps, dur, method, tag):
    """One saturated closed-loop phase of UNIQUE queries (the cache
    cannot contribute; completed QPS measures the serving path alone).
    Returns the loadtest-shaped row."""
    n = min(max(int(qps * dur), 200), 20000)
    qs = rng.standard_normal((n, R)).astype(np.float32)
    return run_async(srv, qs, _oracle_topk(T, qs, k), k, qps, dur,
                     method, tag=tag, n=n)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small M / short durations (CI tier-2 smoke)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on a soundness failure (exactness, "
                         "snapshot schema, prom parse, missing trace); "
                         "the ratio is reported, not gated — CI clocks "
                         "are too noisy")
    ap.add_argument("--check-perf", action="store_true",
                    help="additionally gate the real overhead budget: "
                         "obs-on throughput >= 0.9x obs-off (artifact "
                         "generation on a quiet machine)")
    ap.add_argument("--method", default="auto")
    args = ap.parse_args(argv)

    from repro import obs
    from repro.core import SepLRModel
    from repro.serving.pipeline import AsyncTopKServer
    from repro.serving.server import TopKServer

    M = 4096 if args.quick else 65536
    R, k, pool_n = 32, 10, 512
    dur = 1.0 if args.quick else 3.0
    max_batch = 64
    rng = np.random.default_rng(0)
    T = rng.standard_normal((M, R)).astype(np.float32)
    pool = rng.standard_normal((pool_n, R)).astype(np.float32)
    oracle = _oracle_topk(T, pool, k)
    meta = {"M": M, "R": R, "k": k, "method": args.method,
            "max_batch": max_batch}

    print(f"# obs_overhead M={M} k={k} method={args.method}", flush=True)
    # the sync baseline exists only to locate the saturating rate; it
    # runs uninstrumented so BOTH instrumented phases see the same rate
    obs.set_enabled(False)
    try:
        sync_srv = TopKServer(SepLRModel(T), max_batch=max_batch,
                              delta_capacity=64)
        sync_srv.warmup(k)
        sync_row = dict(run_sync(sync_srv, pool, oracle, k, dur,
                                 args.method), **meta)
        del sync_srv
        sync_qps = sync_row["completed_qps"]
        sat_qps = max(8.0 * sync_qps, 1.0)
        print(f"sync: {sync_qps:.0f} qps -> saturating at "
              f"{sat_qps:.0f} qps", flush=True)

        rows = [sync_row]
        obs.reset()
        obs.TRACER.sample_rate = 1.0       # worst case: trace everything
        srv = AsyncTopKServer(SepLRModel(T), max_batch=max_batch,
                              delta_capacity=64, method=args.method)
        srv.warmup(k)
        phases = {"obs_off": [], "obs_on": []}
        with srv:
            # burn in BOTH modes at the saturated rate before anything
            # is timed: the first instrumented phase otherwise pays
            # one-time costs (label-series creation, allocator growth
            # for the span store) that belong to warmup, not overhead
            for on in (False, True):
                obs.set_enabled(on)
                burn = rng.standard_normal((256, R)).astype(np.float32)
                run_async(srv, burn, _oracle_topk(T, burn, k), k,
                          sat_qps, 0.5, args.method, n=256)
            ratios = []
            for rep in range(2 if args.quick else 4):
                # alternate which mode runs first so slow machine-wide
                # drift within a rep cancels instead of always taxing
                # the same side
                order = ((("obs_off", False), ("obs_on", True))
                         if rep % 2 == 0 else
                         (("obs_on", True), ("obs_off", False)))
                pair = {}
                for mode, on in order:
                    obs.set_enabled(on)
                    row = dict(_saturated_phase(
                        srv, rng, T, R, k, sat_qps, dur, args.method,
                        f"{mode}_run{rep}"), **meta, obs_enabled=on)
                    phases[mode].append(row)
                    pair[mode] = row["completed_qps"]
                    rows.append(row)
                    print(f"{mode} run{rep}: "
                          f"{row['completed_qps']:.0f} qps", flush=True)
                ratios.append(pair["obs_on"] / max(pair["obs_off"], 1e-9))
        best = {mode: max(p["completed_qps"] for p in ps)
                for mode, ps in phases.items()}
        snapshot = obs.REGISTRY.snapshot()
        prom = obs.REGISTRY.render_prom()
        trace = obs.TRACER.slowest()
    finally:
        obs.set_enabled(True)   # never leave the process dark

    ratio = best["obs_on"] / max(best["obs_off"], 1e-9)
    summary = {
        "mode": "summary", **meta,
        "sync_qps": sync_qps,
        "offered_qps": sat_qps,
        "obs_off_qps": best["obs_off"],
        "obs_on_qps": best["obs_on"],
        "overhead_ratio": ratio,
        "per_rep_ratios": ratios,
        "exact_verified": all(r["exact_verified"] for r in rows),
        "n_prom_samples": len(obs.parse_prom_text(prom)),
        "n_traces": len(obs.TRACER.traces()),
        "slowest_trace_us": (None if trace is None
                             else trace.duration_us),
    }
    rows.append(summary)
    # the metrics snapshot of the instrumented run rides in the
    # artifact so the CI obs job can validate it against the
    # checked-in schema without rerunning the bench
    rows.append({"mode": "metrics_snapshot", "snapshot": snapshot,
                 "prom_text": prom})
    save_rows("obs_overhead", rows)
    print(f"overhead_ratio={ratio:.3f} "
          f"(obs_on {best['obs_on']:.0f} / obs_off {best['obs_off']:.0f} "
          f"qps)", flush=True)

    failures = []
    if args.check or args.check_perf:
        if not summary["exact_verified"]:
            failures.append("a served result diverged from the oracle "
                            "while instrumented")
        try:
            obs.validate_snapshot(snapshot)
        except ValueError as e:
            failures.append(f"metrics snapshot violates the checked-in "
                            f"schema: {e}")
        if summary["n_prom_samples"] < 10:
            failures.append("Prometheus rendering parsed to "
                            f"{summary['n_prom_samples']} samples")
        if trace is None or trace.find("device") is None:
            failures.append("no full span tree captured at "
                            "sample_rate=1.0")
    if args.check_perf and ratio < 0.9:
        failures.append(f"overhead ratio {ratio:.3f} < 0.9x — "
                        "observability costs more than its 10% budget")
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
