"""Paper Table 1 — the worked toy example, reproduced exactly.

Expected (paper §2): best item = 6 (1-indexed); Fagin terminates at list
depth 5 having scored 9 of 10 items; TA terminates after 2 rounds having
scored 5 of 10; both return the same top-1 as the naive scan.
"""
import numpy as np

from benchmarks.common import csv_line, save_rows, timed


def run(quick: bool = True):
    import jax.numpy as jnp

    from repro.core import (fagin_topk_np, naive_topk,
                            partial_threshold_topk_np, threshold_topk_np)
    from repro.core.index import build_index
    from repro.core.toy import TOY_BEST_ITEM, TOY_T, TOY_U

    idx = build_index(TOY_T)
    order = np.asarray(idx.order_desc)

    (nv, ni, *_), t_naive = timed(
        lambda: naive_topk(jnp.asarray(TOY_T), jnp.asarray(TOY_U), 1))
    tv, ti, ts = threshold_topk_np(TOY_T, order, TOY_U, 1)
    fv, fi, fs = fagin_topk_np(TOY_T, order, TOY_U, 1)
    pv, pi, ps = partial_threshold_topk_np(TOY_T, order, TOY_U, 1)

    rows = [{
        "best_item_0idx": int(ti[0]),
        "paper_best_item_0idx": TOY_BEST_ITEM,
        "ta_scored": ts.n_scored, "ta_depth": ts.depth,
        "paper_ta_scored": 5, "paper_ta_depth": 2,
        "fagin_scored": fs.n_scored, "fagin_depth": fs.depth,
        "paper_fagin_scored": 9, "paper_fagin_depth": 5,
        "partial_avg_fraction": ps.avg_score_fraction,
        "all_agree": bool(int(ni[0]) == int(ti[0]) == int(fi[0]) == int(pi[0])
                          == TOY_BEST_ITEM),
        "us_per_call": t_naive * 1e6,
    }]
    save_rows("table1_toy", rows)
    return rows


def main(quick: bool = True):
    r = run(quick)[0]
    assert r["all_agree"] and r["ta_scored"] == 5 and r["fagin_scored"] == 9
    print(csv_line("table1_toy", r["us_per_call"],
                   f"ta_scored={r['ta_scored']}/10;fagin={r['fagin_scored']}/10;match=paper"))


if __name__ == "__main__":
    main()
