"""Paper Fig. 3 — behaviour of individual queries: lower-bound
trajectories and the lag between FINDING the correct top and PROVING it.

Reproduces §4.3's observation: the correct top-K is usually found within
a few rounds, long before the TA certificate (lb >= ub) closes — which
motivates the halted TA. We also measure halted-TA precision@K as a
function of the round budget (the §5 uncertainty/cost trade-off).
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows


def run(quick: bool = True):
    from repro.core import threshold_topk_np
    from repro.core.index import build_index
    from repro.data.synthetic import cf_ratings, probabilistic_pca

    rng = np.random.default_rng(2)
    n_users, m_items = (300, 3000) if quick else (2000, 20000)
    n_queries = 20 if quick else 100
    K = 5
    M = cf_ratings(rng, n_users, m_items, density=0.02, implicit=True)
    Uf, Vf = probabilistic_pca(M, 50, n_iters=6)
    idx = build_index(Vf)
    order = np.asarray(idx.order_desc)
    rows = []
    budgets = (1, 2, 5, 10, 25, 50, 100, 250)
    found_at, term_at = [], []
    hit_at_budget = {b: 0 for b in budgets}
    for qi in range(n_queries):
        u = Uf[rng.integers(0, n_users)]
        vals, ids, st = threshold_topk_np(Vf, order, u, K,
                                          track_trajectory=True)
        found_at.append(st.found_at)
        term_at.append(st.depth)
        for b in budgets:
            if st.found_at <= b:
                hit_at_budget[b] += 1
        if qi < 5:
            rows.append({
                "query": qi, "found_at": st.found_at, "terminated": st.depth,
                "lb_trajectory": st.lower_bounds[:50].tolist(),
                "ub_trajectory": st.upper_bounds[:50].tolist()})
    rows.append({
        "summary": True, "K": K, "M": m_items,
        "median_found_at": float(np.median(found_at)),
        "median_terminated": float(np.median(term_at)),
        "lag_x": float(np.median(term_at) / max(np.median(found_at), 1)),
        "halted_precision_at_budget": {
            str(b): hit_at_budget[b] / n_queries for b in budgets},
    })
    save_rows("fig3_halted", rows)
    return rows


def main(quick: bool = True):
    t0 = time.perf_counter()
    rows = run(quick)
    dt = time.perf_counter() - t0
    s = rows[-1]
    derived = (f"median_found={s['median_found_at']:.0f};"
               f"median_term={s['median_terminated']:.0f};"
               f"lag={s['lag_x']:.1f}x;"
               f"halted@50={s['halted_precision_at_budget']['50']:.2f}")
    print(csv_line("fig3_halted", dt * 1e6, derived))


if __name__ == "__main__":
    main()
