"""Paper Fig. 3 — behaviour of individual queries: lower-bound
trajectories and the lag between FINDING the correct top and PROVING it.

Reproduces §4.3's observation: the correct top-K is usually found within
a few rounds, long before the TA certificate (lb >= ub) closes — which
motivates the halted TA. The precision/budget trade-off (§5) is measured
through the REAL budgeted engine path (DESIGN.md §12): each budget runs
the registry engines with ``budget=``, and the per-item certificates
(``upper - value`` gaps) report, per budget, how much of the returned
top-K is PROVABLY exact — the certified fraction — alongside the actual
precision against the dense oracle and the mean certificate gap of the
uncertified remainder. The certified-fraction column is a lower bound on
the precision column by construction; the gate in CI asserts certified
items are never wrong.
"""
import time

import numpy as np

from benchmarks.common import csv_line, save_rows


def run(quick: bool = True):
    import jax.numpy as jnp

    from repro.core import certificate_gaps, threshold_topk_np
    from repro.core.engines import EngineContext, get_engine
    from repro.core.index import build_index
    from repro.data.synthetic import cf_ratings, probabilistic_pca

    rng = np.random.default_rng(2)
    n_users, m_items = (300, 3000) if quick else (2000, 20000)
    n_queries = 20 if quick else 100
    K = 5
    M = cf_ratings(rng, n_users, m_items, density=0.02, implicit=True)
    Uf, Vf = probabilistic_pca(M, 50, n_iters=6)
    idx = build_index(Vf)
    order = np.asarray(idx.order_desc)
    rows = []
    budgets = (1, 2, 5, 10, 25, 50, 100, 250)

    # -- oracle trajectories (the paper's Fig. 3 curves) ---------------------
    found_at, term_at = [], []
    queries = Uf[rng.integers(0, n_users, size=n_queries)]
    for qi in range(n_queries):
        u = queries[qi]
        vals, ids, st = threshold_topk_np(Vf, order, u, K,
                                          track_trajectory=True)
        found_at.append(st.found_at)
        term_at.append(st.depth)
        if qi < 5:
            rows.append({
                "query": qi, "found_at": st.found_at, "terminated": st.depth,
                "lb_trajectory": st.lower_bounds[:50].tolist(),
                "ub_trajectory": st.upper_bounds[:50].tolist()})

    # -- budgeted ENGINE runs: precision + certificates per budget -----------
    ctx = EngineContext(np.ascontiguousarray(Vf, dtype=np.float32),
                        block_size=64, ta_chunk=16)
    U_dev = jnp.asarray(queries.astype(np.float32))
    s = queries.astype(np.float64) @ Vf.astype(np.float64).T
    true_order = np.argsort(-s, kind="stable", axis=1)[:, :K]
    true_vals = s[np.arange(n_queries)[:, None], true_order]
    true_sets = [set(r) for r in true_order]
    for engine in ("ta", "bta", "norm"):
        eng = get_engine(engine)
        for b in budgets:
            res = eng.run(ctx, U_dev, K, budget=b)
            vals = np.asarray(res.values)
            ids = np.asarray(res.indices)
            gaps = np.asarray(certificate_gaps(res))
            certified = gaps <= 0
            n_cert = certified.sum(axis=1)
            # certified slots must BE the true top-K prefix — the
            # exactness gate CI runs (a violation here is a soundness
            # bug, not a tuning artifact)
            cert_exact = all(
                np.allclose(vals[q, :n_cert[q]], true_vals[q, :n_cert[q]],
                            atol=1e-4)
                for q in range(n_queries))
            hits = sum(
                len(set(ids[q][ids[q] >= 0]) & true_sets[q])
                for q in range(n_queries))
            uncert = gaps[np.logical_and(~certified, ids >= 0)]
            rows.append({
                "engine": engine, "budget": b, "K": K, "M": m_items,
                "precision": hits / (n_queries * K),
                "certified_fraction": float(np.mean(n_cert)) / K,
                "certified_exact": bool(cert_exact),
                "mean_uncertified_gap": (
                    float(np.mean(uncert)) if uncert.size else 0.0),
                "mean_depth": float(np.mean(np.asarray(res.depth))),
                "mean_scored": float(np.mean(np.asarray(res.n_scored))),
            })

    rows.append({
        "summary": True, "K": K, "M": m_items,
        "median_found_at": float(np.median(found_at)),
        "median_terminated": float(np.median(term_at)),
        "lag_x": float(np.median(term_at) / max(np.median(found_at), 1)),
    })
    save_rows("fig3_halted", rows)
    return rows


def main(quick: bool = True):
    t0 = time.perf_counter()
    rows = run(quick)
    dt = time.perf_counter() - t0
    s = rows[-1]
    bta50 = next(r for r in rows
                 if r.get("engine") == "bta" and r.get("budget") == 50)
    derived = (f"median_found={s['median_found_at']:.0f};"
               f"median_term={s['median_terminated']:.0f};"
               f"lag={s['lag_x']:.1f}x;"
               f"bta@50:prec={bta50['precision']:.2f},"
               f"cert={bta50['certified_fraction']:.2f}")
    print(csv_line("fig3_halted", dt * 1e6, derived))


if __name__ == "__main__":
    main()
