"""End-to-end behaviour tests for the paper's system: train a SEP-LR
producer (matrix factorisation), index it, serve exact top-K through every
engine, and check the pipeline against the naive ground truth."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (build_index, from_matrix_factorization, naive_topk,
                        threshold_topk_from_index)
from repro.data.synthetic import cf_ratings, probabilistic_pca
from repro.serving.server import TopKServer


def test_end_to_end_cf_pipeline():
    rng = np.random.default_rng(0)
    # 1) "train": factorise a ratings matrix (model-based CF, paper §3.1)
    M = cf_ratings(rng, 200, 1500, density=0.03, implicit=True)
    U, V = probabilistic_pca(M, 16, n_iters=8)
    model = from_matrix_factorization(jnp.asarray(V), name="cf")
    # 2) serve: exact top-K recommendations for user queries
    srv = TopKServer(model, max_batch=16, block_size=64)
    queries = jnp.asarray(U[:8])
    res = srv.query(queries, 10, method="bta")
    truth = naive_topk(model.targets, queries, 10)
    for b in range(8):
        np.testing.assert_allclose(np.sort(res.values[b]),
                                   np.sort(np.asarray(truth.values[b])),
                                   atol=1e-4)
    # 3) the paper's efficiency metric is recorded per engine
    assert srv.stats["bta"].n_queries == 8
    assert srv.stats["bta"].scores_per_query <= 1500


def test_lm_topk_head_is_seplr():
    """The LM unembedding IS a SEP-LR catalogue: TA over it returns the
    same top-K tokens as full-softmax argsort."""
    from repro.configs import get_arch
    from repro.models import transformer as tf_mod

    cfg = get_arch("gemma-2b").make_smoke_config()
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    hidden, _ = tf_mod.forward(params, tokens, cfg)
    u = hidden[0, -1].astype(jnp.float32)
    T = params["unembed"].T.astype(jnp.float32)      # [V, D] catalogue
    idx = build_index(np.asarray(T))
    res = threshold_topk_from_index(T, idx, u, 5)
    ref = jax.lax.top_k(u @ params["unembed"], 5)
    np.testing.assert_allclose(np.sort(np.asarray(res.values)),
                               np.sort(np.asarray(ref[0])), atol=1e-3)
    assert int(res.n_scored) <= cfg.vocab_size
