"""Tests for the §Perf optimized code paths (EXPERIMENTS.md):
expert-parallel MoE dispatch and the grouped flash-decoding attention."""

import subprocess
import sys
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs_explicit_mesh = pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax.sharding, "AxisType")),
    reason="needs the explicit-mesh APIs (jax.set_mesh / sharding.AxisType) "
           "of newer jax; this interpreter's jax predates them")


def _run(code: str, timeout=560):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@needs_explicit_mesh
def test_moe_ep_matches_dense_dispatch():
    """EP dispatch (Perf-A) must be numerically identical to the pjit
    global dispatch when capacity is ample, including under sharding."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models.moe import init_moe, moe_ffn, moe_ffn_ep
        from repro.models.common import MeshRules
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        for seed in range(3):
            params = init_moe(jax.random.PRNGKey(seed), 32, 48, 8)
            h = jax.random.normal(jax.random.PRNGKey(seed + 10), (4, 8, 32))
            ref, _ = moe_ffn(params, h.reshape(32, 32), top_k=2,
                             capacity_factor=8.0)
            with jax.set_mesh(mesh):
                out, aux = moe_ffn_ep(params, h, top_k=2,
                                      capacity_factor=8.0, rules=MeshRules())
            err = float(jnp.max(jnp.abs(out.reshape(32, 32) - ref)))
            assert err < 1e-4, (seed, err)
            assert float(aux["drop_rate"]) < 1e-6
        print("EP_PARITY_OK")
    """)
    assert "EP_PARITY_OK" in out


@needs_explicit_mesh
def test_moe_ep_capacity_dropping_is_bounded():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.models.moe import init_moe, moe_ffn_ep
        from repro.models.common import MeshRules
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        params = init_moe(jax.random.PRNGKey(0), 32, 48, 8)
        h = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32))
        with jax.set_mesh(mesh):
            out, aux = moe_ffn_ep(params, h, top_k=2, capacity_factor=1.0,
                                  rules=MeshRules())
        assert bool(jnp.all(jnp.isfinite(out)))
        d = float(aux["drop_rate"])
        assert 0.0 <= d < 0.6, d
        print("EP_DROP_OK", d)
    """)
    assert "EP_DROP_OK" in out


@pytest.mark.parametrize("n_heads,n_kv", [(8, 8), (8, 2), (4, 1)])
def test_grouped_decode_attention_matches_dense(n_heads, n_kv):
    """Perf-B grouped decode == reference softmax attention (incl. MQA)."""
    from repro.models.attention import decode_attention
    rng = np.random.default_rng(n_heads * 10 + n_kv)
    B, S, D = 2, 64, 16
    q = jnp.asarray(rng.standard_normal((B, 1, n_heads, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, n_kv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, n_kv, D)).astype(np.float32))
    clen = jnp.asarray([40, 64], jnp.int32)
    out = decode_attention(q, k, v, cache_len=clen)
    # dense reference
    kk = jnp.repeat(k, n_heads // n_kv, axis=2)
    vv = jnp.repeat(v, n_heads // n_kv, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q / np.sqrt(D), kk)
    mask = jnp.arange(S)[None, None, None, :] < clen[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bhqs,bshd->bqhd", p, vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@needs_explicit_mesh
def test_kv_cache_specs_folds_idle_data_axis():
    """Perf-B iter 3: batch=1 -> sequence sharded over data AND model."""
    out = _run("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.models.common import MeshRules
        from repro.models.transformer import TransformerConfig, kv_cache_specs
        cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                                n_kv_heads=2, head_dim=8, d_ff=64,
                                vocab_size=128)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        with jax.set_mesh(mesh):
            sp1 = kv_cache_specs(cfg, MeshRules(), batch=1, seq_len=64)["k"]
            sp8 = kv_cache_specs(cfg, MeshRules(), batch=8, seq_len=64)["k"]
        assert tuple(sp1[2]) == ("data", "model"), sp1   # CP over both axes
        assert sp8[1] in ("data", ("data",)) and sp8[2] == "model", sp8
        print("CP_SPEC_OK")
    """)
    assert "CP_SPEC_OK" in out
