"""Fault-injected compaction recovery (DESIGN.md §12).

The recovery paths nothing exercises in the happy path, exercised: N
consecutive background-build failures with every interleaved query still
EXACT against the rebuild oracle, the L0 chain refolding wholesale on the
first successful build, the chain-length cap forcing synchronous
compaction under sustained failure, exponential-backoff gating between
retries, the stuck-build watchdog, and the injected delta-overflow seal.
Plus the :mod:`repro.core.faults` registry contract itself (deterministic
seeded triggers, auto-disarm, cumulative counters).
"""

import time

import numpy as np
import pytest

from repro.core import SegmentedCatalogue, faults, get_engine

R = 10
K = 5


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.disarm_all()
    yield
    faults.disarm_all()


def _rng(seed=0):
    return np.random.default_rng(seed)


def _base(rng, m=200):
    return rng.standard_normal((m, R)).astype(np.float32)


def _oracle(cat, U, k):
    rows, gids = cat.as_dense()
    U = np.atleast_2d(np.asarray(U, np.float32))
    s = U.astype(np.float64) @ rows.astype(np.float64).T
    order = np.argsort(-s, kind="stable", axis=1)[:, :k]
    return s[np.arange(U.shape[0])[:, None], order], gids[order]


def assert_exact(cat, U, k=K, engine="norm"):
    res, info = cat.query(get_engine(engine), U, k)
    ov, _ = _oracle(cat, U, k)
    kk = min(k, cat.num_live)
    np.testing.assert_allclose(np.asarray(res.values)[:, :kk], ov[:, :kk],
                               atol=1e-4)
    return res, info


# -- the registry itself -----------------------------------------------------

def test_registry_basics():
    assert "compaction.build" in faults.list_points()
    with pytest.raises(ValueError, match="unknown fault point"):
        faults.arm("no.such.point")
    with pytest.raises(ValueError, match="p must be"):
        faults.arm("compaction.build", p=0.0)


def test_times_auto_disarms_and_counters_survive():
    before = faults.counters()["delta.overflow"]["fired"]
    faults.arm("delta.overflow", times=2)
    assert faults.fire("delta.overflow")
    assert faults.fire("delta.overflow")
    assert not faults.fire("delta.overflow")     # auto-disarmed
    assert faults.counters()["delta.overflow"]["fired"] == before + 2


def test_after_skips_initial_fires():
    faults.arm("delta.overflow", times=1, after=2)
    assert not faults.fire("delta.overflow")
    assert not faults.fire("delta.overflow")
    assert faults.fire("delta.overflow")


def test_seeded_coin_is_deterministic():
    def run(seed):
        faults.arm("delta.overflow", times=None, p=0.5, seed=seed)
        out = [faults.fire("delta.overflow") for _ in range(32)]
        faults.disarm("delta.overflow")
        return out

    assert run(7) == run(7)
    assert run(7) != run(8)          # astronomically unlikely to collide


def test_injected_context_raises_and_disarms():
    with faults.injected("compaction.build", error=faults.FaultInjected):
        with pytest.raises(faults.FaultInjected, match="compaction.build"):
            faults.fire("compaction.build")
    assert not faults.fire("compaction.build")


# -- mutation input validation ----------------------------------------------

def test_mutations_reject_nonfinite_and_wrong_rank():
    rng = _rng(1)
    cat = SegmentedCatalogue(_base(rng), block_size=16)
    bad = np.ones((2, R), np.float32)
    bad[1, 3] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        cat.add_targets(bad)
    with pytest.raises(ValueError, match="rank mismatch"):
        cat.add_targets(np.ones((2, R + 1), np.float32))
    inf_row = np.full((1, R), np.inf, np.float32)
    with pytest.raises(ValueError, match="non-finite"):
        cat.update_targets([0], inf_row)
    with pytest.raises(ValueError, match="rank mismatch"):
        cat.update_targets([0], np.ones((1, R - 2), np.float32))
    # the failed validations mutated NOTHING
    assert cat.num_live == 200 and cat.delta_occupancy == 0


# -- repeated build failure: exactness + recovery ----------------------------

def test_n_consecutive_build_failures_serve_exact_then_refold():
    """The acceptance scenario: inject N consecutive build faults; every
    interleaved query must stay exact vs the rebuild oracle; the first
    successful build refolds the accumulated L0 chain wholesale; the
    recovery counters tell the story in mutation_stats terms."""
    rng = _rng(2)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=True, max_l0_segments=16,
                             build_backoff_s=0.01)
    U = rng.standard_normal((3, R)).astype(np.float32)
    n_faults = 4
    faults.arm("compaction.build", error=RuntimeError, times=n_faults)
    for i in range(n_faults):
        cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
        cat.flush()                       # this round's build FAILED
        assert_exact(cat, U)              # ... and queries never notice
        if cat.consecutive_build_failures >= 2:
            time.sleep(cat.current_backoff_s + 0.01)  # let retries through
    assert cat.stats.n_failed_compactions == n_faults
    assert cat.consecutive_build_failures == n_faults
    assert isinstance(cat.last_build_error, RuntimeError)
    chain_before = cat.l0_chain_len
    assert chain_before >= 2              # failures really accumulated L0
    assert cat.stats.max_l0_chain >= chain_before
    # fault exhausted: the next (forced) compaction succeeds and refolds
    # the WHOLE chain in one build
    cat.compact(wait=True)
    assert cat.l0_chain_len == 0
    assert cat.last_build_error is None   # stale failure state cleared
    assert cat.consecutive_build_failures == 0
    assert cat.current_backoff_s == 0.0
    assert cat.stats.n_compactions == 1
    assert cat.stats.n_build_retries >= 1
    assert_exact(cat, U)


def test_chain_cap_forces_synchronous_compaction():
    """Past max_l0_segments the mutating caller pays: a forced SYNC build
    folds the chain inline instead of letting queries degrade without
    bound. With the builder healthy again, the cap holds the chain."""
    rng = _rng(3)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=4, block_size=16,
                             compact_async=True, max_l0_segments=2,
                             build_backoff_s=5.0)  # backoff would stall...
    U = rng.standard_normal((2, R)).astype(np.float32)
    # 2 failures start the backoff clock (5s: no ordinary retry fires)
    faults.arm("compaction.build", error=RuntimeError, times=2)
    for _ in range(2):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
        cat.flush()
    assert cat.stats.n_failed_compactions == 2
    # ...but the chain cap outranks the backoff: growing the chain past 2
    # forces sync folds NOW
    for _ in range(4):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
    assert cat.l0_chain_len <= 2
    assert cat.stats.n_forced_sync_compactions >= 1
    assert cat.stats.n_compactions >= 1
    assert cat.consecutive_build_failures == 0
    assert_exact(cat, U)


def test_backoff_gates_ordinary_retries():
    """First failure retries at the next trigger; from the second on,
    triggers inside the backoff window are skipped (no attempt, no new
    failure), and an attempt past the window goes through."""
    rng = _rng(4)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=64, block_size=16,
                             compact_async=False, max_l0_segments=32,
                             build_backoff_s=0.25, build_backoff_max_s=1.0)
    row = rng.standard_normal((1, R)).astype(np.float32)
    cat.add_targets(row)              # non-empty delta: seals really seal
    faults.arm("compaction.build", error=RuntimeError, times=2)
    with faults.injected("delta.overflow", times=3):
        cat.add_targets(row)          # overflow seal -> build fails (#1)
        assert cat.consecutive_build_failures == 1
        cat.add_targets(row)          # immediate retry allowed -> fails (#2)
        assert cat.consecutive_build_failures == 2
        assert cat.current_backoff_s == pytest.approx(0.5)  # 0.25 * 2
        cat.add_targets(row)          # inside the window: GATED
        assert cat.stats.n_failed_compactions == 2          # no attempt
    time.sleep(cat.current_backoff_s + 0.05)
    with faults.injected("delta.overflow", times=1):
        cat.add_targets(row)          # past the window (fault exhausted)
    assert cat.consecutive_build_failures == 0
    assert cat.l0_chain_len == 0
    assert cat.stats.n_build_retries >= 1


def test_retry_limit_stops_ordinary_attempts_but_not_forced():
    rng = _rng(5)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=64, block_size=16,
                             compact_async=False, max_l0_segments=32,
                             build_retry_limit=1, build_backoff_s=0.0)
    row = rng.standard_normal((1, R)).astype(np.float32)
    cat.add_targets(row)              # non-empty delta: seals really seal
    faults.arm("compaction.build", error=RuntimeError, times=10)
    with faults.injected("delta.overflow", times=4):
        cat.add_targets(row)                      # fail #1
        cat.add_targets(row)                      # retry (limit 1) -> #2
        fails = cat.stats.n_failed_compactions
        assert fails == 2
        cat.add_targets(row)                      # past limit: no attempt
        cat.add_targets(row)
        assert cat.stats.n_failed_compactions == fails
    with pytest.raises(RuntimeError, match="compaction build failed"):
        cat.compact(wait=True)                    # force still attempts
    assert cat.stats.n_failed_compactions == fails + 1
    faults.disarm_all()
    cat.compact(wait=True)                        # and force can heal
    assert cat.consecutive_build_failures == 0


def test_watchdog_flags_stuck_build_once():
    rng = _rng(6)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=True, build_watchdog_s=0.05)
    faults.arm("compaction.stall", delay_s=0.4)
    cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
    deadline = time.monotonic() + 2.0
    flagged = False
    while time.monotonic() < deadline and not flagged:
        flagged = cat.check_watchdog()
        time.sleep(0.02)
    assert flagged                        # the stall WAS detected...
    assert cat.stats.n_stuck_builds == 1
    cat.check_watchdog()
    assert cat.stats.n_stuck_builds == 1  # ...and counted once per build
    cat.flush()                           # detection only: build finishes
    assert cat.stats.n_compactions == 1
    assert cat.l0_chain_len == 0


def test_warm_phase_failure_is_a_recorded_build_failure():
    rng = _rng(7)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=False)
    U = rng.standard_normal((2, R)).astype(np.float32)
    with faults.injected("compaction.warm", error=RuntimeError):
        cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
    assert cat.stats.n_failed_compactions == 1
    assert cat.l0_chain_len >= 1
    assert_exact(cat, U)
    cat.compact(wait=True)
    assert cat.last_build_error is None
    assert_exact(cat, U)


def test_injected_delta_overflow_seals_early():
    rng = _rng(8)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=64, block_size=16,
                             compact_async=False)
    U = rng.standard_normal((2, R)).astype(np.float32)
    cat.add_targets(rng.standard_normal((2, R)).astype(np.float32))
    with faults.injected("delta.overflow", times=1):
        cat.add_targets(rng.standard_normal((4, R)).astype(np.float32))
    # the injected overflow forced a seal + compaction long before the
    # 64-row capacity
    assert cat.stats.n_compactions == 1
    assert cat.num_live == 206
    assert_exact(cat, U)


def test_auto_retry_timer_heals_a_quiet_catalogue():
    """auto_retry=True: after a failed async build the catalogue retries
    by itself (backoff-spaced) with NO further mutations or queries."""
    rng = _rng(9)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=True, auto_retry=True,
                             build_backoff_s=0.05)
    with faults.injected("compaction.build", error=RuntimeError, times=1):
        cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
        cat.flush()
    assert cat.stats.n_failed_compactions == 1
    assert cat.retry_pending
    deadline = time.monotonic() + 3.0
    while time.monotonic() < deadline and cat.l0_chain_len:
        time.sleep(0.02)
    cat.flush()
    assert cat.l0_chain_len == 0          # healed hands-off
    assert cat.consecutive_build_failures == 0
    assert cat.stats.n_compactions == 1


# -- LSM ladder seams (DESIGN.md §15) ----------------------------------------

def _lsm(rng, m=64, **kw):
    from repro.core import ShardedLsmCatalogue
    kw.setdefault("n_shards", 4)
    kw.setdefault("delta_capacity", 4)
    kw.setdefault("l1_capacity", 64)
    kw.setdefault("compact_async", False)
    kw.setdefault("build_backoff_s", 0.0)
    kw.setdefault("block_size", 16)
    return ShardedLsmCatalogue(_base(rng, m), **kw)


def test_consecutive_fold_failures_chain_stays_exact():
    """N consecutive injected L0 -> L1 fold failures: nothing is lost,
    the sealed chain keeps growing AND keeps answering exactly, and the
    first healthy fold drains it wholesale."""
    rng = _rng(31)
    cat = _lsm(rng)
    U = rng.standard_normal((2, R)).astype(np.float32)
    faults.arm("compaction.fold_l1", error=RuntimeError, times=3)
    for i in range(3):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
        assert cat.stats.n_failed_l1_folds == i + 1
        assert cat.consecutive_fold_failures == i + 1
        assert cat.l0_chain_len >= 1          # chain retained, queryable
        assert cat.l1_rows == 0               # nothing reached L1 yet
        assert_exact(cat, U)
    assert cat.stats.n_l1_fold_retries >= 2   # attempts 2 and 3 were retries
    assert isinstance(cat.last_fold_error, RuntimeError)
    # fault exhausted (times=3): the next overflow folds the WHOLE chain
    cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
    assert cat.stats.n_l1_folds >= 1
    assert cat.consecutive_fold_failures == 0
    assert cat.fold_backoff_s == 0.0
    assert cat.l0_chain_len == 0
    assert cat.l1_rows > 0
    assert cat.stats.n_compactions == 0       # no full rebuild was needed
    assert_exact(cat, U)


def test_fold_failure_backoff_gates_ordinary_folds():
    """After >= 2 consecutive fold failures a non-forced fold waits out
    an exponential backoff instead of hammering the failing seam."""
    rng = _rng(32)
    cat = _lsm(rng, build_backoff_s=30.0, build_backoff_max_s=60.0)
    faults.arm("compaction.fold_l1", error=RuntimeError, times=2)
    for _ in range(2):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
    assert cat.consecutive_fold_failures == 2
    assert cat.fold_backoff_s >= 30.0
    chain = cat.l0_chain_len
    # fault is exhausted, but the backoff gate holds the ordinary fold
    cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
    assert cat.stats.n_l1_folds == 0
    assert cat.l0_chain_len > chain
    assert_exact(cat, rng.standard_normal((1, R)).astype(np.float32))


def test_promote_fault_is_a_build_failure_and_tier_survives():
    """compaction.promote fires BEFORE anything moves: a failed
    promotion is recorded as a build failure, every tier keeps serving,
    and the healed retry flattens the ladder completely."""
    rng = _rng(33)
    cat = _lsm(rng)
    cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
    assert cat.l1_rows > 0                    # ladder populated
    U = rng.standard_normal((2, R)).astype(np.float32)
    faults.arm("compaction.promote", error=RuntimeError, times=1)
    with pytest.raises(RuntimeError):
        cat.promote(wait=True)
    assert cat.stats.n_failed_compactions == 1
    assert cat.l1_rows > 0                    # nothing moved, nothing lost
    assert_exact(cat, U)
    cat.promote(wait=True)                    # healed
    assert cat.l1_rows == 0 and cat.l0_chain_len == 0
    assert cat.stats.n_compactions >= 1
    assert_exact(cat, U)


def test_lsm_stats_flow_through_mutation_schema():
    """The ladder's retry/backoff stats extend mutation_stats WITHOUT
    schema drift: the produced dict matches MUTATION_STATS_SCHEMA
    exactly, and both drift directions are hard errors."""
    from repro.core import SepLRModel
    from repro.obs.schema import MUTATION_STATS_SCHEMA, build_mutation_stats
    from repro.serving.server import TopKServer

    rng = _rng(34)
    srv = TopKServer(SepLRModel(_base(rng, 48)), n_shards=4,
                     delta_capacity=4, compact_async=False, block_size=16)
    srv.add_targets(_base(rng, 10))           # at least one fold happened
    stats = srv.mutation_stats
    assert set(stats) == set(MUTATION_STATS_SCHEMA)
    assert stats["n_shards"] == 4
    assert stats["n_l1_folds"] >= 1
    assert build_mutation_stats(stats) == stats
    with pytest.raises(KeyError):             # a key going missing
        build_mutation_stats({k: v for k, v in stats.items()
                              if k != "fold_backoff_s"})
    with pytest.raises(KeyError):             # an undeclared key appearing
        build_mutation_stats({**stats, "surprise": 1})
    # the single-level server reports neutral ladder values through the
    # SAME schema — one shape covers both catalogues
    flat = TopKServer(SepLRModel(_base(rng, 32)), delta_capacity=8,
                      block_size=16)
    fs = flat.mutation_stats
    assert fs["n_shards"] == 0 and fs["l1_rows"] == 0
    assert build_mutation_stats(fs) == fs


def test_stale_pending_dead_does_not_kill_updated_row():
    """Regression: a kill recorded while the gid sat in a chain retained
    by a FAILED build used to leave a stale pending-dead entry; when the
    gid was re-appended via update before the next successful build, the
    swap wrongly killed the live new copy. The capture now clears the
    set (it already reflects every kill landed so far)."""
    rng = _rng(35)
    cat = SegmentedCatalogue(_base(rng, 32), delta_capacity=4,
                             compact_async=False, build_backoff_s=0.0,
                             block_size=16)
    gids = cat.add_targets(rng.standard_normal((4, R)).astype(np.float32))
    faults.arm("compaction.build", error=RuntimeError, times=1)
    with pytest.raises(RuntimeError):
        cat.compact(wait=True)                # chain retained by the failure
    assert cat.l0_chain_len >= 1
    victim = int(gids[0])
    new_row = np.full((1, R), 3.0, np.float32)   # unmistakable top-1
    cat.update_targets([victim], new_row)     # kill-in-frozen + re-append
    n_live = cat.num_live
    cat.compact(wait=True)                    # healed build swaps in
    assert cat.num_live == n_live             # the new copy SURVIVED the swap
    res, _ = cat.query(get_engine("norm"), np.ones((1, R), np.float32), 1)
    assert int(np.asarray(res.indices)[0, 0]) == victim
    np.testing.assert_allclose(np.asarray(res.values)[0, 0], 3.0 * R,
                               rtol=1e-5)
    assert_exact(cat, rng.standard_normal((2, R)).astype(np.float32))
