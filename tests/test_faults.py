"""Fault-injected compaction recovery (DESIGN.md §12).

The recovery paths nothing exercises in the happy path, exercised: N
consecutive background-build failures with every interleaved query still
EXACT against the rebuild oracle, the L0 chain refolding wholesale on the
first successful build, the chain-length cap forcing synchronous
compaction under sustained failure, exponential-backoff gating between
retries, the stuck-build watchdog, and the injected delta-overflow seal.
Plus the :mod:`repro.core.faults` registry contract itself (deterministic
seeded triggers, auto-disarm, cumulative counters).
"""

import time

import numpy as np
import pytest

from repro.core import SegmentedCatalogue, faults, get_engine

R = 10
K = 5


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.disarm_all()
    yield
    faults.disarm_all()


def _rng(seed=0):
    return np.random.default_rng(seed)


def _base(rng, m=200):
    return rng.standard_normal((m, R)).astype(np.float32)


def _oracle(cat, U, k):
    rows, gids = cat.as_dense()
    U = np.atleast_2d(np.asarray(U, np.float32))
    s = U.astype(np.float64) @ rows.astype(np.float64).T
    order = np.argsort(-s, kind="stable", axis=1)[:, :k]
    return s[np.arange(U.shape[0])[:, None], order], gids[order]


def assert_exact(cat, U, k=K, engine="norm"):
    res, info = cat.query(get_engine(engine), U, k)
    ov, _ = _oracle(cat, U, k)
    kk = min(k, cat.num_live)
    np.testing.assert_allclose(np.asarray(res.values)[:, :kk], ov[:, :kk],
                               atol=1e-4)
    return res, info


# -- the registry itself -----------------------------------------------------

def test_registry_basics():
    assert "compaction.build" in faults.list_points()
    with pytest.raises(ValueError, match="unknown fault point"):
        faults.arm("no.such.point")
    with pytest.raises(ValueError, match="p must be"):
        faults.arm("compaction.build", p=0.0)


def test_times_auto_disarms_and_counters_survive():
    before = faults.counters()["delta.overflow"]["fired"]
    faults.arm("delta.overflow", times=2)
    assert faults.fire("delta.overflow")
    assert faults.fire("delta.overflow")
    assert not faults.fire("delta.overflow")     # auto-disarmed
    assert faults.counters()["delta.overflow"]["fired"] == before + 2


def test_after_skips_initial_fires():
    faults.arm("delta.overflow", times=1, after=2)
    assert not faults.fire("delta.overflow")
    assert not faults.fire("delta.overflow")
    assert faults.fire("delta.overflow")


def test_seeded_coin_is_deterministic():
    def run(seed):
        faults.arm("delta.overflow", times=None, p=0.5, seed=seed)
        out = [faults.fire("delta.overflow") for _ in range(32)]
        faults.disarm("delta.overflow")
        return out

    assert run(7) == run(7)
    assert run(7) != run(8)          # astronomically unlikely to collide


def test_injected_context_raises_and_disarms():
    with faults.injected("compaction.build", error=faults.FaultInjected):
        with pytest.raises(faults.FaultInjected, match="compaction.build"):
            faults.fire("compaction.build")
    assert not faults.fire("compaction.build")


# -- mutation input validation ----------------------------------------------

def test_mutations_reject_nonfinite_and_wrong_rank():
    rng = _rng(1)
    cat = SegmentedCatalogue(_base(rng), block_size=16)
    bad = np.ones((2, R), np.float32)
    bad[1, 3] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        cat.add_targets(bad)
    with pytest.raises(ValueError, match="rank mismatch"):
        cat.add_targets(np.ones((2, R + 1), np.float32))
    inf_row = np.full((1, R), np.inf, np.float32)
    with pytest.raises(ValueError, match="non-finite"):
        cat.update_targets([0], inf_row)
    with pytest.raises(ValueError, match="rank mismatch"):
        cat.update_targets([0], np.ones((1, R - 2), np.float32))
    # the failed validations mutated NOTHING
    assert cat.num_live == 200 and cat.delta_occupancy == 0


# -- repeated build failure: exactness + recovery ----------------------------

def test_n_consecutive_build_failures_serve_exact_then_refold():
    """The acceptance scenario: inject N consecutive build faults; every
    interleaved query must stay exact vs the rebuild oracle; the first
    successful build refolds the accumulated L0 chain wholesale; the
    recovery counters tell the story in mutation_stats terms."""
    rng = _rng(2)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=True, max_l0_segments=16,
                             build_backoff_s=0.01)
    U = rng.standard_normal((3, R)).astype(np.float32)
    n_faults = 4
    faults.arm("compaction.build", error=RuntimeError, times=n_faults)
    for i in range(n_faults):
        cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
        cat.flush()                       # this round's build FAILED
        assert_exact(cat, U)              # ... and queries never notice
        if cat.consecutive_build_failures >= 2:
            time.sleep(cat.current_backoff_s + 0.01)  # let retries through
    assert cat.stats.n_failed_compactions == n_faults
    assert cat.consecutive_build_failures == n_faults
    assert isinstance(cat.last_build_error, RuntimeError)
    chain_before = cat.l0_chain_len
    assert chain_before >= 2              # failures really accumulated L0
    assert cat.stats.max_l0_chain >= chain_before
    # fault exhausted: the next (forced) compaction succeeds and refolds
    # the WHOLE chain in one build
    cat.compact(wait=True)
    assert cat.l0_chain_len == 0
    assert cat.last_build_error is None   # stale failure state cleared
    assert cat.consecutive_build_failures == 0
    assert cat.current_backoff_s == 0.0
    assert cat.stats.n_compactions == 1
    assert cat.stats.n_build_retries >= 1
    assert_exact(cat, U)


def test_chain_cap_forces_synchronous_compaction():
    """Past max_l0_segments the mutating caller pays: a forced SYNC build
    folds the chain inline instead of letting queries degrade without
    bound. With the builder healthy again, the cap holds the chain."""
    rng = _rng(3)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=4, block_size=16,
                             compact_async=True, max_l0_segments=2,
                             build_backoff_s=5.0)  # backoff would stall...
    U = rng.standard_normal((2, R)).astype(np.float32)
    # 2 failures start the backoff clock (5s: no ordinary retry fires)
    faults.arm("compaction.build", error=RuntimeError, times=2)
    for _ in range(2):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
        cat.flush()
    assert cat.stats.n_failed_compactions == 2
    # ...but the chain cap outranks the backoff: growing the chain past 2
    # forces sync folds NOW
    for _ in range(4):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
    assert cat.l0_chain_len <= 2
    assert cat.stats.n_forced_sync_compactions >= 1
    assert cat.stats.n_compactions >= 1
    assert cat.consecutive_build_failures == 0
    assert_exact(cat, U)


def test_backoff_gates_ordinary_retries():
    """First failure retries at the next trigger; from the second on,
    triggers inside the backoff window are skipped (no attempt, no new
    failure), and an attempt past the window goes through."""
    rng = _rng(4)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=64, block_size=16,
                             compact_async=False, max_l0_segments=32,
                             build_backoff_s=0.25, build_backoff_max_s=1.0)
    row = rng.standard_normal((1, R)).astype(np.float32)
    cat.add_targets(row)              # non-empty delta: seals really seal
    faults.arm("compaction.build", error=RuntimeError, times=2)
    with faults.injected("delta.overflow", times=3):
        cat.add_targets(row)          # overflow seal -> build fails (#1)
        assert cat.consecutive_build_failures == 1
        cat.add_targets(row)          # immediate retry allowed -> fails (#2)
        assert cat.consecutive_build_failures == 2
        assert cat.current_backoff_s == pytest.approx(0.5)  # 0.25 * 2
        cat.add_targets(row)          # inside the window: GATED
        assert cat.stats.n_failed_compactions == 2          # no attempt
    time.sleep(cat.current_backoff_s + 0.05)
    with faults.injected("delta.overflow", times=1):
        cat.add_targets(row)          # past the window (fault exhausted)
    assert cat.consecutive_build_failures == 0
    assert cat.l0_chain_len == 0
    assert cat.stats.n_build_retries >= 1


def test_retry_limit_stops_ordinary_attempts_but_not_forced():
    rng = _rng(5)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=64, block_size=16,
                             compact_async=False, max_l0_segments=32,
                             build_retry_limit=1, build_backoff_s=0.0)
    row = rng.standard_normal((1, R)).astype(np.float32)
    cat.add_targets(row)              # non-empty delta: seals really seal
    faults.arm("compaction.build", error=RuntimeError, times=10)
    with faults.injected("delta.overflow", times=4):
        cat.add_targets(row)                      # fail #1
        cat.add_targets(row)                      # retry (limit 1) -> #2
        fails = cat.stats.n_failed_compactions
        assert fails == 2
        cat.add_targets(row)                      # past limit: no attempt
        cat.add_targets(row)
        assert cat.stats.n_failed_compactions == fails
    with pytest.raises(RuntimeError, match="compaction build failed"):
        cat.compact(wait=True)                    # force still attempts
    assert cat.stats.n_failed_compactions == fails + 1
    faults.disarm_all()
    cat.compact(wait=True)                        # and force can heal
    assert cat.consecutive_build_failures == 0


def test_watchdog_flags_stuck_build_once():
    rng = _rng(6)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=True, build_watchdog_s=0.05)
    faults.arm("compaction.stall", delay_s=0.4)
    cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
    deadline = time.monotonic() + 2.0
    flagged = False
    while time.monotonic() < deadline and not flagged:
        flagged = cat.check_watchdog()
        time.sleep(0.02)
    assert flagged                        # the stall WAS detected...
    assert cat.stats.n_stuck_builds == 1
    cat.check_watchdog()
    assert cat.stats.n_stuck_builds == 1  # ...and counted once per build
    cat.flush()                           # detection only: build finishes
    assert cat.stats.n_compactions == 1
    assert cat.l0_chain_len == 0


def test_warm_phase_failure_is_a_recorded_build_failure():
    rng = _rng(7)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=False)
    U = rng.standard_normal((2, R)).astype(np.float32)
    with faults.injected("compaction.warm", error=RuntimeError):
        cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
    assert cat.stats.n_failed_compactions == 1
    assert cat.l0_chain_len >= 1
    assert_exact(cat, U)
    cat.compact(wait=True)
    assert cat.last_build_error is None
    assert_exact(cat, U)


def test_injected_delta_overflow_seals_early():
    rng = _rng(8)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=64, block_size=16,
                             compact_async=False)
    U = rng.standard_normal((2, R)).astype(np.float32)
    cat.add_targets(rng.standard_normal((2, R)).astype(np.float32))
    with faults.injected("delta.overflow", times=1):
        cat.add_targets(rng.standard_normal((4, R)).astype(np.float32))
    # the injected overflow forced a seal + compaction long before the
    # 64-row capacity
    assert cat.stats.n_compactions == 1
    assert cat.num_live == 206
    assert_exact(cat, U)


def test_auto_retry_timer_heals_a_quiet_catalogue():
    """auto_retry=True: after a failed async build the catalogue retries
    by itself (backoff-spaced) with NO further mutations or queries."""
    rng = _rng(9)
    cat = SegmentedCatalogue(_base(rng), delta_capacity=8, block_size=16,
                             compact_async=True, auto_retry=True,
                             build_backoff_s=0.05)
    with faults.injected("compaction.build", error=RuntimeError, times=1):
        cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
        cat.flush()
    assert cat.stats.n_failed_compactions == 1
    assert cat.retry_pending
    deadline = time.monotonic() + 3.0
    while time.monotonic() < deadline and cat.l0_chain_len:
        time.sleep(0.02)
    cat.flush()
    assert cat.l0_chain_len == 0          # healed hands-off
    assert cat.consecutive_build_failures == 0
    assert cat.stats.n_compactions == 1
