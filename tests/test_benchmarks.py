"""Benchmark-harness smoke: every paper-table module runs in quick mode and
its derived paper-claim flags hold (the same checks benchmarks/run.py
prints; here they gate CI)."""

import json
import os

import numpy as np
import pytest


def test_table1_exact_reproduction():
    from benchmarks import table1_toy
    r = table1_toy.run(quick=True)[0]
    assert r["all_agree"]
    assert r["ta_scored"] == r["paper_ta_scored"] == 5
    assert r["fagin_scored"] == r["paper_fagin_scored"] == 9
    assert r["ta_depth"] == 2 and r["fagin_depth"] == 5


def test_fig3_found_before_proven():
    from benchmarks import fig3_halted
    rows = fig3_halted.run(quick=True)
    s = rows[-1]
    assert s["median_found_at"] < s["median_terminated"]
    eng = [r for r in rows if r.get("engine")]
    assert eng, "no budgeted engine rows"
    # soundness: certified slots are never wrong, at any budget
    assert all(r["certified_exact"] for r in eng)
    assert all(r["certified_fraction"] <= r["precision"] + 1e-9 for r in eng)
    # the paper's halted-TA point, through the real engine: a modest
    # budget already finds the true top-K even though proving it
    # (certified_fraction -> 1) takes longer
    ta250 = next(r for r in eng
                 if r["engine"] == "ta" and r["budget"] == 250)
    assert ta250["precision"] >= 0.95


def test_table4_scaling_shape():
    from benchmarks import table4_scaling
    rows = table4_scaling.run(quick=True)
    fr = {r["R"]: r["fraction"] for r in rows}
    rs = sorted(fr)
    assert fr[rs[0]] < fr[rs[-1]]            # scores grow with R
    assert all(v < 0.5 for v in fr.values())  # but stay a small fraction


@pytest.mark.slow
def test_engines_sweep_smoke():
    """Tier-2 benchmark smoke (CI `bench` job): the M=8k engines sweep
    runs end to end, every exact engine verifies against naive, and the
    JSON artifact carries the trajectory-tracking fields."""
    # save under a scratch name: the committed results/bench/engines.json
    # is the recorded trajectory artifact and must not be clobbered by a
    # smoke run on a loaded CI box
    from benchmarks import engines
    rows = engines.run(quick=True, iters=5, save_as="engines_smoke")
    assert rows, "sweep produced no rows"
    bad = [r["engine"] for r in rows if r["exact"] and not r["exact_verified"]]
    assert not bad, f"exact engines diverged from naive: {bad}"
    required = {"engine", "resolved", "backend", "M", "batch", "sign",
                "sign_bucket", "traces_by_sign", "avg_scores",
                "us_per_query", "queries_per_s", "speedup_vs_naive",
                "interpret_mode", "exact_verified"}
    assert all(required <= set(r) for r in rows)
    # the B x sign grid is present: all three batch sizes, both sign
    # axes for the list engines, and the quick sweep forces the list
    # layout ON so the batched sign-specialised path is what ran
    assert {r["batch"] for r in rows} == {1, 8, 64}
    ta_rows = [r for r in rows if r["engine"] == "ta"]
    assert {r["sign"] for r in ta_rows} == {"mixed", "nonneg"}
    assert all(r["prefix_depth"] == engines.QUICK_PREFIX_DEPTH
               for r in ta_rows)
    assert all(r["sign_bucket"] == ("mixed-sparse" if r["sign"] == "mixed"
                                    else "nonneg-dense")
               for r in ta_rows)
    # warmed sign buckets compiled exactly once each (process-wide
    # counters: >= 1 guards against double-traces without being brittle
    # to other tests sharing the executor cache)
    for r in ta_rows:
        assert r["traces_by_sign"].get(r["sign_bucket"], 0) >= 1
    # pallas rows off-TPU must be flagged as interpreter time
    import jax
    if jax.default_backend() != "tpu":
        assert all(r["interpret_mode"] for r in rows
                   if r["resolved"] == "pallas")
    # the artifact the CI job uploads round-trips through JSON
    with open(os.path.join("results", "bench", "engines_smoke.json")) as f:
        assert json.load(f) == rows


@pytest.mark.slow
def test_streaming_sweep_smoke():
    """Tier-2 benchmark smoke (CI `bench` job): the streaming sweep runs
    at small M with a high mutation rate, every segmented query result is
    verified (to float tolerance) against a float64 oracle replay of the
    schedule (the job FAILS on any `exact_verified: false`), and the JSON
    artifact carries the delta/compaction and latency-percentile
    columns."""
    # scratch name: results/bench/streaming.json is the committed artifact
    from benchmarks import streaming
    rows = streaming.run(quick=True, rounds=4, save_as="streaming_smoke")
    assert rows, "sweep produced no rows"
    bad = [r["M"] for r in rows if not r["exact_verified"]]
    assert not bad, f"segmented results diverged from the oracle: {bad}"
    required = {"M", "exact_verified", "segmented_s", "rebuild_s",
                "rebuild_lazy_s", "speedup_vs_rebuild", "qps_segmented",
                "p50_us", "p95_us", "p99_us", "n_compactions",
                "max_delta_occupancy", "n_tombstones_final",
                "snapshot_version", "delta_capacity"}
    assert all(required <= set(r) for r in rows)
    for r in rows:
        assert r["n_compactions"] >= 1          # churn actually compacted
        assert 0 < r["p50_us"] <= r["p95_us"] <= r["p99_us"]
    with open(os.path.join("results", "bench", "streaming_smoke.json")) as f:
        assert json.load(f) == rows


@pytest.mark.slow
def test_streaming_lsm_sweep_smoke():
    """Tier-2 benchmark smoke: the LSM-ladder sweep (DESIGN.md §15) at
    quick M with just enough rounds to overflow the delta — the ladder
    side must absorb the overflow with L1 folds (zero full rebuilds)
    while the single-level side rebuilds, every stored query verified
    against the incremental array oracle, and the §10/§15 compile
    contract holds."""
    # scratch name: results/bench/streaming_lsm.json is the committed
    # 1M artifact the CI lsm job gates on
    from benchmarks import streaming_lsm
    rows = streaming_lsm.run(quick=True, rounds=12,
                             save_as="streaming_lsm_smoke")
    assert rows, "sweep produced no rows"
    bad = [r["M"] for r in rows if not r["exact_verified"]]
    assert not bad, f"ladder results diverged from the oracle: {bad}"
    required = {"M", "n_shards", "exact_verified", "full_rebuilds_lsm",
                "full_rebuilds_single_level", "rebuild_amortisation",
                "n_l1_folds", "l1_fold_s_total", "wall_s_lsm",
                "wall_s_single_level", "engine_compiles_per_compaction",
                "l1_rows_final", "delta_capacity"}
    assert all(required <= set(r) for r in rows)
    for r in rows:
        assert r["n_l1_folds"] >= 1             # overflows DID fold
        assert r["full_rebuilds_lsm"] == 0      # ...and never rebuilt
        assert r["full_rebuilds_single_level"] >= 1
        assert r["engine_compiles_per_compaction"] == 0
    with open(os.path.join("results", "bench",
                           "streaming_lsm_smoke.json")) as f:
        assert json.load(f) == rows


def test_bta_engines_close_to_ta():
    from benchmarks import bta_tpu
    rows = bta_tpu.run(quick=True)
    by = {r["engine"]: r for r in rows}
    ta = by["ta_reference"]["avg_scores"]
    for b in (64, 256, 1024):
        # BTA wastes at most ~one block of scores per list vs item-level TA
        assert by[f"bta_b{b}"]["avg_scores"] <= ta + 64 * b / 4
    assert by["norm_pruned"]["avg_scores"] <= by["naive_matmul"]["avg_scores"]
    # the Pallas kernel implements the same norm-pruned scan
    assert (by["pallas_topk_mips(interpret)"]["avg_scores"]
            == pytest.approx(by["norm_pruned"]["avg_scores"], rel=0.05))
