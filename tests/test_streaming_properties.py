"""Randomized differential harness for the streaming LSM ladder (§15).

Interleaved insert / update / delete / query / compact / fault-injection
schedules run against :class:`ShardedLsmCatalogue` (and, as the
n_shards=0 arm, the single-level :class:`SegmentedCatalogue`) and EVERY
query is checked against a fresh-rebuild oracle: an independent
``{gid: row}`` shadow dict scored in float64. The ladder may be in any
internal state — active delta, sealed L0 chain (including chains
retained by injected fold/build failures), per-shard L1 runs,
mid-promotion — and the answers must still be exactly the dense top-K.

Two drivers share one replay core:

* a seeded numpy schedule sweep that always runs —
  ``STREAMING_SCHEDULES=200`` (default 30) reproduces the acceptance
  sweep with no third-party dependency; every schedule prints its
  repro seed on failure;
* hypothesis properties (when the library is installed) that add
  minimised counterexamples on top. ``HYPOTHESIS_PROFILE=ci`` runs a
  bounded-example smoke, ``full`` the 200-schedule sweep (100 examples
  x 2 properties), the default sits in between. Shrunk failures replay
  from the ``note()``-printed draw, independent of the profile that
  found them.
"""

import os

import numpy as np
import pytest

from repro.core import (
    SegmentedCatalogue,
    ShardedLsmCatalogue,
    faults,
    get_engine,
)

try:
    from hypothesis import HealthCheck, given, note, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

R = 6
K = 4

# boundary row counts the delta/block quantisation is most likely to
# mis-handle: 2^n - 1, 2^n, 2^n + 1
BOUNDARY_M = [7, 8, 9, 15, 16, 17, 31, 32, 33]
SHARD_COUNTS = [0, 1, 4, 8]          # 0 = single-level SegmentedCatalogue

_KINDS = ["insert", "delete", "update", "query", "compact", "flush",
          "fault_build", "fault_fold"]
_WEIGHTS = [0.30, 0.12, 0.12, 0.18, 0.10, 0.06, 0.06, 0.06]


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.disarm_all()
    yield
    faults.disarm_all()


def _rows(rng, n, positive):
    r = rng.standard_normal((n, R)).astype(np.float32)
    return np.abs(r) if positive else r


def _make(base, n_shards, compact_async):
    kw = dict(delta_capacity=4, block_size=8, compact_async=compact_async,
              build_backoff_s=0.0, max_l0_segments=8)
    if n_shards == 0:
        return SegmentedCatalogue(base, **kw)
    return ShardedLsmCatalogue(base, n_shards=n_shards, l1_capacity=8, **kw)


def _check_query(cat, shadow, U, k=K, engine="norm"):
    """One query vs the fresh-rebuild oracle: exact values, live +
    consistent gids, correct padding."""
    res, _ = cat.query(get_engine(engine), U, k)
    vals = np.asarray(res.values)
    idx = np.asarray(res.indices)
    assert cat.num_live == len(shadow)
    kk = min(k, len(shadow))
    if kk == 0:
        assert np.all(idx == -1)
        return
    gids = np.fromiter(shadow.keys(), np.int64, len(shadow))
    rows = np.stack([shadow[int(g)] for g in gids]).astype(np.float64)
    s = np.atleast_2d(U).astype(np.float64) @ rows.T
    want = -np.sort(-s, axis=1)[:, :kk]
    np.testing.assert_allclose(vals[:, :kk], want, atol=1e-4)
    # every returned gid is live and scores to the value next to it
    by_gid = {int(g): rows[i] for i, g in enumerate(gids)}
    for b in range(idx.shape[0]):
        for j in range(kk):
            g = int(idx[b, j])
            assert g in by_gid, (b, j, g)
            np.testing.assert_allclose(
                vals[b, j],
                float(np.atleast_2d(U).astype(np.float64)[b] @ by_gid[g]),
                atol=1e-4)
    assert np.all(idx[:, kk:] == -1)


def _replay(cat, shadow, ops, rng, positive, *, faultable=True):
    """Apply one schedule to (catalogue, shadow) in lockstep, checking
    exactness at every query op and once more at the end."""
    for op in ops:
        kind = op[0]
        if kind == "insert":
            rows = _rows(rng, op[1], positive)
            for g, row in zip(cat.add_targets(rows), rows):
                shadow[int(g)] = row
        elif kind == "delete":
            if shadow:
                victim = sorted(shadow)[op[1] % len(shadow)]
                cat.delete_targets([victim])
                del shadow[victim]
        elif kind == "update":
            if shadow:
                victim = sorted(shadow)[op[1] % len(shadow)]
                row = _rows(rng, 1, positive)
                cat.update_targets([victim], row)
                shadow[victim] = row[0]
        elif kind == "query":
            _check_query(cat, shadow, _rows(rng, op[1], positive))
        elif kind == "compact":
            try:
                cat.compact(wait=True)
            except RuntimeError:
                pass                     # injected failure: chain retained
        elif kind == "flush":
            cat.flush()
        elif kind == "fault_build" and faultable:
            faults.arm("compaction.build", error=RuntimeError, times=1)
        elif kind == "fault_fold" and faultable:
            faults.arm("compaction.fold_l1", error=RuntimeError, times=1)
    _check_query(cat, shadow, _rows(rng, 2, positive))


def _draw_schedule(rng, *, faultable=True):
    ops = []
    for _ in range(int(rng.integers(1, 25))):
        kind = rng.choice(_KINDS, p=_WEIGHTS)
        if not faultable and kind.startswith("fault"):
            kind = "compact"
        if kind == "insert":
            ops.append(("insert", int(rng.integers(1, 7))))
        elif kind in ("delete", "update"):
            ops.append((kind, int(rng.integers(0, 64))))
        elif kind == "query":
            ops.append(("query", int(rng.integers(1, 3))))
        else:
            ops.append((kind,))
    return ops


def _run_one_schedule(seed):
    """One fully seed-determined schedule: catalogue shape, op stream
    and data all derive from ``seed``."""
    rng = np.random.default_rng(seed)
    n_shards = SHARD_COUNTS[int(rng.integers(len(SHARD_COUNTS)))]
    m0 = BOUNDARY_M[int(rng.integers(len(BOUNDARY_M)))]
    positive = bool(rng.integers(2))
    compact_async = bool(rng.integers(2))
    ops = _draw_schedule(rng)
    base = _rows(rng, m0, positive)
    cat = _make(base, n_shards, compact_async)
    shadow = {i: base[i] for i in range(m0)}
    try:
        _replay(cat, shadow, ops, rng, positive)
    finally:
        faults.disarm_all()
        cat.flush()


def test_seeded_schedule_sweep():
    """The dependency-free sweep: STREAMING_SCHEDULES independent
    schedules (acceptance: 200), each reproducible from the printed
    seed alone via ``_run_one_schedule(seed)``."""
    n = int(os.environ.get("STREAMING_SCHEDULES", "30"))
    for seed in range(n):
        try:
            _run_one_schedule(seed)
        except Exception:
            print(f"streaming schedule FAILED: "
                  f"_run_one_schedule({seed}) reproduces it")
            raise


if HAVE_HYPOTHESIS:
    settings.register_profile(
        "ci", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much])
    settings.register_profile(
        "default", max_examples=40, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much])
    settings.register_profile(
        "full", max_examples=100, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))

    _OPS = st.lists(
        st.one_of(
            st.tuples(st.just("insert"), st.integers(1, 6)),
            st.tuples(st.just("delete"), st.integers(0, 63)),
            st.tuples(st.just("update"), st.integers(0, 63)),
            st.tuples(st.just("query"), st.integers(1, 2)),
            st.tuples(st.just("compact")),
            st.tuples(st.just("flush")),
            st.tuples(st.just("fault_build")),
            st.tuples(st.just("fault_fold")),
        ),
        min_size=1, max_size=24)

    # the fault-free subset (for the two-catalogue differential, where
    # an injected failure would just make both arms take the same detour)
    _CLEAN_OPS = st.lists(
        st.one_of(
            st.tuples(st.just("insert"), st.integers(1, 6)),
            st.tuples(st.just("delete"), st.integers(0, 63)),
            st.tuples(st.just("update"), st.integers(0, 63)),
            st.tuples(st.just("query"), st.integers(1, 2)),
            st.tuples(st.just("compact")),
            st.tuples(st.just("flush")),
        ),
        min_size=1, max_size=24)

    @given(data=st.data())
    def test_interleaved_schedules_match_fresh_rebuild_oracle(data):
        """The headline property: ANY interleaving of mutations,
        queries, compactions and injected fold/build failures, over any
        shard count and boundary base size, answers every query
        exactly."""
        n_shards = data.draw(st.sampled_from(SHARD_COUNTS),
                             label="n_shards")
        m0 = data.draw(st.sampled_from(BOUNDARY_M), label="M0")
        positive = data.draw(st.booleans(), label="positive")
        compact_async = data.draw(st.booleans(), label="compact_async")
        seed = data.draw(st.integers(0, 2**32 - 1), label="seed")
        ops = data.draw(_OPS, label="ops")
        note(f"repro: seed={seed} n_shards={n_shards} M0={m0} "
             f"positive={positive} compact_async={compact_async} ops={ops}")
        rng = np.random.default_rng(seed)
        base = _rows(rng, m0, positive)
        cat = _make(base, n_shards, compact_async)
        shadow = {i: base[i] for i in range(m0)}
        try:
            _replay(cat, shadow, ops, rng, positive)
        finally:
            faults.disarm_all()
            cat.flush()

    @given(data=st.data())
    def test_ladder_and_flat_catalogue_agree(data):
        """Differential arm: the SAME fault-free schedule replayed on
        the LSM ladder and on the single-level catalogue ends in the
        SAME visible contents — identical {gid: row} maps — and both
        answer the same final queries exactly."""
        n_shards = data.draw(st.sampled_from([1, 4, 8]), label="n_shards")
        m0 = data.draw(st.sampled_from(BOUNDARY_M), label="M0")
        positive = data.draw(st.booleans(), label="positive")
        seed = data.draw(st.integers(0, 2**32 - 1), label="seed")
        ops = data.draw(_CLEAN_OPS, label="ops")
        note(f"repro: seed={seed} n_shards={n_shards} M0={m0} "
             f"positive={positive} ops={ops}")
        rng = np.random.default_rng(seed)
        base = _rows(rng, m0, positive)
        lsm = _make(base, n_shards, compact_async=False)
        flat = _make(base, 0, compact_async=False)
        shadow_l = {i: base[i] for i in range(m0)}
        shadow_f = {i: base[i] for i in range(m0)}
        # identical rng streams: replay consumes draws in the same order
        _replay(lsm, shadow_l, ops, np.random.default_rng(seed + 1),
                positive, faultable=False)
        _replay(flat, shadow_f, ops, np.random.default_rng(seed + 1),
                positive, faultable=False)
        assert shadow_l.keys() == shadow_f.keys()
        dl = {int(g): r for g, r in zip(*lsm.as_dense()[::-1])}
        df = {int(g): r for g, r in zip(*flat.as_dense()[::-1])}
        assert set(dl) == set(df) == set(shadow_l)
        for g in shadow_l:
            np.testing.assert_array_equal(dl[g], df[g])
            np.testing.assert_array_equal(dl[g], shadow_l[g])
else:                                                # pragma: no cover
    def test_interleaved_schedules_match_fresh_rebuild_oracle():
        pytest.importorskip("hypothesis")

    def test_ladder_and_flat_catalogue_agree():
        pytest.importorskip("hypothesis")


# -- deterministic companions ------------------------------------------------


def test_steady_state_folds_are_compile_free():
    """The §10 contract extended to the ladder: after warm(), a stream
    whose overflows are absorbed by L0 -> L1 folds triggers ZERO engine
    compiles and no new segmented-tail traces."""
    rng = np.random.default_rng(3)
    base = rng.standard_normal((64, R)).astype(np.float32)
    cat = ShardedLsmCatalogue(base, n_shards=4, delta_capacity=4,
                              l1_capacity=64, block_size=8,
                              compact_async=False)
    eng = get_engine("norm")
    cat.warm(K)
    U = rng.standard_normal((2, R)).astype(np.float32)
    # priming rounds: 5-row inserts cycle the delta occupancy through
    # every residue mod the capacity, so after one full cycle every
    # lazily-traced tail shape the steady state can present is cached
    for _ in range(4):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
        cat.query(eng, U, K)
    folds0 = cat.stats.n_l1_folds
    tails0 = cat.trace_counts.get("segmented_tail", 0)
    shadow = {int(g): r for g, r in zip(*cat.as_dense()[::-1])}
    for _ in range(6):
        rows = rng.standard_normal((5, R)).astype(np.float32)
        for g, row in zip(cat.add_targets(rows), rows):
            shadow[int(g)] = row
        _check_query(cat, shadow, U)
    assert cat.stats.n_l1_folds > folds0          # the stream DID fold
    assert cat.stats.n_compactions == 0           # ...never a full rebuild
    assert cat.stats.engine_compiles_total == 0   # the §10 gate
    assert cat.trace_counts.get("segmented_tail", 0) == tails0


def test_norm_sharded_engine_on_ladder_is_exact():
    """The title configuration: the norm_sharded engine querying the
    sharded LSM catalogue (runs on 1 device via compat_shard_map; CI
    re-runs this file under 8 forced host devices)."""
    rng = np.random.default_rng(17)
    base = rng.standard_normal((96, R)).astype(np.float32)
    cat = ShardedLsmCatalogue(base, n_shards=4, delta_capacity=4,
                              l1_capacity=32, block_size=8,
                              compact_async=False)
    shadow = {i: base[i] for i in range(96)}
    rows = rng.standard_normal((9, R)).astype(np.float32)
    for g, row in zip(cat.add_targets(rows), rows):
        shadow[int(g)] = row
    cat.delete_targets([0, 50])
    del shadow[0], shadow[50]
    U = rng.standard_normal((3, R)).astype(np.float32)
    _check_query(cat, shadow, U, engine="norm_sharded")
    cat.promote(wait=True)
    assert cat.l1_rows == 0 and cat.l0_chain_len == 0
    _check_query(cat, shadow, U, engine="norm_sharded")
