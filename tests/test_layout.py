"""Layout-subsystem tests (DESIGN.md §7): construction invariants, the
gather-free list-prefix path (including ascending/negative walks and
prefix-overflow fallback), and the sharded norm deal."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EngineContext,
    blocked_topk,
    build_layout,
    chunked_ta_topk,
    get_engine,
    layout_names,
    naive_topk,
    threshold_topk_np,
)
from repro.core.index import build_index


def _problem(seed=5, m=220, r=10):
    rng = np.random.default_rng(seed)
    T = rng.standard_normal((m, r)).astype(np.float32)
    idx = build_index(T)
    return rng, T, idx


# ---------------------------------------------------------------------------
# Registry + construction invariants
# ---------------------------------------------------------------------------


def test_layout_registry_names_and_unknown():
    assert set(layout_names()) == {"row_major", "norm_major", "list_major",
                                   "norm_sharded"}
    with pytest.raises(ValueError, match="unknown layout"):
        build_layout("column_major", np.zeros((4, 2), np.float32))


def test_list_major_materialises_walk_orders():
    _, T, idx = _problem()
    lay = build_layout("list_major", T, idx, prefix_depth=32)
    od = np.asarray(idx.order_desc)
    assert lay.prefix_depth == 32
    np.testing.assert_array_equal(np.asarray(lay.head_ids), od[:, :32])
    np.testing.assert_array_equal(np.asarray(lay.tail_ids),
                                  od[:, ::-1][:, :32])
    # head_rows[r, p] IS the catalogue row of the p-th descending item
    np.testing.assert_allclose(np.asarray(lay.head_rows), T[od[:, :32]])
    np.testing.assert_allclose(np.asarray(lay.tail_rows),
                               T[od[:, ::-1][:, :32]])
    # rank_by_item is rank_desc transposed
    np.testing.assert_array_equal(np.asarray(lay.rank_by_item),
                                  np.asarray(idx.rank_desc).T)
    assert lay.prefix_steps(8) == 4 and lay.prefix_steps(7) == 4


def test_list_major_prefix_clamped_to_catalogue():
    _, T, idx = _problem(m=50)
    lay = build_layout("list_major", T, idx, prefix_depth=4096)
    assert lay.prefix_depth == 50


def test_query_views_returns_flags_without_copies():
    _, T, idx = _problem()
    u = jnp.asarray(np.float32([1, -1, 0, 2, -3, 1, 1, -1, 0, 1]))
    order, t_sorted, neg = idx.query_views(u)
    # the SAME index arrays come back — no flipped materialisation
    assert order is idx.order_desc
    assert t_sorted is idx.t_sorted_desc
    np.testing.assert_array_equal(np.asarray(neg),
                                  np.asarray(u) < 0)


def test_context_builds_and_caches_layouts():
    rng, T, _ = _problem()
    ctx = EngineContext(T, block_size=16, prefix_depth=48)
    lay = ctx.layout("list_major")
    assert lay is ctx.layout("list_major")          # cached
    assert lay.prefix_depth == 48
    assert ctx.layout("norm_major").targets_by_norm is ctx.index.targets_by_norm
    # prefix_depth=0 disables the list layout path
    ctx0 = EngineContext(T, block_size=16, prefix_depth=0)
    assert ctx0.resolved_prefix_depth == 0


# ---------------------------------------------------------------------------
# The list_major scan path: signs, prefix overflow, count-faithfulness
# ---------------------------------------------------------------------------


def _sign_queries(rng, r):
    dense = rng.standard_normal((3, r)).astype(np.float32)
    mixed = dense.copy()
    mixed[:, ::2] *= -1.0
    return {
        "positive": np.abs(dense),
        "mixed_sign": mixed,
        "all_negative": -np.abs(dense),
        "sparse_negative": np.where(rng.random((3, r)) < 0.5, 0.0,
                                    -np.abs(dense)).astype(np.float32),
    }


@pytest.mark.parametrize("prefix", [16, 64, 512])
@pytest.mark.parametrize("regime", ["positive", "mixed_sign", "all_negative",
                                    "sparse_negative"])
def test_blocked_layout_path_matches_gather_path(prefix, regime):
    """blocked_topk with the list_major layout == without, on every sign
    pattern — including prefix=16 where nearly every scan overflows into
    the gather tail."""
    rng, T, idx = _problem(seed=11)
    lay = build_layout("list_major", T, idx, prefix_depth=prefix)
    for u in _sign_queries(rng, 10)[regime]:
        if not np.any(u):
            u[0] = -1.0
        uj = jnp.asarray(u)
        base = blocked_topk(jnp.asarray(T), idx.order_desc,
                            idx.t_sorted_desc, uj, 6, block_size=16,
                            rank_desc=idx.rank_desc)
        with_lay = blocked_topk(jnp.asarray(T), idx.order_desc,
                                idx.t_sorted_desc, uj, 6, block_size=16,
                                layout=lay)
        np.testing.assert_allclose(np.asarray(with_lay.values),
                                   np.asarray(base.values), atol=1e-4)
        assert int(with_lay.n_scored) == int(base.n_scored), regime
        assert int(with_lay.depth) == int(base.depth), regime


@pytest.mark.parametrize("prefix", [32, 96])
@pytest.mark.parametrize("regime", ["positive", "mixed_sign", "all_negative",
                                    "sparse_negative"])
def test_chunked_ta_layout_counts_match_sequential_oracle(prefix, regime):
    """The layout-path ta engine stays count-faithful to the item-at-a-time
    oracle through BOTH phases (prefix=32 with chunk=16 forces deep scans
    through the gather fallback)."""
    rng, T, idx = _problem(seed=13, m=180, r=12)
    lay = build_layout("list_major", T, idx, prefix_depth=prefix)
    for u in _sign_queries(rng, 12)[regime]:
        if not np.any(u):
            u[0] = -1.0
        ov, _, ostats = threshold_topk_np(T, np.asarray(idx.order_desc), u, 5)
        r = chunked_ta_topk(jnp.asarray(T), idx.order_desc,
                            idx.t_sorted_desc, idx.rank_desc,
                            jnp.asarray(u), 5, chunk=16, layout=lay)
        np.testing.assert_allclose(np.sort(np.asarray(r.values)),
                                   np.sort(ov), atol=1e-4)
        assert int(r.n_scored) == ostats.n_scored, (prefix, regime)
        assert int(r.depth) == ostats.depth, (prefix, regime)


def test_engine_paths_use_layout_and_stay_exact():
    """ta/bta through the registry (tiny prefix → overflow exercised) match
    naive on all sign regimes."""
    rng, T, _ = _problem(seed=17, m=300, r=8)
    ctx = EngineContext(T, block_size=16, ta_chunk=8, prefix_depth=32)
    for regime, U in _sign_queries(rng, 8).items():
        Uj = jnp.asarray(U)
        ref = np.sort(np.asarray(naive_topk(ctx.targets, Uj, 7).values),
                      axis=1)
        for name in ("ta", "bta"):
            res = get_engine(name).run(ctx, Uj, 7)
            np.testing.assert_allclose(
                np.sort(np.asarray(res.values), axis=1), ref, atol=1e-3,
                err_msg=f"{name}/{regime}")


def test_halted_budget_respected_through_layout_phases():
    rng, T, idx = _problem(seed=19, m=400, r=12)
    lay = build_layout("list_major", T, idx, prefix_depth=64)
    u = jnp.asarray(rng.standard_normal(12).astype(np.float32))
    r = chunked_ta_topk(jnp.asarray(T), idx.order_desc, idx.t_sorted_desc,
                        idx.rank_desc, u, 5, chunk=16, max_rounds=90,
                        layout=lay)
    assert int(r.depth) <= 90           # budget spans prefix + tail
    rb = blocked_topk(jnp.asarray(T), idx.order_desc, idx.t_sorted_desc,
                      u, 5, block_size=16, max_blocks=3, layout=lay)
    assert int(rb.depth) <= 3 * 16


# ---------------------------------------------------------------------------
# Sharded norm layout: the round-robin deal
# ---------------------------------------------------------------------------


def test_norm_sharded_layout_deals_round_robin():
    _, T, idx = _problem(seed=23, m=37, r=6)
    lay = build_layout("norm_sharded", T, idx, n_shards=4)
    m_local = -(-37 // 4)                               # 10, padded
    order = np.asarray(idx.norm_order)
    ids = np.asarray(lay.ids_sharded)
    norms = np.asarray(lay.norms_sharded)
    Tsh = np.asarray(lay.targets_sharded)
    assert ids.shape == (4 * m_local,)
    for s in range(4):
        slab = ids[s * m_local:(s + 1) * m_local]
        expect = order[s::4]
        np.testing.assert_array_equal(slab[:len(expect)], expect)
        assert np.all(slab[len(expect):] == -1)         # padding
        # each slab is itself in decreasing-norm order
        real = norms[s * m_local: s * m_local + len(expect)]
        assert np.all(np.diff(real) <= 1e-6)
        np.testing.assert_allclose(
            Tsh[s * m_local: s * m_local + len(expect)], T[expect])


def test_norm_sharded_engine_matches_norm_counts_single_device():
    """On a 1-device mesh the sharded scan degenerates to the single-host
    batched norm scan — same values AND same n_scored."""
    import jax
    if jax.device_count() != 1:
        pytest.skip("degenerate count equality needs exactly 1 device; "
                    "per-shard counts legitimately differ on a real mesh "
                    "(multi-device exactness is covered in test_sharded.py)")
    rng = np.random.default_rng(29)
    T = rng.standard_normal((512, 16)).astype(np.float32)
    T *= (1.0 / np.sqrt(1.0 + np.arange(512)))[:, None]
    ctx = EngineContext(T, block_size=64)
    U = jnp.asarray(rng.standard_normal((5, 16)).astype(np.float32))
    r_norm = get_engine("norm").run(ctx, U, 9)
    r_sh = get_engine("norm_sharded").run(ctx, U, 9)
    np.testing.assert_allclose(np.sort(np.asarray(r_sh.values), axis=1),
                               np.sort(np.asarray(r_norm.values), axis=1),
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(r_sh.n_scored),
                                  np.asarray(r_norm.n_scored))


# ---------------------------------------------------------------------------
# Traffic estimators (the benchmark's memory-traffic columns)
# ---------------------------------------------------------------------------


def test_traffic_estimates_show_gather_to_contiguous_shift():
    rng = np.random.default_rng(31)
    T = rng.standard_normal((400, 8)).astype(np.float32)
    U = jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))
    deep = EngineContext(T, block_size=16, ta_chunk=8, prefix_depth=400)
    shallow = EngineContext(T, block_size=16, ta_chunk=8, prefix_depth=8)
    eng = get_engine("ta")
    t_deep = eng.traffic(deep, eng.run(deep, U, 5))
    t_shallow = eng.traffic(shallow, eng.run(shallow, U, 5))
    assert t_deep["rows_gathered"] == 0.0           # prefix covers the scan
    assert t_deep["gather_fraction"] == 0.0
    assert t_shallow["rows_gathered"] > 0.0         # overflow gathers
    for t in (t_deep, t_shallow):
        assert t["est_bytes_moved"] > 0
    nt = get_engine("naive")
    tn = nt.traffic(deep, nt.run(deep, U, 5))
    assert tn["rows_contiguous"] == 400 and tn["rows_gathered"] == 0.0
