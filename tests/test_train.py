"""Substrate tests: optimizer, checkpointing, crash/resume, compression,
data determinism."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.loader import PrefetchLoader
from repro.data.synthetic import (cf_ratings, lm_batches, probabilistic_pca,
                                  recsys_batches)
from repro.models import recsys
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import dequantize_int8, ef_compress
from repro.train.optimizer import (OptimizerConfig, apply_updates, init_state,
                                   lr_schedule)
from repro.train.trainer import SimulatedPreemption, Trainer, TrainerConfig

RCFG = recsys.RecsysConfig("fm-t", "fm", 0, 8, 4, 200)


def _loss(p, b):
    return recsys.loss_fn(p, b, RCFG)


def _loader(batch=32):
    return PrefetchLoader(lambda: recsys_batches(0, 0, 8, 200, batch))


class TestOptimizer:
    @pytest.mark.parametrize("kind", ["adamw", "adam", "adagrad", "sgd"])
    def test_converges_on_quadratic(self, kind):
        lr = 0.5 if kind == "adagrad" else 0.05   # adagrad's steps shrink
        cfg = OptimizerConfig(kind=kind, lr=lr, warmup_steps=0,
                              total_steps=400, weight_decay=0.0,
                              momentum=0.5)
        p = {"w": jnp.asarray([3.0, -2.0, 1.0])}
        st = init_state(cfg, p)
        for _ in range(300):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)
            p, st, _ = apply_updates(cfg, p, g, st)
        assert float(jnp.sum(p["w"] ** 2)) < 1e-2

    def test_lr_schedule_shape(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
        lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(101)]
        assert lrs[0] < lrs[10]                 # warmup
        assert abs(lrs[10] - 1.0) < 0.02        # peak
        assert lrs[100] == pytest.approx(0.1, rel=0.05)   # floor

    def test_grad_clipping(self):
        cfg = OptimizerConfig(grad_clip=1.0, lr=1.0, warmup_steps=0)
        p = {"w": jnp.zeros(3)}
        g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
        _, _, m = apply_updates(cfg, p, g, init_state(cfg, p))
        assert float(m["grad_norm"]) == pytest.approx(100.0)


class TestCheckpoint:
    def test_roundtrip_and_gc(self):
        tree = {"a": jnp.arange(10.0), "b": [{"w": jnp.ones((3, 4))}],
                "opt": (jnp.int32(7), jnp.zeros(2))}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=False)
            for step in (10, 20, 30):
                mgr.save(step, tree, block=True)
            assert mgr.list_steps() == [20, 30]   # keep-last-2 GC
            restored, step = mgr.restore(tree)
            assert step == 30
            np.testing.assert_array_equal(np.asarray(restored["a"]),
                                          np.arange(10.0))

    def test_atomicity_tmp_never_visible(self):
        tree = {"a": jnp.ones(4)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=3, async_save=False)
            mgr.save(1, tree, block=True)
            assert not any(f.endswith(".tmp") for f in os.listdir(d))

    def test_restore_rejects_shape_mismatch(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(1, {"a": jnp.ones(4)}, block=True)
            with pytest.raises(ValueError):
                mgr.restore({"a": jnp.ones(5)})


class TestFaultTolerance:
    def test_crash_resume_bitwise_deterministic(self):
        params = recsys.init_params(RCFG, jax.random.PRNGKey(0))
        opt = OptimizerConfig(kind="adamw", lr=1e-2, warmup_steps=2,
                              total_steps=30)
        with tempfile.TemporaryDirectory() as d:
            t1 = Trainer(_loss, params, opt, _loader(), TrainerConfig(
                total_steps=30, ckpt_every=10, ckpt_dir=d, fail_at_step=17))
            with pytest.raises(SimulatedPreemption):
                t1.run()
            p2 = recsys.init_params(RCFG, jax.random.PRNGKey(0))
            t2 = Trainer(_loss, p2, opt, _loader(), TrainerConfig(
                total_steps=30, ckpt_every=10, ckpt_dir=d))
            t2.run()
            assert t2.step == 30
            p3 = recsys.init_params(RCFG, jax.random.PRNGKey(0))
            t3 = Trainer(_loss, p3, opt, _loader(), TrainerConfig(
                total_steps=30, ckpt_every=1000))
            t3.run()
            a = np.asarray(t2.params["embed"])
            b = np.asarray(t3.params["embed"])
            np.testing.assert_array_equal(a, b)   # bitwise

    def test_training_reduces_loss(self):
        params = recsys.init_params(RCFG, jax.random.PRNGKey(0))
        opt = OptimizerConfig(kind="adamw", lr=5e-3, warmup_steps=5,
                              total_steps=60)
        tr = Trainer(_loss, params, opt, _loader(64),
                     TrainerConfig(total_steps=60, log_every=5))
        tr.run()
        # per-step losses are single-batch samples; compare early/late
        # windows so one noisy batch can't flip the verdict
        losses = [h["loss"] for h in tr.history]
        assert np.mean(losses[:3]) > np.mean(losses[-3:])


class TestCompression:
    def test_error_feedback_unbiased_over_time(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
        err = jnp.zeros_like(x)
        acc = jnp.zeros_like(x)
        for _ in range(50):
            q, s, err = ef_compress(x, err)
            acc = acc + dequantize_int8(q, s)
        assert float(jnp.max(jnp.abs(acc / 50 - x))) < 0.01

    def test_quantize_wire_width(self):
        from repro.train.compression import quantize_int8
        q, s = quantize_int8(jnp.asarray([1.0, -3.0, 2.0]))
        assert q.dtype == jnp.int8          # 4x fewer DCI bytes than f32


class TestData:
    def test_lm_batches_deterministic_and_shard_disjoint(self):
        a = list(zip(range(3), lm_batches(0, 100, 8, 16)))
        b = list(zip(range(3), lm_batches(0, 100, 8, 16)))
        for (_, x), (_, y) in zip(a, b):
            np.testing.assert_array_equal(x["tokens"], y["tokens"])
        s0 = next(iter(lm_batches(0, 100, 8, 16, shard=0, num_shards=2)))
        s1 = next(iter(lm_batches(0, 100, 8, 16, shard=1, num_shards=2)))
        assert s0["tokens"].shape == (4, 16)
        assert not np.array_equal(s0["tokens"], s1["tokens"])

    def test_loader_skip_resumes_stream(self):
        mk = lambda: lm_batches(0, 100, 4, 8)  # noqa: E731
        direct = list(zip(range(5), mk()))
        loader = PrefetchLoader(mk).skip(3)
        got = next(iter(loader))
        np.testing.assert_array_equal(got["tokens"], direct[3][1]["tokens"])

    def test_ppca_reconstructs_lowrank(self):
        rng = np.random.default_rng(0)
        M = cf_ratings(rng, 100, 200, density=0.5, rank=5)
        U, V = probabilistic_pca(M, 20, n_iters=15)
        rel = np.linalg.norm(M - U @ V.T) / np.linalg.norm(M)
        assert rel < 0.7                      # captures most structure
