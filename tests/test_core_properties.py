"""Property-based exactness tests (hypothesis).

This module is skipped in its entirety when hypothesis is not installed
(the deterministic equivalents in ``test_core_exact.py`` and the registry
sweep in ``test_engines.py`` still run everywhere).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    blocked_topk,
    naive_topk,
    norm_pruned_topk,
    threshold_topk_from_index,
    threshold_topk_np,
)
from repro.core.index import build_index


def _problem(draw):
    m = draw(st.integers(5, 120))
    r = draw(st.integers(2, 16))
    k = draw(st.integers(1, min(m, 8)))
    seed = draw(st.integers(0, 2**31 - 1))
    sparse = draw(st.booleans())
    rng = np.random.default_rng(seed)
    T = rng.standard_normal((m, r)).astype(np.float32)
    u = rng.standard_normal(r).astype(np.float32)
    if sparse:
        u[rng.random(r) < 0.5] = 0.0
        if np.all(u == 0):
            u[0] = 1.0
    return T, u, k


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_ta_equals_naive(data):
    T, u, k = _problem(data.draw)
    nv = np.sort(np.asarray(naive_topk(jnp.asarray(T), jnp.asarray(u), k).values))
    idx = build_index(T)
    tv, _, ts = threshold_topk_np(T, np.asarray(idx.order_desc), u, k)
    np.testing.assert_allclose(np.sort(tv), nv, atol=1e-4)
    jr = threshold_topk_from_index(jnp.asarray(T), idx, jnp.asarray(u), k)
    np.testing.assert_allclose(np.sort(np.asarray(jr.values)), nv, atol=1e-4)
    # the JAX TA is count-faithful to the oracle
    assert int(jr.n_scored) == ts.n_scored
    assert int(jr.depth) == ts.depth


@settings(max_examples=25, deadline=None)
@given(data=st.data(), block=st.sampled_from([1, 3, 8, 32]))
def test_bta_exact_any_block_size(data, block):
    T, u, k = _problem(data.draw)
    nv = np.sort(np.asarray(naive_topk(jnp.asarray(T), jnp.asarray(u), k).values))
    idx = build_index(T)
    r = blocked_topk(jnp.asarray(T), idx.order_desc, idx.t_sorted_desc,
                     jnp.asarray(u), k, block_size=block)
    np.testing.assert_allclose(np.sort(np.asarray(r.values)), nv, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_norm_pruned_exact(data):
    T, u, k = _problem(data.draw)
    nv = np.sort(np.asarray(naive_topk(jnp.asarray(T), jnp.asarray(u), k).values))
    idx = build_index(T)
    r = norm_pruned_topk(jnp.asarray(T), idx.norm_order, idx.norms_sorted,
                         jnp.asarray(u), k, block_size=16)
    np.testing.assert_allclose(np.sort(np.asarray(r.values)), nv, atol=1e-4)
