"""Streaming-catalogue exactness: the segmented (base + delta + tombstone)
top-K equals a freshly rebuilt index's top-K at EVERY point of randomized
insert/update/delete/query interleavings (DESIGN.md §9), across delta
occupancies 0 -> overflow-forced compaction, including tombstoned-rows-in-
the-base-top-K and all-negative queries."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EngineContext, SegmentedCatalogue, get_engine

R = 12
K = 5


def _rng(seed=0):
    return np.random.default_rng(seed)


def _base(rng, m=400):
    return rng.standard_normal((m, R)).astype(np.float32)


def _oracle(cat, U, k):
    """Fresh-rebuild oracle: dense scores over the live set, NumPy argsort."""
    rows, gids = cat.as_dense()
    U = np.atleast_2d(np.asarray(U, np.float32))
    s = U.astype(np.float64) @ rows.astype(np.float64).T
    order = np.argsort(-s, kind="stable", axis=1)[:, :k]
    return s[np.arange(U.shape[0])[:, None], order], gids[order]


def _rebuilt_engine_topk(cat, engine_name, U, k):
    """A FRESH index + engine over the live set — the rebuild the
    streaming layer replaces. Returns (values, gids)."""
    rows, gids = cat.as_dense()
    ctx = EngineContext(rows, block_size=32)
    res = get_engine(engine_name).run(
        ctx, jnp.atleast_2d(jnp.asarray(U)), k)
    idx = np.asarray(res.indices)
    return np.asarray(res.values), np.where(idx >= 0, gids[idx], -1)


def assert_exact(cat, U, k=K, engine="norm"):
    """Segmented top-K == fresh-rebuild top-K: identical value vectors, and
    identical id sets wherever the k-boundary is unambiguous."""
    res, info = cat.query(get_engine(engine), U, k)
    vals = np.asarray(res.values)
    gids = np.asarray(res.indices)
    ov, og = _oracle(cat, U, k)
    n_live = cat.num_live
    kk = min(k, n_live)
    np.testing.assert_allclose(vals[:, :kk], ov[:, :kk], atol=1e-4,
                               err_msg="segmented values != rebuilt values")
    if kk < k:          # fewer live items than k: the rest must be padding
        assert np.all(vals[:, kk:] == -np.inf)
        assert np.all(gids[:, kk:] == -1)
    # every returned id is live and scores what the result claims
    rows, all_gids = cat.as_dense()
    by_gid = {int(g): rows[i] for i, g in enumerate(all_gids)}
    for b in range(vals.shape[0]):
        for j in range(kk):
            g = int(gids[b, j])
            assert g in by_gid, f"returned gid {g} is not live"
            np.testing.assert_allclose(
                float(np.asarray(U, np.float32).reshape(-1, R)[b]
                      @ by_gid[g]), vals[b, j], atol=1e-4)
        # id SETS agree when the k-th / (k+1)-th gap is unambiguous
        if n_live > kk and kk > 0 and ov[b, kk - 1] - _oracle(
                cat, np.atleast_2d(U)[b], kk + 1)[0][0, kk] > 1e-4:
            assert set(gids[b, :kk].tolist()) == set(og[b, :kk].tolist())
    return res, info


def test_pristine_matches_static_path():
    rng = _rng(0)
    cat = SegmentedCatalogue(_base(rng), block_size=32)
    U = rng.standard_normal((4, R)).astype(np.float32)
    assert cat.pristine
    res, info = cat.query(get_engine("bta"), U, K)
    assert info.n_segments == 0 and info.delta_scored == 0
    ov, og = _oracle(cat, U, K)
    np.testing.assert_allclose(np.asarray(res.values), ov, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(res.indices), og)


def test_tombstoned_row_in_base_topk_is_dropped():
    """Delete the base item that IS the top-1: the over-fetch must recover
    the true top-K from the survivors."""
    rng = _rng(1)
    cat = SegmentedCatalogue(_base(rng), block_size=32)
    U = rng.standard_normal((3, R)).astype(np.float32)
    _, top_gids = _oracle(cat, U, 1)
    victims = sorted({int(g) for g in top_gids.ravel()})
    cat.delete_targets(victims)
    res, info = assert_exact(cat, U)
    # the tombstone-adaptive fetch over-fetches k + reserve in ONE run; a
    # handful of dead rows fit that margin, so no ladder climb is needed
    assert not info.retried
    assert info.overfetch_k == min(cat.snapshot.num_rows,
                                   K + cat.overfetch_reserve)
    returned = set(np.asarray(res.indices).ravel().tolist())
    assert not (returned & set(victims))


def test_update_replaces_in_place_and_twice():
    rng = _rng(2)
    cat = SegmentedCatalogue(_base(rng), block_size=32)
    U = rng.standard_normal((2, R)).astype(np.float32)
    gid = 7
    big = (10.0 * U[0] / np.linalg.norm(U[0])).astype(np.float32)
    cat.update_targets([gid], [big])
    res, _ = assert_exact(cat, U)
    assert int(np.asarray(res.indices)[0, 0]) == gid   # updated row wins
    # update the SAME gid again: only the latest copy may be visible
    cat.update_targets([gid], [np.zeros(R, np.float32)])
    res, _ = assert_exact(cat, U)
    assert int(np.asarray(res.indices)[0, 0]) != gid
    assert cat.num_live == 400


def test_all_negative_queries_stay_exact():
    rng = _rng(3)
    cat = SegmentedCatalogue(_base(rng), block_size=32)
    U = -np.abs(rng.standard_normal((3, R))).astype(np.float32)
    cat.add_targets(-np.abs(rng.standard_normal((5, R))).astype(np.float32))
    cat.delete_targets([0, 1])
    assert_exact(cat, U)


def test_delta_overflow_forces_compaction_and_stays_exact():
    rng = _rng(4)
    cat = SegmentedCatalogue(_base(rng, 200), delta_capacity=8,
                             block_size=32)
    U = rng.standard_normal((2, R)).astype(np.float32)
    for i in range(30):                      # 30 inserts through capacity 8
        cat.add_targets(rng.standard_normal((1, R)).astype(np.float32) * 2)
        if i % 7 == 0:
            assert_exact(cat, U)
    assert cat.stats.n_compactions >= 3
    assert cat.version == cat.stats.n_compactions
    assert cat.num_live == 230
    assert_exact(cat, U)
    # compaction re-packed: tombstones gone, fresh context per version
    assert cat.n_tombstones == 0
    assert cat.snapshot.ctx.version == cat.version


def test_n_scored_extends_to_delta_and_depth_is_base():
    rng = _rng(5)
    cat = SegmentedCatalogue(_base(rng), block_size=32)
    U = rng.standard_normal((2, R)).astype(np.float32)
    eng = get_engine("norm")
    base_res, _ = cat.query(eng, U, K)
    cat.add_targets(rng.standard_normal((6, R)).astype(np.float32))
    cat.delete_targets([3])                  # one delta-irrelevant tombstone
    res, info = cat.query(eng, U, K)
    assert info.delta_scored == 6
    # n_scored = base engine scores (at the over-fetched k) + live delta
    assert np.all(np.asarray(res.n_scored)
                  >= np.asarray(base_res.n_scored) + 6)
    assert np.all(np.asarray(res.depth) >= 1)


def test_tombstone_adaptive_overfetch():
    """No tombstones -> the base runs at plain k; any tombstones -> the
    single pre-warmed k + reserve over-fetch, absorbing hits without a
    rerun — even when the deleted item WAS a query's top-1."""
    rng = _rng(6)
    cat = SegmentedCatalogue(_base(rng), block_size=32)
    U = rng.standard_normal((1, R)).astype(np.float32)
    eng = get_engine("norm")
    _, info = cat.query(eng, U, K)
    assert info.overfetch_k == K and not info.retried
    sv, sg = _oracle(cat, U, 400)            # full ranking for this query
    kb_esc = min(cat.snapshot.num_rows, K + cat.overfetch_reserve)
    # tombstone 4 items from the BOTTOM of the ranking (miss the top-k)
    cat.delete_targets([int(g) for g in sg[0, -4:]])
    res, info = cat.query(eng, U, K)
    assert not info.retried and info.overfetch_k == kb_esc
    assert_exact(cat, U)
    # tombstone the query's top-1: still one run — the reserve margin
    # absorbs the hit, no ladder climb
    cat.delete_targets([int(sg[0, 0])])
    res, info = cat.query(eng, U, K)
    assert not info.retried and info.overfetch_k == kb_esc
    assert int(sg[0, 0]) not in set(np.asarray(res.indices)[0].tolist())
    assert_exact(cat, U)


def test_randomized_interleaving_always_exact():
    """The acceptance property: random insert/update/delete streams, exact
    vs a fresh rebuild at every query point, across delta occupancies
    0 -> overflow (capacity 8 forces multiple compactions)."""
    rng = _rng(7)
    cat = SegmentedCatalogue(_base(rng, 150), delta_capacity=8,
                             block_size=32)
    live = list(range(150))
    for step in range(60):
        op = rng.choice(["ins", "del", "upd", "query"],
                        p=[0.3, 0.2, 0.2, 0.3])
        if op == "ins":
            n = int(rng.integers(1, 4))
            gids = cat.add_targets(
                rng.standard_normal((n, R)).astype(np.float32) * 1.5)
            live.extend(int(g) for g in gids)
        elif op == "del" and len(live) > K + 2:
            victim = live.pop(int(rng.integers(len(live))))
            cat.delete_targets([victim])
        elif op == "upd" and live:
            gid = live[int(rng.integers(len(live)))]
            cat.update_targets(
                [gid], rng.standard_normal((1, R)).astype(np.float32) * 2)
        else:
            U = rng.standard_normal(
                (int(rng.integers(1, 5)), R)).astype(np.float32)
            assert_exact(cat, U)
    assert cat.stats.n_compactions >= 1      # overflow was actually hit
    assert_exact(cat, rng.standard_normal((3, R)).astype(np.float32))
    assert cat.num_live == len(live)


def test_engines_agree_after_mutations():
    """Every jax registry engine serves the SAME mutated catalogue state
    through the segmented wrapper — engines untouched, mutation-aware."""
    rng = _rng(8)
    cat = SegmentedCatalogue(_base(rng, 300), block_size=32)
    cat.add_targets(rng.standard_normal((10, R)).astype(np.float32))
    cat.delete_targets([5, 6, 7])
    cat.update_targets([10], rng.standard_normal((1, R)).astype(np.float32))
    U = rng.standard_normal((4, R)).astype(np.float32)
    ref, _ = cat.query(get_engine("naive"), U, K)
    for name in ("ta", "bta", "norm"):
        res, _ = cat.query(get_engine(name), U, K)
        np.testing.assert_allclose(np.asarray(res.values),
                                   np.asarray(ref.values), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(res.indices),
                                      np.asarray(ref.indices))


def test_segmented_matches_rebuilt_engine_not_just_numpy():
    """Cross-check against an actual rebuilt INDEX + engine (not only the
    numpy oracle): same values, same gid sets."""
    rng = _rng(9)
    cat = SegmentedCatalogue(_base(rng, 250), delta_capacity=16,
                             block_size=32)
    cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
    cat.delete_targets([int(g) for g in range(0, 20, 3)])
    U = rng.standard_normal((3, R)).astype(np.float32)
    res, _ = cat.query(get_engine("bta"), U, K)
    rv, rg = _rebuilt_engine_topk(cat, "bta", U, K)
    np.testing.assert_allclose(np.asarray(res.values), rv, atol=1e-4)
    for b in range(3):
        assert (set(np.asarray(res.indices)[b].tolist())
                == set(rg[b].tolist()))


def test_delete_everything_then_recover():
    rng = _rng(10)
    cat = SegmentedCatalogue(_base(rng, 30), delta_capacity=8,
                             block_size=16)
    U = rng.standard_normal((2, R)).astype(np.float32)
    cat.delete_targets(list(range(30)))
    assert cat.num_live == 0
    res, _ = cat.query(get_engine("norm"), U, K)
    assert np.all(np.asarray(res.values) == -np.inf)
    assert np.all(np.asarray(res.indices) == -1)
    cat.compact()                            # empty compaction: guard row
    res, _ = cat.query(get_engine("norm"), U, K)
    assert np.all(np.asarray(res.indices) == -1)
    gids = cat.add_targets(rng.standard_normal((4, R)).astype(np.float32))
    res, _ = assert_exact(cat, U, k=3)
    assert set(np.asarray(res.indices)[0].tolist()) <= set(
        int(g) for g in gids)


def test_background_compaction_with_concurrent_mutations():
    """compact_async=True: queries and mutations keep landing while the
    replacement snapshot builds; deletes that race the build are re-applied
    at swap (pending-dead), so the post-swap state is exact."""
    rng = _rng(11)
    cat = SegmentedCatalogue(_base(rng, 300), delta_capacity=8,
                             block_size=32, compact_async=True)
    U = rng.standard_normal((2, R)).astype(np.float32)
    cat.add_targets(rng.standard_normal((8, R)).astype(np.float32))  # full
    cat.add_targets(rng.standard_normal((1, R)).astype(np.float32))  # trigger
    # race the build: a base delete + an active-delta query
    cat.delete_targets([0, 1, 2])
    assert_exact(cat, U)
    cat.flush()
    assert cat.stats.n_compactions == 1
    assert_exact(cat, U)
    assert cat.num_live == 300 + 9 - 3
    # the deletes survived the swap no matter when they landed
    returned = set(np.asarray(
        cat.query(get_engine("naive"), U, 300)[0].indices).ravel().tolist())
    assert not (returned & {0, 1, 2})


def test_version_monotone_and_snapshot_pytrees_stable():
    rng = _rng(12)
    cat = SegmentedCatalogue(_base(rng, 100), delta_capacity=4,
                             block_size=16)
    snap0 = cat.snapshot
    versions = [cat.version]
    for _ in range(3):
        cat.add_targets(rng.standard_normal((5, R)).astype(np.float32))
        versions.append(cat.version)
    assert versions == sorted(versions) and versions[-1] >= 1
    # the old snapshot's arrays are untouched by the swap (in-flight jitted
    # calls keep valid pytrees)
    assert snap0.version == 0
    assert snap0.ctx.version == 0
    np.testing.assert_array_equal(snap0.gids_np, np.arange(100))


def test_escalation_ladder_climbs_past_reserve():
    """> reserve dead rows inside one query's top slice: the margin check
    fails at k + reserve and the fetch climbs x4 — still exact."""
    from repro.core.segments import ESCALATION_STEP
    rng = _rng(17)
    T = rng.standard_normal((300, R)).astype(np.float32)
    u = rng.standard_normal(R).astype(np.float32)
    un = (u / np.linalg.norm(u)).astype(np.float32)
    n_top = 40                               # > reserve (32)
    T[:n_top] = un[None, :] * (
        10.0 + np.arange(n_top, dtype=np.float32))[:, None]
    cat = SegmentedCatalogue(T, block_size=32)
    cat.delete_targets(list(range(n_top)))
    res, info = cat.query(get_engine("norm"), u[None], K)
    assert info.retried
    assert info.overfetch_k == min(
        300, K + ESCALATION_STEP * cat.overfetch_reserve)
    assert not (set(np.asarray(res.indices)[0].tolist())
                & set(range(n_top)))
    assert_exact(cat, u[None])


def test_mutation_batches_are_atomic_on_error():
    """Validate-then-apply: a bad gid anywhere in a batch leaves the
    catalogue untouched, so the batch is retryable."""
    rng = _rng(14)
    cat = SegmentedCatalogue(_base(rng, 60), block_size=16)
    with pytest.raises(KeyError):
        cat.delete_targets([5, 99999])
    with pytest.raises(KeyError):
        cat.delete_targets([7, 7])               # duplicate in one batch
    with pytest.raises(KeyError):
        cat.update_targets([6, 99999], np.zeros((2, R), np.float32))
    assert cat.num_live == 60                    # nothing was tombstoned
    assert cat.stats.n_deletes == 0 and cat.stats.n_updates == 0
    cat.delete_targets([5, 7])                   # the retry succeeds
    cat.update_targets([6], np.zeros((1, R), np.float32))
    assert cat.num_live == 58


def test_update_same_gid_twice_in_one_batch_last_wins():
    rng = _rng(15)
    cat = SegmentedCatalogue(_base(rng, 80), block_size=16)
    U = rng.standard_normal((2, R)).astype(np.float32)
    rows = np.stack([np.full(R, 9.0, np.float32),
                     rng.standard_normal(R).astype(np.float32)])
    cat.update_targets([3, 3], rows)
    assert cat.num_live == 80
    res, _ = assert_exact(cat, U)
    rows_live, gids_live = cat.as_dense()
    np.testing.assert_array_equal(
        rows_live[list(gids_live).index(3)], rows[1])


def test_failed_background_build_loses_nothing(monkeypatch):
    """A build() crash must strand no rows: the sealed segments stay
    queryable and the next compaction folds the whole chain."""
    import repro.core.segments as seg_mod
    rng = _rng(16)
    cat = SegmentedCatalogue(_base(rng, 120), delta_capacity=8,
                             block_size=16, compact_async=True)
    U = rng.standard_normal((2, R)).astype(np.float32)
    real_ctx = seg_mod.EngineContext
    boom = {"armed": True}

    def flaky_ctx(*args, **kwargs):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated snapshot build failure")
        return real_ctx(*args, **kwargs)

    monkeypatch.setattr(seg_mod, "EngineContext", flaky_ctx)
    gids = cat.add_targets(
        rng.standard_normal((9, R)).astype(np.float32))  # overflow -> build
    cat.flush()                                  # the build FAILED
    assert cat.stats.n_compactions == 0
    assert cat.stats.n_failed_compactions == 1   # recorded, not raised
    assert isinstance(cat.last_build_error, RuntimeError)
    assert len(cat._frozen) == 1                 # sealed chain intact
    assert cat.num_live == 129
    res, info = assert_exact(cat, U)             # frozen rows still served
    assert info.n_segments >= 1
    cat.delete_targets([int(gids[0])])           # mutations keep working
    cat.add_targets(rng.standard_normal((8, R)).astype(np.float32))
    cat.flush()                                  # second build succeeds
    assert cat.stats.n_compactions == 1
    assert not cat._frozen
    assert cat.num_live == 136
    assert_exact(cat, U)


def test_noop_compact_keeps_snapshot_and_version():
    """compact() with nothing to fold must not rebuild (a rebuild would
    bump the version and invalidate every warmed engine executable)."""
    cat = SegmentedCatalogue(_base(_rng(19), 50), block_size=16)
    snap = cat.snapshot
    cat.compact()
    assert cat.snapshot is snap and cat.version == 0
    assert cat.stats.n_compactions == 0


def test_sync_build_failure_keeps_mutation_batches_atomic(monkeypatch):
    """A synchronous build failure mid-mutation is recorded, not raised:
    the batch completes (no row lost) and compact() surfaces the error."""
    import repro.core.segments as seg_mod
    rng = _rng(20)
    cat = SegmentedCatalogue(_base(rng, 100), delta_capacity=8,
                             block_size=16)
    U = rng.standard_normal((2, R)).astype(np.float32)
    cat.add_targets(rng.standard_normal((8, R)).astype(np.float32))
    real_ctx = seg_mod.EngineContext
    boom = {"armed": True}

    def flaky_ctx(*args, **kwargs):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated build failure")
        return real_ctx(*args, **kwargs)

    monkeypatch.setattr(seg_mod, "EngineContext", flaky_ctx)
    new_row = rng.standard_normal((1, R)).astype(np.float32)
    cat.update_targets([3], new_row)         # full delta -> failing build
    assert cat.stats.n_failed_compactions == 1
    assert cat.num_live == 108               # the update was NOT lost
    res, _ = assert_exact(cat, U)
    rows_live, gids_live = cat.as_dense()
    np.testing.assert_array_equal(
        rows_live[list(gids_live).index(3)], new_row[0])
    cat.compact()                            # next build succeeds, folds all
    assert cat.stats.n_compactions == 1 and not cat._frozen
    assert_exact(cat, U)


def test_compaction_never_blocks_mutations(monkeypatch):
    """A second delta overflow while a build is in flight seals onto the
    L0 chain and returns immediately — mutations never wait on a build,
    and the chain drains (auto-refold) once builds catch up."""
    import time as _time

    import repro.core.segments as seg_mod
    rng = _rng(18)
    cat = SegmentedCatalogue(_base(rng, 150), delta_capacity=8,
                             block_size=16, compact_async=True)
    U = rng.standard_normal((2, R)).astype(np.float32)
    real_ctx = seg_mod.EngineContext

    def slow_ctx(*args, **kwargs):
        _time.sleep(1.0)
        return real_ctx(*args, **kwargs)

    monkeypatch.setattr(seg_mod, "EngineContext", slow_ctx)
    cat.add_targets(rng.standard_normal((9, R)).astype(np.float32))
    t0 = _time.perf_counter()
    cat.add_targets(rng.standard_normal((16, R)).astype(np.float32))
    assert _time.perf_counter() - t0 < 0.8   # sealed + returned, no join
    assert_exact(cat, U)                     # base + chain + active served
    cat.flush()                              # builds (incl. refold) drain
    assert not cat._frozen
    assert cat.stats.n_compactions >= 2
    assert cat.num_live == 175
    assert_exact(cat, U)


def test_unknown_gid_raises():
    rng = _rng(13)
    cat = SegmentedCatalogue(_base(rng, 50), block_size=16)
    cat.delete_targets([3])
    with pytest.raises(KeyError):
        cat.delete_targets([3])              # already dead
    with pytest.raises(KeyError):
        cat.update_targets([999], [np.zeros(R, np.float32)])
    with pytest.raises(ValueError):
        cat.add_targets(np.zeros((1, R + 1), np.float32))
