"""Serving-layer tests: engines agree, decode==forward, two-stage ranking."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import random_model
from repro.models import transformer as tf_mod
from repro.serving.server import TopKServer, TwoStageRanker


def test_server_engines_agree_and_count_scores():
    model = random_model(np.random.default_rng(0), 3000, 24,
                         "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64)
    U = jnp.asarray(np.random.default_rng(1).standard_normal(
        (12, 24)).astype(np.float32))
    r_naive = srv.query(U, 10, "naive")
    for eng in ("bta", "norm"):
        r = srv.query(U, 10, eng)
        np.testing.assert_allclose(np.sort(r.values, axis=1),
                                   np.sort(r_naive.values, axis=1), atol=1e-4)
    assert srv.stats["naive"].scores_per_query == 3000
    assert srv.stats["norm"].scores_per_query <= 3000


def test_server_warmup_then_queries_hit_compiled_cache():
    """Acceptance: repeated same-shape TopKServer.query calls hit the
    compiled-executable cache — 0 new traces after warmup."""
    model = random_model(np.random.default_rng(5), 2000, 16,
                         "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64)
    srv.warmup(10, batch_sizes=(8,), engines=["naive", "ta", "bta", "norm"])
    warm = dict(srv.ctx.trace_counts)
    U = np.random.default_rng(6).standard_normal((8, 16)).astype(np.float32)
    for _ in range(3):
        for eng in ("naive", "ta", "bta", "norm"):
            srv.query(U, 10, eng)
    assert srv.ctx.trace_counts == warm
    # and the answers stayed exact through the cache
    r = srv.query(U, 10, "norm")
    r0 = srv.query(U, 10, "naive")
    np.testing.assert_allclose(np.sort(r.values, axis=1),
                               np.sort(r0.values, axis=1), atol=1e-4)


def test_two_stage_ranker_reranks_retrieved():
    rng = np.random.default_rng(2)
    model = random_model(rng, 2000, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64)

    def rerank(batch, cand_ids):
        # a "full model" that reverses the retrieval order deterministically
        return -np.asarray(cand_ids, np.float64)

    ranker = TwoStageRanker(srv, rerank, retrieve_n=50)
    U = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    ids, scores = ranker.rank({}, U, k=5)
    assert ids.shape == (4, 5)
    # reranker prefers small ids among the retrieved 50
    retrieved = srv.query(U, 50, "bta")
    for b in range(4):
        assert set(ids[b]) <= set(np.asarray(retrieved.indices[b]).tolist())
        assert list(ids[b]) == sorted(ids[b])


def test_lm_decode_matches_forward_fp32():
    cfg = tf_mod.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=64, vocab_size=128, logit_chunk=8, kv_block=8,
        compute_dtype=jnp.float32)
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 128)
    hidden, _ = tf_mod.forward(params, tokens, cfg)
    full = tf_mod.logits_from_hidden(params, hidden, cfg)
    cache = tf_mod.init_kv_cache(cfg, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(12):
        lg, cache = tf_mod.serve_step(params, cache, tokens[:, t:t + 1], t, cfg)
        outs.append(lg)
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full), atol=2e-3)


def test_prefill_cache_matches_incremental():
    cfg = tf_mod.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=64, vocab_size=128, logit_chunk=8, kv_block=8,
        compute_dtype=jnp.float32)
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    _, cache_pf = tf_mod.prefill(params, tokens, cfg, cache_dtype=jnp.float32)
    cache = tf_mod.init_kv_cache(cfg, 2, 8, dtype=jnp.float32)
    for t in range(8):
        _, cache = tf_mod.serve_step(params, cache, tokens[:, t:t + 1], t, cfg)
    np.testing.assert_allclose(np.asarray(cache_pf["k"]),
                               np.asarray(cache["k"]), atol=2e-3)


def test_halted_vs_exact_precision_tradeoff():
    """Halted TA at a tiny budget returns plausible but possibly inexact
    tops; at a generous budget it matches the exact engine (paper §4.3)."""
    from repro.core import blocked_topk, naive_topk
    from repro.core.index import build_index
    rng = np.random.default_rng(3)
    T = rng.standard_normal((2000, 20)).astype(np.float32)
    T *= (1.0 / np.sqrt(1.0 + np.arange(2000)))[:, None]
    u = rng.standard_normal(20).astype(np.float32)
    idx = build_index(T)
    exact = naive_topk(jnp.asarray(T), jnp.asarray(u), 5)
    generous = blocked_topk(jnp.asarray(T), idx.order_desc,
                            idx.t_sorted_desc, jnp.asarray(u), 5,
                            block_size=64, max_blocks=2000 // 64 + 1)
    np.testing.assert_allclose(np.sort(np.asarray(generous.values)),
                               np.sort(np.asarray(exact.values)), atol=1e-4)
    tiny = blocked_topk(jnp.asarray(T), idx.order_desc, idx.t_sorted_desc,
                        jnp.asarray(u), 5, block_size=64, max_blocks=1)
    hits = len(set(np.asarray(tiny.indices).tolist())
               & set(np.asarray(exact.indices).tolist()))
    assert hits >= 1          # finds most of the top fast; exactness needs proof rounds


def test_server_norm_sharded_method():
    """norm_sharded is reachable through TopKServer.query by registry name
    and agrees with the single-host norm engine."""
    model = random_model(np.random.default_rng(9), 2000, 16,
                         "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64)
    U = jnp.asarray(np.random.default_rng(10).standard_normal(
        (8, 16)).astype(np.float32))
    r_norm = srv.query(U, 10, "norm")
    r_sh = srv.query(U, 10, "norm_sharded")
    np.testing.assert_allclose(np.sort(r_sh.values, axis=1),
                               np.sort(r_norm.values, axis=1), atol=1e-4)
    assert srv.stats["norm_sharded"].n_queries == 8


def test_server_streaming_mutations_exact_and_stats():
    """add/delete/update through TopKServer: results carry global ids,
    match a freshly rebuilt server, and the mutation/latency stats fill."""
    rng = np.random.default_rng(20)
    model = random_model(rng, 1500, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64, delta_capacity=16)
    U = rng.standard_normal((8, 16)).astype(np.float32)
    srv.query(U, 10, "norm")
    new_rows = (rng.standard_normal((5, 16)) * 2).astype(np.float32)
    gids = srv.add_targets(new_rows)
    assert list(gids) == [1500, 1501, 1502, 1503, 1504]
    srv.delete_targets([0, 1])
    srv.update_targets([10], rng.standard_normal((1, 16)).astype(np.float32))
    res = srv.query(U, 10, "norm")
    # fresh rebuild over the live set
    rows, live_gids = srv.catalogue.as_dense()
    from repro.core import SepLRModel
    fresh = TopKServer(SepLRModel(jnp.asarray(rows)), max_batch=8,
                       block_size=64)
    ref = fresh.query(U, 10, "norm")
    np.testing.assert_allclose(np.asarray(res.values),
                               np.asarray(ref.values), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  live_gids[np.asarray(ref.indices)])
    ms = srv.mutation_stats
    assert ms["n_inserts"] == 5 and ms["n_deletes"] == 2
    assert ms["n_updates"] == 1 and ms["n_tombstones"] == 3
    assert ms["num_live"] == 1503
    st = srv.stats["norm"]
    assert st.delta_scored > 0
    assert 0 < st.p50_us <= st.p99_us
    assert len(st.lat_us_ring) == 2          # one entry per served batch


def test_server_warmup_covers_delta_buckets_zero_retrace_post_insert():
    """Satellite: warmup() warms the delta-capacity buckets, and the FIRST
    query after an insert triggers 0 new traces (engine cache AND the
    segmented tail cache, via trace_counts)."""
    rng = np.random.default_rng(21)
    model = random_model(rng, 1200, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64, delta_capacity=32)
    srv.warmup(10, batch_sizes=(8,), engines=["norm", "bta"])
    assert srv.trace_counts.get("segmented_tail", 0) > 0
    warm = dict(srv.trace_counts)
    U = rng.standard_normal((8, 16)).astype(np.float32)
    # inserts walking the delta through several pow2 buckets
    for n in (1, 1, 2, 4, 8, 16):
        srv.add_targets(rng.standard_normal((n, 16)).astype(np.float32))
        srv.query(U, 10, "norm")
        srv.query(U, 10, "bta")
        assert srv.trace_counts == warm, "post-insert query retraced"


def test_server_latency_percentiles_ring_bounded():
    from repro.serving.server import LATENCY_RING, ServeStats
    s = ServeStats()
    assert s.p50_us == 0.0                   # empty ring is well-defined
    for i in range(2 * LATENCY_RING):
        s.lat_us_ring.append(float(i))
    assert len(s.lat_us_ring) == LATENCY_RING
    assert s.p50_us >= LATENCY_RING          # old entries evicted
    assert s.p50_us <= s.p95_us <= s.p99_us


def test_server_compaction_off_hot_path_preserves_engine_exactness():
    """Force delta overflow through the server; post-compaction queries
    still match naive and the snapshot version advanced."""
    rng = np.random.default_rng(22)
    model = random_model(rng, 800, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64, delta_capacity=8)
    U = rng.standard_normal((8, 16)).astype(np.float32)
    for _ in range(4):
        srv.add_targets(rng.standard_normal((5, 16)).astype(np.float32))
        srv.delete_targets(srv.query(U, 1, "naive").indices[:1, 0].tolist())
    ms = srv.mutation_stats
    assert ms["n_compactions"] >= 2
    assert ms["snapshot_version"] == ms["n_compactions"]
    r = srv.query(U, 10, "bta")
    r0 = srv.query(U, 10, "naive")
    np.testing.assert_allclose(np.sort(r.values, axis=1),
                               np.sort(r0.values, axis=1), atol=1e-4)


def test_server_host_oracle_methods():
    """The registered numpy reference oracles serve (slowly) by name."""
    model = random_model(np.random.default_rng(11), 300, 8,
                         "lowrank_spectrum")
    srv = TopKServer(model, max_batch=4, block_size=16)
    U = np.random.default_rng(12).standard_normal((4, 8)).astype(np.float32)
    r_ta = srv.query(U, 5, "ta")
    for oracle in ("fagin", "partial"):
        r = srv.query(U, 5, oracle)
        np.testing.assert_allclose(np.sort(r.values, axis=1),
                                   np.sort(r_ta.values, axis=1), atol=1e-4)


def test_admission_ladder_downgrades_and_records():
    """With a deadline too tight for the preferred engine (per the cost
    model) the server walks the ladder — norm, then budgeted norm — and
    records every decision under the REQUESTED method."""
    from repro.serving.server import AdmissionPolicy
    rng = np.random.default_rng(30)
    model = random_model(rng, 600, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64,
                     policy=AdmissionPolicy(degrade_budget=16))
    U = rng.standard_normal((8, 16)).astype(np.float32)
    ref = srv.query(U, 5, "naive")
    # deterministic cost model: bta "slow", norm fast
    srv._cost_ewma.update({"bta": 10.0, "norm": 1e-9})
    res = srv.query(U, 5, "bta", deadline_ms=50.0)
    assert srv.stats["bta"].degradations == {"to_norm": 1}
    # the downgraded rung is still EXACT (norm is an exact engine)
    np.testing.assert_allclose(np.sort(res.values, axis=1),
                               np.sort(ref.values, axis=1), atol=1e-4)
    assert srv.stats["norm"].n_queries == 8      # served by norm
    # now norm is also "slow": budgeted rung, certificates mandatory
    srv._cost_ewma.update({"norm": 10.0})
    res = srv.query(U, 5, "bta", deadline_ms=50.0)
    assert srv.stats["bta"].degradations["to_budgeted"] == 1
    assert res.upper is not None
    gaps = np.asarray(res.upper)[:, None] - np.asarray(res.values)
    certified = gaps <= 0
    # certified slots are a prefix of the true top-K
    ov = np.sort(np.asarray(ref.values), axis=1)[:, ::-1]
    for q in range(U.shape[0]):
        c = int(np.sum(certified[q]))
        np.testing.assert_allclose(np.asarray(res.values)[q, :c],
                                   ov[q, :c], atol=1e-4)


def test_expired_deadline_sheds_with_sentinels():
    from repro.serving.server import AdmissionPolicy
    rng = np.random.default_rng(31)
    model = random_model(rng, 400, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64)
    U = rng.standard_normal((10, 16)).astype(np.float32)
    res = srv.query(U, 5, "norm", deadline_ms=0.0)
    assert np.all(np.asarray(res.indices) == -1)
    assert np.all(np.asarray(res.values) == -np.inf)
    assert np.all(np.asarray(res.upper) == np.inf)   # nothing certified
    assert srv.stats["norm"].degradations["shed"] == 2   # both chunks
    assert srv.stats["norm"].n_uncertified == 10
    # shed_on_overload=False: the expired deadline downgrades instead
    srv.policy.shed_on_overload = False
    res = srv.query(U, 5, "norm", deadline_ms=0.0)
    assert np.all(np.asarray(res.indices)[:, 0] >= 0)    # real answers
    assert srv.stats["norm"].degradations["to_budgeted"] == 2


def test_overload_sheds_at_max_inflight():
    from repro.serving.server import AdmissionPolicy
    rng = np.random.default_rng(32)
    model = random_model(rng, 400, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64,
                     policy=AdmissionPolicy(max_inflight=0))
    U = rng.standard_normal((4, 16)).astype(np.float32)
    res = srv.query(U, 5, "norm")          # 0 slots: immediate shed
    assert np.all(np.asarray(res.indices) == -1)
    assert srv.stats["norm"].degradations["shed"] == 1
    srv.policy.max_inflight = 8
    res = srv.query(U, 5, "norm")          # slots again: served
    assert np.all(np.asarray(res.indices)[:, 0] >= 0)


def test_no_deadline_path_is_unchanged_and_fully_certified():
    """Without a deadline the ladder never engages; exact engines report
    full certification through the server API."""
    rng = np.random.default_rng(33)
    model = random_model(rng, 500, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64)
    U = rng.standard_normal((8, 16)).astype(np.float32)
    res = srv.query(U, 5, "norm")
    assert srv.stats["norm"].degradations == {}
    gaps = np.asarray(res.upper)[:, None] - np.asarray(res.values)
    assert np.all(gaps <= 0)
    assert srv.stats["norm"].n_uncertified == 0


def test_server_budget_reaches_mutated_catalogue():
    """Explicit budgets work on the segmented path too: certificates stay
    valid (prefix-exact) with a live delta and tombstones."""
    rng = np.random.default_rng(34)
    model = random_model(rng, 500, 16, "lowrank_spectrum")
    srv = TopKServer(model, max_batch=8, block_size=64, delta_capacity=16)
    U = rng.standard_normal((8, 16)).astype(np.float32)
    srv.add_targets(rng.standard_normal((5, 16)).astype(np.float32))
    srv.delete_targets([0, 1])
    res = srv.query(U, 5, "norm", budget=4)
    ref = srv.query(U, 5, "naive")
    gaps = np.asarray(res.upper)[:, None] - np.asarray(res.values)
    ov = np.asarray(ref.values)
    for q in range(U.shape[0]):
        c = int(np.sum(gaps[q] <= 0))
        np.testing.assert_allclose(np.asarray(res.values)[q, :c],
                                   ov[q, :c], atol=1e-4)
    assert srv.stats["norm"].n_uncertified >= 0  # counter exists and sane
