"""Batched-native list scan tests (DESIGN.md §11): one shared
prefix-tile enumeration per step for the whole batch, per-query
freshness masks and liveness gating, sign-specialised compiles.

Covers the tentpole exactness contract — a MIXED batch (different sign
patterns AND different stopping depths in one batch) must return the
sequential oracle's values AND its ``n_scored``/``depth`` counts — at
the off-by-one catalogue sizes M = 2^n - 1 / 2^n / 2^n + 1, in both the
prefix-hit and prefix-overflow regimes, plus the sign-bucket compile-key
accounting (warmed buckets add 0 retraces; an unseen bucket pays exactly
one trace).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EngineContext,
    blocked_topk,
    get_engine,
    naive_topk,
    threshold_topk_np,
)
from repro.core.blocked import (
    blocked_topk_batched_native,
    chunked_ta_topk_batched_native,
)
from repro.core.engines import trace_detail
from repro.core.index import build_index
from repro.core.layout import build_list_major
from repro.core.strategies import sign_bucket, sign_bucket_label
from repro.core.threshold import threshold_topk_batched_from_index


def _mixed_batch(rng, r):
    """One batch spanning every sign bucket, with different stopping
    depths (the sparse query deactivates half its lists and certifies
    much earlier than the dense ones)."""
    U = np.zeros((4, r), np.float32)
    U[0] = np.abs(rng.standard_normal(r)) + 0.05      # nonneg dense
    U[1] = -np.abs(rng.standard_normal(r)) - 0.05     # nonpos dense
    U[2] = rng.standard_normal(r)                     # mixed
    U[3] = np.abs(rng.standard_normal(r))
    U[3, ::2] = 0.0                                   # nonneg sparse
    return U


def _ta_oracle(T, idx, U, k):
    od = np.asarray(idx.order_desc)
    vals, ns, dep = [], [], []
    for u in U:
        v, _, st = threshold_topk_np(T, od, u, k)
        vals.append(v)
        ns.append(st.n_scored)
        dep.append(st.depth)
    return np.asarray(vals), np.asarray(ns), np.asarray(dep)


# ---------------------------------------------------------------------------
# Tentpole exactness: mixed batches, M = 2^n - 1 / 2^n / 2^n + 1,
# prefix-hit AND prefix-overflow depths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [255, 256, 257])
@pytest.mark.parametrize("prefix_depth", [16, 300])  # overflow / hit
def test_batched_ta_mixed_batch_matches_sequential_oracle(m, prefix_depth):
    rng = np.random.default_rng(100 + m + prefix_depth)
    r, k = 7, 5
    T = rng.standard_normal((m, r)).astype(np.float32)
    ctx = EngineContext(T, prefix_depth=prefix_depth, ta_chunk=8,
                        block_size=16)
    U = _mixed_batch(rng, r)
    assert sign_bucket(U) == (0, False)
    res = get_engine("ta").run(ctx, jnp.asarray(U), k)
    ref_v, ref_n, ref_d = _ta_oracle(T, ctx.index, U, k)
    np.testing.assert_allclose(np.asarray(res.values), ref_v, atol=1e-4)
    # count-faithfulness: the batch-level loop halts at the max live
    # query's depth, but per-lane gating must keep every lane's counts
    # EQUAL to its sequential run
    np.testing.assert_array_equal(np.asarray(res.n_scored), ref_n)
    np.testing.assert_array_equal(np.asarray(res.depth), ref_d)
    assert len(set(ref_d.tolist())) > 1   # genuinely different depths


@pytest.mark.parametrize("m", [255, 256, 257])
@pytest.mark.parametrize("prefix_depth", [16, 300])
def test_batched_bta_mixed_batch_exact_and_count_faithful(m, prefix_depth):
    rng = np.random.default_rng(200 + m + prefix_depth)
    r, k = 7, 5
    T = rng.standard_normal((m, r)).astype(np.float32)
    ctx = EngineContext(T, prefix_depth=prefix_depth, ta_chunk=8,
                        block_size=16)
    U = _mixed_batch(rng, r)
    res = get_engine("bta").run(ctx, jnp.asarray(U), k)
    ref = naive_topk(jnp.asarray(T), jnp.asarray(U), k)
    np.testing.assert_allclose(np.sort(np.asarray(res.values), axis=1),
                               np.sort(np.asarray(ref.values), axis=1),
                               atol=1e-4)
    # BTA is block-granular: its counts are defined by the SEQUENTIAL
    # per-query blocked scan, which the batched-native path must match
    # lane for lane
    args = ctx.engine_args(get_engine("bta"))
    for b in range(U.shape[0]):
        one = blocked_topk(args["targets"], args["order_desc"],
                           args["t_sorted_desc"], jnp.asarray(U[b]), k,
                           16, -1, rank_desc=args["rank_desc"],
                           layout=args["layout"], m_real=args["m_real"])
        assert int(res.n_scored[b]) == int(one.n_scored)
        assert int(res.depth[b]) == int(one.depth)


def test_batched_ta_single_sign_dense_batches():
    # dense single-sign buckets take the shared-freshness fast path
    # (query-independent keys) — must still match the sequential oracle
    rng = np.random.default_rng(7)
    m, r, k = 257, 6, 4
    T = rng.standard_normal((m, r)).astype(np.float32)
    ctx = EngineContext(T, prefix_depth=64, ta_chunk=8, block_size=16)
    for sgn in (1.0, -1.0):
        U = sgn * (np.abs(rng.standard_normal((5, r))) + 0.01)
        U = U.astype(np.float32)
        assert sign_bucket(U)[0] == int(sgn)
        res = get_engine("ta").run(ctx, jnp.asarray(U), k)
        ref_v, ref_n, ref_d = _ta_oracle(T, ctx.index, U, k)
        np.testing.assert_allclose(np.asarray(res.values), ref_v,
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(res.n_scored), ref_n)
        np.testing.assert_array_equal(np.asarray(res.depth), ref_d)


# ---------------------------------------------------------------------------
# Single-sided layouts (head-only / tail-only prefix tiles)
# ---------------------------------------------------------------------------


def test_sided_layouts_serve_their_sign_natively():
    rng = np.random.default_rng(11)
    m, r, k = 255, 6, 4
    T = rng.standard_normal((m, r)).astype(np.float32)
    idx = build_index(T)
    full = build_list_major(T, idx, prefix_depth=48)
    head = build_list_major(T, idx, prefix_depth=48, sides=("head",))
    tail = full.sided("tail")
    assert full.two_sided and full.sides == ("head", "tail")
    assert head.sides == ("head",) and tail.sides == ("tail",)
    assert head.serves_sign(1) and not head.serves_sign(-1)
    assert tail.serves_sign(-1) and not tail.serves_sign(0)

    Tj = jnp.asarray(T)
    U_pos = jnp.asarray(
        np.abs(rng.standard_normal((4, r))).astype(np.float32) + 0.01)
    U_neg = -U_pos
    for lay, U, sign in ((head, U_pos, 1), (tail, U_neg, -1)):
        res = blocked_topk_batched_native(
            Tj, idx.order_desc, idx.t_sorted_desc, U, k,
            block_size=16, layout=lay, sign=sign, dense=True)
        ref = naive_topk(Tj, U, k)
        np.testing.assert_allclose(
            np.sort(np.asarray(res.values), axis=1),
            np.sort(np.asarray(ref.values), axis=1), atol=1e-4)
        res_ta = chunked_ta_topk_batched_native(
            Tj, idx.order_desc, idx.t_sorted_desc, U, k,
            chunk=8, layout=lay, sign=sign, dense=True)
        ref_v, ref_n, ref_d = _ta_oracle(T, idx, np.asarray(U), k)
        np.testing.assert_allclose(np.asarray(res_ta.values), ref_v,
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(res_ta.n_scored), ref_n)
        np.testing.assert_array_equal(np.asarray(res_ta.depth), ref_d)


def test_sided_layout_mismatch_raises_and_mixed_requires_two_sides():
    rng = np.random.default_rng(13)
    T = rng.standard_normal((255, 5)).astype(np.float32)
    idx = build_index(T)
    head = build_list_major(T, idx, prefix_depth=32, sides=("head",))
    U = jnp.asarray(rng.standard_normal((2, 5)).astype(np.float32))
    with pytest.raises(ValueError, match="serve"):
        blocked_topk_batched_native(
            jnp.asarray(T), idx.order_desc, idx.t_sorted_desc, U, 3,
            block_size=16, layout=head, sign=0, dense=False)


def test_threshold_batched_wrapper_routes_and_falls_back():
    rng = np.random.default_rng(17)
    m, r, k = 257, 6, 4
    T = rng.standard_normal((m, r)).astype(np.float32)
    idx = build_index(T)
    lay = build_list_major(T, idx, prefix_depth=64)
    U = _mixed_batch(rng, r)
    ref_v, ref_n, ref_d = _ta_oracle(T, idx, U, k)
    for kw in (dict(chunk=8, layout=lay),    # batched-native route
               dict()):                      # vmapped per-query fallback
        res = threshold_topk_batched_from_index(
            jnp.asarray(T), idx, jnp.asarray(U), k, **kw)
        np.testing.assert_allclose(np.asarray(res.values), ref_v,
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(res.n_scored), ref_n)
        np.testing.assert_array_equal(np.asarray(res.depth), ref_d)


# ---------------------------------------------------------------------------
# Sign buckets: host helper + compile-count accounting
# ---------------------------------------------------------------------------


def test_sign_bucket_helper():
    assert sign_bucket(np.ones((2, 3))) == (1, True)
    assert sign_bucket(-np.ones((2, 3))) == (-1, True)
    assert sign_bucket(np.array([[1.0, -1.0]])) == (0, False)
    assert sign_bucket(np.array([[1.0, 0.0]])) == (1, False)
    assert sign_bucket(np.array([[-1.0, 0.0]])) == (-1, False)
    assert sign_bucket(np.zeros((1, 2))) == (1, False)  # degenerate: head
    assert sign_bucket_label(()) == "unbucketed"
    assert sign_bucket_label((1, True)) == "nonneg-dense"
    assert sign_bucket_label((-1, False)) == "nonpos-sparse"
    assert sign_bucket_label((0, False)) == "mixed-sparse"


def test_sign_bucket_compile_counts():
    # process-unique shapes (R=23, k=7, M-bucket 1024, prefix 40): the
    # argument-passing executors' trace cache is PROCESS-WIDE, so a
    # signature another test compiled would attribute 0 traces here
    rng = np.random.default_rng(23)
    m, r, k = 705, 23, 7
    T = rng.standard_normal((m, r)).astype(np.float32)
    ctx = EngineContext(T, prefix_depth=40, ta_chunk=8, block_size=16)
    ctx.warmup(k, batch_sizes=(4,), engines=["ta", "bta"])
    warm = dict(ctx.trace_counts)
    # every warmed sign bucket serves with 0 retraces
    dense = np.abs(rng.standard_normal((4, r))).astype(np.float32) + 0.01
    mixed = rng.standard_normal((4, r)).astype(np.float32)
    sparse = dense.copy()
    sparse[:, ::2] = 0.0
    for U in (dense, -dense, mixed, sparse):
        for name in ("ta", "bta"):
            get_engine(name).run(ctx, jnp.asarray(U), k)
    assert ctx.trace_counts == warm
    # the one unwarmed bucket (nonpos-sparse) pays exactly one trace per
    # engine, attributed to the right (engine, bucket) key
    before = trace_detail()
    for name in ("ta", "bta"):
        res = get_engine(name).run(ctx, jnp.asarray(-sparse), k)
        ref = naive_topk(jnp.asarray(T), jnp.asarray(-sparse), k)
        np.testing.assert_allclose(
            np.sort(np.asarray(res.values), axis=1),
            np.sort(np.asarray(ref.values), axis=1), atol=1e-4)
    after = trace_detail()
    for name in ("ta", "bta"):
        key = (name, (-1, False))
        assert after.get(key, 0) - before.get(key, 0) == 1
        assert ctx.trace_counts[name] == warm[name] + 1
    # and re-serving the now-warm bucket adds nothing
    snap = dict(ctx.trace_counts)
    for name in ("ta", "bta"):
        get_engine(name).run(ctx, jnp.asarray(-sparse), k)
    assert ctx.trace_counts == snap


def test_compaction_compile_free_with_sign_buckets():
    """DESIGN.md §11 acceptance: `engine_compiles_per_compaction` stays 0
    with the sign-specialised variants enabled (list layout ON)."""
    from repro.core.segments import SegmentedCatalogue

    rng = np.random.default_rng(29)
    m, r, k = 420, 27, 5           # process-unique executor signatures
    T = rng.standard_normal((m, r)).astype(np.float32)
    cat = SegmentedCatalogue(T, delta_capacity=32, prefix_depth=48,
                             ta_chunk=8, block_size=16)
    # boot warmup: plain-k engine executables (all sign buckets) + the
    # segmented tails / escalated shape
    cat.snapshot.ctx.warmup(k, batch_sizes=(4,), engines=["ta", "bta"])
    cat.warm(k, batch_sizes=(4,), engines=["ta", "bta"])
    cat.set_warm_spec(k, (4,), engines=["ta", "bta"], headroom=False)
    # every common sign bucket is warmed on the boot snapshot
    assert {nm for (nm, bc) in trace_detail() if bc == (-1, True)} \
        >= {"ta", "bta"}
    # overflow the delta so at least one compaction (same M-bucket) runs
    for _ in range(3):
        cat.add_targets(rng.standard_normal((20, r)).astype(np.float32))
    cat.flush()
    assert cat.stats.n_compactions >= 1
    assert cat.stats.engine_compiles_total == 0
    # post-compaction serving in every warmed bucket: still 0 retraces
    snap_counts = dict(cat.snapshot.ctx.trace_counts)
    U = np.abs(rng.standard_normal((4, r))).astype(np.float32) + 0.01
    for q in (U, -U, rng.standard_normal((4, r)).astype(np.float32)):
        for name in ("ta", "bta"):
            get_engine(name).run(cat.snapshot.ctx, jnp.asarray(q), k)
    assert cat.snapshot.ctx.trace_counts == snap_counts
