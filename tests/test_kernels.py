"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import MIPSCatalog, embedding_bag, fm_interaction
from repro.kernels.ref import embedding_bag_ref, fm_interaction_ref


@pytest.mark.parametrize("m,r,k,block", [
    (256, 8, 1, 64), (512, 32, 10, 128), (1000, 64, 5, 256),
    (128, 128, 16, 128), (300, 17, 3, 64),
])
def test_topk_mips_shapes(m, r, k, block):
    rng = np.random.default_rng(m + r)
    T = rng.standard_normal((m, r)).astype(np.float32)
    cat = MIPSCatalog(T, block_m=block)
    u = rng.standard_normal(r).astype(np.float32)
    vals, ids, stats = cat.query(jnp.asarray(u), k)
    scores = T @ u
    ref = np.sort(scores)[::-1][:k]
    np.testing.assert_allclose(np.asarray(vals), ref, atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(scores[np.asarray(ids)], np.asarray(vals),
                               atol=1e-3, rtol=1e-4)


def test_topk_mips_prunes_decaying_catalogue():
    rng = np.random.default_rng(0)
    T = rng.standard_normal((4096, 16)).astype(np.float32)
    T *= (1.0 / (1.0 + np.arange(4096)))[:, None] ** 0.5
    cat = MIPSCatalog(T, block_m=128)
    u = rng.standard_normal(16).astype(np.float32)
    vals, ids, stats = cat.query(jnp.asarray(u), 5)
    assert int(stats[1]) < 4096 // 128          # visited < all blocks
    ref = np.sort(T @ u)[::-1][:5]
    np.testing.assert_allclose(np.asarray(vals), ref, atol=1e-4)


def test_topk_mips_two_level_bounds_skip_dma():
    """The scalar-prefetch pre-screen must cut DMA'd blocks (stats col 2)
    below the full block count on a decaying catalogue — and results plus
    scored/visited counts must match the runtime-only bound exactly."""
    rng = np.random.default_rng(9)
    T = rng.standard_normal((2048, 16)).astype(np.float32)
    T *= (1.0 / (1.0 + np.arange(2048)))[:, None].astype(np.float32) ** 0.7
    cat = MIPSCatalog(T, block_m=128, superblock=4)
    U = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    vals, ids, stats = cat.query_batch(U, 5)
    stats = np.asarray(stats)
    n_blocks = cat.n_blocks
    assert np.all(stats[:, 2] < n_blocks), "pre-screen skipped no DMA"
    assert np.all(stats[:, 1] <= stats[:, 2]), "scored more than loaded"
    ref = np.sort(np.asarray(U) @ T.T, axis=1)[:, ::-1][:, :5]
    np.testing.assert_allclose(np.asarray(vals), ref, atol=1e-3)
    # single-query path too
    u = jnp.asarray(np.asarray(U)[0])
    v1, i1, s1 = cat.query(u, 5)
    np.testing.assert_allclose(np.asarray(v1), ref[0], atol=1e-3)
    assert int(np.asarray(s1)[2]) < n_blocks


def test_topk_mips_flat_norms_stay_exact():
    """Constant-norm catalogue: the pre-screen can prune nothing (lb0
    equals every bound at best) — the two-level kernel must degrade to a
    full scan, not to a wrong answer."""
    rng = np.random.default_rng(10)
    T = rng.standard_normal((512, 8)).astype(np.float32)
    T /= np.linalg.norm(T, axis=1, keepdims=True)
    cat = MIPSCatalog(T, block_m=64, superblock=4)
    U = jnp.asarray(rng.standard_normal((3, 8)).astype(np.float32))
    vals, ids, stats = cat.query_batch(U, 5)
    ref = np.sort(np.asarray(U) @ T.T, axis=1)[:, ::-1][:, :5]
    np.testing.assert_allclose(np.asarray(vals), ref, atol=1e-3)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("b,f,v,d", [(8, 4, 100, 8), (13, 26, 500, 16),
                                     (32, 39, 200, 10)])
def test_embedding_bag_sweep(b, f, v, d, dtype):
    rng = np.random.default_rng(b * f)
    table = rng.standard_normal((v, d)).astype(dtype)
    ids = rng.integers(0, v, (b, f)).astype(np.int32)
    out = embedding_bag(jnp.asarray(table), jnp.asarray(ids))
    ref = embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids))
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_embedding_bag_mean_mode():
    rng = np.random.default_rng(1)
    table = rng.standard_normal((50, 4)).astype(np.float32)
    ids = rng.integers(0, 50, (6, 5)).astype(np.int32)
    out = embedding_bag(jnp.asarray(table), jnp.asarray(ids), mode="mean")
    ref = embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids), mode="mean")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("b,f,d", [(16, 4, 8), (50, 39, 10), (128, 26, 16),
                                   (7, 2, 3)])
def test_fm_interaction_sweep(b, f, d, dtype):
    rng = np.random.default_rng(b + f + d)
    emb = (rng.standard_normal((b, f, d)) * 0.5).astype(dtype)
    out = fm_interaction(jnp.asarray(emb), block_b=16)
    ref = fm_interaction_ref(jnp.asarray(emb).astype(jnp.float32))
    tol = 1e-3 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_fm_interaction_matches_explicit_pairwise():
    rng = np.random.default_rng(2)
    emb = rng.standard_normal((4, 6, 5)).astype(np.float32)
    out = np.asarray(fm_interaction(jnp.asarray(emb), block_b=4))
    for b in range(4):
        explicit = sum(float(emb[b, i] @ emb[b, j])
                       for i in range(6) for j in range(i + 1, 6))
        assert abs(out[b] - explicit) < 1e-3


def test_gather_scores_pallas_matches_xla_gather():
    """The gather-fused scorer (scalar-prefetch index-map gather) must equal
    targets[ids] @ u, including repeated ids."""
    from repro.kernels.topk_mips import gather_scores_pallas
    rng = np.random.default_rng(21)
    T = rng.standard_normal((256, 24)).astype(np.float32)
    u = rng.standard_normal(24).astype(np.float32)
    ids = np.concatenate([rng.integers(0, 256, 30),
                          [0, 0, 255, 255]]).astype(np.int32)
    out = gather_scores_pallas(jnp.asarray(T), jnp.asarray(ids),
                               jnp.asarray(u))
    np.testing.assert_allclose(np.asarray(out), T[ids] @ u,
                               atol=1e-4, rtol=1e-4)


def test_gather_scores_pallas_under_jit_and_vmap():
    """The tail scorer is called inside jitted, vmapped scan bodies — the
    kernel must survive both transforms."""
    import jax

    from repro.kernels.topk_mips import gather_scores_pallas
    rng = np.random.default_rng(22)
    T = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    U = jnp.asarray(rng.standard_normal((3, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (3, 10)).astype(np.int32))
    fn = jax.jit(jax.vmap(lambda i, u: gather_scores_pallas(T, i, u)))
    out = fn(ids, U)
    ref = np.take(np.asarray(T), np.asarray(ids), axis=0) @ \
        np.asarray(U)[:, :, None]
    np.testing.assert_allclose(np.asarray(out), ref[..., 0], atol=1e-4,
                               rtol=1e-4)
